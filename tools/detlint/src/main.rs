//! detlint — determinism lint for the wavescale replay-exact paths.
//!
//! The repo's central claim (EXPERIMENTS.md) is that every simulated run
//! is replay-exact: same seed, same decision log, bit-identical report.
//! That property dies quietly — one `Instant::now()` in a decision path,
//! one iteration over a randomized-state `HashMap`, one NaN-unstable
//! float sort — so this tool rejects the hazard *patterns* at lint time
//! rather than chasing nondeterminism after the fact.
//!
//! ## Rules
//!
//! | rule | rejects | where |
//! |------|---------|-------|
//! | `wallclock` | `Instant::now` / `SystemTime` / `std::time::Instant` — wall time bypassing the `clock/` abstraction | everywhere except `clock/` |
//! | `hash-collection` | importing or naming `std::collections::HashMap`/`HashSet` (iteration order is seeded per-process) | decision/trace modules (see `HASH_SCOPE`) |
//! | `float-sort` | `sort_by`/`max_by`/`min_by` through `partial_cmp`, or `partial_cmp(..).unwrap()` — NaN panics / unstable order; use `total_cmp` | everywhere |
//! | `randomness` | `thread_rng` / `rand::random` / `from_entropy` / `RandomState` — OS-entropy randomness | everywhere |
//! | `std-sync-bypass` | `std::sync` / `std::cell` / `std::hint` imports that bypass the `crate::sync` loom shim | `coordinator/`, `clock/`, `metrics/` |
//! | `thread-spawn` | `thread::spawn` / `thread::Builder` outside the registered-actor protocol — an unregistered thread is invisible to the virtual scheduler (and to the parallel engine's advance-domains) | everywhere |
//!
//! ## Allows
//!
//! A finding is suppressed by an audit comment on the same line or the
//! directly preceding comment line(s):
//!
//! ```text
//! // detlint: allow(hash-collection) -- keyed by ThreadId, lookup only
//! use std::collections::HashMap;
//! ```
//!
//! The reason after `--` is mandatory: an allow is a reviewed claim that
//! the use is sound, not an opt-out. Unknown rule names in an allow are
//! reported as errors so typos cannot silently disable coverage.
//!
//! ## Mechanics and limits
//!
//! The scan is line-based over `rust/src/**/*.rs` (vendored crates and
//! the `sync/` shim itself are excluded). Text after `//` on a line is
//! ignored, so prose mentioning a pattern does not trip the lint; the
//! flip side is that a `//` inside a string literal truncates matching
//! for that line. That trade keeps the tool dependency-free (no parser)
//! and has no false negatives on the patterns above in this codebase.
//!
//! Exit status: 0 clean, 1 findings, 2 usage/IO error.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// A lint rule: a stable name users put in allow comments, a scope
/// predicate over repo-relative paths, a line predicate, and the message
/// explaining the determinism hazard.
struct Rule {
    name: &'static str,
    message: &'static str,
    in_scope: fn(&str) -> bool,
    matches: fn(&str) -> bool,
}

/// Decision/trace-path modules where hash-randomized iteration order can
/// leak into logs, schedules, or reports.
const HASH_SCOPE: [&str; 7] = [
    "coordinator/", "clock/", "control/", "vscale/", "workload/", "markov/", "metrics/",
];

/// Modules whose concurrency primitives must route through the
/// `crate::sync` shim so loom models exercise the real code.
const SHIM_SCOPE: [&str; 3] = ["coordinator/", "clock/", "metrics/"];

const RULES: [Rule; 6] = [
    Rule {
        name: "wallclock",
        message: "wall-clock time outside clock/: route through the Clock trait so \
                  virtual-clock replays stay deterministic",
        in_scope: |p| !p.starts_with("clock/"),
        matches: |l| {
            (has_word(l, "Instant") || has_word(l, "SystemTime"))
                && (l.contains("std::time::") || l.contains("::now("))
        },
    },
    Rule {
        name: "hash-collection",
        message: "HashMap/HashSet in a decision/trace path: iteration order is \
                  seeded per-process; use BTreeMap/BTreeSet or an index-keyed Vec",
        in_scope: |p| HASH_SCOPE.iter().any(|s| p.starts_with(s)),
        matches: |l| {
            (l.contains("collections::HashMap") || l.contains("collections::HashSet"))
                || (l.trim_start().starts_with("use ")
                    && (has_word(l, "HashMap") || has_word(l, "HashSet")))
        },
    },
    Rule {
        name: "float-sort",
        message: "float ordering through partial_cmp: NaN panics the unwrap or \
                  destabilizes the order; use f64::total_cmp",
        in_scope: |_| true,
        matches: |l| {
            let sorting = ["sort_by", "max_by", "min_by"].iter().any(|s| l.contains(s));
            (sorting && l.contains("partial_cmp"))
                || (l.contains("partial_cmp(") && l.contains(").unwrap()"))
        },
    },
    Rule {
        name: "randomness",
        message: "OS-entropy randomness: derive from the run seed (util::prop / \
                  workload generators) so runs are replayable",
        in_scope: |_| true,
        matches: |l| {
            has_word(l, "thread_rng")
                || l.contains("rand::random")
                || has_word(l, "from_entropy")
                || has_word(l, "RandomState")
        },
    },
    Rule {
        name: "std-sync-bypass",
        message: "std concurrency primitive bypasses the crate::sync shim: loom \
                  models cannot see it; import from crate::sync instead",
        in_scope: |p| SHIM_SCOPE.iter().any(|s| p.starts_with(s)),
        matches: |l| {
            l.contains("std::sync::") || l.contains("std::cell::") || l.contains("std::hint::")
        },
    },
    Rule {
        name: "thread-spawn",
        message: "raw OS thread spawn: pre-register the actor on the spawning \
                  thread (Clock::register_actor / register_actor_in) and attach \
                  inside the thread, or the virtual scheduler cannot order it; \
                  audited wall-clock-only spawns take an allow",
        in_scope: |_| true,
        matches: |l| l.contains("thread::spawn") || l.contains("thread::Builder"),
    },
];

struct Finding {
    path: PathBuf,
    line: usize,
    rule: &'static str,
    message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.path.display(),
            self.line,
            self.rule,
            self.message
        )
    }
}

/// `needle` appears in `hay` delimited by non-identifier characters.
fn has_word(hay: &str, needle: &str) -> bool {
    let mut start = 0;
    while let Some(i) = hay[start..].find(needle) {
        let at = start + i;
        let before = hay[..at].chars().next_back();
        let after = hay[at + needle.len()..].chars().next();
        let boundary = |c: Option<char>| c.map_or(true, |c| !c.is_alphanumeric() && c != '_');
        if boundary(before) && boundary(after) {
            return true;
        }
        start = at + needle.len();
    }
    false
}

/// Rule names named by `// detlint: allow(rule, rule) -- reason` markers
/// in a line; `Err` on a marker with no reason or an unknown rule name.
fn parse_allows(line: &str, out: &mut Vec<&'static str>) -> Result<(), String> {
    let Some(at) = line.find("detlint: allow(") else {
        return Ok(());
    };
    let rest = &line[at + "detlint: allow(".len()..];
    let Some(close) = rest.find(')') else {
        return Err("malformed allow: missing ')'".to_string());
    };
    if !rest[close..].contains("--") {
        return Err("allow without a reason: append `-- <why this is sound>`".to_string());
    }
    for name in rest[..close].split(',').map(str::trim).filter(|s| !s.is_empty()) {
        match RULES.iter().find(|r| r.name == name) {
            Some(r) => out.push(r.name),
            None => return Err(format!("allow names unknown rule `{name}`")),
        }
    }
    Ok(())
}

/// Lint one file; `rel` is its path relative to the scan root, with the
/// root itself stripped (e.g. `coordinator/shard.rs`).
fn lint_file(path: &Path, rel: &str, src: &str, findings: &mut Vec<Finding>) {
    // Allows from directly preceding comment-only lines, pending
    // attachment to the next code line.
    let mut pending: Vec<&'static str> = Vec::new();
    for (idx, raw) in src.lines().enumerate() {
        let line_no = idx + 1;
        let mut allows = Vec::new();
        if let Err(msg) = parse_allows(raw, &mut allows) {
            findings.push(Finding {
                path: path.to_path_buf(),
                line: line_no,
                rule: "allow-syntax",
                message: msg,
            });
        }
        let trimmed = raw.trim_start();
        let comment_only = trimmed.starts_with("//") || trimmed.is_empty();
        if comment_only {
            // Comment (or blank) line: accumulate allows for the code
            // line that follows; nothing on it can match a rule.
            pending.extend(allows);
            continue;
        }
        allows.extend(pending.drain(..));

        // Strip the trailing comment so prose never matches a rule.
        let code = raw.split("//").next().unwrap_or(raw);
        for rule in &RULES {
            if (rule.in_scope)(rel) && (rule.matches)(code) && !allows.contains(&rule.name) {
                findings.push(Finding {
                    path: path.to_path_buf(),
                    line: line_no,
                    rule: rule.name,
                    message: rule.message.to_string(),
                });
            }
        }
    }
}

/// Recursively collect `.rs` files under `dir`, skipping vendored crates
/// and the `sync/` shim (whose whole job is wrapping `std::sync`).
fn collect(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.path());
    for entry in entries {
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if path.is_dir() {
            if name == "vendor" || name == "sync" || name == "target" {
                continue;
            }
            collect(&path, out)?;
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help" || a == "-h") {
        println!("usage: detlint [SRC_ROOT]...   (default: rust/src)");
        println!("rules:");
        for r in &RULES {
            println!("  {:<16} {}", r.name, r.message);
        }
        return ExitCode::SUCCESS;
    }
    let roots: Vec<PathBuf> = if args.is_empty() {
        vec![PathBuf::from("rust/src")]
    } else {
        args.iter().map(PathBuf::from).collect()
    };

    let mut findings = Vec::new();
    let mut scanned = 0usize;
    for root in &roots {
        let mut files = Vec::new();
        if let Err(e) = collect(root, &mut files) {
            eprintln!("detlint: cannot scan {}: {e}", root.display());
            return ExitCode::from(2);
        }
        for file in files {
            let src = match fs::read_to_string(&file) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("detlint: cannot read {}: {e}", file.display());
                    return ExitCode::from(2);
                }
            };
            let rel = file
                .strip_prefix(root)
                .unwrap_or(&file)
                .to_string_lossy()
                .replace('\\', "/");
            lint_file(&file, &rel, &src, &mut findings);
            scanned += 1;
        }
    }

    for f in &findings {
        println!("{f}");
    }
    if findings.is_empty() {
        println!("detlint: {scanned} files clean");
        ExitCode::SUCCESS
    } else {
        println!("detlint: {} finding(s) in {scanned} files", findings.len());
        ExitCode::FAILURE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_str(rel: &str, src: &str) -> Vec<String> {
        let mut findings = Vec::new();
        lint_file(Path::new(rel), rel, src, &mut findings);
        findings.iter().map(|f| f.rule.to_string()).collect()
    }

    #[test]
    fn wallclock_flagged_outside_clock() {
        assert_eq!(
            lint_str("coordinator/x.rs", "let t = std::time::Instant::now();"),
            vec!["wallclock"]
        );
        assert!(lint_str("clock/mod.rs", "let t = std::time::Instant::now();").is_empty());
    }

    #[test]
    fn wallclock_ignores_duration_only_imports() {
        assert!(lint_str("coordinator/x.rs", "use std::time::Duration;").is_empty());
    }

    #[test]
    fn hash_collection_scoped_to_decision_paths() {
        assert_eq!(
            lint_str("control/x.rs", "use std::collections::HashMap;"),
            vec!["hash-collection"]
        );
        // Reporting/CLI layers may hash freely.
        assert!(lint_str("main.rs", "use std::collections::HashMap;").is_empty());
    }

    #[test]
    fn float_sort_catches_single_and_multi_line_shapes() {
        assert_eq!(
            lint_str("sta/x.rs", "v.sort_by(|a, b| a.partial_cmp(b).unwrap());"),
            vec!["float-sort"]
        );
        // The sta/mod.rs shape that motivated the rule: unwrap on its
        // own line still contains `partial_cmp(..).unwrap()`.
        assert_eq!(
            lint_str("sta/x.rs", "arrival[b].partial_cmp(&arrival[a]).unwrap()"),
            vec!["float-sort"]
        );
        assert!(lint_str("sta/x.rs", "v.sort_by(|a, b| a.total_cmp(b));").is_empty());
    }

    #[test]
    fn sync_bypass_scoped_to_shim_modules() {
        assert_eq!(
            lint_str("coordinator/x.rs", "use std::sync::Mutex;"),
            vec!["std-sync-bypass"]
        );
        assert!(lint_str("runtime/mod.rs", "use std::sync::Mutex;").is_empty());
        assert!(lint_str("coordinator/x.rs", "use crate::sync::Mutex;").is_empty());
    }

    #[test]
    fn same_line_and_preceding_line_allows_suppress() {
        let inline = "use std::collections::HashMap; // detlint: allow(hash-collection) -- lookup only";
        assert!(lint_str("clock/mod.rs", inline).is_empty());
        let preceding = "\
// detlint: allow(std-sync-bypass) -- OnceLock epoch, wrapped before use
use std::sync::OnceLock;
";
        assert!(lint_str("clock/mod.rs", preceding).is_empty());
    }

    #[test]
    fn allow_does_not_leak_past_the_next_code_line() {
        let src = "\
// detlint: allow(hash-collection) -- first use audited
use std::collections::HashMap;
use std::collections::HashSet;
";
        assert_eq!(lint_str("control/x.rs", src), vec!["hash-collection"]);
    }

    #[test]
    fn allow_without_reason_or_unknown_rule_is_an_error() {
        let no_reason = "use std::sync::Mutex; // detlint: allow(std-sync-bypass)";
        assert_eq!(
            lint_str("coordinator/x.rs", no_reason),
            vec!["allow-syntax", "std-sync-bypass"]
        );
        let typo = "use std::sync::Mutex; // detlint: allow(std-sync-bypas) -- oops";
        assert_eq!(
            lint_str("coordinator/x.rs", typo),
            vec!["allow-syntax", "std-sync-bypass"]
        );
    }

    #[test]
    fn prose_in_comments_never_matches() {
        let src = "// the old sort_by(partial_cmp().unwrap()) panicked on NaN\nlet x = 1;";
        assert!(lint_str("sta/x.rs", src).is_empty());
    }

    #[test]
    fn randomness_flagged_everywhere() {
        assert_eq!(
            lint_str("util/x.rs", "let mut rng = thread_rng();"),
            vec!["randomness"]
        );
    }

    #[test]
    fn thread_spawn_flagged_without_registered_actor_allow() {
        assert_eq!(
            lint_str("clock/parallel.rs", "let h = std::thread::spawn(move || work());"),
            vec!["thread-spawn"]
        );
        assert_eq!(
            lint_str("coordinator/node.rs", "thread::Builder::new().spawn(f)?;"),
            vec!["thread-spawn"]
        );
        // The sanctioned pattern: an audited allow naming why the spawn is
        // outside (or ahead of) the scheduler's view.
        let audited = "\
// detlint: allow(thread-spawn) -- actor pre-registered above; the
// thread attaches before touching simulated time
let h = std::thread::spawn(run);
";
        assert!(lint_str("coordinator/node.rs", audited).is_empty());
        // Registered-actor plumbing itself never matches: spawning is the
        // hazard, registration is the cure.
        assert!(lint_str("clock/parallel.rs", "let id = c.register_actor_in(n, 3);").is_empty());
    }
}
