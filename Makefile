# wavescale build orchestration.
#
#   make artifacts   AOT-compile the JAX/Pallas layers into artifacts/
#                    (requires python3 + jax; the rust stack runs without
#                    them on the native fallback backend)
#   make build       release build of the rust workspace
#   make test        tier-1 verify: build + tests (artifacts built first
#                    when python/jax are available, so PJRT paths run too)
#   make bench       regenerate every paper figure/table CSV into results/
#   make golden      regenerate the virtual-time golden traces
#                    (rust/testdata/golden/); commit the result — CI fails
#                    when tracked goldens drift from a fresh replay
#   make bench-coordinator  virtual-time scenario sweep -> results/
#                    BENCH_coordinator.{json,csv} perf baseline
#   make bench-predictor  predictor ensemble/guardband sweep (offline +
#                    virtual-time, seed-pinned) -> results/
#                    BENCH_predictor.{json,csv} baseline
#   make sim-scale   sequential vs parallel virtual-time engine at
#                    10/100/1000 synthetic groups (DESIGN.md S24) ->
#                    results/BENCH_sim_scale.{json,csv}
#   make faults      fault-injection acceptance suite: board failures,
#                    stragglers, correlated surges on every scenario x
#                    policy (seed-pinned, deterministic)
#   make topology-smoke  fleet-of-fleets acceptance: 2- and 4-node runs
#                    of every scenario, scripted migrations, distributed
#                    control equivalence (DESIGN.md S21)
#   make fmt         rustfmt the whole workspace (CI runs the --check
#                    twin alongside clippy)
#   make lint        determinism lint (tools/detlint) over rust/src
#   make loom        exhaustive loom model checking of the lock-free
#                    coordinator core (rust/tests/loom_models.rs)
#   make miri        Miri over the unsafe slot-protocol unit tests
#                    (nightly toolchain + miri component)
#   make tsan        ThreadSanitizer over the concurrency test subset
#                    (nightly + rust-src; advisory in CI)
#   make doc         rustdoc with warnings surfaced

ARTIFACTS_DIR := artifacts
PY            := python3

.PHONY: artifacts build test bench golden bench-coordinator bench-predictor sim-scale doc fmt fmt-check lint loom miri tsan scenario-smoke faults topology-smoke clean

artifacts:
	cd python && $(PY) -m compile.aot --out-dir ../$(ARTIFACTS_DIR)

build:
	cargo build --release

test:
	@if $(PY) -c "import jax" 2>/dev/null; then \
		$(MAKE) artifacts; \
	else \
		echo "(python/jax unavailable — skipping make artifacts; tests use the native backend)"; \
	fi
	cargo build --release
	cargo test -q

bench: build
	@for b in fig1_delay fig2_dynamic_power fig3_static_power fig4_workload \
	          fig5_alpha fig6_beta fig8_markov fig10_tabla_trace \
	          fig11_voltage_trace fig12_accelerators table1_utilization \
	          table2_summary pll_overhead hybrid_capacity perf_predictor; do \
		cargo bench --bench $$b || exit 1; \
	done

# Regenerate the deterministic golden traces (byte-identical per seed under
# the VirtualClock). Run after an intentional coordinator/scenario change
# and commit rust/testdata/golden/; the sim_golden test (and CI's git-diff
# guard) fails when a tracked golden drifts from a fresh replay.
golden: build
	WAVESCALE_UPDATE_GOLDEN=1 cargo test --release --test sim_golden

# Emit the coordinator perf baseline (virtual-time sweep of all scenarios
# x capacity policies) into results/BENCH_coordinator.{json,csv}.
# WAVESCALE_VIRTUAL_ONLY=1 skips the bench's wall-clock serving section —
# only the deterministic virtual sweep feeds the baseline.
bench-coordinator: build
	WAVESCALE_VIRTUAL_ONLY=1 cargo bench --bench perf_fleet_serving

# Emit the predictor-ensemble/guardband baseline (offline 240-step
# scenarios + virtual-time golden-parameter sweep; every number is
# seed-pinned and deterministic) into results/BENCH_predictor.{json,csv}.
bench-predictor: build
	cargo bench --bench perf_predictor

# Scale sweep of the conservative parallel discrete-event engine
# (DESIGN.md S24): sequential vs parallel replay of synthetic fleets at
# 10/100/1000 groups, asserting byte-identical traces and reporting the
# wall-clock speedup into results/BENCH_sim_scale.{json,csv}. Set
# WAVESCALE_SCALE_MAX=100 on small runners to skip the 1000-group row.
sim-scale: build
	cargo bench --bench perf_sim_scale

# Format the workspace / verify it is formatted (fmt-check is the CI
# twin, run alongside clippy).
fmt:
	cargo fmt --all

fmt-check:
	cargo fmt --all -- --check

# Shortened end-to-end smoke of the elastic capacity manager: an
# overnight trough through both the offline scenario sim (with the
# dvfs/pg/hybrid side-by-side) and the live serve-fleet coordinator,
# plus the control-plane suite proving the offline and live paths make
# identical decisions (DESIGN.md S19). The adversarial scenarios smoke
# through serve-fleet with their canonical fault plans injected
# (--faults; DESIGN.md S20) and tiered-tenants pins per-tenant QoS tiers.
# CI runs this so the serving path is exercised beyond unit tests.
scenario-smoke: build
	cargo run --release -- scenario --name overnight --steps 120
	cargo run --release -- scenario --name tiered-tenants --steps 120
	cargo run --release -- serve-fleet --scenario overnight --epochs 6 \
	    --epoch-ms 60 --rps 800 --instances 2
	cargo run --release -- serve-fleet --scenario board-failure --epochs 9 \
	    --epoch-ms 60 --rps 800 --instances 2 --virtual-time --faults
	cargo run --release -- serve-fleet --scenario straggler --epochs 9 \
	    --epoch-ms 60 --rps 800 --instances 2 --virtual-time --faults
	cargo run --release -- serve-fleet --scenario correlated-surge --epochs 9 \
	    --epoch-ms 60 --rps 800 --instances 2 --virtual-time --faults
	cargo run --release -- serve-fleet --scenario tiered-tenants --epochs 9 \
	    --epoch-ms 60 --rps 800 --instances 2 --qos-target standard
	cargo test --release --test control_equivalence

# Fault-injection acceptance suite (DESIGN.md S20): mid-run board
# failures, stragglers and correlated surges across every scenario x
# capacity policy, plus the randomized fault property — seed-pinned so a
# failure replays exactly.
faults: build
	cargo test --release --test sim_faults
	WAVESCALE_PROP_SEED=2019 cargo test --release --test sim_properties \
	    prop_fault_injection_preserves_conservation_and_never_drops_work

# Fleet-of-fleets acceptance (DESIGN.md S21): 2- and 4-node virtual-time
# runs of every scenario under the hybrid policy (conservation + node-count
# invariance + bitwise replay), scripted-migration conservation, the
# distributed control-equivalence matrix (N in {1,2,4} x scenario x
# policy), the randomized migration property, and a live 2-/4-node
# serve-fleet smoke with the topology snapshot printed.
topology-smoke: build
	cargo test --release --test sim_topology
	cargo test --release --test control_equivalence \
	    offline_and_live_decisions_agree_on_every_scenario_and_capacity_policy
	WAVESCALE_PROP_SEED=2019 cargo test --release --test sim_properties \
	    prop_migration_conserves_work
	cargo run --release -- serve-fleet --scenario mixed-tenant --epochs 9 \
	    --epoch-ms 60 --rps 800 --instances 2 --nodes 2 --virtual-time
	cargo run --release -- serve-fleet --scenario diurnal --epochs 9 \
	    --epoch-ms 60 --rps 800 --instances 2 --nodes 4 --virtual-time
	cargo run --release -- topology --scenario mixed-tenant --nodes 4

# Determinism lint (DESIGN.md S23): rejects wall-clock reads outside
# clock/, hash-ordered collections in decision/trace paths, NaN-unstable
# float sorts, OS-entropy randomness, std::sync imports that bypass the
# crate::sync loom shim, and raw thread spawns outside the
# registered-actor protocol. An audited exception is marked in-source:
#   // detlint: allow(<rule>) -- <reason>
lint:
	cargo run --release -p detlint -- rust/src

# Exhaustive loom model checking of the concurrency core: the five S23
# invariants over the lock-free shard/topology code plus the two S24
# barrier/merge models of the parallel virtual clock, all in
# rust/tests/loom_models.rs, every schedule explored (no iteration cap).
# Set LOOM_MAX_PREEMPTIONS=2 for a quick local smoke pass; CI runs
# unbounded.
loom:
	RUSTFLAGS="--cfg loom" cargo test --release -p wavescale --test loom_models

# Miri over the unsafe slot-protocol code: the ShardQueue unit tests
# drive both Ring unsafe sites (producer publish, reaper take) plus the
# Sync/Send contracts under the interpreter's aliasing + data-race
# checks. Requires: rustup +nightly component add miri.
miri:
	cargo +nightly miri test -p wavescale --lib coordinator::shard

# ThreadSanitizer over the concurrency test subset (shard queue, clock
# wait slots, dispatch). Needs nightly + the rust-src component for
# -Zbuild-std (TSan must instrument std too). Advisory in CI: TSan has
# no false positives on data races but can flag lock-order inversions
# the deterministic tests never hit.
tsan:
	RUSTFLAGS="-Zsanitizer=thread" cargo +nightly test -p wavescale --lib \
	    -Zbuild-std --target x86_64-unknown-linux-gnu \
	    coordinator::shard coordinator::dispatch clock::

doc:
	cargo doc --no-deps

clean:
	cargo clean
	rm -rf $(ARTIFACTS_DIR) results
