# wavescale build orchestration.
#
#   make artifacts   AOT-compile the JAX/Pallas layers into artifacts/
#                    (requires python3 + jax; the rust stack runs without
#                    them on the native fallback backend)
#   make build       release build of the rust workspace
#   make test        tier-1 verify: build + tests (artifacts built first
#                    when python/jax are available, so PJRT paths run too)
#   make bench       regenerate every paper figure/table CSV into results/
#   make doc         rustdoc with warnings surfaced

ARTIFACTS_DIR := artifacts
PY            := python3

.PHONY: artifacts build test bench doc scenario-smoke clean

artifacts:
	cd python && $(PY) -m compile.aot --out-dir ../$(ARTIFACTS_DIR)

build:
	cargo build --release

test:
	@if $(PY) -c "import jax" 2>/dev/null; then \
		$(MAKE) artifacts; \
	else \
		echo "(python/jax unavailable — skipping make artifacts; tests use the native backend)"; \
	fi
	cargo build --release
	cargo test -q

bench: build
	@for b in fig1_delay fig2_dynamic_power fig3_static_power fig4_workload \
	          fig5_alpha fig6_beta fig8_markov fig10_tabla_trace \
	          fig11_voltage_trace fig12_accelerators table1_utilization \
	          table2_summary pll_overhead hybrid_capacity; do \
		cargo bench --bench $$b || exit 1; \
	done

# Shortened end-to-end smoke of the elastic capacity manager: an
# overnight trough through both the offline scenario sim (with the
# dvfs/pg/hybrid side-by-side) and the live serve-fleet coordinator.
# CI runs this so the serving path is exercised beyond unit tests.
scenario-smoke: build
	cargo run --release -- scenario --name overnight --steps 120
	cargo run --release -- serve-fleet --scenario overnight --epochs 6 \
	    --epoch-ms 60 --rps 800 --instances 2

doc:
	cargo doc --no-deps

clean:
	cargo clean
	rm -rf $(ARTIFACTS_DIR) results
