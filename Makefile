# wavescale build orchestration.
#
#   make artifacts   AOT-compile the JAX/Pallas layers into artifacts/
#                    (requires python3 + jax; the rust stack runs without
#                    them on the native fallback backend)
#   make build       release build of the rust workspace
#   make test        tier-1 verify: build + tests (artifacts built first
#                    when python/jax are available, so PJRT paths run too)
#   make bench       regenerate every paper figure/table CSV into results/
#   make doc         rustdoc with warnings surfaced

ARTIFACTS_DIR := artifacts
PY            := python3

.PHONY: artifacts build test bench doc clean

artifacts:
	cd python && $(PY) -m compile.aot --out-dir ../$(ARTIFACTS_DIR)

build:
	cargo build --release

test:
	@if $(PY) -c "import jax" 2>/dev/null; then \
		$(MAKE) artifacts; \
	else \
		echo "(python/jax unavailable — skipping make artifacts; tests use the native backend)"; \
	fi
	cargo build --release
	cargo test -q

bench: build
	@for b in fig1_delay fig2_dynamic_power fig3_static_power fig4_workload \
	          fig5_alpha fig6_beta fig8_markov fig10_tabla_trace \
	          fig11_voltage_trace fig12_accelerators table1_utilization \
	          table2_summary pll_overhead; do \
		cargo bench --bench $$b || exit 1; \
	done

doc:
	cargo doc --no-deps

clean:
	cargo clean
	rm -rf $(ARTIFACTS_DIR) results
