"""MXU-tiled Pallas matmul — the served accelerator's compute hot-spot.

The multi-FPGA platform in the paper hosts DNN accelerators (Tabla,
DnnWeaver, DianNao, Stripes, Proteus); in this reproduction each simulated
FPGA instance executes an AOT-compiled DNN forward pass whose matmuls lower
through this kernel.

TPU adaptation (DESIGN.md section 7): the FPGA accelerators' systolic MAC
arrays map onto the MXU; tiling is (bm, bk) x (bk, bn) blocks resident in
VMEM with the K reduction carried across the innermost grid dimension. The
output block's index_map ignores k, so the same VMEM tile is revisited and
accumulated in place — the Pallas idiom for a K-loop with double-buffered
operand streaming.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, *, bm: int = 128, bn: int = 128, bk: int = 128):
    """Blocked matmul ``x @ y`` with (bm, bn, bk) MXU tiles.

    Dimensions must divide by the respective tile. f32 accumulate
    (bfloat16 inputs are upcast by ``preferred_element_type``).
    """
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {y.shape}")
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shapes {x.shape} @ {y.shape} not tiled by ({bm},{bn},{bk})")
    nk = k // bk

    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)
