"""Voltage-grid optimizer kernel (the paper's Eq. (1)-(3) hot-spot).

Given the pre-characterized per-voltage tables of the FPGA resource library
(DESIGN.md S1) and a batch of operating points, the kernel evaluates every
``(Vcore, Vbram)`` pair on the DC-DC grid and selects, per operating point,
the minimum-power pair that still meets the workload-stretched critical
path:

    delay(i, j)  = dl[i] + alpha * dm[j]            (Eq. 1, normalized)
    feasible     = delay(i, j) <= (1 + alpha) * sw  (Eq. 2)
    power(i, j)  = (1-beta) * (gl * pl_dyn[i] / sw + (1-gl) * pl_st[i])
                 +    beta  * (gm * pm_dyn[j] / sw + (1-gm) * pm_st[j])
                                                    (Eq. 3; f = f_nom / sw)

Table convention: index 0 is the nominal voltage; ascending index means
*descending* voltage (25 mV DC-DC steps, ref. [39] of the paper). Index 0 is
therefore always feasible for sw >= 1, so the masked argmin is total.

TPU adaptation (DESIGN.md section 7): the voltage grid is tiny (NV x NM ~
13 x 19) and lives in VMEM for the whole batch; the batch is tiled along the
Pallas grid, and the six characterization tables are re-used by every
program instance (constant index_map), so the HBM<->VMEM traffic is one
table load plus one batch-tile stream -- the same schedule a GPU version
would express with a threadblock-resident lookup table.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Optimization modes: which rail(s) the policy may scale. Baked per artifact
# so the rust runtime gets one executable per policy variant.
MODES = ("prop", "core_only", "bram_only")

# Default batch tile. The voltage surface per element is NV*NM floats; at
# (13, 19) a 64-element tile keeps the whole working set < 1 MiB of VMEM.
DEFAULT_BLOCK_B = 64


def _vgrid_kernel(
    dl_ref,
    dm_ref,
    pl_dyn_ref,
    pl_st_ref,
    pm_dyn_ref,
    pm_st_ref,
    alpha_ref,
    beta_ref,
    gl_ref,
    gm_ref,
    sw_ref,
    icore_ref,
    ibram_ref,
    power_ref,
    *,
    mode: str,
):
    dl = dl_ref[...]  # (NV,)  logic+routing delay scale vs Vcore
    dm = dm_ref[...]  # (NM,)  BRAM delay scale vs Vbram
    pl_dyn = pl_dyn_ref[...]  # (NV,)  core-rail dynamic energy/cycle scale
    pl_st = pl_st_ref[...]  # (NV,)  core-rail static power scale
    pm_dyn = pm_dyn_ref[...]  # (NM,)  bram-rail dynamic energy/cycle scale
    pm_st = pm_st_ref[...]  # (NM,)  bram-rail static power scale

    alpha = alpha_ref[...]  # (B,) BRAM share of critical-path delay
    beta = beta_ref[...]  # (B,) BRAM share of total power
    gl = gl_ref[...]  # (B,) dynamic fraction of core-rail power
    gm = gm_ref[...]  # (B,) dynamic fraction of bram-rail power
    sw = sw_ref[...]  # (B,) workload slack factor (>= 1)

    nv = dl.shape[0]
    nm = dm.shape[0]

    # Delay surface (B, NV, NM) and the Eq. (2) feasibility mask.
    delay = dl[None, :, None] + alpha[:, None, None] * dm[None, None, :]
    budget = ((1.0 + alpha) * sw)[:, None, None]
    feasible = delay <= budget

    # Rail powers at the workload-scaled frequency f = f_nom / sw.
    fr = (1.0 / sw)[:, None]  # frequency ratio, (B, 1)
    p_core = gl[:, None] * pl_dyn[None, :] * fr + (1.0 - gl)[:, None] * pl_st[None, :]
    p_bram = gm[:, None] * pm_dyn[None, :] * fr + (1.0 - gm)[:, None] * pm_st[None, :]
    power = (
        (1.0 - beta)[:, None, None] * p_core[:, :, None]
        + beta[:, None, None] * p_bram[:, None, :]
    )

    # Policy restriction: single-rail baselines pin the other rail to
    # index 0 (nominal voltage).
    if mode == "core_only":
        col = jax.lax.broadcasted_iota(jnp.int32, power.shape, 2)
        feasible = jnp.logical_and(feasible, col == 0)
    elif mode == "bram_only":
        row = jax.lax.broadcasted_iota(jnp.int32, power.shape, 1)
        feasible = jnp.logical_and(feasible, row == 0)

    masked = jnp.where(feasible, power, jnp.inf)
    flat = masked.reshape((masked.shape[0], nv * nm))
    best = jnp.argmin(flat, axis=1).astype(jnp.int32)
    best_power = jnp.min(flat, axis=1)

    icore_ref[...] = best // nm
    ibram_ref[...] = best % nm
    power_ref[...] = best_power


@functools.partial(jax.jit, static_argnames=("mode", "block_b"))
def vgrid_optimize(
    dl,
    dm,
    pl_dyn,
    pl_st,
    pm_dyn,
    pm_st,
    alpha,
    beta,
    gl,
    gm,
    sw,
    *,
    mode: str = "prop",
    block_b: int = DEFAULT_BLOCK_B,
):
    """Batched optimal-voltage-pair selection on the DC-DC grid.

    Args:
      dl, pl_dyn, pl_st: f32[NV] core-rail tables (index 0 = nominal).
      dm, pm_dyn, pm_st: f32[NM] bram-rail tables (index 0 = nominal).
      alpha, beta, gl, gm, sw: f32[B] per-operating-point parameters.
      mode: "prop" (both rails), "core_only", or "bram_only".
      block_b: Pallas batch tile; B must be a multiple.

    Returns:
      (icore i32[B], ibram i32[B], power f32[B]) -- chosen table indices and
      the achieved normalized power.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    b = alpha.shape[0]
    if b % block_b != 0:
        raise ValueError(f"batch {b} not a multiple of block_b {block_b}")
    nv = dl.shape[0]
    nm = dm.shape[0]

    table = lambda n: pl.BlockSpec((n,), lambda i: (0,))  # noqa: E731
    batch = pl.BlockSpec((block_b,), lambda i: (i,))

    return pl.pallas_call(
        functools.partial(_vgrid_kernel, mode=mode),
        grid=(b // block_b,),
        in_specs=[
            table(nv),
            table(nm),
            table(nv),
            table(nv),
            table(nm),
            table(nm),
            batch,
            batch,
            batch,
            batch,
            batch,
        ],
        out_specs=[batch, batch, batch],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=True,
    )(dl, dm, pl_dyn, pl_st, pm_dyn, pm_st, alpha, beta, gl, gm, sw)
