"""Pure-jnp oracles for the Pallas kernels (the build-time correctness bar).

Every kernel in this package must match its oracle to float tolerance under
pytest/hypothesis before ``aot.py`` is allowed to emit artifacts.
"""

import numpy as np
import jax.numpy as jnp


def matmul_ref(x, y):
    """Oracle for kernels.matmul."""
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def vgrid_optimize_ref(
    dl, dm, pl_dyn, pl_st, pm_dyn, pm_st, alpha, beta, gl, gm, sw, *, mode="prop"
):
    """Oracle for kernels.vgrid_optimize (vectorized, no Pallas).

    Mirrors Eq. (1)-(3) of the paper with identical flattened-argmin
    tie-breaking (row-major over (icore, ibram), lowest index wins).
    """
    dl = jnp.asarray(dl)
    dm = jnp.asarray(dm)
    nv, nm = dl.shape[0], dm.shape[0]

    delay = dl[None, :, None] + alpha[:, None, None] * dm[None, None, :]
    budget = ((1.0 + alpha) * sw)[:, None, None]
    feasible = delay <= budget

    fr = (1.0 / sw)[:, None]
    p_core = gl[:, None] * pl_dyn[None, :] * fr + (1.0 - gl)[:, None] * pl_st[None, :]
    p_bram = gm[:, None] * pm_dyn[None, :] * fr + (1.0 - gm)[:, None] * pm_st[None, :]
    power = (
        (1.0 - beta)[:, None, None] * p_core[:, :, None]
        + beta[:, None, None] * p_bram[:, None, :]
    )

    if mode == "core_only":
        idx = jnp.arange(nm)[None, None, :]
        feasible = jnp.logical_and(feasible, idx == 0)
    elif mode == "bram_only":
        idx = jnp.arange(nv)[None, :, None]
        feasible = jnp.logical_and(feasible, idx == 0)

    masked = jnp.where(feasible, power, jnp.inf)
    flat = masked.reshape((masked.shape[0], nv * nm))
    best = jnp.argmin(flat, axis=1).astype(jnp.int32)
    return best // nm, best % nm, jnp.min(flat, axis=1)


def example_tables(nv: int = 13, nm: int = 19):
    """Synthetic-but-realistic characterization tables for tests.

    Shapes follow the paper's Figures 1-3: index 0 = nominal voltage
    (Vcore 0.80 V / Vbram 0.95 V), 25 mV descending steps, delay scale
    rising super-linearly toward the crash voltage, dynamic power ~ V^2,
    static power dropping exponentially (DIBL). The rust `chars` module is
    the production generator; this is only a test fixture with the same
    qualitative structure.
    """
    v_core = 0.80 - 0.025 * np.arange(nv)
    v_bram = 0.95 - 0.025 * np.arange(nm)

    def delay_scale(v, v0, vth, a=1.3):
        # Clamp the overdrive so deep grids (tests sweep nv/nm past the
        # physical crash voltage) stay finite; the rust chars module owns
        # the real crash-voltage semantics.
        ov = np.maximum(v - vth, 0.02)
        return ((v0 - vth) ** a / ov**a) * (v / v0)

    dl = delay_scale(v_core, 0.80, 0.35)
    # BRAM: high-Vth cells, flat region near nominal then a spike (Fig. 1).
    dm = delay_scale(v_bram, 0.95, 0.42, a=1.6)
    pl_dyn = (v_core / 0.80) ** 2
    pm_dyn = (v_bram / 0.95) ** 2
    pl_st = (v_core / 0.80) * np.exp((v_core - 0.80) / 0.045)
    pm_st = (v_bram / 0.95) * np.exp((v_bram - 0.95) / 0.040)
    f32 = lambda a: jnp.asarray(np.asarray(a), jnp.float32)  # noqa: E731
    return tuple(f32(t) for t in (dl, dm, pl_dyn, pl_st, pm_dyn, pm_st))
