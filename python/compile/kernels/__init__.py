"""Layer-1 Pallas kernels for wavescale.

Two kernels:
  * ``vgrid``  -- the paper's numeric hot-spot: evaluate the (Vcore, Vbram)
    voltage grid (delay feasibility, Eq. (2); power, Eq. (3)) for a batch of
    (alpha, beta, Sw) operating points and reduce to the optimal pair.
  * ``matmul`` -- MXU-tiled matmul used by the served DNN forward pass.

Both are lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, so interpret mode is the correctness path; real-TPU
performance is estimated from the BlockSpecs (see DESIGN.md section 7).
"""

from compile.kernels.vgrid import vgrid_optimize, MODES  # noqa: F401
from compile.kernels.matmul import matmul  # noqa: F401
