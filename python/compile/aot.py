"""AOT bridge: lower the Layer-2 graphs to HLO *text* + a manifest.

Run once by ``make artifacts``; the rust binary is self-contained after.

HLO text — not ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/load_hlo/).

Emitted artifacts:
  voltage_opt_{prop,core_only,bram_only}.hlo.txt   Voltage Selector variants
  dnn_{tabla,dnnweaver,diannao,stripes,proteus}.hlo.txt  served models
  manifest.json                                    shapes/dtypes/meta index

Every artifact is numerically self-checked against its oracle before being
written; a failing check aborts the build.
"""

import argparse
import hashlib
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import ref


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    return {"float32": "f32", "int32": "i32"}[np.dtype(dt).name]


def _arg_meta(args):
    return [{"shape": list(a.shape), "dtype": _dtype_name(a.dtype)} for a in args]


def _hlo_stats(text: str) -> dict:
    """Cheap structural stats recorded in the manifest (perf tracking)."""
    lines = text.splitlines()
    return {
        "bytes": len(text),
        "computations": sum(1 for l in lines if l.lstrip().startswith("%fused") or l.startswith("ENTRY")),
        "fusions": sum(1 for l in lines if " fusion(" in l),
        "while_loops": sum(1 for l in lines if " while(" in l),
        "dots": sum(1 for l in lines if " dot(" in l),
    }


def _check(name, got, want, atol=1e-5, rtol=1e-5):
    got = np.asarray(got)
    want = np.asarray(want)
    if got.dtype.kind == "i":
        ok = np.array_equal(got, want)
    else:
        ok = np.allclose(got, want, atol=atol, rtol=rtol)
    if not ok:
        raise SystemExit(f"AOT self-check FAILED for {name}: kernel != oracle")


def build_voltage_opt(out_dir: str, mode: str, rng: np.random.Generator) -> dict:
    """Lower one Voltage Selector variant; self-check vs the oracle first."""
    nv, nm, b = model.NV, model.NM, model.OPT_BATCH
    tables = ref.example_tables(nv, nm)
    alpha = jnp.asarray(rng.uniform(0.0, 0.5, b), jnp.float32)
    beta = jnp.asarray(rng.uniform(0.1, 0.7, b), jnp.float32)
    gl = jnp.asarray(rng.uniform(0.3, 0.9, b), jnp.float32)
    gm = jnp.asarray(rng.uniform(0.3, 0.9, b), jnp.float32)
    sw = jnp.asarray(rng.uniform(1.0, 8.0, b), jnp.float32)

    fn = lambda *a: model.voltage_optimize(*a, mode=mode)  # noqa: E731
    got = jax.jit(fn)(*tables, alpha, beta, gl, gm, sw)
    want = ref.vgrid_optimize_ref(*tables, alpha, beta, gl, gm, sw, mode=mode)
    for g, w, part in zip(got, want, ("icore", "ibram", "power")):
        _check(f"voltage_opt_{mode}.{part}", g, w)

    spec = lambda n: jax.ShapeDtypeStruct((n,), jnp.float32)  # noqa: E731
    args = [spec(nv), spec(nm), spec(nv), spec(nv), spec(nm), spec(nm)] + [
        spec(b)
    ] * 5
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    name = f"voltage_opt_{mode}"
    path = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(text)
    return {
        "path": path,
        "args": _arg_meta(args),
        "results": [
            {"shape": [b], "dtype": "i32"},
            {"shape": [b], "dtype": "i32"},
            {"shape": [b], "dtype": "f32"},
        ],
        "meta": {
            "kind": "voltage_opt",
            "mode": mode,
            "nv": nv,
            "nm": nm,
            "batch": b,
            "vcore_nom": model.VCORE_NOM,
            "vbram_nom": model.VBRAM_NOM,
            "v_step": model.V_STEP,
            "v_crash": model.V_CRASH,
            "hlo": _hlo_stats(text),
        },
    }


def build_dnn(out_dir: str, variant: str, rng: np.random.Generator) -> dict:
    """Lower one served-model variant; self-check vs the pure-jnp oracle."""
    x_shape, layer_shapes = model.dnn_param_shapes(variant)
    params = model.dnn_init_params(variant)
    x = jnp.asarray(rng.standard_normal(x_shape), jnp.float32)

    got = jax.jit(model.dnn_forward)(x, *params)

    def forward_ref(x, *params):
        n = len(params) // 2
        for i in range(n):
            w, b = params[2 * i], params[2 * i + 1]
            x = ref.matmul_ref(x, w) + b[None, :]
            if i + 1 < n:
                x = jax.nn.relu(x)
        return x

    _check(f"dnn_{variant}", got, forward_ref(x, *params), atol=1e-3, rtol=1e-4)

    arg_specs = [jax.ShapeDtypeStruct(x_shape, jnp.float32)]
    for (w_shape, b_shape) in layer_shapes:
        arg_specs.append(jax.ShapeDtypeStruct(w_shape, jnp.float32))
        arg_specs.append(jax.ShapeDtypeStruct(b_shape, jnp.float32))
    lowered = jax.jit(model.dnn_forward).lower(*arg_specs)
    text = to_hlo_text(lowered)
    name = f"dnn_{variant}"
    path = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(text)

    # Side files so the rust runtime can execute with the exact parameters
    # used here and smoke-check numerics after its own compile:
    #   <name>.params.bin  f32-LE params concatenated in arg order
    #   <name>.golden.bin  f32-LE x then y, flattened row-major
    params_bin = f"{name}.params.bin"
    golden_bin = f"{name}.golden.bin"
    with open(os.path.join(out_dir, params_bin), "wb") as f:
        for p in params:
            f.write(np.asarray(p, dtype="<f4").tobytes())
    with open(os.path.join(out_dir, golden_bin), "wb") as f:
        f.write(np.asarray(x, dtype="<f4").tobytes())
        f.write(np.asarray(got, dtype="<f4").tobytes())
    golden = {
        "x_first8": np.asarray(x).reshape(-1)[:8].tolist(),
        "y_first8": np.asarray(got).reshape(-1)[:8].tolist(),
        "params_bin": params_bin,
        "golden_bin": golden_bin,
    }
    return {
        "path": path,
        "args": _arg_meta(arg_specs),
        "results": [{"shape": list(got.shape), "dtype": "f32"}],
        "meta": {
            "kind": "dnn",
            "variant": variant,
            "batch": x_shape[0],
            "layers": list(model.DNN_VARIANTS[variant]),
            "golden": golden,
            "hlo": _hlo_stats(text),
        },
    }


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--skip-dnn", action="store_true", help="voltage artifacts only (fast dev)"
    )
    ns = parser.parse_args()
    os.makedirs(ns.out_dir, exist_ok=True)
    rng = np.random.default_rng(2019)

    artifacts = {}
    for mode in ("prop", "core_only", "bram_only"):
        name = f"voltage_opt_{mode}"
        artifacts[name] = build_voltage_opt(ns.out_dir, mode, rng)
        print(f"  {name}: {artifacts[name]['meta']['hlo']['bytes']} bytes")
    if not ns.skip_dnn:
        for variant in model.DNN_VARIANTS:
            name = f"dnn_{variant}"
            artifacts[name] = build_dnn(ns.out_dir, variant, rng)
            print(f"  {name}: {artifacts[name]['meta']['hlo']['bytes']} bytes")

    src_digest = hashlib.sha256()
    here = os.path.dirname(os.path.abspath(__file__))
    for root, _, files in os.walk(here):
        for fname in sorted(files):
            if fname.endswith(".py"):
                with open(os.path.join(root, fname), "rb") as f:
                    src_digest.update(f.read())

    manifest = {
        "version": 1,
        "jax": jax.__version__,
        "source_sha256": src_digest.hexdigest(),
        "artifacts": artifacts,
    }
    with open(os.path.join(ns.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {len(artifacts)} artifacts + manifest.json to {ns.out_dir}")


if __name__ == "__main__":
    main()
