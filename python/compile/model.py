"""Layer-2 JAX model: the compute graphs that get AOT-lowered to HLO text.

Two graph families:

* ``voltage_optimize``  — the central controller's Voltage Selector
  (paper §V): batched optimal (Vcore, Vbram) selection on the DC-DC grid,
  built on the :mod:`compile.kernels.vgrid` Pallas kernel. The
  characterization tables are *runtime inputs* so one artifact serves any
  rust-side characterization library.

* ``dnn_forward``       — the served accelerator workload: an MLP forward
  pass built on the :mod:`compile.kernels.matmul` Pallas kernel, with one
  shape variant per paper benchmark (Table I). Each simulated FPGA instance
  executes one of these through PJRT on the request path.
"""

import os

import jax
import jax.numpy as jnp

from compile import kernels

# ---------------------------------------------------------------------------
# Voltage grid dimensions (paper §III/§IV):
#   Vcore: 0.800 V nominal down to 0.500 V crash voltage, 25 mV steps -> 13
#   Vbram: 0.950 V nominal down to 0.500 V crash voltage, 25 mV steps -> 19
# Index 0 = nominal; ascending index = descending voltage.
# ---------------------------------------------------------------------------
VCORE_NOM = 0.800
VBRAM_NOM = 0.950
V_CRASH = 0.500
V_STEP = 0.025
NV = int(round((VCORE_NOM - V_CRASH) / V_STEP)) + 1  # 13
NM = int(round((VBRAM_NOM - V_CRASH) / V_STEP)) + 1  # 19

# AOT batch of operating points per Voltage Selector call. The rust CC pads
# its (benchmark x workload-level) queries up to this.
OPT_BATCH = 64

# Served-model batch (requests per inference dispatch).
DNN_BATCH = 16

# Benchmark shape variants, loosely scaled after Table I logic utilization
# (LAB counts: Tabla 127 ... Stripes 12343). (input, hidden..., output);
# all dims are multiples of the 64-wide MXU-tile floor used at this size.
DNN_VARIANTS = {
    "tabla": (128, 256, 256, 64),
    "dnnweaver": (256, 512, 512, 64),
    "diannao": (512, 1024, 1024, 64),
    "stripes": (1024, 1024, 1024, 64),
    "proteus": (512, 1024, 512, 64),
}


def voltage_optimize(
    dl, dm, pl_dyn, pl_st, pm_dyn, pm_st, alpha, beta, gl, gm, sw, *, mode="prop"
):
    """Optimal voltage pairs for a batch of operating points.

    See :func:`compile.kernels.vgrid.vgrid_optimize`. ``sw`` is clamped to
    >= 1 (a platform never runs faster than nominal), which also guarantees
    the nominal grid point stays feasible and the argmin is total.
    """
    sw = jnp.maximum(sw, 1.0)
    return kernels.vgrid_optimize(
        dl, dm, pl_dyn, pl_st, pm_dyn, pm_st, alpha, beta, gl, gm, sw, mode=mode
    )


def matmul_tiles(m, k, n):
    """Deployment-aware Pallas tile selection (perf pass, EXPERIMENTS.md
    §Perf-L1).

    The artifacts in this repo execute on the CPU PJRT client, where each
    Pallas grid step lowers to one while-loop iteration — iteration count,
    not VMEM residency, dominates wall time (measured 80x on the stripes
    variant). Default therefore maximizes tile size (minimizes grid steps).
    Set WAVESCALE_TPU_TILES=1 to emit the TPU deploy shape instead:
    (128, 512, 512) keeps x/w/acc tiles ~2.3 MiB — double-buffered well
    under the ~16 MiB VMEM budget — with MXU-aligned 128-multiples.
    """
    if os.environ.get("WAVESCALE_TPU_TILES") == "1":
        return min(m, 128), min(n, 512), min(k, 512)
    return min(m, 128), min(n, 1024), min(k, 1024)


def dnn_forward(x, *params):
    """MLP forward pass over Pallas matmuls: relu(x@W+b) ... @W_last+b_last.

    ``params`` is a flat (W0, b0, W1, b1, ...) tuple so the lowered HLO has
    a stable positional signature for the rust runtime.
    """
    if len(params) < 2 or len(params) % 2 != 0:
        raise ValueError("params must be a non-empty flat (W, b, ...) tuple")
    n_layers = len(params) // 2
    for i in range(n_layers):
        w, b = params[2 * i], params[2 * i + 1]
        bm, bn, bk = matmul_tiles(x.shape[0], w.shape[0], w.shape[1])
        x = kernels.matmul(x, w, bm=bm, bn=bn, bk=bk) + b[None, :]
        if i + 1 < n_layers:
            x = jax.nn.relu(x)
    return x


def dnn_param_shapes(variant: str, batch: int = DNN_BATCH):
    """(x_shape, [(w, b) shapes...]) for a Table-I benchmark variant."""
    dims = DNN_VARIANTS[variant]
    x_shape = (batch, dims[0])
    layer_shapes = []
    for din, dout in zip(dims[:-1], dims[1:]):
        layer_shapes.append(((din, dout), (dout,)))
    return x_shape, layer_shapes


def dnn_init_params(variant: str, seed: int = 0):
    """Deterministic small random parameters for a variant (He-ish init)."""
    _, layer_shapes = dnn_param_shapes(variant)
    key = jax.random.PRNGKey(seed)
    params = []
    for (w_shape, b_shape) in layer_shapes:
        key, kw = jax.random.split(key)
        scale = (2.0 / w_shape[0]) ** 0.5
        params.append(jax.random.normal(kw, w_shape, jnp.float32) * scale)
        params.append(jnp.zeros(b_shape, jnp.float32))
    return params
