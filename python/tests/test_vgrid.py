"""vgrid Pallas kernel vs pure-jnp oracle — the core correctness signal."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.vgrid import vgrid_optimize, MODES


def _params(rng, b):
    return (
        jnp.asarray(rng.uniform(0.0, 0.6, b), jnp.float32),  # alpha
        jnp.asarray(rng.uniform(0.05, 0.8, b), jnp.float32),  # beta
        jnp.asarray(rng.uniform(0.2, 0.95, b), jnp.float32),  # gl
        jnp.asarray(rng.uniform(0.2, 0.95, b), jnp.float32),  # gm
        jnp.asarray(rng.uniform(1.0, 10.0, b), jnp.float32),  # sw
    )


def _run_both(tables, params, mode, block_b):
    got = vgrid_optimize(*tables, *params, mode=mode, block_b=block_b)
    want = ref.vgrid_optimize_ref(*tables, *params, mode=mode)
    return got, want


@pytest.mark.parametrize("mode", MODES)
def test_matches_oracle(mode):
    rng = np.random.default_rng(7)
    tables = ref.example_tables()
    params = _params(rng, 128)
    got, want = _run_both(tables, params, mode, 64)
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])
    np.testing.assert_allclose(got[2], want[2], rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    nv=st.integers(2, 24),
    nm=st.integers(2, 24),
    b=st.sampled_from([16, 32, 64]),
    mode=st.sampled_from(MODES),
)
def test_matches_oracle_hypothesis(seed, nv, nm, b, mode):
    rng = np.random.default_rng(seed)
    tables = ref.example_tables(nv, nm)
    params = _params(rng, b)
    got, want = _run_both(tables, params, mode, b)
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])
    np.testing.assert_allclose(got[2], want[2], rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), mode=st.sampled_from(MODES))
def test_feasibility_invariant(seed, mode):
    """Chosen pair always meets Eq. (2); power is the true masked minimum."""
    rng = np.random.default_rng(seed)
    tables = ref.example_tables()
    dl, dm = np.asarray(tables[0]), np.asarray(tables[1])
    params = _params(rng, 64)
    alpha, _, _, _, sw = (np.asarray(p) for p in params)
    icore, ibram, power = (np.asarray(a) for a in vgrid_optimize(*tables, *params, mode=mode))
    delay = dl[icore] + alpha * dm[ibram]
    assert np.all(delay <= (1.0 + alpha) * sw * (1.0 + 1e-6))
    assert np.all(np.isfinite(power))
    if mode == "core_only":
        assert np.all(ibram == 0)
    if mode == "bram_only":
        assert np.all(icore == 0)


def test_nominal_always_feasible_at_sw1():
    """sw == 1 leaves no slack: the kernel must pick a pair at least as
    good as nominal and still meet timing."""
    tables = ref.example_tables()
    b = 64
    ones = jnp.ones((b,), jnp.float32)
    alpha = ones * 0.2
    icore, ibram, power = vgrid_optimize(
        *tables, alpha, ones * 0.4, ones * 0.7, ones * 0.6, ones, mode="prop"
    )
    assert np.all(np.isfinite(np.asarray(power)))
    # Nominal normalized power at sw=1 is gl*1+... == 1 by construction.
    assert np.all(np.asarray(power) <= 1.0 + 1e-6)


def test_monotone_in_workload():
    """More slack (higher sw) can never cost more power."""
    tables = ref.example_tables()
    b = 64
    ones = jnp.ones((b,), jnp.float32)
    sw_lo = jnp.linspace(1.0, 4.0, b).astype(jnp.float32)
    sw_hi = sw_lo * 1.5
    common = (ones * 0.2, ones * 0.4, ones * 0.7, ones * 0.6)
    _, _, p_lo = vgrid_optimize(*tables, *common, sw_lo, mode="prop")
    _, _, p_hi = vgrid_optimize(*tables, *common, sw_hi, mode="prop")
    assert np.all(np.asarray(p_hi) <= np.asarray(p_lo) + 1e-6)


def test_prop_beats_single_rail():
    """Two-rail optimization dominates both single-rail baselines (§III)."""
    rng = np.random.default_rng(11)
    tables = ref.example_tables()
    params = _params(rng, 128)
    _, _, p_prop = vgrid_optimize(*tables, *params, mode="prop", block_b=64)
    _, _, p_core = vgrid_optimize(*tables, *params, mode="core_only", block_b=64)
    _, _, p_bram = vgrid_optimize(*tables, *params, mode="bram_only", block_b=64)
    assert np.all(np.asarray(p_prop) <= np.asarray(p_core) + 1e-6)
    assert np.all(np.asarray(p_prop) <= np.asarray(p_bram) + 1e-6)


def test_bad_mode_rejected():
    tables = ref.example_tables()
    ones = jnp.ones((64,), jnp.float32)
    with pytest.raises(ValueError):
        vgrid_optimize(*tables, ones, ones, ones, ones, ones, mode="nope")


def test_bad_batch_rejected():
    tables = ref.example_tables()
    ones = jnp.ones((65,), jnp.float32)
    with pytest.raises(ValueError):
        vgrid_optimize(*tables, ones, ones, ones, ones, ones, block_b=64)
