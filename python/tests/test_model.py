"""Layer-2 model graphs: shapes, variants, and oracle agreement."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref


@pytest.mark.parametrize("variant", sorted(model.DNN_VARIANTS))
def test_dnn_forward_shape(variant):
    x_shape, layer_shapes = model.dnn_param_shapes(variant)
    params = model.dnn_init_params(variant)
    assert len(params) == 2 * len(layer_shapes)
    x = jnp.zeros(x_shape, jnp.float32)
    out = model.dnn_forward(x, *params)
    assert out.shape == (x_shape[0], model.DNN_VARIANTS[variant][-1])


def test_dnn_forward_matches_oracle():
    variant = "tabla"
    x_shape, _ = model.dnn_param_shapes(variant)
    params = model.dnn_init_params(variant)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(x_shape), jnp.float32)

    got = model.dnn_forward(x, *params)
    want = x
    n = len(params) // 2
    for i in range(n):
        w, b = params[2 * i], params[2 * i + 1]
        want = ref.matmul_ref(want, w) + b[None, :]
        if i + 1 < n:
            want = jax.nn.relu(want)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_dnn_variant_dims_are_tiled():
    """All variant dims must be multiples of the 64-wide tile floor."""
    for dims in model.DNN_VARIANTS.values():
        assert all(d % 64 == 0 for d in dims), dims
    assert model.DNN_BATCH % 16 == 0


def test_voltage_grid_constants():
    assert model.NV == 13
    assert model.NM == 19
    # grid index -> voltage round trip
    assert model.VCORE_NOM - model.V_STEP * (model.NV - 1) == pytest.approx(0.5)
    assert model.VBRAM_NOM - model.V_STEP * (model.NM - 1) == pytest.approx(0.5)


def test_voltage_optimize_clamps_sw():
    """sw < 1 (overload) must behave exactly like sw == 1."""
    tables = ref.example_tables()
    b = 64
    ones = jnp.ones((b,), jnp.float32)
    common = (ones * 0.2, ones * 0.4, ones * 0.7, ones * 0.6)
    out_lo = model.voltage_optimize(*tables, *common, ones * 0.5)
    out_1 = model.voltage_optimize(*tables, *common, ones)
    for a, b_ in zip(out_lo, out_1):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


def test_dnn_forward_rejects_bad_params():
    x = jnp.zeros((16, 128), jnp.float32)
    with pytest.raises(ValueError):
        model.dnn_forward(x)
    with pytest.raises(ValueError):
        model.dnn_forward(x, jnp.zeros((128, 64)))


def test_matmul_tiles_cpu_vs_tpu(monkeypatch):
    """Tile selection is deployment-aware (EXPERIMENTS.md §Perf-L1)."""
    monkeypatch.delenv("WAVESCALE_TPU_TILES", raising=False)
    assert model.matmul_tiles(16, 1024, 1024) == (16, 1024, 1024)
    monkeypatch.setenv("WAVESCALE_TPU_TILES", "1")
    bm, bn, bk = model.matmul_tiles(16, 1024, 1024)
    assert (bm, bn, bk) == (16, 512, 512)
    # TPU tiles bound VMEM: x + w + acc under ~2.5 MiB for f32.
    assert (bm * bk + bk * bn + bm * bn) * 4 <= 2.5 * 2**20


def test_tpu_tiles_do_not_change_numerics(monkeypatch):
    import numpy as np

    x_shape, _ = model.dnn_param_shapes("tabla")
    params = model.dnn_init_params("tabla")
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal(x_shape), jnp.float32)
    monkeypatch.delenv("WAVESCALE_TPU_TILES", raising=False)
    a = model.dnn_forward(x, *params)
    monkeypatch.setenv("WAVESCALE_TPU_TILES", "1")
    b = model.dnn_forward(x, *params)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
