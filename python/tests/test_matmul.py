"""matmul Pallas kernel vs jnp.dot oracle (hypothesis over shapes/tiles)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul import matmul
from compile.kernels.ref import matmul_ref


def _rand(rng, shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.sampled_from([16, 32, 64, 128]),
    k=st.sampled_from([32, 64, 128, 256]),
    n=st.sampled_from([32, 64, 128]),
)
def test_matches_oracle_hypothesis(seed, m, k, n):
    rng = np.random.default_rng(seed)
    x, y = _rand(rng, (m, k)), _rand(rng, (k, n))
    # Tiled K accumulates in a different order than the one-shot oracle;
    # tolerance scales with sqrt(k) worth of f32 rounding.
    np.testing.assert_allclose(
        matmul(x, y), matmul_ref(x, y), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    bm=st.sampled_from([16, 32, 64]),
    bn=st.sampled_from([32, 64]),
    bk=st.sampled_from([32, 64]),
)
def test_tile_sizes_hypothesis(seed, bm, bn, bk):
    """Result is tile-shape independent."""
    rng = np.random.default_rng(seed)
    x, y = _rand(rng, (64, 128)), _rand(rng, (128, 64))
    np.testing.assert_allclose(
        matmul(x, y, bm=bm, bn=bn, bk=bk),
        matmul_ref(x, y),
        rtol=1e-4,
        atol=1e-4,
    )


def test_k_accumulation_order():
    """Multi-step K reduction (nk > 1) accumulates exactly."""
    rng = np.random.default_rng(3)
    x, y = _rand(rng, (32, 512)), _rand(rng, (512, 32))
    np.testing.assert_allclose(
        matmul(x, y, bk=64), matmul_ref(x, y), rtol=1e-4, atol=1e-4
    )


def test_bfloat16_upcast():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((32, 64)), jnp.bfloat16)
    y = jnp.asarray(rng.standard_normal((64, 32)), jnp.bfloat16)
    out = matmul(x, y)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(
        out, matmul_ref(x, y).astype(jnp.float32), rtol=2e-2, atol=2e-2
    )


def test_shape_mismatch_rejected():
    x = jnp.zeros((32, 64), jnp.float32)
    y = jnp.zeros((32, 64), jnp.float32)
    with pytest.raises(ValueError):
        matmul(x, y)


def test_untiled_shape_rejected():
    x = jnp.zeros((30, 64), jnp.float32)
    y = jnp.zeros((64, 32), jnp.float32)
    with pytest.raises(ValueError):
        matmul(x, y, bm=16)
