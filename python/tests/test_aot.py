"""AOT pipeline smoke: artifacts + manifest are well-formed HLO text."""

import json
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(os.path.abspath(__file__))
PYROOT = os.path.dirname(HERE)


@pytest.fixture(scope="module")
def aot_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--skip-dnn"],
        cwd=PYROOT,
        check=True,
    )
    return out


def test_manifest_schema(aot_dir):
    with open(aot_dir / "manifest.json") as f:
        manifest = json.load(f)
    assert manifest["version"] == 1
    arts = manifest["artifacts"]
    for mode in ("prop", "core_only", "bram_only"):
        art = arts[f"voltage_opt_{mode}"]
        assert art["meta"]["nv"] == 13
        assert art["meta"]["nm"] == 19
        assert len(art["args"]) == 11
        assert [r["dtype"] for r in art["results"]] == ["i32", "i32", "f32"]
        assert (aot_dir / art["path"]).exists()


def test_hlo_is_text(aot_dir):
    """The artifact must be parseable HLO text (the 0.5.1-compat format)."""
    with open(aot_dir / "voltage_opt_prop.hlo.txt") as f:
        head = f.read(4096)
    assert head.startswith("HloModule"), head[:80]
    assert "ENTRY" in head or "ENTRY" in open(aot_dir / "voltage_opt_prop.hlo.txt").read()


def test_aot_is_deterministic(aot_dir, tmp_path):
    """Same sources -> byte-identical HLO (cache-friendly `make artifacts`)."""
    out2 = tmp_path / "again"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out2), "--skip-dnn"],
        cwd=PYROOT,
        check=True,
    )
    a = (aot_dir / "voltage_opt_prop.hlo.txt").read_text()
    b = (out2 / "voltage_opt_prop.hlo.txt").read_text()
    assert a == b
