//! Workload predictor study (paper §IV.A / Fig. 8).
//!
//!     cargo run --release --example workload_prediction
//!
//! Compares the Markov-chain predictor against periodic/EWMA/last-value
//! baselines on four workload shapes, reporting exact-bin accuracy and
//! QoS coverage (prediction + 5% margin >= actual).

use wavescale::markov::{
    EwmaPredictor, LastValuePredictor, MarkovPredictor, PeriodicPredictor, Predictor,
};
use wavescale::report::{row, table};
use wavescale::workload;

fn evaluate(p: &mut dyn Predictor, loads: &[f64], warmup: usize) -> (f64, f64) {
    let bins = 10.0;
    let bin_of = |x: f64| ((x.clamp(0.0, 1.0) * bins).ceil() as usize).clamp(1, 10) - 1;
    let mut exact = 0usize;
    let mut covered = 0usize;
    let mut total = 0usize;
    for (i, &load) in loads.iter().enumerate() {
        if i > warmup {
            total += 1;
            let pred = p.predict();
            if bin_of(pred) == bin_of(load) {
                exact += 1;
            }
            if pred * 1.05 + 1.0 / bins >= load {
                covered += 1;
            }
        }
        p.observe(load);
    }
    (exact as f64 / total as f64, covered as f64 / total as f64)
}

fn main() {
    let steps = 4000;
    let traces = vec![
        workload::bursty(&workload::BurstyConfig { steps, ..Default::default() }),
        workload::periodic(steps, 96, 0.15, 0.85, 0.03, 11),
        workload::poisson(steps, 0.4, 1000.0, 12),
        workload::square(steps, 60, 0.2, 0.8),
    ];

    for trace in traces {
        let stats = trace.measured_stats(1000.0);
        println!(
            "\n{} | mean {:.2} | Hurst(R/S) {:.2} | IDC {:.0}",
            trace.label, stats.mean_load, stats.hurst_rs, stats.idc
        );
        let mut rows = vec![row(["predictor", "exact-bin", "coverage(+5%)"])];
        let mut predictors: Vec<Box<dyn Predictor>> = vec![
            Box::new(MarkovPredictor::new(10, 20)),
            Box::new(PeriodicPredictor::new(96)),
            Box::new(EwmaPredictor::new(0.3)),
            Box::new(LastValuePredictor::default()),
        ];
        for p in predictors.iter_mut() {
            let (exact, covered) = evaluate(p.as_mut(), &trace.loads, 20);
            rows.push(vec![
                p.name().to_string(),
                format!("{:.1}%", exact * 100.0),
                format!("{:.1}%", covered * 100.0),
            ]);
        }
        print!("{}", table(&rows));
    }
    println!("\nworkload_prediction OK");
}
