//! Policy comparison across all five Table I accelerators — the Table II
//! experiment as a runnable example.
//!
//!     cargo run --release --example policy_comparison
//!
//! Runs Proposed / core-only / bram-only / power-gating / oracle over the
//! same bursty 40%-mean workload and prints the per-benchmark power gains
//! next to the paper's numbers.

use wavescale::arch::TABLE1;
use wavescale::platform::{build_platform, PlatformConfig, Policy};
use wavescale::report::{row, table};
use wavescale::vscale::Mode;
use wavescale::workload::{bursty, BurstyConfig};

fn main() -> Result<(), String> {
    let trace = bursty(&BurstyConfig { steps: 1200, ..Default::default() });
    println!(
        "workload: {} steps, mean load {:.3} (paper: 40% average, H=0.76)\n",
        trace.len(),
        trace.mean()
    );

    // Paper Table II for side-by-side comparison.
    let paper: &[(&str, f64, f64, f64)] = &[
        ("tabla", 4.1, 2.9, 2.7),
        ("dnnweaver", 4.4, 2.9, 2.9),
        ("diannao", 3.9, 3.1, 1.9),
        ("stripes", 3.9, 3.1, 1.8),
        ("proteus", 3.8, 3.1, 2.0),
    ];

    let mut rows = vec![row([
        "benchmark", "prop", "(paper)", "core-only", "(paper)", "bram-only", "(paper)", "pg",
        "oracle",
    ])];
    let mut sums = [0.0f64; 5];
    for spec in TABLE1 {
        let run = |policy: Policy| -> Result<f64, String> {
            let mut p = build_platform(spec.name, PlatformConfig::default(), policy)?;
            Ok(p.run(&trace.loads).power_gain)
        };
        let prop = run(Policy::Dvfs(Mode::Proposed))?;
        let core = run(Policy::Dvfs(Mode::CoreOnly))?;
        let bram = run(Policy::Dvfs(Mode::BramOnly))?;
        let pg = run(Policy::PowerGating)?;
        let oracle = run(Policy::DvfsOracle(Mode::Proposed))?;
        let (_, pp, pc, pb) = *paper.iter().find(|(n, ..)| *n == spec.name).unwrap();
        rows.push(vec![
            spec.name.to_string(),
            format!("{prop:.2}x"),
            format!("{pp:.1}x"),
            format!("{core:.2}x"),
            format!("{pc:.1}x"),
            format!("{bram:.2}x"),
            format!("{pb:.1}x"),
            format!("{pg:.2}x"),
            format!("{oracle:.2}x"),
        ]);
        for (i, v) in [prop, core, bram, pg, oracle].into_iter().enumerate() {
            sums[i] += v / TABLE1.len() as f64;
        }
    }
    rows.push(vec![
        "average".into(),
        format!("{:.2}x", sums[0]),
        "4.0x".into(),
        format!("{:.2}x", sums[1]),
        "3.0x".into(),
        format!("{:.2}x", sums[2]),
        "2.3x".into(),
        format!("{:.2}x", sums[3]),
        format!("{:.2}x", sums[4]),
    ]);
    print!("{}", table(&rows));

    println!(
        "\nproposed vs best single-rail: +{:.1}% (paper: +33.6%)",
        (sums[0] / sums[1] - 1.0) * 100.0
    );
    Ok(())
}
