//! END-TO-END driver: the full three-layer stack on a real workload.
//!
//!     make artifacts && cargo run --release --example e2e_serving
//!
//! Proves all layers compose (recorded in EXPERIMENTS.md §E2E):
//!   L1  Pallas kernels (vgrid, matmul) — inside the AOT'd HLO,
//!   L2  JAX model (voltage_optimize, dnn_* variants) — `artifacts/`,
//!   L3  rust coordinator — PJRT execution, batching, DVFS epochs.
//!
//! The run: load every DNN artifact, golden-check numerics, then serve a
//! bursty request stream against `dnn_tabla` on simulated FPGA instances
//! while the Central Controller drives frequency/voltage through the
//! AOT'd Pallas Voltage Selector. Reports throughput, latency, and the
//! measured power gain vs a nominal-voltage platform.

use std::time::{Duration, Instant};

use wavescale::coordinator::{Coordinator, ServingConfig};
use wavescale::platform::{build_platform, PlatformConfig, Policy};
use wavescale::runtime::{DnnClient, Engine};
use wavescale::util::prng::Rng;
use wavescale::vscale::Mode;
use wavescale::workload::{bursty, BurstyConfig};

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from(
        std::env::var("WAVESCALE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );

    // ---- 1. verify every artifact's numerics against python goldens ----
    let engine = Engine::open(&dir)?;
    println!(
        "PJRT {} | {} artifacts (jax {})",
        engine.platform_name(),
        engine.manifest.artifacts.len(),
        engine.manifest.jax_version
    );
    for variant in engine.manifest.dnn_variants() {
        let dnn = DnnClient::new(&engine, &variant)?;
        let err = dnn.verify_golden(&engine)?;
        anyhow::ensure!(err < 1e-3, "dnn_{variant} golden check failed ({err:.2e})");
        println!("  dnn_{variant:<10} golden max rel err {err:.1e} OK");
    }
    drop(engine);

    // ---- 2. serve a bursty stream with DVFS --------------------------
    let variant = "tabla";
    let platform = build_platform(variant, PlatformConfig::default(), Policy::Dvfs(Mode::Proposed))
        .map_err(anyhow::Error::msg)?;
    let cfg = ServingConfig {
        variant: variant.into(),
        n_instances: 2,
        epoch: Duration::from_millis(250),
        mode: Mode::Proposed,
        selector_via_pjrt: true,
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        dir,
        platform.design.clone(),
        platform.optimizer_ref().clone(),
    )?;

    // Offered load follows a bursty trace, one trace step per epoch.
    let trace = bursty(&BurstyConfig { steps: 24, mean_load: 0.4, ..Default::default() });
    let mut rng = Rng::new(7);
    let peak_rps = 4_000.0;
    let epoch = Duration::from_millis(250);
    println!("\nserving dnn_{variant}: 2 instances, {} epochs, peak {peak_rps} rps", trace.len());

    let t0 = Instant::now();
    let mut submitted = 0u64;
    let mut rejected = 0u64;
    for &load in &trace.loads {
        let target = (load.max(0.02) * peak_rps * epoch.as_secs_f64()) as usize;
        // Submit in bursts of 16 so sleep granularity doesn't cap the
        // offered rate; the epoch pacing stays accurate.
        let bursts = target.div_ceil(16).max(1);
        let gap = epoch / bursts as u32;
        let epoch_start = Instant::now();
        for b in 0..bursts {
            let n = (target - b * 16).min(16);
            for _ in 0..n {
                match coord.submit(rng.normal_vec_f32(coord.in_dim)) {
                    Ok(_) => submitted += 1,
                    Err(_) => rejected += 1,
                }
            }
            std::thread::sleep(gap);
        }
        // Keep epochs aligned even if submission ran long (sample the
        // elapsed time once; a re-sample can exceed `epoch` and underflow).
        let elapsed = epoch_start.elapsed();
        std::thread::sleep(epoch.saturating_sub(elapsed));
    }
    // Drain.
    std::thread::sleep(Duration::from_millis(500));
    let wall = t0.elapsed();
    let (stats, records) = coord.shutdown()?;

    // ---- 3. report ----------------------------------------------------
    println!("\n== E2E results ==");
    println!(
        "  wall {:.1} s | submitted {submitted} | completed {} | rejected {} ({} backpressure)",
        wall.as_secs_f64(),
        stats.completed,
        rejected,
        stats.rejected
    );
    println!(
        "  throughput {:.0} req/s | latency mean {:.1} ms p50 {:.1} ms p99 {:.1} ms",
        stats.completed as f64 / wall.as_secs_f64(),
        stats.mean_latency_s * 1e3,
        stats.p50_latency_s * 1e3,
        stats.p99_latency_s * 1e3
    );
    println!(
        "  energy {:.2} J vs nominal {:.2} J -> measured power gain {:.2}x over {} epochs",
        stats.energy_j, stats.nominal_energy_j, stats.power_gain, stats.epochs
    );
    println!("\n  epoch trace (CC decisions through the AOT'd Voltage Selector):");
    for r in &records {
        println!(
            "    {:>3}: load {:.2} -> predicted {:.2} | f/fnom {:.2} | Vcore {:.3} Vbram {:.3} | {:.2} W",
            r.epoch, r.load, r.predicted, r.freq_ratio, r.vcore, r.vbram, r.power_w
        );
    }

    anyhow::ensure!(stats.completed > 0, "no requests served");
    anyhow::ensure!(stats.power_gain > 1.0, "DVFS must beat nominal");
    println!("\ne2e_serving OK");
    Ok(())
}
