//! Live multi-tenant fleet serving: a ≥2-group mixed-tenant scenario
//! through the sharded coordinator.
//!
//!     cargo run --release --example fleet_serving
//!     WAVESCALE_SCENARIO=flash-crowd cargo run --release --example fleet_serving
//!     WAVESCALE_VIRTUAL=1 cargo run --release --example fleet_serving
//!
//! With `WAVESCALE_VIRTUAL=1` the fleet runs on the deterministic
//! [`VirtualClock`](wavescale::clock::VirtualClock): the same 16-epoch
//! scenario replays in milliseconds of wall time and reruns are
//! bit-identical (DESIGN.md S18).
//!
//! One `FleetServing` coordinator serves several benchmark groups (Tabla +
//! DianNao + Stripes for the default mixed-tenant scenario) concurrently:
//! per-instance bounded shard queues with least-loaded dispatch and work
//! stealing, one DVFS domain (Markov predictor + voltage LUT) per group,
//! and a shared fleet-level metrics/report surface. Inference runs through
//! PJRT when `make artifacts` output is present and falls back to the
//! deterministic native backend otherwise, so this example runs anywhere.
//!
//! The run drives one scenario step per DVFS epoch and finishes with the
//! fleet report: per-group throughput, latency, power gain, and QoS
//! violation rate.

use std::sync::Arc;
use std::time::{Duration, Instant};

use wavescale::clock::{self, ActorScope, Clock, VirtualClock};
use wavescale::coordinator::{
    drive_scenario, fleet_report_rows, FleetServing, FleetServingConfig, GroupConfig,
};
use wavescale::report::table;
use wavescale::workload::Scenario;

fn main() -> anyhow::Result<()> {
    let scenario_name =
        std::env::var("WAVESCALE_SCENARIO").unwrap_or_else(|_| "mixed-tenant".into());
    let virtual_time = std::env::var("WAVESCALE_VIRTUAL").as_deref() == Ok("1");
    // Virtual-time replays are bit-identical per seed only if they cannot
    // depend on installed artifacts: force the native backend like simtest.
    let artifacts = std::path::PathBuf::from(if virtual_time {
        "sim-no-artifacts".to_string()
    } else {
        std::env::var("WAVESCALE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
    });
    let clock: Arc<dyn Clock> = if virtual_time {
        Arc::new(VirtualClock::new())
    } else {
        clock::wall()
    };
    let _driver = virtual_time.then(|| ActorScope::enter(&clock, "example-driver"));
    let epochs = 16usize;
    let epoch = Duration::from_millis(150);
    let peak_rps = 4_000.0;
    let n_instances = 2usize;

    // One scenario step per DVFS epoch.
    let scenario = Scenario::by_name(&scenario_name, epochs, 7)
        .map_err(anyhow::Error::msg)?;
    anyhow::ensure!(scenario.tenants.len() >= 2, "need a multi-tenant scenario");

    let cfg = FleetServingConfig {
        groups: scenario
            .tenants
            .iter()
            .map(|t| GroupConfig {
                benchmark: t.benchmark.clone(),
                share: t.share,
                n_instances,
                // Tenant QoS tiers (tiered-tenants scenario) refine the
                // guardband only when a run-level target is set; this
                // example keeps the static margin, so they stay inert.
                qos_target: t.qos_target,
            })
            .collect(),
        epoch,
        selector_via_pjrt: !virtual_time,
        clock: clock.clone(),
        ..Default::default()
    };
    let fleet = FleetServing::start(cfg, artifacts)?;
    println!(
        "scenario {scenario_name}: {} | {} groups x {n_instances} instances, {epochs} epochs @ {} ms{}",
        scenario.description,
        scenario.tenants.len(),
        epoch.as_millis(),
        if virtual_time { " (virtual time)" } else { "" }
    );

    // ---- drive the scenario (shared driver, one step per epoch) ------
    let t0 = Instant::now();
    let submitted = drive_scenario(&fleet, &scenario, peak_rps, 42);
    let wall = t0.elapsed();
    let report = fleet.shutdown()?;

    // ---- fleet report -------------------------------------------------
    println!("\n== fleet report ({:.1} s wall, {submitted} submitted) ==", wall.as_secs_f64());
    print!("{}", table(&fleet_report_rows(&report.stats)));
    let s = &report.stats;
    println!(
        "energy {:.2} J vs nominal {:.2} J over {} epochs",
        s.energy_j, s.nominal_energy_j, s.epochs
    );

    println!("\nper-group CC traces (first 4 epochs):");
    for (g, recs) in report.stats.per_group.iter().zip(&report.epoch_records) {
        for r in recs.iter().take(4) {
            println!(
                "  {:<10} epoch {:>2}: load {:.2} predicted {:.2} f/fnom {:.2} Vcore {:.3} Vbram {:.3} active {}/{} {:.2} W",
                g.name, r.epoch, r.load, r.predicted, r.freq_ratio, r.vcore, r.vbram,
                r.n_active, g.n_instances, r.power_w
            );
        }
    }

    anyhow::ensure!(s.completed > 0, "no requests served");
    anyhow::ensure!(
        report.stats.per_group.len() >= 2,
        "fleet must serve at least two groups"
    );
    println!("\nfleet_serving OK");
    Ok(())
}
