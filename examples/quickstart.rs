//! Quickstart: build the full stack for one benchmark and run the paper's
//! DVFS framework over a bursty workload.
//!
//!     cargo run --release --example quickstart
//!
//! Walks the public API end to end: characterization library → benchmark
//! netlist → STA → power model → voltage optimizer → LUT → platform
//! simulation, and prints the headline power gain.

use wavescale::arch::{BenchmarkSpec, DeviceFamily};
use wavescale::chars::{CharLibrary, ResourceClass};
use wavescale::netlist::gen::{generate, GenConfig};
use wavescale::platform::{build_platform, PlatformConfig, Policy};
use wavescale::power::{DesignPower, PowerParams};
use wavescale::sta::{analyze, DelayParams};
use wavescale::vscale::{Mode, Optimizer, VoltageLut};
use wavescale::workload::{bursty, BurstyConfig};

fn main() -> Result<(), String> {
    // 1. The pre-characterized library (COFFE substitute): delay & power
    //    vs voltage for each resource class (paper Figs. 1-3).
    let chars = CharLibrary::stratix_iv_22nm();
    println!("characterization (22nm, 45C):");
    for class in ResourceClass::ALL {
        println!(
            "  {:<8} delay x{:.2} @0.65V | static x{:.2} @0.65V-rail",
            class.name(),
            chars.delay_scale(class, if class.on_bram_rail() { 0.80 } else { 0.65 }),
            chars.static_scale(class, if class.on_bram_rail() { 0.80 } else { 0.65 }),
        );
    }

    // 2. A Table I benchmark: synthesize its netlist, run STA.
    let spec = BenchmarkSpec::by_name("tabla").unwrap();
    let net = generate(spec, &GenConfig { scale: 0.05, seed: 2019, luts_per_lab: 10 });
    let timing = analyze(&net, &DelayParams::default(), 8)?;
    println!(
        "\ntabla: fmax {:.1} MHz (Table I: {:.0}), alpha {:.2}",
        timing.fmax_mhz,
        spec.freq_mhz,
        timing.cp.alpha()
    );

    // 3. Power model on the VTR-sized device; rail tables for Eq. (1)-(3).
    let design = DesignPower::from_spec(
        spec,
        &DeviceFamily::stratix_iv(),
        chars.clone(),
        PowerParams::default(),
    )?;
    let nominal = design.nominal();
    println!(
        "power: {:.2} W nominal (beta {:.2}, gamma_l {:.2})",
        nominal.total_w(),
        nominal.beta(),
        nominal.gamma_l()
    );

    // 4. The core contribution: minimum-power (Vcore, Vbram) at 40% load.
    let tables = design.rail_tables(&timing.cp);
    let opt = Optimizer::new(chars.grid(), tables).with_paths(&chars, timing.top_paths.clone());
    let pt = opt.optimize(2.5, Mode::Proposed);
    println!(
        "at 40% workload: Vcore {:.3} V, Vbram {:.3} V -> {:.1}% of nominal power",
        pt.vcore,
        pt.vbram,
        pt.power_norm * 100.0
    );

    // 5. Synthesis-time LUT (what the Central Controller stores).
    let lut = VoltageLut::build(&opt, 10, 0.05, Mode::Proposed);
    println!("LUT: {} bins, top bin freq ratio {:.2}", lut.m_bins(), lut.entries[9].freq_ratio);

    // 6. Simulate the multi-FPGA platform on a bursty 40%-mean workload.
    let trace = bursty(&BurstyConfig { steps: 600, ..Default::default() });
    let mut platform = build_platform("tabla", PlatformConfig::default(), Policy::Dvfs(Mode::Proposed))?;
    let report = platform.run(&trace.loads);
    println!(
        "\nsimulated {} steps (mean load {:.2}): power gain {:.2}x, QoS violations {:.1}%",
        trace.len(),
        trace.mean(),
        report.power_gain,
        report.violation_rate * 100.0
    );
    assert!(report.power_gain > 2.0, "expected a clear win over nominal");
    println!("quickstart OK");
    Ok(())
}
