//! Model-thread spawn/join/yield.

use crate::rt;

/// Handle to a spawned model thread.
pub struct JoinHandle<T> {
    tid: usize,
    os: Option<std::thread::JoinHandle<std::thread::Result<T>>>,
}

impl<T> JoinHandle<T> {
    /// Cooperatively wait for the thread to finish and return its result.
    pub fn join(mut self) -> std::thread::Result<T> {
        rt::join_wait(self.tid);
        self.os
            .take()
            .expect("join called twice")
            .join()
            .expect("model OS thread vanished")
    }
}

/// Spawn a model thread. The closure does not run until the scheduler
/// grants it a turn, so the spawn itself is an explored decision point.
pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    let (exec, tid) = rt::register_thread();
    let os = std::thread::spawn(move || rt::run_thread(exec, tid, f));
    rt::post_spawn();
    JoinHandle { tid, os: Some(os) }
}

/// Deschedule the caller until another runnable thread has executed at
/// least one operation (loom's spin-loop pruning semantics).
pub fn yield_now() {
    rt::yield_now();
}
