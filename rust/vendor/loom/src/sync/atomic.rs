//! Instrumented atomics. Every operation is a scheduling point; the value
//! itself lives in the matching `std` atomic and is accessed with `SeqCst`
//! regardless of the ordering the caller passes (see crate docs: the
//! checker explores interleavings under sequential consistency, not
//! weak-memory reorderings).

use crate::rt;

pub use std::sync::atomic::Ordering;

/// Instrumented memory fence (scheduling point + `SeqCst` fence).
pub fn fence(_order: Ordering) {
    rt::op();
    std::sync::atomic::fence(Ordering::SeqCst);
}

macro_rules! atomic_int {
    ($name:ident, $ty:ty) => {
        /// Instrumented integer atomic (see module docs).
        #[derive(Debug, Default)]
        pub struct $name(std::sync::atomic::$name);

        impl $name {
            /// Create a new atomic with the given initial value.
            pub fn new(v: $ty) -> Self {
                $name(std::sync::atomic::$name::new(v))
            }

            /// Load the value.
            pub fn load(&self, _order: Ordering) -> $ty {
                rt::op();
                self.0.load(Ordering::SeqCst)
            }

            /// Store a value.
            pub fn store(&self, v: $ty, _order: Ordering) {
                rt::op();
                self.0.store(v, Ordering::SeqCst)
            }

            /// Swap in a value, returning the previous one.
            pub fn swap(&self, v: $ty, _order: Ordering) -> $ty {
                rt::op();
                self.0.swap(v, Ordering::SeqCst)
            }

            /// Add, returning the previous value.
            pub fn fetch_add(&self, v: $ty, _order: Ordering) -> $ty {
                rt::op();
                self.0.fetch_add(v, Ordering::SeqCst)
            }

            /// Subtract, returning the previous value.
            pub fn fetch_sub(&self, v: $ty, _order: Ordering) -> $ty {
                rt::op();
                self.0.fetch_sub(v, Ordering::SeqCst)
            }

            /// Maximum, returning the previous value.
            pub fn fetch_max(&self, v: $ty, _order: Ordering) -> $ty {
                rt::op();
                self.0.fetch_max(v, Ordering::SeqCst)
            }

            /// Minimum, returning the previous value.
            pub fn fetch_min(&self, v: $ty, _order: Ordering) -> $ty {
                rt::op();
                self.0.fetch_min(v, Ordering::SeqCst)
            }

            /// Compare-and-exchange.
            pub fn compare_exchange(
                &self,
                current: $ty,
                new: $ty,
                _success: Ordering,
                _failure: Ordering,
            ) -> Result<$ty, $ty> {
                rt::op();
                self.0.compare_exchange(current, new, Ordering::SeqCst, Ordering::SeqCst)
            }

            /// Weak compare-and-exchange; never fails spuriously under the
            /// checker (callers loop on failure anyway).
            pub fn compare_exchange_weak(
                &self,
                current: $ty,
                new: $ty,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$ty, $ty> {
                self.compare_exchange(current, new, success, failure)
            }

            /// Consume the atomic, returning the value (no scheduling
            /// point: requires unique ownership).
            pub fn into_inner(self) -> $ty {
                self.0.into_inner()
            }
        }
    };
}

atomic_int!(AtomicUsize, usize);
atomic_int!(AtomicU64, u64);

/// Instrumented boolean atomic (see module docs).
#[derive(Debug, Default)]
pub struct AtomicBool(std::sync::atomic::AtomicBool);

impl AtomicBool {
    /// Create a new atomic with the given initial value.
    pub fn new(v: bool) -> Self {
        AtomicBool(std::sync::atomic::AtomicBool::new(v))
    }

    /// Load the value.
    pub fn load(&self, _order: Ordering) -> bool {
        rt::op();
        self.0.load(Ordering::SeqCst)
    }

    /// Store a value.
    pub fn store(&self, v: bool, _order: Ordering) {
        rt::op();
        self.0.store(v, Ordering::SeqCst)
    }

    /// Swap in a value, returning the previous one.
    pub fn swap(&self, v: bool, _order: Ordering) -> bool {
        rt::op();
        self.0.swap(v, Ordering::SeqCst)
    }

    /// Logical AND, returning the previous value.
    pub fn fetch_and(&self, v: bool, _order: Ordering) -> bool {
        rt::op();
        self.0.fetch_and(v, Ordering::SeqCst)
    }

    /// Logical OR, returning the previous value.
    pub fn fetch_or(&self, v: bool, _order: Ordering) -> bool {
        rt::op();
        self.0.fetch_or(v, Ordering::SeqCst)
    }

    /// Compare-and-exchange.
    pub fn compare_exchange(
        &self,
        current: bool,
        new: bool,
        _success: Ordering,
        _failure: Ordering,
    ) -> Result<bool, bool> {
        rt::op();
        self.0.compare_exchange(current, new, Ordering::SeqCst, Ordering::SeqCst)
    }

    /// Weak compare-and-exchange; never fails spuriously.
    pub fn compare_exchange_weak(
        &self,
        current: bool,
        new: bool,
        success: Ordering,
        failure: Ordering,
    ) -> Result<bool, bool> {
        self.compare_exchange(current, new, success, failure)
    }
}
