//! Spin-loop hint: under the checker a spin must yield, or a schedule
//! that keeps running the spinner would never terminate.

/// Scheduling hint used inside spin loops; equivalent to
/// [`crate::thread::yield_now`].
pub fn spin_loop() {
    crate::thread::yield_now();
}
