//! Offline, API-compatible subset of the [loom] concurrency model checker.
//!
//! The build environment is fully offline, so the real crates.io `loom`
//! cannot be pulled in; this vendored stand-in implements the part of its
//! API that `wavescale`'s `crate::sync` shim re-exports, backed by a real
//! (if simpler) model checker:
//!
//! * every model thread is an OS thread driven by a **cooperative
//!   scheduler** — exactly one model thread runs at any instant, and every
//!   instrumented operation (atomic access, mutex lock/unlock, condvar
//!   wait/notify, `UnsafeCell` access window, spawn/join/yield) is a
//!   scheduling point;
//! * [`model`] runs the closure repeatedly, performing an **exhaustive
//!   depth-first search over all scheduling decisions**: each execution
//!   replays a recorded decision prefix and flips the next unexplored
//!   branch, until no unexplored branch remains. There is no iteration
//!   cap by default (`LOOM_MAX_BRANCHES=0`); `LOOM_MAX_PREEMPTIONS` can
//!   optionally bound preemptive switches the way real loom does.
//!
//! On an invariant violation (user panic, detected deadlock, overlapping
//! `UnsafeCell` access windows) the failing schedule — the sequence of
//! chosen thread ids — is printed so the interleaving can be reasoned
//! about, and [`model`] panics, failing the test.
//!
//! # Fidelity limits (vs. real loom)
//!
//! * **Sequential consistency only.** Operations execute with `SeqCst`
//!   semantics regardless of the `Ordering` passed; the checker explores
//!   all *interleavings* but not weak-memory *reorderings*, so it detects
//!   logic races (lost wakeups, over-admission, torn publication, slot
//!   aliasing) but cannot prove a `Relaxed`-vs-`Acquire` choice correct.
//!   The DESIGN.md S23 ordering table carries the pairing arguments.
//! * `compare_exchange_weak` never fails spuriously (callers loop anyway).
//! * Condvars have no spurious wakeups; `wait_timeout` "times out" only
//!   when the whole model would otherwise deadlock (every thread blocked).
//!   A protocol that silently *relies* on a timeout to recover from a lost
//!   wakeup is therefore visible to models via [`timeout_fired`].
//! * `thread::yield_now` (and the shim's `hint::spin_loop`) deschedules
//!   the caller until another runnable thread has executed at least one
//!   operation, which keeps spin loops from exploding the schedule space —
//!   the same pruning real loom applies to yields.
//!
//! [loom]: https://docs.rs/loom

pub mod cell;
pub mod hint;
mod rt;
pub mod sync;
pub mod thread;

pub use rt::{model, timeout_fired};
