//! Instrumented `UnsafeCell` with concurrent-access detection.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::rt;

/// An `UnsafeCell` whose `with`/`with_mut` access windows are tracked by
/// the checker: two overlapping windows (any writer concurrent with any
/// other access) fail the model with the offending schedule. A scheduling
/// point *inside* each window gives overlap a chance to manifest, so a
/// wrong `// SAFETY:` exclusivity argument becomes a deterministic test
/// failure instead of silent UB.
#[derive(Debug, Default)]
pub struct UnsafeCell<T> {
    data: std::cell::UnsafeCell<T>,
    readers: AtomicUsize,
    writers: AtomicUsize,
}

impl<T> UnsafeCell<T> {
    /// Wrap `value` in a cell.
    pub fn new(value: T) -> Self {
        UnsafeCell {
            data: std::cell::UnsafeCell::new(value),
            readers: AtomicUsize::new(0),
            writers: AtomicUsize::new(0),
        }
    }

    /// Run `f` with a shared raw pointer to the contents; the window must
    /// not overlap any `with_mut` window.
    pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
        rt::op();
        if self.writers.load(Ordering::SeqCst) != 0 {
            rt::fail_current("UnsafeCell: immutable access concurrent with a mutable access".into());
        }
        self.readers.fetch_add(1, Ordering::SeqCst);
        rt::op(); // let an overlapping writer run and be detected
        let out = f(self.data.get());
        self.readers.fetch_sub(1, Ordering::SeqCst);
        out
    }

    /// Run `f` with an exclusive raw pointer to the contents; the window
    /// must not overlap any other access window.
    pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
        rt::op();
        if self.writers.fetch_add(1, Ordering::SeqCst) != 0 {
            rt::fail_current("UnsafeCell: two concurrent mutable accesses".into());
        }
        if self.readers.load(Ordering::SeqCst) != 0 {
            rt::fail_current("UnsafeCell: mutable access concurrent with an immutable access".into());
        }
        rt::op(); // let an overlapping accessor run and be detected
        let out = f(self.data.get());
        self.writers.fetch_sub(1, Ordering::SeqCst);
        out
    }
}
