//! Instrumented `Mutex`/`Condvar` plus the `atomic` submodule.

use std::cell::UnsafeCell as StdUnsafeCell;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicUsize as StdAtomicUsize, Ordering as StdOrdering};
use std::sync::LockResult;
use std::time::Duration;

use crate::rt;

pub use std::sync::Arc;

pub mod atomic;

const ID_UNSET: usize = usize::MAX;

/// Lazily bind a primitive to a per-execution scheduler id. Objects are
/// created fresh inside each execution of the model closure, so the id is
/// allocated on first use and lives exactly as long as the execution.
fn bind_id(slot: &StdAtomicUsize, alloc: fn() -> usize) -> usize {
    let cur = slot.load(StdOrdering::Relaxed);
    if cur != ID_UNSET {
        return cur;
    }
    let id = alloc();
    match slot.compare_exchange(ID_UNSET, id, StdOrdering::Relaxed, StdOrdering::Relaxed) {
        Ok(_) => id,
        Err(existing) => existing,
    }
}

/// Model-checked mutual exclusion lock (cooperative; blocking a model
/// thread deschedules it, it never blocks the OS thread uncooperatively).
#[derive(Debug)]
pub struct Mutex<T> {
    id: StdAtomicUsize,
    data: StdUnsafeCell<T>,
}

// SAFETY: the scheduler guarantees at most one `MutexGuard` exists per
// mutex at a time (ownership is tracked in `ExecState::locks`), so `data`
// is never accessed concurrently.
unsafe impl<T: Send> Send for Mutex<T> {}
unsafe impl<T: Send> Sync for Mutex<T> {}

impl<T> Mutex<T> {
    /// Create a new unlocked mutex.
    pub fn new(value: T) -> Self {
        Mutex { id: StdAtomicUsize::new(ID_UNSET), data: StdUnsafeCell::new(value) }
    }

    fn lock_id(&self) -> usize {
        bind_id(&self.id, rt::alloc_lock)
    }

    /// Acquire the lock, descheduling the model thread while contended.
    /// Never returns `Err`: a panicking model thread aborts the whole
    /// execution, so poisoning is unobservable under the checker.
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        let id = self.lock_id();
        rt::lock_acquire(id);
        Ok(MutexGuard { lock: self })
    }

    /// Consume the mutex, returning the inner value.
    pub fn into_inner(self) -> LockResult<T> {
        Ok(self.data.into_inner())
    }
}

/// Scoped ownership of a [`Mutex`]; releases (a scheduling point) on drop.
#[derive(Debug)]
pub struct MutexGuard<'a, T> {
    lock: &'a Mutex<T>,
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        // SAFETY: the guard proves this model thread holds the lock.
        unsafe { &*self.lock.data.get() }
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        // SAFETY: as above; `&mut self` gives unique guard access.
        unsafe { &mut *self.lock.data.get() }
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        rt::lock_release(self.lock.lock_id());
    }
}

/// Result of a timed condvar wait; `timed_out` is true only when the
/// deadlock-timeout rule (see crate docs) released the waiter.
#[derive(Debug, Clone, Copy)]
pub struct WaitTimeoutResult(bool);

impl WaitTimeoutResult {
    /// Whether the wait ended by timeout rather than a notification.
    pub fn timed_out(&self) -> bool {
        self.0
    }
}

/// Model-checked condition variable.
#[derive(Debug)]
pub struct Condvar {
    id: StdAtomicUsize,
}

impl Condvar {
    /// Create a new condvar.
    pub fn new() -> Self {
        Condvar { id: StdAtomicUsize::new(ID_UNSET) }
    }

    fn cv_id(&self) -> usize {
        bind_id(&self.id, rt::alloc_condvar)
    }

    /// Release the guard's mutex and wait for a notification (no spurious
    /// wakeups are modeled).
    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        let lock = guard.lock;
        std::mem::forget(guard); // release happens inside condvar_wait
        rt::condvar_wait(self.cv_id(), lock.lock_id(), false);
        Ok(MutexGuard { lock })
    }

    /// Timed wait. The duration is not simulated: the wait "times out"
    /// only when every model thread is otherwise blocked, which makes a
    /// protocol that leans on timeouts to paper over lost wakeups visible
    /// via [`crate::timeout_fired`].
    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        _dur: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        let lock = guard.lock;
        std::mem::forget(guard);
        let timed_out = rt::condvar_wait(self.cv_id(), lock.lock_id(), true);
        Ok((MutexGuard { lock }, WaitTimeoutResult(timed_out)))
    }

    /// Wake one waiter (the lowest thread id, deterministically).
    pub fn notify_one(&self) {
        rt::condvar_notify(self.cv_id(), false);
    }

    /// Wake every waiter.
    pub fn notify_all(&self) {
        rt::condvar_notify(self.cv_id(), true);
    }
}
