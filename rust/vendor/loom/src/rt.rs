//! Cooperative scheduler and exhaustive schedule explorer.
//!
//! One `Execution` is one run of the model closure under one schedule.
//! Model threads are OS threads that hand a single "active" token around:
//! a thread may only perform an instrumented operation while it holds the
//! token, and every operation routes through [`Execution::transition`],
//! which picks the next thread to run. When more than one thread is
//! runnable the pick is a recorded `Decision`; [`model`] drives the
//! depth-first search by replaying a decision prefix and advancing the
//! last branch that still has unexplored alternatives.

use std::cell::RefCell;
use std::panic::{self, AssertUnwindSafe};
use std::sync::{Arc, Condvar as OsCondvar, Mutex as OsMutex, MutexGuard as OsGuard};

/// Upper bound on model threads (keeps the schedule space sane).
pub(crate) const MAX_THREADS: usize = 8;

/// Per-execution operation bound; tripping it means a loop in the model
/// makes no progress under some schedule (e.g. an un-yielding spin).
const MAX_OPS_PER_EXECUTION: usize = 1_000_000;

/// Panic payload used to unwind model threads once an execution aborts;
/// never reported as a failure itself.
pub(crate) struct AbortToken;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Run {
    /// Schedulable.
    Runnable,
    /// Descheduled by `yield_now` until another thread runs an op.
    Yielded,
    /// Waiting for a mutex (id) to be released.
    BlockedLock(usize),
    /// Waiting on a condvar (cv id, mutex id, whether the wait is timed).
    BlockedCondvar(usize, usize, bool),
    /// Waiting for a thread (id) to finish.
    BlockedJoin(usize),
    /// Returned (or unwound).
    Finished,
}

/// One scheduling decision: which of `num` runnable candidates ran.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Decision {
    /// Index into the (tid-sorted) candidate list.
    chosen: usize,
    /// Candidate count at this point (for replay validation/backtrack).
    num: usize,
    /// Chosen thread id (for failure traces).
    tid: usize,
}

struct ExecState {
    threads: Vec<Run>,
    /// Set while a condvar waiter was released by the deadlock-timeout
    /// rule rather than a notify.
    woken_by_timeout: Vec<bool>,
    /// Thread currently holding the run token (`usize::MAX` once done).
    active: usize,
    decisions: Vec<Decision>,
    /// Next decision index (replayed below `decisions.len()` at entry).
    depth: usize,
    ops: usize,
    /// Mutexes: `Some(tid)` while held.
    locks: Vec<Option<usize>>,
    condvars: usize,
    abort: bool,
    failure: Option<String>,
    timeout_fired: bool,
    preemptions: usize,
    preemption_bound: Option<usize>,
    finished: usize,
}

pub(crate) struct Execution {
    state: OsMutex<ExecState>,
    cv: OsCondvar,
}

thread_local! {
    static CURRENT: RefCell<Option<(Arc<Execution>, usize)>> = const { RefCell::new(None) };
}

fn current() -> (Arc<Execution>, usize) {
    CURRENT.with(|c| {
        c.borrow()
            .clone()
            .expect("loom primitive used outside of loom::model")
    })
}

impl Execution {
    fn new(replay: Vec<Decision>, preemption_bound: Option<usize>) -> Self {
        Execution {
            state: OsMutex::new(ExecState {
                threads: vec![Run::Runnable],
                woken_by_timeout: vec![false],
                active: 0,
                decisions: replay,
                depth: 0,
                ops: 0,
                locks: Vec::new(),
                condvars: 0,
                abort: false,
                failure: None,
                timeout_fired: false,
                preemptions: 0,
                preemption_bound,
                finished: 0,
            }),
            cv: OsCondvar::new(),
        }
    }

    /// Record a failure (first one wins) and abort the execution.
    fn fail(&self, st: &mut ExecState, msg: String) {
        if st.failure.is_none() {
            let trace: Vec<usize> = st.decisions[..st.depth].iter().map(|d| d.tid).collect();
            st.failure = Some(format!("{msg}\n  schedule (thread ids): {trace:?}"));
        }
        st.abort = true;
        self.cv.notify_all();
    }

    /// Pick the next thread to run. Called with the state lock held by a
    /// thread that has already moved itself to its new `Run` state.
    fn schedule(&self, st: &mut ExecState) {
        if st.abort {
            self.cv.notify_all();
            return;
        }
        if st.finished == st.threads.len() {
            st.active = usize::MAX;
            self.cv.notify_all();
            return;
        }
        st.ops += 1;
        if st.ops > MAX_OPS_PER_EXECUTION {
            self.fail(
                st,
                format!("execution exceeded {MAX_OPS_PER_EXECUTION} operations (unbounded spin loop in the model?)"),
            );
            return;
        }
        let mut candidates: Vec<usize>;
        loop {
            candidates = (0..st.threads.len())
                .filter(|&t| st.threads[t] == Run::Runnable)
                .collect();
            if !candidates.is_empty() {
                break;
            }
            // No plain runnable thread: promote yielded threads first,
            // then (only when the model would otherwise be stuck) fire
            // every timed condvar wait, and only then call it a deadlock.
            let yielded: Vec<usize> = (0..st.threads.len())
                .filter(|&t| st.threads[t] == Run::Yielded)
                .collect();
            if !yielded.is_empty() {
                for t in yielded {
                    st.threads[t] = Run::Runnable;
                }
                continue;
            }
            let timed: Vec<usize> = (0..st.threads.len())
                .filter(|&t| matches!(st.threads[t], Run::BlockedCondvar(_, _, true)))
                .collect();
            if !timed.is_empty() {
                for t in timed {
                    st.threads[t] = Run::Runnable;
                    st.woken_by_timeout[t] = true;
                }
                st.timeout_fired = true;
                continue;
            }
            self.fail(st, "deadlock: every model thread is blocked".to_string());
            return;
        }
        // Optional loom-style preemption bounding (LOOM_MAX_PREEMPTIONS).
        let prev = st.active;
        if let Some(bound) = st.preemption_bound {
            if st.preemptions >= bound && candidates.contains(&prev) {
                candidates = vec![prev];
            }
        }
        let chosen = if st.depth < st.decisions.len() {
            let d = st.decisions[st.depth];
            if d.num != candidates.len() {
                self.fail(
                    st,
                    format!(
                        "nondeterministic model: replay expected {} candidates at decision {}, found {}",
                        d.num,
                        st.depth,
                        candidates.len()
                    ),
                );
                return;
            }
            candidates[d.chosen]
        } else {
            st.decisions.push(Decision { chosen: 0, num: candidates.len(), tid: candidates[0] });
            candidates[0]
        };
        st.decisions[st.depth].tid = chosen;
        st.depth += 1;
        if chosen != prev && st.threads.get(prev).copied() == Some(Run::Runnable) {
            st.preemptions += 1;
        }
        // A yielded thread becomes runnable again once any *other* thread
        // has been granted an operation.
        for t in 0..st.threads.len() {
            if t != chosen && st.threads[t] == Run::Yielded {
                st.threads[t] = Run::Runnable;
            }
        }
        st.active = chosen;
        self.cv.notify_all();
    }

    /// Block the calling OS thread until it is the scheduled model thread.
    fn wait_for_turn(&self, tid: usize) {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.abort {
                drop(st);
                panic::panic_any(AbortToken);
            }
            if st.active == tid && st.threads[tid] == Run::Runnable {
                return;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// One scheduling point: move the caller to `to`, schedule, and (for
    /// non-final states) wait until the caller is scheduled again.
    fn transition(&self, tid: usize, to: Run) {
        let mut st = self.state.lock().unwrap();
        if st.abort {
            drop(st);
            panic::panic_any(AbortToken);
        }
        st.threads[tid] = to;
        self.schedule(&mut st);
        drop(st);
        if to != Run::Finished {
            self.wait_for_turn(tid);
        }
    }

    fn locked(&self) -> OsGuard<'_, ExecState> {
        self.state.lock().unwrap()
    }
}

// ---------------------------------------------------------------------------
// Hooks used by the instrumented primitive types.
// ---------------------------------------------------------------------------

/// Plain scheduling point before a shared-memory operation.
pub(crate) fn op() {
    let (exec, tid) = current();
    exec.transition(tid, Run::Runnable);
}

/// Report an invariant violation detected by a primitive (e.g. an
/// overlapping `UnsafeCell` access window) and unwind the caller.
pub(crate) fn fail_current(msg: String) -> ! {
    let (exec, tid) = current();
    {
        let mut st = exec.locked();
        exec.fail(&mut st, format!("thread {tid}: {msg}"));
    }
    panic::panic_any(AbortToken);
}

pub(crate) fn alloc_lock() -> usize {
    let (exec, _) = current();
    let mut st = exec.locked();
    st.locks.push(None);
    st.locks.len() - 1
}

pub(crate) fn alloc_condvar() -> usize {
    let (exec, _) = current();
    let mut st = exec.locked();
    st.condvars += 1;
    st.condvars - 1
}

pub(crate) fn lock_acquire(id: usize) {
    let (exec, tid) = current();
    loop {
        exec.transition(tid, Run::Runnable);
        let mut st = exec.locked();
        if st.abort {
            drop(st);
            panic::panic_any(AbortToken);
        }
        if st.locks[id].is_none() {
            st.locks[id] = Some(tid);
            return;
        }
        st.threads[tid] = Run::BlockedLock(id);
        exec.schedule(&mut st);
        drop(st);
        exec.wait_for_turn(tid);
    }
}

fn release_lock_inner(st: &mut ExecState, id: usize) {
    st.locks[id] = None;
    for t in 0..st.threads.len() {
        if st.threads[t] == Run::BlockedLock(id) {
            st.threads[t] = Run::Runnable;
        }
    }
}

pub(crate) fn lock_release(id: usize) {
    let (exec, tid) = current();
    let mut st = exec.locked();
    release_lock_inner(&mut st, id);
    if std::thread::panicking() || st.abort {
        // Guard dropped during an unwind (or after an abort): release the
        // lock so peers can proceed, but do not schedule — a second panic
        // here would abort the process.
        exec.cv.notify_all();
        return;
    }
    exec.schedule(&mut st);
    drop(st);
    exec.wait_for_turn(tid);
}

/// Condvar wait: atomically release the mutex and block; returns whether
/// the wakeup came from the deadlock-timeout rule (not a notify). The
/// caller re-acquires the mutex via [`lock_acquire`] before returning to
/// user code.
pub(crate) fn condvar_wait(cv: usize, lock: usize, timed: bool) -> bool {
    let (exec, tid) = current();
    {
        let mut st = exec.locked();
        if st.abort {
            drop(st);
            panic::panic_any(AbortToken);
        }
        release_lock_inner(&mut st, lock);
        st.woken_by_timeout[tid] = false;
        st.threads[tid] = Run::BlockedCondvar(cv, lock, timed);
        exec.schedule(&mut st);
    }
    exec.wait_for_turn(tid);
    lock_acquire(lock);
    let st = exec.locked();
    st.woken_by_timeout[tid]
}

pub(crate) fn condvar_notify(cv: usize, all: bool) {
    let (exec, tid) = current();
    {
        let mut st = exec.locked();
        if st.abort {
            drop(st);
            panic::panic_any(AbortToken);
        }
        for t in 0..st.threads.len() {
            if matches!(st.threads[t], Run::BlockedCondvar(c, _, _) if c == cv) {
                st.threads[t] = Run::Runnable;
                if !all {
                    break;
                }
            }
        }
    }
    exec.transition(tid, Run::Runnable);
}

pub(crate) fn yield_now() {
    let (exec, tid) = current();
    exec.transition(tid, Run::Yielded);
}

/// Register a new model thread; returns its id. The spawning thread then
/// passes through a scheduling point so the child is immediately eligible.
pub(crate) fn register_thread() -> (Arc<Execution>, usize) {
    let (exec, _) = current();
    let child = {
        let mut st = exec.locked();
        assert!(
            st.threads.len() < MAX_THREADS,
            "loom model spawned more than {MAX_THREADS} threads"
        );
        st.threads.push(Run::Runnable);
        st.woken_by_timeout.push(false);
        st.threads.len() - 1
    };
    (exec, child)
}

/// Scheduling point after a spawn (gives the child a chance to run).
pub(crate) fn post_spawn() {
    op();
}

/// Body wrapper for every model OS thread: waits for its first turn, runs
/// the closure, records any non-abort panic as the model failure, and
/// marks the thread finished (waking joiners).
pub(crate) fn run_thread<T>(
    exec: Arc<Execution>,
    tid: usize,
    f: impl FnOnce() -> T,
) -> std::thread::Result<T> {
    CURRENT.with(|c| *c.borrow_mut() = Some((exec.clone(), tid)));
    let result = panic::catch_unwind(AssertUnwindSafe(|| {
        exec.wait_for_turn(tid);
        f()
    }));
    let mut st = exec.locked();
    if let Err(payload) = &result {
        if !payload.is::<AbortToken>() {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic payload>".to_string());
            exec.fail(&mut st, format!("thread {tid} panicked: {msg}"));
        } else {
            st.abort = true;
        }
    }
    st.threads[tid] = Run::Finished;
    st.finished += 1;
    for t in 0..st.threads.len() {
        if st.threads[t] == Run::BlockedJoin(tid) {
            st.threads[t] = Run::Runnable;
        }
    }
    exec.schedule(&mut st);
    drop(st);
    CURRENT.with(|c| *c.borrow_mut() = None);
    result
}

/// Cooperatively wait for `target` to finish.
pub(crate) fn join_wait(target: usize) {
    let (exec, tid) = current();
    loop {
        let mut st = exec.locked();
        if st.abort {
            drop(st);
            panic::panic_any(AbortToken);
        }
        if st.threads[target] == Run::Finished {
            return;
        }
        st.threads[tid] = Run::BlockedJoin(target);
        exec.schedule(&mut st);
        drop(st);
        exec.wait_for_turn(tid);
    }
}

/// True when the current execution released a timed condvar wait via the
/// deadlock-timeout rule — i.e. a wakeup was *lost* and only the timeout
/// rescued progress. Models asserting "no lost wakeups" check this.
pub fn timeout_fired() -> bool {
    let (exec, _) = current();
    let st = exec.locked();
    st.timeout_fired
}

// ---------------------------------------------------------------------------
// The explorer.
// ---------------------------------------------------------------------------

fn env_usize(name: &str) -> Option<usize> {
    std::env::var(name).ok().and_then(|v| v.parse().ok())
}

/// Run `f` under every schedule (exhaustive DFS over scheduling
/// decisions); panics with the failing schedule on the first violation.
pub fn model<F>(f: F)
where
    F: Fn() + Sync + Send + 'static,
{
    let f = Arc::new(f);
    let preemption_bound = env_usize("LOOM_MAX_PREEMPTIONS");
    let max_branches = env_usize("LOOM_MAX_BRANCHES").unwrap_or(0);
    let mut replay: Vec<Decision> = Vec::new();
    let mut iters = 0usize;
    loop {
        iters += 1;
        let exec = Arc::new(Execution::new(replay.clone(), preemption_bound));
        let (e2, f2) = (exec.clone(), f.clone());
        let main = std::thread::spawn(move || {
            let _ = run_thread(e2, 0, move || f2());
        });
        // Wait for every model thread (including late spawns) to finish.
        {
            let mut st = exec.state.lock().unwrap();
            while st.finished < st.threads.len() {
                st = exec.cv.wait(st).unwrap();
            }
        }
        let _ = main.join();
        let st = exec.state.lock().unwrap();
        if let Some(failure) = &st.failure {
            panic!("loom: model failed on execution {iters}:\n  {failure}");
        }
        replay = st.decisions.clone();
        drop(st);
        // Backtrack: advance the deepest decision with an unexplored
        // alternative; drop fully-explored suffixes.
        loop {
            match replay.last_mut() {
                None => return, // every schedule explored
                Some(d) if d.chosen + 1 < d.num => {
                    d.chosen += 1;
                    break;
                }
                Some(_) => {
                    replay.pop();
                }
            }
        }
        if max_branches != 0 && iters >= max_branches {
            panic!("loom: LOOM_MAX_BRANCHES={max_branches} reached before the schedule space was exhausted");
        }
    }
}
