//! Offline stub of the `xla` (PJRT) binding surface.
//!
//! The real crate links `xla_extension` and executes AOT-compiled HLO on a
//! PJRT CPU client. This build environment has neither the shared library
//! nor network access, so this stub provides the exact API surface the
//! `wavescale::runtime` module compiles against while reporting
//! `unavailable` at runtime: [`PjRtClient::cpu`] returns an error, which
//! the serving coordinator detects and uses to fall back to its native
//! (pure-Rust) inference backend.
//!
//! Swapping in the real binding is a Cargo.toml change only — no source
//! edits — because every type and method signature here mirrors the
//! binding the runtime was written against.

use std::error::Error as StdError;
use std::fmt;

/// Error type mirroring the binding's; all stub operations produce it.
#[derive(Debug, Clone)]
pub struct XlaError {
    message: String,
}

impl XlaError {
    /// Build an error with the given message.
    pub fn new(message: impl Into<String>) -> Self {
        XlaError { message: message.into() }
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.message)
    }
}

impl StdError for XlaError {}

/// Result alias used throughout the stub.
pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError::new(format!(
        "{what}: PJRT runtime unavailable (offline xla stub); the serving \
         stack falls back to the native backend"
    ))
}

/// Typed storage behind a [`Literal`]. Public only because the sealed
/// [`NativeType`] conversion methods must name it; not for direct use.
#[doc(hidden)]
#[derive(Clone)]
pub enum Storage {
    /// 32-bit float elements.
    F32(Vec<f32>),
    /// 32-bit signed integer elements.
    I32(Vec<i32>),
}

impl Storage {
    fn len(&self) -> usize {
        match self {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
        }
    }
}

/// Element types the runtime moves across the host boundary.
pub trait NativeType: Copy + 'static {
    /// Short dtype tag used in error messages.
    const DTYPE: &'static str;

    /// Pack a slice into typed storage.
    #[doc(hidden)]
    fn store(values: &[Self]) -> Storage;

    /// Unpack typed storage; `None` on dtype mismatch.
    #[doc(hidden)]
    fn load(storage: &Storage) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    const DTYPE: &'static str = "f32";

    fn store(values: &[Self]) -> Storage {
        Storage::F32(values.to_vec())
    }

    fn load(storage: &Storage) -> Option<Vec<Self>> {
        match storage {
            Storage::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const DTYPE: &'static str = "i32";

    fn store(values: &[Self]) -> Storage {
        Storage::I32(values.to_vec())
    }

    fn load(storage: &Storage) -> Option<Vec<Self>> {
        match storage {
            Storage::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host-side literal (typed tensor), constructible but not executable.
pub struct Literal {
    data: Storage,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from a slice of f32 or i32 values.
    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        Literal { data: T::store(values), dims: vec![values.len() as i64] }
    }

    /// Reinterpret the literal with new dimensions (element count checked).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        let have = self.data.len();
        if n < 0 || n as usize != have {
            return Err(XlaError::new(format!(
                "reshape: {have} elements into shape {dims:?}"
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Decompose a tuple literal; the stub never produces tuples.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    /// Copy the literal out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::load(&self.data).ok_or_else(|| {
            XlaError::new(format!("to_vec: literal is not {}", T::DTYPE))
        })
    }

    /// Dimensions of the literal.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module proto (stub: path-carrying placeholder).
pub struct HloModuleProto {
    path: String,
}

impl HloModuleProto {
    /// Parse an HLO text file. The stub fails unless the file exists, to
    /// keep the error surface close to the real binding's.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        if !std::path::Path::new(path).exists() {
            return Err(XlaError::new(format!("{path}: no such file")));
        }
        Ok(HloModuleProto { path: path.to_string() })
    }

    /// Source path of the module.
    pub fn path(&self) -> &str {
        &self.path
    }
}

/// An XLA computation handle (stub placeholder).
pub struct XlaComputation {
    _path: String,
}

impl XlaComputation {
    /// Wrap a parsed module proto.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _path: proto.path.clone() }
    }
}

/// A compiled, device-loaded executable (stub: never constructible).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with host literals. Unreachable in the stub.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }

    /// Execute with device-resident buffers. Unreachable in the stub.
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// A device-resident buffer (stub: never constructible).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Fetch the buffer back to the host. Unreachable in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// The PJRT client handle.
#[derive(Clone)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Open the CPU client. Always fails in the stub — callers treat this
    /// as "PJRT unavailable" and fall back to native execution.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Platform name of the client.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation. Unreachable in the stub.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    /// Upload a host buffer to the device. Unreachable in the stub.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_round_trips_host_data() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 3]).is_err());
        let i = Literal::vec1(&[1i32, 2]);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![1, 2]);
    }

    #[test]
    fn hlo_text_requires_existing_file() {
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo").is_err());
    }
}
