//! Minimal, offline-vendored subset of the `anyhow` API.
//!
//! The build environment has no network access to crates.io, so this crate
//! provides exactly the surface the workspace uses: [`Error`], [`Result`],
//! the [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the [`Context`]
//! extension trait. Semantics follow the real crate where it matters:
//!
//! * `Error` is a boxed dynamic error that does **not** itself implement
//!   `std::error::Error` (so the blanket `From<E: std::error::Error>`
//!   conversion used by `?` cannot conflict with `From<Error>`);
//! * `Context` wraps the source error and prints a `Caused by:` chain in
//!   `{:?}` (Debug) formatting, mirroring anyhow's report format.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` — the crate's ubiquitous alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A boxed dynamic error with an optional chain of context messages.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

impl Error {
    /// Wrap any displayable message as an error value.
    pub fn msg<M>(message: M) -> Error
    where
        M: fmt::Display + Send + Sync + 'static,
    {
        Error { inner: Box::new(MessageError(message.to_string())) }
    }

    /// Create from a concrete `std::error::Error` value.
    pub fn new<E>(error: E) -> Error
    where
        E: StdError + Send + Sync + 'static,
    {
        Error { inner: Box::new(error) }
    }

    /// Attach a context message; the prior error becomes the source.
    pub fn context<C>(self, context: C) -> Error
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        Error {
            inner: Box::new(ContextError { context: context.to_string(), source: self.inner }),
        }
    }

    /// Iterate the chain of sources starting at this error.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self.inner.as_ref()) }
    }

    /// The outermost (most recently attached) message.
    pub fn root_message(&self) -> String {
        self.inner.to_string()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut source = self.inner.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(s) = source {
            write!(f, "\n    {s}")?;
            source = s.source();
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Iterator over an error's source chain (see [`Error::chain`]).
pub struct Chain<'a> {
    next: Option<&'a (dyn StdError + 'static)>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a (dyn StdError + 'static);

    fn next(&mut self) -> Option<Self::Item> {
        let cur = self.next.take()?;
        self.next = cur.source();
        Some(cur)
    }
}

struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

struct ContextError {
    context: String,
    source: Box<dyn StdError + Send + Sync + 'static>,
}

impl fmt::Display for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.context)
    }
}

impl fmt::Debug for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.context, self.source)
    }
}

impl StdError for ContextError {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        Some(self.source.as_ref())
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results
/// and options, mirroring anyhow's.
pub trait Context<T> {
    /// Attach a fixed context message to the error case.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Attach a lazily-built context message to the error case.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string (like `format!`).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_chains_and_debug_prints_causes() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "loading manifest").unwrap_err();
        assert_eq!(e.to_string(), "loading manifest");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
        assert!(dbg.contains("gone"), "{dbg}");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag must hold");
            if !flag {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert_eq!(f(false).unwrap_err().to_string(), "flag must hold");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(1u32).context("missing").unwrap(), 1);
    }
}
