#![allow(dead_code)]

//! Shared helpers for the figure/table regeneration benches.

use wavescale::chars::{CharLibrary, ResourceClass};
use wavescale::power::{OperatingParams, RailTables};
use wavescale::vscale::Optimizer;

/// Analytic rail tables for the §III motivational model: core-rail delay
/// blends logic/routing/DSP with the paper's representative weights; the
/// power tables use the given dynamic fractions.
pub fn analytic_optimizer(alpha: f64, beta: f64, gamma_l: f64, gamma_m: f64) -> Optimizer {
    let chars = CharLibrary::stratix_iv_22nm();
    let grid = chars.grid();
    let (wl, wr, wd) = (0.40, 0.55, 0.05);
    let dl = grid
        .vcore
        .iter()
        .map(|&v| {
            wl * chars.delay_scale(ResourceClass::Logic, v)
                + wr * chars.delay_scale(ResourceClass::Routing, v)
                + wd * chars.delay_scale(ResourceClass::Dsp, v)
        })
        .collect();
    let dm = grid
        .vbram
        .iter()
        .map(|&v| chars.delay_scale(ResourceClass::Bram, v))
        .collect();
    let pl_dyn = grid.vcore.iter().map(|&v| chars.dyn_scale(ResourceClass::Logic, v)).collect();
    let pl_st = grid
        .vcore
        .iter()
        .map(|&v| {
            wl * chars.static_scale(ResourceClass::Logic, v)
                + wr * chars.static_scale(ResourceClass::Routing, v)
                + wd * chars.static_scale(ResourceClass::Dsp, v)
        })
        .collect();
    let pm_dyn = grid.vbram.iter().map(|&v| chars.dyn_scale(ResourceClass::Bram, v)).collect();
    let pm_st = grid.vbram.iter().map(|&v| chars.static_scale(ResourceClass::Bram, v)).collect();
    Optimizer::new(
        grid,
        RailTables {
            dl,
            dm,
            pl_dyn,
            pl_st,
            pm_dyn,
            pm_st,
            op: OperatingParams { alpha, beta, gamma_l, gamma_m },
        },
    )
}

/// True when AOT artifacts exist (PJRT-dependent benches skip otherwise).
pub fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

/// Emit a CSV into results/ and log the path.
pub fn emit_csv(name: &str, rows: &[Vec<String>]) {
    match wavescale::report::write_results(name, &wavescale::report::csv(rows)) {
        Ok(p) => println!("[csv] {}", p.display()),
        Err(e) => eprintln!("[csv] failed to write {name}: {e}"),
    }
}
