//! Figure 1: delay of FPGA resources versus voltage.
//!
//! Regenerates the per-class delay-vs-voltage curves from the
//! characterization library and checks the paper's §III shape claims.

mod common;

use wavescale::chars::{CharLibrary, ResourceClass};
use wavescale::report::{row, table};

fn main() {
    println!("=== Figure 1: delay vs voltage ===");
    let lib = CharLibrary::stratix_iv_22nm();
    let grid = lib.grid();

    let mut rows = vec![row(["vcore", "logic", "routing", "dsp", "vbram", "memory"])];
    let mut csv = rows.clone();
    let n = grid.vbram.len();
    for i in 0..n {
        let vb = grid.vbram[i];
        let vc = grid.vcore.get(i).copied();
        let f = |x: f64| format!("{x:.3}");
        let cells = vec![
            vc.map(|v| f(v)).unwrap_or_else(|| "-".into()),
            vc.map(|v| f(lib.delay_scale(ResourceClass::Logic, v))).unwrap_or_else(|| "-".into()),
            vc.map(|v| f(lib.delay_scale(ResourceClass::Routing, v))).unwrap_or_else(|| "-".into()),
            vc.map(|v| f(lib.delay_scale(ResourceClass::Dsp, v))).unwrap_or_else(|| "-".into()),
            f(vb),
            f(lib.delay_scale(ResourceClass::Bram, vb)),
        ];
        rows.push(cells.clone());
        csv.push(cells);
    }
    print!("{}", table(&rows));
    common::emit_csv("fig1_delay.csv", &csv);

    // Paper §III shape claims.
    let mem_080 = lib.delay_scale(ResourceClass::Bram, 0.80);
    let mem_070 = lib.delay_scale(ResourceClass::Bram, 0.70);
    let logic_060 = lib.delay_scale(ResourceClass::Logic, 0.60);
    let rout_060 = lib.delay_scale(ResourceClass::Routing, 0.60);
    println!("\nshape checks (paper §III):");
    println!("  memory 0.95->0.80 V small delay effect: x{mem_080:.2} (want < 1.25)  {}",
        ok(mem_080 < 1.25));
    println!("  memory spike below ~0.75 V: x{mem_070:.2} @0.70 V (want > 1.8)      {}",
        ok(mem_070 > 1.8));
    println!("  routing tolerant vs logic @0.60 V: {rout_060:.2} vs {logic_060:.2}    {}",
        ok(logic_060 > 1.25 * rout_060));
}

fn ok(b: bool) -> &'static str {
    if b {
        "OK"
    } else {
        "MISMATCH"
    }
}
