//! Figure 6: comparing DVFS techniques across BRAM power shares
//! (β sweep at 50% workload, α = 0.2).

mod common;

use wavescale::report::{row, table};
use wavescale::vscale::Mode;

fn main() {
    println!("=== Figure 6: technique power vs beta (50% workload, alpha=0.2) ===");
    let mut rows = vec![row(["beta", "prop", "core-only", "bram-only"])];
    let mut core_gains = Vec::new();
    let mut bram_gains = Vec::new();
    for step in 0..=6 {
        let beta = 0.1 + step as f64 * 0.1;
        let opt = common::analytic_optimizer(0.2, beta, 0.7, 0.5);
        let sw = 2.0;
        let prop = opt.optimize(sw, Mode::Proposed).power_norm;
        let core = opt.optimize(sw, Mode::CoreOnly).power_norm;
        let bram = opt.optimize(sw, Mode::BramOnly).power_norm;
        core_gains.push(1.0 / core);
        bram_gains.push(1.0 / bram);
        rows.push(vec![
            format!("{beta:.1}"),
            format!("{prop:.3}"),
            format!("{core:.3}"),
            format!("{bram:.3}"),
        ]);
    }
    print!("{}", table(&rows));
    common::emit_csv("fig6_beta.csv", &rows);

    // Paper: core-only effectiveness degrades / bram-only improves as the
    // BRAM power share grows.
    let core_trend = core_gains.first().unwrap() > core_gains.last().unwrap();
    let bram_trend = bram_gains.first().unwrap() < bram_gains.last().unwrap();
    println!("\ncore-only degrades with beta: {}", ok(core_trend));
    println!("bram-only improves with beta: {}", ok(bram_trend));
}

fn ok(b: bool) -> &'static str {
    if b {
        "OK"
    } else {
        "MISMATCH"
    }
}
