//! Figure 2: dynamic power of FPGA resources versus voltage (CV²f).

mod common;

use wavescale::chars::{CharLibrary, ResourceClass};
use wavescale::report::{row, table};

fn main() {
    println!("=== Figure 2: dynamic power vs voltage ===");
    let lib = CharLibrary::stratix_iv_22nm();
    let grid = lib.grid();
    let mut rows = vec![row(["vcore", "logic", "routing", "dsp", "vbram", "memory"])];
    for i in 0..grid.vbram.len() {
        let vb = grid.vbram[i];
        let vc = grid.vcore.get(i).copied();
        let f = |x: f64| format!("{x:.3}");
        rows.push(vec![
            vc.map(|v| f(v)).unwrap_or_else(|| "-".into()),
            vc.map(|v| f(lib.dyn_scale(ResourceClass::Logic, v))).unwrap_or_else(|| "-".into()),
            vc.map(|v| f(lib.dyn_scale(ResourceClass::Routing, v))).unwrap_or_else(|| "-".into()),
            vc.map(|v| f(lib.dyn_scale(ResourceClass::Dsp, v))).unwrap_or_else(|| "-".into()),
            f(vb),
            f(lib.dyn_scale(ResourceClass::Bram, vb)),
        ]);
    }
    print!("{}", table(&rows));
    common::emit_csv("fig2_dynamic_power.csv", &rows);

    // V² sanity: half voltage -> quarter dynamic power.
    let q = lib.dyn_scale(ResourceClass::Logic, 0.40);
    println!("\nCV² check: dyn(0.40 V)/dyn(0.80 V) = {q:.3} (want 0.250)  {}",
        if (q - 0.25).abs() < 1e-9 { "OK" } else { "MISMATCH" });
}
