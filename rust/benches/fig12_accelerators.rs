//! Figure 12: power efficiency of the proposed technique across all five
//! accelerator frameworks, plus the Tabla-vs-Proteus Vbram comparison.

mod common;

use wavescale::arch::TABLE1;
use wavescale::platform::{build_platform, PlatformConfig, Policy, SimReport};
use wavescale::report::row;
use wavescale::util::stats;
use wavescale::vscale::Mode;
use wavescale::workload::{bursty, BurstyConfig};

fn main() {
    println!("=== Figure 12: proposed technique across accelerators ===");
    let trace = bursty(&BurstyConfig { steps: 1000, ..Default::default() });
    let mut reports: Vec<(String, SimReport)> = Vec::new();
    for spec in TABLE1 {
        let mut p =
            build_platform(spec.name, PlatformConfig::default(), Policy::Dvfs(Mode::Proposed))
                .unwrap();
        reports.push((spec.name.to_string(), p.run(&trace.loads)));
    }

    let mut csv = vec![{
        let mut h = vec!["step".to_string(), "load".to_string()];
        h.extend(reports.iter().map(|(n, _)| format!("gain_{n}")));
        h.push("vbram_tabla".into());
        h.push("vbram_proteus".into());
        h
    }];
    for i in 0..trace.len() {
        let mut cells = vec![i.to_string(), format!("{:.4}", trace.loads[i])];
        for (_, r) in &reports {
            cells.push(format!("{:.3}", r.nominal_power_w / r.records[i].power_w));
        }
        cells.push(format!("{:.3}", reports[0].1.records[i].vbram)); // tabla
        cells.push(format!("{:.3}", reports[4].1.records[i].vbram)); // proteus
        csv.push(cells);
    }
    common::emit_csv("fig12_accelerators.csv", &csv);

    println!("\naverage gains under the proposed technique:");
    for (name, r) in &reports {
        println!("  {name:<10} {:.2}x", r.power_gain);
    }

    // Paper: the gain trends overlap across accelerators (workload
    // dominates), yet Tabla and Proteus reach different minimum Vbram.
    let gains: Vec<f64> = reports.iter().map(|(_, r)| r.power_gain).collect();
    let spread = (stats::max(&gains) - stats::min(&gains)) / stats::mean(&gains);
    println!("\ngain spread across accelerators: {:.0}% (paper: trends nearly overlap)", spread * 100.0);
    let skip = 20;
    let vb_min = |r: &SimReport| {
        r.records[skip..]
            .iter()
            .map(|x| x.vbram)
            .fold(f64::INFINITY, f64::min)
    };
    let t = vb_min(&reports[0].1);
    let p = vb_min(&reports[4].1);
    println!(
        "min Vbram: tabla {t:.3} V vs proteus {p:.3} V — noticeably different points: {}",
        if (t - p).abs() >= 0.024 { "OK" } else { "MISMATCH" }
    );
}
