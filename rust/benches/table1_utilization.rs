//! Table I: post-place-and-route resource utilization and timing of the
//! five benchmarks — regenerated from the synthetic netlists + STA.

mod common;

use wavescale::arch::{DeviceFamily, TABLE1};
use wavescale::netlist::gen::{generate, GenConfig};
use wavescale::report::{row, table};
use wavescale::sta::{analyze, DelayParams};

fn main() {
    println!("=== Table I: utilization and timing ===");
    let family = DeviceFamily::stratix_iv();
    let mut rows = vec![row([
        "benchmark", "LAB", "DSP", "M9K", "M144K", "I/O", "Fmax(model)", "Fmax(paper)", "err%",
        "device(LABs)", "alpha",
    ])];
    let mut max_err: f64 = 0.0;
    for spec in TABLE1 {
        let net = generate(spec, &GenConfig { scale: 0.05, seed: 2019, luts_per_lab: 10 });
        let rep = analyze(&net, &DelayParams::default(), 8).expect("sta");
        let dev = family.vtr_min_device(&spec.utilization());
        let err = (rep.fmax_mhz - spec.freq_mhz).abs() / spec.freq_mhz * 100.0;
        max_err = max_err.max(err);
        rows.push(vec![
            spec.name.to_string(),
            spec.labs.to_string(),
            spec.dsps.to_string(),
            spec.m9ks.to_string(),
            spec.m144ks.to_string(),
            spec.io_pins.to_string(),
            format!("{:.1}", rep.fmax_mhz),
            format!("{:.1}", spec.freq_mhz),
            format!("{err:.1}"),
            format!("{}", dev.labs),
            format!("{:.2}", rep.cp.alpha()),
        ]);
    }
    print!("{}", table(&rows));
    common::emit_csv("table1_utilization.csv", &rows);
    println!(
        "\nworst Fmax error vs Table I: {max_err:.1}% {}",
        if max_err < 20.0 { "(within the 20% reproduction band)" } else { "MISMATCH" }
    );
}
