//! Figure 3: static power of FPGA resources versus voltage
//! (subthreshold + DIBL leakage, temperature-scaled).

mod common;

use wavescale::chars::{CharLibrary, ResourceClass};
use wavescale::report::{row, table};

fn main() {
    println!("=== Figure 3: static power vs voltage ===");
    let lib = CharLibrary::stratix_iv_22nm();
    let grid = lib.grid();
    let mut rows = vec![row(["vcore", "logic", "routing", "dsp", "vbram", "memory"])];
    for i in 0..grid.vbram.len() {
        let vb = grid.vbram[i];
        let vc = grid.vcore.get(i).copied();
        let f = |x: f64| format!("{x:.3}");
        rows.push(vec![
            vc.map(|v| f(v)).unwrap_or_else(|| "-".into()),
            vc.map(|v| f(lib.static_scale(ResourceClass::Logic, v))).unwrap_or_else(|| "-".into()),
            vc.map(|v| f(lib.static_scale(ResourceClass::Routing, v))).unwrap_or_else(|| "-".into()),
            vc.map(|v| f(lib.static_scale(ResourceClass::Dsp, v))).unwrap_or_else(|| "-".into()),
            f(vb),
            f(lib.static_scale(ResourceClass::Bram, vb)),
        ]);
    }
    print!("{}", table(&rows));
    common::emit_csv("fig3_static_power.csv", &rows);

    let mem = lib.static_scale(ResourceClass::Bram, 0.80);
    println!(
        "\npaper §III check: Vbram 0.95->0.80 V cuts BRAM static by {:.0}% (want > 75%)  {}",
        (1.0 - mem) * 100.0,
        if mem < 0.25 { "OK" } else { "MISMATCH" }
    );
    println!("temperature factor at 45C vs 25C: x{:.2}", lib.temp_leak_factor());
}
