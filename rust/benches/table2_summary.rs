//! Table II: average power-gain comparison of all techniques across the
//! five benchmarks, with the paper's numbers and efficiency deltas.

mod common;

use wavescale::arch::TABLE1;
use wavescale::platform::{build_platform, PlatformConfig, Policy};
use wavescale::report::{row, table};
use wavescale::vscale::Mode;
use wavescale::workload::{bursty, BurstyConfig};

const PAPER: &[(&str, f64, f64, f64)] = &[
    ("tabla", 4.1, 2.9, 2.7),
    ("dnnweaver", 4.4, 2.9, 2.9),
    ("diannao", 3.9, 3.1, 1.9),
    ("stripes", 3.9, 3.1, 1.8),
    ("proteus", 3.8, 3.1, 2.0),
];

fn main() {
    println!("=== Table II: power efficiency of the approaches ===");
    let trace = bursty(&BurstyConfig { steps: 1500, ..Default::default() });
    println!("workload: {} steps, mean {:.3}\n", trace.len(), trace.mean());

    let mut rows = vec![row([
        "technique", "tabla", "dnnweaver", "diannao", "stripes", "proteus", "average",
    ])];
    let mut gains = std::collections::BTreeMap::<&str, Vec<f64>>::new();
    for (label, policy) in [
        ("core-only", Policy::Dvfs(Mode::CoreOnly)),
        ("bram-only", Policy::Dvfs(Mode::BramOnly)),
        ("proposed", Policy::Dvfs(Mode::Proposed)),
    ] {
        let mut cells = vec![label.to_string()];
        let mut sum = 0.0;
        for spec in TABLE1 {
            let mut p = build_platform(spec.name, PlatformConfig::default(), policy).unwrap();
            let g = p.run(&trace.loads).power_gain;
            gains.entry(label).or_default().push(g);
            cells.push(format!("{g:.2}x"));
            sum += g;
        }
        cells.push(format!("{:.2}x", sum / TABLE1.len() as f64));
        rows.push(cells);
    }
    // Efficiency row: prop vs best single-rail per benchmark.
    let mut cells = vec!["efficiency".to_string()];
    let mut lo = f64::INFINITY;
    let mut hi: f64 = 0.0;
    for i in 0..TABLE1.len() {
        let prop = gains["proposed"][i];
        let core = gains["core-only"][i];
        let bram = gains["bram-only"][i];
        let best = core.max(bram);
        let worst = core.min(bram);
        let a = (prop / best - 1.0) * 100.0;
        let b = (prop / worst - 1.0) * 100.0;
        lo = lo.min(a);
        hi = hi.max(b);
        cells.push(format!("{a:.0}-{b:.0}%"));
    }
    cells.push(format!("{lo:.0}%-{hi:.0}%"));
    rows.push(cells);
    print!("{}", table(&rows));
    common::emit_csv("table2_summary.csv", &rows);

    println!("\npaper Table II:");
    let mut prows = vec![row(["technique", "tabla", "dnnweaver", "diannao", "stripes", "proteus", "average"])];
    for (label, idx) in [("core-only", 2usize), ("bram-only", 3), ("proposed", 1)] {
        let mut cells = vec![label.to_string()];
        let mut sum = 0.0;
        for (_, p, c, b) in PAPER {
            let v = [0.0, *p, *c, *b][idx];
            cells.push(format!("{v:.1}x"));
            sum += v;
        }
        cells.push(format!("{:.2}x", sum / PAPER.len() as f64));
        prows.push(cells);
    }
    print!("{}", table(&prows));

    let avg = |k: &str| gains[k].iter().sum::<f64>() / gains[k].len() as f64;
    println!(
        "\nheadline: proposed {:.2}x avg (paper 4.0x); vs core-only +{:.1}% (paper +33.6%); vs bram-only +{:.1}% (paper up to +83%)",
        avg("proposed"),
        (avg("proposed") / avg("core-only") - 1.0) * 100.0,
        (avg("proposed") / avg("bram-only") - 1.0) * 100.0
    );
}
