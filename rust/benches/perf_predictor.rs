//! Perf/quality baseline for the prediction subsystem (Fig. 8 extended):
//! every named scenario × every predictor configuration, both offline
//! (long-horizon `Fleet::compare_predictors`) and live on the
//! `VirtualClock` (golden-trace parameters, seed-pinned, deterministic),
//! emitting `results/BENCH_predictor.{json,csv}` — the predictor baseline
//! future PRs diff against.

mod common;

use wavescale::bench_support::section;
use wavescale::markov::PredictorKind;
use wavescale::platform::{fleet::Fleet, PlatformConfig};
use wavescale::report::{row, table};
use wavescale::simtest::{self, SimSpec};
use wavescale::util::json::Json;
use wavescale::vscale::Mode;
use wavescale::workload::Scenario;

const QOS_TARGET: f64 = 0.01;

fn main() {
    let mut runs = Vec::new();
    let mut rows = vec![row([
        "path", "scenario", "predictor", "energy_j", "gain", "violations%", "wall_ms",
    ])];
    offline_compare(&mut rows, &mut runs);
    virtual_time_sweep(&mut rows, &mut runs);
    common::emit_csv("BENCH_predictor.csv", &rows);
    let doc = Json::obj(vec![
        ("bench", Json::Str("perf_predictor".into())),
        ("qos_target", Json::Num(QOS_TARGET)),
        ("mode", Json::Str(if cfg!(debug_assertions) { "debug" } else { "release" }.into())),
        ("runs", Json::Arr(runs)),
    ]);
    match wavescale::report::write_results("BENCH_predictor.json", &doc.to_string_pretty()) {
        Ok(p) => println!("[json] {} (predictor baseline)", p.display()),
        Err(e) => eprintln!("[json] failed to write BENCH_predictor.json: {e}"),
    }
}

/// Offline simulator: 240-step named scenarios under hybrid capacity,
/// static-margin Markov vs every predictor with the adaptive guardband.
fn offline_compare(rows: &mut Vec<Vec<String>>, runs: &mut Vec<Json>) {
    section("predictors offline: static markov vs adaptive guardband (hybrid, 240 steps)");
    for s in Scenario::all(240, 2019) {
        let reports = Fleet::compare_predictors(
            &s,
            PlatformConfig::default(),
            Mode::Proposed,
            QOS_TARGET,
        )
        .expect("compare_predictors");
        for (label, r) in &reports {
            println!(
                "  {:<12} {:<22} energy {:8.1} J | gain {:.2}x | violations {:.2}%",
                s.name,
                label,
                r.energy_j(),
                r.power_gain,
                r.violation_rate * 100.0
            );
            rows.push(vec![
                "offline".into(),
                s.name.clone(),
                label.clone(),
                format!("{:.3}", r.energy_j()),
                format!("{:.3}", r.power_gain),
                format!("{:.2}", r.violation_rate * 100.0),
                "-".into(),
            ]);
            runs.push(Json::obj(vec![
                ("path", Json::Str("offline".into())),
                ("scenario", Json::Str(s.name.clone())),
                ("predictor", Json::Str(label.clone())),
                ("energy_j", Json::Num(r.energy_j())),
                ("power_gain", Json::Num(r.power_gain)),
                ("violation_rate", Json::Num(r.violation_rate)),
            ]));
        }
    }
}

/// Live coordinator on the `VirtualClock`: golden-trace parameters
/// (48 epochs, seed 2019, hybrid capacity), static Markov baseline plus
/// every predictor kind with the guardband — bit-identical per seed.
fn virtual_time_sweep(rows: &mut Vec<Vec<String>>, runs: &mut Vec<Json>) {
    section("predictors live: virtual-time sweep (4 scenarios, golden params)");
    // Warm the memoized platform builds so timed rows measure replays.
    for name in Scenario::NAMES {
        let warm = SimSpec { epochs: 1, ..SimSpec::golden(name) };
        simtest::run(&warm).expect("warmup replay");
    }
    for name in Scenario::NAMES {
        let mut specs = vec![("markov-static".to_string(), SimSpec::golden(name))];
        for kind in PredictorKind::ALL {
            specs.push((
                format!("{}+guardband", kind.name()),
                SimSpec {
                    predictor: kind,
                    qos_target: Some(QOS_TARGET),
                    ..SimSpec::golden(name)
                },
            ));
        }
        for (label, spec) in specs {
            let out = simtest::run(&spec).expect("virtual replay");
            let s = &out.report.stats;
            let wall_ms = out.wall.as_secs_f64() * 1e3;
            println!(
                "  {name:<12} {label:<22} energy {:8.3} J | gain {:.2}x | \
                 violations {:.1}% | {wall_ms:6.1} ms wall",
                s.energy_j,
                s.power_gain,
                s.violation_rate * 100.0
            );
            rows.push(vec![
                "virtual".into(),
                name.to_string(),
                label.clone(),
                format!("{:.3}", s.energy_j),
                format!("{:.3}", s.power_gain),
                format!("{:.2}", s.violation_rate * 100.0),
                format!("{wall_ms:.2}"),
            ]);
            runs.push(Json::obj(vec![
                ("path", Json::Str("virtual".into())),
                ("scenario", Json::Str(name.to_string())),
                ("predictor", Json::Str(label)),
                ("epochs", Json::Num(spec.epochs as f64)),
                ("seed", Json::Num(spec.seed as f64)),
                ("accepted", Json::Num(out.accepted as f64)),
                ("completed", Json::Num(s.completed as f64)),
                ("energy_j", Json::Num(s.energy_j)),
                ("power_gain", Json::Num(s.power_gain)),
                ("violation_rate", Json::Num(s.violation_rate)),
                ("wall_ms", Json::Num(wall_ms)),
            ]));
        }
    }
    print!("{}", table(rows));
}
