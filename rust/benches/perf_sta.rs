//! Perf: netlist generation + static timing analysis at full Table I
//! scale (stripes: ~123k LUTs).

mod common;

use wavescale::arch::BenchmarkSpec;
use wavescale::bench_support::{bench_fn, black_box, section};
use wavescale::chars::CharLibrary;
use wavescale::netlist::gen::{generate, GenConfig};
use wavescale::sta::{analyze, cp_delay_at, DelayParams};

fn main() {
    section("perf: netlist generation + STA");
    let d = DelayParams::default();
    let chars = CharLibrary::stratix_iv_22nm();

    for (name, scale) in [("tabla", 1.0), ("diannao", 1.0), ("stripes", 1.0)] {
        let spec = BenchmarkSpec::by_name(name).unwrap();
        let net = generate(spec, &GenConfig { scale, seed: 2019, luts_per_lab: 10 });
        let c = net.counts();
        println!(
            "\n{name} @scale {scale}: {} nodes, {} edges",
            net.node_count(),
            net.edges.len()
        );
        let r = bench_fn(&format!("generate {name}"), || {
            black_box(generate(spec, &GenConfig { scale, seed: 2019, luts_per_lab: 10 }))
        });
        println!("{}", r.report());
        let r = bench_fn(&format!("analyze {name} (top-8 paths)"), || {
            black_box(analyze(&net, &d, 8).unwrap())
        });
        println!("{}", r.report());
        let per_node = r.median.as_secs_f64() * 1e9 / net.node_count() as f64;
        println!("  -> {per_node:.1} ns/node ({} LUTs)", c.luts);
        let r = bench_fn(&format!("cp_delay_at {name} (full re-STA)"), || {
            black_box(cp_delay_at(&net, &d, &chars, 0.65, 0.8).unwrap())
        });
        println!("{}", r.report());
    }
}
