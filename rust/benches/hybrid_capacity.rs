//! Elastic capacity manager: DVFS-only vs PG-only vs hybrid fleet energy
//! on every named scenario — the fleet-level extension of the paper's
//! Fig. 4 (voltage scaling vs power gating vs their combination below the
//! crash-voltage floor, DESIGN.md S6.1).

mod common;

use wavescale::platform::fleet::Fleet;
use wavescale::platform::PlatformConfig;
use wavescale::report::{row, table};
use wavescale::vscale::Mode;
use wavescale::workload::Scenario;

fn main() {
    println!("=== hybrid capacity: DVFS-only vs PG-only vs hybrid (fleet epoch energy) ===");
    let mut rows = vec![row([
        "scenario", "dvfs_J", "pg_J", "hybrid_J", "hybrid_vs_dvfs", "hybrid_vs_pg",
    ])];
    let mut hybrid_always_wins = true;
    let mut strict_overnight = false;
    for s in Scenario::all(600, 2019) {
        let reports =
            Fleet::compare_capacity_policies(&s, PlatformConfig::default(), Mode::Proposed)
                .expect("scenario fleets build");
        let (dvfs, pg, hybrid) = (
            reports[0].1.energy_j(),
            reports[1].1.energy_j(),
            reports[2].1.energy_j(),
        );
        hybrid_always_wins &= hybrid <= dvfs * 1.01 && hybrid <= pg * 1.01;
        if s.name == "overnight" && hybrid < dvfs * 0.995 {
            strict_overnight = true;
        }
        rows.push(vec![
            s.name.clone(),
            format!("{dvfs:.1}"),
            format!("{pg:.1}"),
            format!("{hybrid:.1}"),
            format!("{:.3}", hybrid / dvfs),
            format!("{:.3}", hybrid / pg),
        ]);
    }
    print!("{}", table(&rows));
    common::emit_csv("hybrid_capacity.csv", &rows);

    println!("\nshape checks (paper §III taken fleet-level):");
    println!(
        "  hybrid <= min(dvfs-only, pg-only) within 1% on every scenario: {}",
        ok(hybrid_always_wins)
    );
    println!(
        "  hybrid strictly beats dvfs-only in the overnight trough: {}",
        ok(strict_overnight)
    );
}

fn ok(b: bool) -> &'static str {
    if b {
        "OK"
    } else {
        "MISMATCH"
    }
}
