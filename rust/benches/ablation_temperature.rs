//! Ablation: board temperature — the paper motivates voltage scaling
//! with "elevated temperatures near FPGA boards in data centers [that]
//! exponentially increase the leakage current" (§I). Hotter boards leak
//! more at nominal voltage, so the static-power headroom (and the win
//! over frequency-only scaling) grows with temperature.

mod common;

use wavescale::arch::{BenchmarkSpec, DeviceFamily};
use wavescale::chars::CharLibrary;
use wavescale::netlist::gen::{generate, GenConfig};
use wavescale::platform::{Platform, PlatformConfig, Policy};
use wavescale::power::{DesignPower, PowerParams};
use wavescale::report::{row, table};
use wavescale::sta::{analyze, DelayParams};
use wavescale::vscale::{Mode, Optimizer};
use wavescale::workload::{bursty, BurstyConfig};

fn run_at(temp_c: f64, loads: &[f64], mode: Mode) -> (f64, f64) {
    let mut chars = CharLibrary::stratix_iv_22nm();
    chars.temp_c = temp_c;
    let spec = BenchmarkSpec::by_name("stripes").unwrap();
    let design = DesignPower::from_spec(
        spec,
        &DeviceFamily::stratix_iv(),
        chars.clone(),
        PowerParams::default(),
    )
    .unwrap();
    let nominal = design.nominal().total_w();
    let net = generate(spec, &GenConfig { scale: 0.05, seed: 2019, luts_per_lab: 10 });
    let rep = analyze(&net, &DelayParams::default(), 8).unwrap();
    let opt = Optimizer::new(chars.grid(), design.rail_tables(&rep.cp))
        .with_paths(&chars, rep.top_paths);
    let mut platform = Platform::new(PlatformConfig::default(), design, opt, Policy::Dvfs(mode));
    (platform.run(loads).power_gain, nominal)
}

fn main() {
    println!("=== Ablation: board temperature (stripes) ===");
    let trace = bursty(&BurstyConfig { steps: 600, ..Default::default() });
    let mut rows = vec![row(["temp_C", "nominal_W", "prop_gain", "freq_only_gain"])];
    let mut gains = Vec::new();
    for t in [25.0, 45.0, 65.0, 85.0] {
        let (prop, nominal) = run_at(t, &trace.loads, Mode::Proposed);
        let (freq, _) = run_at(t, &trace.loads, Mode::FreqOnly);
        gains.push(prop / freq);
        rows.push(vec![
            format!("{t:.0}"),
            format!("{nominal:.1}"),
            format!("{prop:.3}x"),
            format!("{freq:.3}x"),
        ]);
    }
    print!("{}", table(&rows));
    common::emit_csv("ablation_temperature.csv", &rows);
    let rising = gains.windows(2).all(|w| w[1] >= w[0] - 0.02);
    println!(
        "\nvoltage scaling's edge over freq-only grows with temperature: {}",
        if rising { "OK" } else { "MISMATCH" }
    );
}
