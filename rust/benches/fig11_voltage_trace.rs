//! Figure 11: Vcore/Vbram traces of all techniques for the Fig. 10 run.

mod common;

use wavescale::platform::{build_platform, PlatformConfig, Policy, SimReport};
use wavescale::report::row;
use wavescale::util::stats;
use wavescale::vscale::Mode;
use wavescale::workload::{bursty, BurstyConfig};

fn main() {
    println!("=== Figure 11: voltage traces (Tabla, 40% avg bursty workload) ===");
    let trace = bursty(&BurstyConfig { steps: 1000, ..Default::default() });
    let run = |policy: Policy| -> SimReport {
        let mut p = build_platform("tabla", PlatformConfig::default(), policy).unwrap();
        p.run(&trace.loads)
    };
    let prop = run(Policy::Dvfs(Mode::Proposed));
    let core = run(Policy::Dvfs(Mode::CoreOnly));
    let bram = run(Policy::Dvfs(Mode::BramOnly));

    let mut csv = vec![row([
        "step", "load", "vcore_prop", "vbram_prop", "vcore_coreonly", "vbram_bramonly",
    ])];
    println!("\nstep  load   Vc(prop) Vb(prop) Vc(core) Vb(bram)  (every 50th)");
    for i in 0..trace.len() {
        csv.push(vec![
            i.to_string(),
            format!("{:.4}", trace.loads[i]),
            format!("{:.3}", prop.records[i].vcore),
            format!("{:.3}", prop.records[i].vbram),
            format!("{:.3}", core.records[i].vcore),
            format!("{:.3}", bram.records[i].vbram),
        ]);
        if i % 50 == 0 {
            println!(
                "{i:>4}  {:.2}   {:.3}    {:.3}    {:.3}    {:.3}",
                trace.loads[i],
                prop.records[i].vcore,
                prop.records[i].vbram,
                core.records[i].vcore,
                bram.records[i].vbram
            );
        }
    }
    common::emit_csv("fig11_voltage_trace.csv", &csv);

    // Paper's observation: bram-only tracks the same trend as prop's
    // Vbram, but prop keeps Vbram higher (it also scales Vcore).
    let skip = 20;
    let vb_prop: Vec<f64> = prop.records[skip..].iter().map(|r| r.vbram).collect();
    let vb_bram: Vec<f64> = bram.records[skip..].iter().map(|r| r.vbram).collect();
    let mean_prop = stats::mean(&vb_prop);
    let mean_bram = stats::mean(&vb_bram);
    println!(
        "\nmean Vbram: prop {mean_prop:.3} V vs bram-only {mean_bram:.3} V — prop stays higher: {}",
        if mean_prop >= mean_bram - 1e-9 { "OK" } else { "MISMATCH" }
    );
    let frac_ge = vb_prop
        .iter()
        .zip(&vb_bram)
        .filter(|(a, b)| **a >= **b - 1e-9)
        .count() as f64
        / vb_prop.len() as f64;
    println!("Vbram(prop) >= Vbram(bram-only) on {:.0}% of steps", frac_ge * 100.0);
}
