//! Perf: PJRT runtime — artifact compile time and inference latency per
//! served model variant. Requires `make artifacts`.

mod common;

use wavescale::bench_support::{bench_fn, black_box, section};
use wavescale::runtime::{DnnClient, Engine};
use wavescale::util::prng::Rng;

fn main() {
    section("perf: PJRT runtime");
    if !common::artifacts_available() {
        println!("(artifacts/ missing — run `make artifacts` first)");
        return;
    }
    let engine = Engine::open("artifacts").expect("engine");
    println!("platform: {}", engine.platform_name());
    let mut rng = Rng::new(1);

    for variant in engine.manifest.dnn_variants() {
        let t0 = std::time::Instant::now();
        let dnn = DnnClient::new(&engine, &variant).expect("client");
        let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
        let x = rng.normal_vec_f32(dnn.batch * dnn.in_dim);
        let r = bench_fn(&format!("dnn_{variant} infer batch={}", dnn.batch), || {
            black_box(dnn.infer(&x).unwrap())
        });
        println!("{}", r.report());
        println!(
            "  compile+load {compile_ms:.0} ms | {:.1} us/request | {:.0} req/s/instance",
            r.median.as_secs_f64() * 1e6 / dnn.batch as f64,
            dnn.batch as f64 / r.median.as_secs_f64()
        );
    }
}
