//! Ablation: predictor quality driving the DVFS loop — Markov (the
//! paper's choice) vs the oracle upper bound, across workload shapes.

mod common;

use wavescale::platform::{build_platform, PlatformConfig, Policy};
use wavescale::report::{row, table};
use wavescale::vscale::Mode;
use wavescale::workload;

fn main() {
    println!("=== Ablation: predictor vs oracle across workload shapes ===");
    let steps = 800;
    let traces = vec![
        workload::bursty(&workload::BurstyConfig { steps, ..Default::default() }),
        workload::periodic(steps, 96, 0.15, 0.85, 0.03, 9),
        workload::poisson(steps, 0.4, 1000.0, 9),
        workload::square(steps, 60, 0.2, 0.8),
    ];
    let mut rows = vec![row([
        "workload", "markov_gain", "oracle_gain", "markov/oracle", "markov_viol%",
    ])];
    for trace in traces {
        let run = |policy| {
            let mut p = build_platform("tabla", PlatformConfig::default(), policy).unwrap();
            p.run(&trace.loads)
        };
        let markov = run(Policy::Dvfs(Mode::Proposed));
        let oracle = run(Policy::DvfsOracle(Mode::Proposed));
        rows.push(vec![
            trace.label.clone(),
            format!("{:.3}x", markov.power_gain),
            format!("{:.3}x", oracle.power_gain),
            format!("{:.1}%", markov.power_gain / oracle.power_gain * 100.0),
            format!("{:.2}", markov.violation_rate * 100.0),
        ]);
    }
    print!("{}", table(&rows));
    common::emit_csv("ablation_predictor.csv", &rows);
    println!("\nthe light-weight Markov predictor should capture most of the oracle's gain");
}
