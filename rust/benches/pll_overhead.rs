//! Eq. (4)/(5): the 1-PLL vs 2-PLL energy trade-off, with the paper's own
//! constants (P_design = 20 W, P_PLL = 0.1 W, t_lock = 10 µs), plus the
//! simulator's measured stall behaviour.

mod common;

use wavescale::platform::{build_platform, PlatformConfig, Policy};
use wavescale::report::{row, table};
use wavescale::vscale::Mode;
use wavescale::workload::{bursty, BurstyConfig};

fn main() {
    println!("=== Eq. 4/5: PLL overhead ===");
    let p_design = 20.0f64;
    let p_pll = 0.1f64;
    let t_lock = 10e-6f64;

    let mut rows = vec![row([
        "tau", "one_pll_overhead_J", "two_pll_overhead_J", "winner",
    ])];
    for tau in [1e-4, 5e-4, 1e-3, 2e-3, 5e-3, 1e-2, 0.1, 1.0, 10.0] {
        // Eq. (4): per-step overhead with one PLL (stall + PLL energy).
        let one = p_design * t_lock + p_pll * (tau + t_lock);
        // Two PLLs: the second PLL burns continuously.
        let two = 2.0 * p_pll * tau;
        rows.push(vec![
            format!("{tau:>8.4} s"),
            format!("{one:.6}"),
            format!("{two:.6}"),
            if one > two { "two-PLL".into() } else { "one-PLL".to_string() },
        ]);
    }
    print!("{}", table(&rows));
    common::emit_csv("pll_overhead.csv", &rows);

    let crossover = (p_design * t_lock + p_pll * t_lock) / p_pll;
    println!(
        "\nEq. (5) crossover: P_design·t_lock + P_PLL·t_lock = P_PLL·tau  =>  tau = {:.2} ms",
        crossover * 1e3
    );
    println!(
        "note: the paper concludes two PLLs are \"always more beneficial\" for tau in seconds; \
         energetically the second PLL costs P_PLL·tau, so for tau >> {:.0} ms the dual-PLL choice \
         buys zero stall (100 µs/step) rather than energy — the simulator quantifies both below.",
        crossover * 1e3
    );

    // Measured in the simulator.
    let trace = bursty(&BurstyConfig { steps: 400, ..Default::default() });
    let mut rows = vec![row(["config", "power_gain", "stall_us_total", "pll_energy_J"])];
    for dual in [true, false] {
        let cfg = PlatformConfig { dual_pll: dual, ..Default::default() };
        let mut p = build_platform("tabla", cfg, Policy::Dvfs(Mode::Proposed)).unwrap();
        let r = p.run(&trace.loads);
        rows.push(vec![
            if dual { "dual-PLL".into() } else { "single-PLL".to_string() },
            format!("{:.3}x", r.power_gain),
            format!("{:.0}", r.stalled_us),
            format!("{:.2}", r.pll_energy_j),
        ]);
    }
    print!("{}", table(&rows));
}
