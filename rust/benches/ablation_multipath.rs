//! Ablation: single-path vs multi-path timing feasibility.
//!
//! The paper criticizes prior work for tracking only the nominal critical
//! path: "originally non-critical paths might become critical when the
//! voltage changes" (§II). Our optimizer checks the top-K STA path
//! compositions. This bench quantifies the cost of that safety (power
//! given up) and the risk of skipping it (ground-truth STA violations).

mod common;

use wavescale::arch::TABLE1;
use wavescale::chars::CharLibrary;
use wavescale::netlist::gen::{generate, GenConfig};
use wavescale::power::{DesignPower, PowerParams};
use wavescale::report::{row, table};
use wavescale::sta::{analyze, cp_delay_at, DelayParams};
use wavescale::vscale::{Mode, Optimizer};

fn main() {
    println!("=== Ablation: multi-path feasibility check ===");
    let chars = CharLibrary::stratix_iv_22nm();
    let d = DelayParams::default();
    let mut rows = vec![row([
        "benchmark", "sw", "single(Vc,Vb)", "multi(Vc,Vb)", "power_cost%", "single_violates_STA",
    ])];
    let mut any_violation = false;
    for spec in TABLE1 {
        let design = DesignPower::from_spec(
            spec,
            &wavescale::arch::DeviceFamily::stratix_iv(),
            chars.clone(),
            PowerParams::default(),
        )
        .unwrap();
        let net = generate(spec, &GenConfig { scale: 0.05, seed: 2019, luts_per_lab: 10 });
        let rep = analyze(&net, &d, 8).unwrap();
        let tables = design.rail_tables(&rep.cp);
        let single = Optimizer::new(chars.grid(), tables.clone());
        let multi = Optimizer::new(chars.grid(), tables)
            .with_paths(&chars, rep.top_paths.clone());
        for sw in [1.5, 2.5, 4.0] {
            let a = single.optimize(sw, Mode::Proposed);
            let b = multi.optimize(sw, Mode::Proposed);
            // Ground truth: full STA re-analysis at the chosen voltages.
            let truth = cp_delay_at(&net, &d, &chars, a.vcore, a.vbram).unwrap();
            let budget = rep.cp.total_ns() * sw * (1.0 + 1e-9);
            let violates = truth > budget;
            any_violation |= violates;
            rows.push(vec![
                spec.name.to_string(),
                format!("{sw:.1}"),
                format!("({:.3},{:.3})", a.vcore, a.vbram),
                format!("({:.3},{:.3})", b.vcore, b.vbram),
                format!("{:.2}", (b.power_norm / a.power_norm - 1.0) * 100.0),
                if violates { "YES".into() } else { "no".to_string() },
            ]);
        }
    }
    print!("{}", table(&rows));
    common::emit_csv("ablation_multipath.csv", &rows);
    println!(
        "\nmulti-path check cost is small; single-path STA violations observed: {}",
        if any_violation { "yes (multi-path needed)" } else { "none on these netlists (headroom held)" }
    );
}
