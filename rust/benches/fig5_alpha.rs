//! Figure 5: comparing DVFS techniques across critical-path compositions
//! (α sweep at 50% workload, β = 0.4).

mod common;

use wavescale::report::{row, table};
use wavescale::vscale::Mode;

fn main() {
    println!("=== Figure 5: technique power vs alpha (50% workload, beta=0.4) ===");
    let mut rows = vec![row([
        "alpha", "prop", "core-only", "bram-only", "vcore(prop)", "vbram(prop)",
    ])];
    let mut prop_at_zero = f64::NAN;
    let mut prop_at_half = f64::NAN;
    for step in 0..=10 {
        let alpha = step as f64 * 0.05;
        let opt = common::analytic_optimizer(alpha, 0.4, 0.7, 0.5);
        let sw = 2.0;
        let prop = opt.optimize(sw, Mode::Proposed);
        let core = opt.optimize(sw, Mode::CoreOnly).power_norm;
        let bram = opt.optimize(sw, Mode::BramOnly).power_norm;
        if step == 0 {
            prop_at_zero = prop.power_norm;
        }
        if step == 10 {
            prop_at_half = prop.power_norm;
        }
        rows.push(vec![
            format!("{alpha:.2}"),
            format!("{:.3}", prop.power_norm),
            format!("{core:.3}"),
            format!("{bram:.3}"),
            format!("{:.3}", prop.vcore),
            format!("{:.3}", prop.vbram),
        ]);
    }
    print!("{}", table(&rows));
    common::emit_csv("fig5_alpha.csv", &rows);

    // Paper: "For alpha = 0 highest power saving is achieved as the
    // proposed method can scale the voltage to the minimum possible".
    println!(
        "\nalpha=0 gives the deepest saving ({prop_at_zero:.3} vs {prop_at_half:.3} at alpha=0.5): {}",
        if prop_at_zero <= prop_at_half + 1e-9 { "OK" } else { "MISMATCH" }
    );
}
