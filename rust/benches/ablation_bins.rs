//! Ablation: Markov bin count M (DESIGN.md design choice).
//!
//! Narrower bins cut quantization waste but raise misprediction rates —
//! a real trade-off we hit during calibration (M = 16 lost 15% of the
//! gain to recovery steps). This bench maps the curve.

mod common;

use wavescale::platform::{build_platform, PlatformConfig, Policy};
use wavescale::report::{row, table};
use wavescale::vscale::Mode;
use wavescale::workload::{bursty, BurstyConfig};

fn main() {
    println!("=== Ablation: number of workload bins M ===");
    let trace = bursty(&BurstyConfig { steps: 1000, ..Default::default() });
    let mut rows = vec![row(["m_bins", "power_gain", "violations%", "mispred/step"])];
    let mut best = (0usize, 0.0f64);
    for m in [4, 6, 8, 10, 12, 16, 24, 32] {
        let cfg = PlatformConfig { m_bins: m, ..Default::default() };
        let mut p = build_platform("tabla", cfg, Policy::Dvfs(Mode::Proposed)).unwrap();
        let r = p.run(&trace.loads);
        // "Best" must respect QoS: only configs under 5% violations count.
        if r.violation_rate < 0.05 && r.power_gain > best.1 {
            best = (m, r.power_gain);
        }
        rows.push(vec![
            m.to_string(),
            format!("{:.3}x", r.power_gain),
            format!("{:.2}", r.violation_rate * 100.0),
            format!("{:.3}", r.mispredictions as f64 / trace.len() as f64),
        ]);
    }
    print!("{}", table(&rows));
    common::emit_csv("ablation_bins.csv", &rows);
    println!(
        "\nbest QoS-respecting M = {} ({:.2}x) — finer bins raise gain but blow the violation budget",
        best.0, best.1
    );
}
