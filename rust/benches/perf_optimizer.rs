//! Perf: the voltage optimizer hot path — single optimize() call, LUT
//! build, and the batched PJRT Voltage Selector (when artifacts exist).

mod common;

use wavescale::bench_support::{bench_fn, black_box, section};
use wavescale::vscale::{Mode, VoltageLut};

fn main() {
    section("perf: voltage optimizer");
    let opt = common::analytic_optimizer(0.25, 0.4, 0.7, 0.5);

    let r = bench_fn("optimize(prop) single point", || {
        black_box(opt.optimize(black_box(2.5), Mode::Proposed))
    });
    println!("{}", r.report());

    let r = bench_fn("optimize all 4 modes", || {
        for m in Mode::ALL {
            black_box(opt.optimize(black_box(2.5), m));
        }
    });
    println!("{}", r.report());

    let r = bench_fn("VoltageLut::build (10 bins)", || {
        black_box(VoltageLut::build(&opt, 10, 0.05, Mode::Proposed))
    });
    println!("{}", r.report());

    let r = bench_fn("sweep 100 workload levels", || {
        let mut acc = 0.0;
        for i in 1..=100 {
            acc += opt.optimize(1.0 / (i as f64 / 100.0), Mode::Proposed).power_norm;
        }
        black_box(acc)
    });
    println!("{}", r.report());

    if common::artifacts_available() {
        use wavescale::runtime::{Engine, OpQuery, VoltageSelectorClient};
        let engine = Engine::open("artifacts").expect("engine");
        let vs = VoltageSelectorClient::new(&engine);
        // Warm the compile cache.
        let q = OpQuery { alpha: 0.25, beta: 0.4, gamma_l: 0.7, gamma_m: 0.5, sw: 2.5 };
        vs.select(Mode::Proposed, &opt.tables, &[q]).expect("select");
        let queries: Vec<OpQuery> = (0..64)
            .map(|i| OpQuery { sw: 1.0 + i as f32 * 0.1, ..q })
            .collect();
        let r = bench_fn("PJRT voltage_opt_prop batch=64", || {
            black_box(vs.select(Mode::Proposed, &opt.tables, &queries).unwrap())
        });
        println!("{}", r.report());
        println!(
            "  -> {:.1} us per operating point (batched)",
            r.median.as_secs_f64() * 1e6 / 64.0
        );
    } else {
        println!("(artifacts/ missing — run `make artifacts` for the PJRT benches)");
    }
}
