//! Figure 4: comparing DVFS techniques across workload levels
//! (analytic §III model, α = 0.2, β = 0.4), plus Prop's chosen voltages.

mod common;

use wavescale::report::{row, table};
use wavescale::vscale::{Mode, Optimizer};

fn main() {
    println!("=== Figure 4: technique power vs workload (alpha=0.2, beta=0.4) ===");
    let opt = common::analytic_optimizer(0.2, 0.4, 0.7, 0.5);
    let mut rows = vec![row([
        "workload%", "prop", "core-only", "bram-only", "pg", "vcore(prop)", "vbram(prop)",
    ])];
    let mut prop_beats_all = true;
    let mut pg_wins_low = false;
    for w in std::iter::once(3).chain(std::iter::once(5)).chain((10..=100).step_by(5)) {
        let load = w as f64 / 100.0;
        let sw = 1.0 / load;
        let prop = opt.optimize(sw, Mode::Proposed);
        let core = opt.optimize(sw, Mode::CoreOnly).power_norm;
        let bram = opt.optimize(sw, Mode::BramOnly).power_norm;
        let pg = Optimizer::power_gating_ideal(load);
        prop_beats_all &= prop.power_norm <= core + 1e-12 && prop.power_norm <= bram + 1e-12;
        if w <= 8 && pg < prop.power_norm {
            pg_wins_low = true;
        }
        rows.push(vec![
            format!("{w}"),
            format!("{:.3}", prop.power_norm),
            format!("{core:.3}"),
            format!("{bram:.3}"),
            format!("{pg:.3}"),
            format!("{:.3}", prop.vcore),
            format!("{:.3}", prop.vbram),
        ]);
    }
    print!("{}", table(&rows));
    common::emit_csv("fig4_workload.csv", &rows);

    println!("\nshape checks (paper §III, Fig. 4):");
    println!("  prop <= single-rail at every workload: {}", ok(prop_beats_all));
    println!("  power gating wins at very low workloads (crash-voltage floor): {}", ok(pg_wins_low));

    // High-workload behaviour: >90% load leaves little slack; Prop should
    // scale Vbram first (alpha = 0.2 leaves Vbram headroom).
    let hi = opt.optimize(1.0 / 0.95, Mode::Proposed);
    println!(
        "  at 95% load prop scales Vbram ({:.3} V) before Vcore ({:.3} V): {}",
        hi.vbram,
        hi.vcore,
        ok(hi.vbram < 0.95 - 1e-9 && hi.vcore > 0.70)
    );
}

fn ok(b: bool) -> &'static str {
    if b {
        "OK"
    } else {
        "MISMATCH"
    }
}
