//! Ablation: throughput margin t (paper §IV.A uses 5%) — power cost of
//! the safety margin vs the QoS violations it prevents.

mod common;

use wavescale::platform::{build_platform, PlatformConfig, Policy};
use wavescale::report::{row, table};
use wavescale::vscale::Mode;
use wavescale::workload::{bursty, BurstyConfig};

fn main() {
    println!("=== Ablation: throughput margin t ===");
    let trace = bursty(&BurstyConfig { steps: 1000, ..Default::default() });
    let mut rows = vec![row(["margin_t", "power_gain", "violations%"])];
    let mut v_at_0 = 0.0;
    let mut v_at_10 = 0.0;
    for t in [0.0, 0.025, 0.05, 0.075, 0.10, 0.15, 0.20] {
        let cfg = PlatformConfig { margin_t: t, ..Default::default() };
        let mut p = build_platform("tabla", cfg, Policy::Dvfs(Mode::Proposed)).unwrap();
        let r = p.run(&trace.loads);
        if t == 0.0 {
            v_at_0 = r.violation_rate;
        }
        if t == 0.10 {
            v_at_10 = r.violation_rate;
        }
        rows.push(vec![
            format!("{:.1}%", t * 100.0),
            format!("{:.3}x", r.power_gain),
            format!("{:.2}", r.violation_rate * 100.0),
        ]);
    }
    print!("{}", table(&rows));
    common::emit_csv("ablation_margin.csv", &rows);
    println!(
        "\nmargin buys QoS: violations {:.1}% (t=0) -> {:.1}% (t=10%)  {}",
        v_at_0 * 100.0,
        v_at_10 * 100.0,
        if v_at_10 <= v_at_0 { "OK" } else { "MISMATCH" }
    );
}
