//! Figure 8: the Markov-chain workload predictor — transition matrix of a
//! 4-state example plus online prediction accuracy across workload shapes.

mod common;

use wavescale::markov::{MarkovPredictor, Predictor};
use wavescale::report::{row, table};
use wavescale::workload;

fn main() {
    println!("=== Figure 8: Markov workload predictor ===");

    // 4-state example as drawn in the paper.
    let mut p = MarkovPredictor::new(4, 0);
    let cycle = [0.10, 0.35, 0.60, 0.85, 0.60, 0.35];
    for i in 0..600 {
        p.observe(cycle[i % cycle.len()]);
    }
    println!("\nlearned transition matrix (4 states, cyclic workload):");
    let mut rows = vec![row(["from\\to", "S0", "S1", "S2", "S3"])];
    for (i, r) in p.transition_matrix().iter().enumerate() {
        let mut cells = vec![format!("S{i}")];
        cells.extend(r.iter().map(|x| format!("{x:.2}")));
        rows.push(cells);
    }
    print!("{}", table(&rows));

    // Accuracy across workload shapes (M = 10 bins, 5% margin).
    println!("\nprediction quality (10 bins, t = 5%):");
    let mut rows = vec![row(["workload", "exact-bin%", "coverage%", "mispred/step"])];
    let steps = 6000;
    for trace in [
        workload::bursty(&workload::BurstyConfig { steps, ..Default::default() }),
        workload::periodic(steps, 96, 0.15, 0.85, 0.03, 5),
        workload::poisson(steps, 0.4, 1000.0, 6),
        workload::square(steps, 60, 0.2, 0.8),
    ] {
        let mut p = MarkovPredictor::new(10, 20);
        let (mut exact, mut covered, mut mis, mut total) = (0, 0, 0, 0);
        for (i, &load) in trace.loads.iter().enumerate() {
            if i > 20 {
                total += 1;
                let pred = p.predict();
                if p.bin_of(pred) == p.bin_of(load) {
                    exact += 1;
                } else {
                    mis += 1;
                }
                if pred * 1.05 >= load {
                    covered += 1;
                }
            }
            p.observe(load);
        }
        rows.push(vec![
            trace.label.clone(),
            format!("{:.1}", 100.0 * exact as f64 / total as f64),
            format!("{:.1}", 100.0 * covered as f64 / total as f64),
            format!("{:.3}", mis as f64 / total as f64),
        ]);
    }
    print!("{}", table(&rows));
    common::emit_csv("fig8_markov.csv", &rows);
}
