//! Figure 10: power gains of all voltage-scaling techniques for Tabla
//! under the bursty 40%-average workload, per time step.

mod common;

use wavescale::platform::{build_platform, PlatformConfig, Policy, SimReport};
use wavescale::report::row;
use wavescale::vscale::Mode;
use wavescale::workload::{bursty, BurstyConfig};

fn main() {
    println!("=== Figure 10: Tabla power gain trace (40% avg bursty workload) ===");
    let trace = bursty(&BurstyConfig { steps: 1000, ..Default::default() });
    let stats = trace.measured_stats(1000.0);
    println!(
        "workload: mean {:.3}, Hurst(R/S) {:.2}, Hurst(VT) {:.2}, IDC {:.0} (paper: 0.40, 0.76, -, 500)",
        stats.mean_load, stats.hurst_rs, stats.hurst_vt, stats.idc
    );

    let run = |policy: Policy| -> SimReport {
        let mut p = build_platform("tabla", PlatformConfig::default(), policy).unwrap();
        p.run(&trace.loads)
    };
    let prop = run(Policy::Dvfs(Mode::Proposed));
    let core = run(Policy::Dvfs(Mode::CoreOnly));
    let bram = run(Policy::Dvfs(Mode::BramOnly));
    let pg = run(Policy::PowerGating);

    // Per-step instantaneous gain (nominal / power), decimated for print.
    let mut csv = vec![row(["step", "load", "prop", "core_only", "bram_only", "pg"])];
    println!("\nstep  load   prop   core   bram   pg   (every 50th step)");
    for i in 0..trace.len() {
        let g = |r: &SimReport| r.nominal_power_w / r.records[i].power_w;
        csv.push(vec![
            i.to_string(),
            format!("{:.4}", trace.loads[i]),
            format!("{:.3}", g(&prop)),
            format!("{:.3}", g(&core)),
            format!("{:.3}", g(&bram)),
            format!("{:.3}", g(&pg)),
        ]);
        if i % 50 == 0 {
            println!(
                "{i:>4}  {:.2}  {:5.2}  {:5.2}  {:5.2}  {:5.2}",
                trace.loads[i],
                g(&prop),
                g(&core),
                g(&bram),
                g(&pg)
            );
        }
    }
    common::emit_csv("fig10_tabla_trace.csv", &csv);

    println!("\naverage power gains (paper Fig. 10: prop 4.1x, core 2.9x, bram 2.7x):");
    for r in [&prop, &core, &bram, &pg] {
        println!("  {:<12} {:.2}x  (QoS violations {:.1}%)", r.policy, r.power_gain,
            r.violation_rate * 100.0);
    }
    let ok = prop.power_gain > core.power_gain && prop.power_gain > bram.power_gain;
    println!("\nprop dominates single-rail techniques: {}", if ok { "OK" } else { "MISMATCH" });
}
