//! Perf: multi-tenant fleet serving — two benchmark groups live on one
//! sharded coordinator, mixed-tenant offered load, fleet report at the
//! end. Runs with the PJRT backend when artifacts exist, native otherwise.
//!
//! Part two is the **virtual-time sweep**: every named scenario × every
//! capacity policy replayed deterministically on the `VirtualClock`
//! (golden-trace parameters) in one run, emitting
//! `results/BENCH_coordinator.json` — the coordinator perf baseline
//! future PRs diff against (wall ms per replay, virtual-to-wall speedup,
//! energy, completion counts).

mod common;

use std::time::{Duration, Instant};

use wavescale::bench_support::section;
use wavescale::coordinator::{FleetServing, FleetServingConfig, GroupConfig};
use wavescale::simtest::{self, SimSpec};
use wavescale::util::json::Json;
use wavescale::util::prng::Rng;
use wavescale::vscale::CapacityPolicy;
use wavescale::workload::Scenario;

fn main() {
    // `make bench-coordinator` (and CI's baseline step) sets
    // WAVESCALE_VIRTUAL_ONLY=1 to skip the wall-clock live-serving
    // section — it takes real seconds and its numbers are load-sensitive
    // on shared runners; only the virtual sweep feeds the baseline JSON.
    if std::env::var("WAVESCALE_VIRTUAL_ONLY").as_deref() != Ok("1") {
        wall_clock_serving();
    }
    virtual_time_sweep();
    batch_knob_sweep();
}

/// Part one: live wall-clock serving of a 2-group fleet (submit-path
/// µs/req + drain throughput).
fn wall_clock_serving() {
    section("perf: fleet serving (2-group mixed tenant)");
    if !common::artifacts_available() {
        println!("(artifacts/ missing — using the native inference backend)");
    }

    let cfg = FleetServingConfig {
        groups: vec![
            GroupConfig {
                benchmark: "tabla".into(),
                share: 0.5,
                n_instances: 2,
                qos_target: None,
            },
            GroupConfig {
                benchmark: "diannao".into(),
                share: 0.5,
                n_instances: 2,
                qos_target: None,
            },
        ],
        epoch: Duration::from_millis(100),
        cycles_per_batch: 1.0e4,
        queue_capacity: 16_384,
        ..Default::default()
    };
    let fleet = FleetServing::start(cfg, "artifacts".into()).expect("fleet");

    let mut rng = Rng::new(11);
    let per_group = 2_048usize;
    let payloads: Vec<(usize, Vec<f32>)> = (0..2 * per_group)
        .map(|i| {
            let gi = i % 2;
            (gi, rng.normal_vec_f32(fleet.in_dim(gi)))
        })
        .collect();

    let t0 = Instant::now();
    let mut sent = 0u64;
    for (gi, p) in &payloads {
        if fleet.submit(*gi, p.clone()).is_ok() {
            sent += 1;
        }
    }
    let submit_us = t0.elapsed().as_secs_f64() * 1e6 / payloads.len() as f64;
    println!("submit(): {submit_us:.2} us/request across 2 groups ({sent} accepted)");

    let t0 = Instant::now();
    while fleet.stats().completed < sent {
        if t0.elapsed() > Duration::from_secs(30) {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let registry_snapshot = fleet.registry().snapshot();
    let report = fleet.shutdown().expect("shutdown");
    println!(
        "drained {} requests in {wall:.2} s -> {:.0} req/s fleet-wide",
        report.stats.completed,
        report.stats.completed as f64 / wall
    );
    for g in &report.stats.per_group {
        println!(
            "  {:<10} [{}] done {} | stolen {} | p50 {:.1} ms p99 {:.1} ms | gain {:.2}x | violations {:.1}%",
            g.name,
            g.backend,
            g.completed,
            g.stolen_batches,
            g.p50_latency_s * 1e3,
            g.p99_latency_s * 1e3,
            g.power_gain,
            g.violation_rate * 100.0
        );
    }
    println!(
        "fleet gain {:.2}x | worst violation rate {:.1}% | {} epochs | registry: {registry_snapshot:?}",
        report.stats.power_gain,
        report.stats.violation_rate * 100.0,
        report.stats.epochs
    );
}

/// All named scenarios × 3 capacity policies replayed under the
/// `VirtualClock` in one run — including the adversarial fault scenarios
/// with their canonical `FaultPlan`s; the coordinator perf baseline.
fn virtual_time_sweep() {
    section("perf: virtual-time scenario sweep (all scenarios x 3 policies)");
    // Warm simtest's memoized netlist+STA platform builds so every timed
    // row measures the replay, not a one-off build that would otherwise
    // land in whichever scenario/policy happens to run first.
    for name in Scenario::NAMES {
        let warm = SimSpec { epochs: 1, ..SimSpec::golden(name) };
        simtest::run(&warm).expect("warmup replay");
    }
    let mut rows = vec![wavescale::report::row([
        "scenario", "policy", "epochs", "accepted", "completed", "energy_j", "gain",
        "violations%", "wall_ms", "speedup",
    ])];
    let mut runs = Vec::new();
    for name in Scenario::NAMES {
        for policy in CapacityPolicy::ALL {
            let spec = SimSpec { policy, ..SimSpec::golden(name) };
            let out = simtest::run(&spec).expect("virtual replay");
            let s = &out.report.stats;
            let virtual_s = (spec.epochs + 1) as f64 * spec.epoch.as_secs_f64();
            let wall_ms = out.wall.as_secs_f64() * 1e3;
            let speedup = virtual_s / out.wall.as_secs_f64().max(1e-9);
            println!(
                "  {name:<12} {:<9} {:>5} req in {wall_ms:7.1} ms wall \
                 ({speedup:6.0}x real time) | gain {:.2}x | violations {:.1}%",
                policy.name(),
                s.completed,
                s.power_gain,
                s.violation_rate * 100.0
            );
            rows.push(vec![
                name.to_string(),
                policy.name().to_string(),
                spec.epochs.to_string(),
                out.accepted.to_string(),
                s.completed.to_string(),
                format!("{:.3}", s.energy_j),
                format!("{:.3}", s.power_gain),
                format!("{:.2}", s.violation_rate * 100.0),
                format!("{wall_ms:.2}"),
                format!("{speedup:.0}"),
            ]);
            runs.push(Json::obj(vec![
                ("scenario", Json::Str(name.to_string())),
                ("policy", Json::Str(policy.name().to_string())),
                ("epochs", Json::Num(spec.epochs as f64)),
                ("seed", Json::Num(spec.seed as f64)),
                ("accepted", Json::Num(out.accepted as f64)),
                ("completed", Json::Num(s.completed as f64)),
                ("energy_j", Json::Num(s.energy_j)),
                ("power_gain", Json::Num(s.power_gain)),
                ("violation_rate", Json::Num(s.violation_rate)),
                ("wall_ms", Json::Num(wall_ms)),
                ("speedup_vs_real_time", Json::Num(speedup)),
            ]));
        }
    }
    common::emit_csv("BENCH_coordinator.csv", &rows);
    let doc = Json::obj(vec![
        ("bench", Json::Str("perf_fleet_serving/virtual_time_sweep".into())),
        ("mode", Json::Str(if cfg!(debug_assertions) { "debug" } else { "release" }.into())),
        ("runs", Json::Arr(runs)),
    ]);
    match wavescale::report::write_results("BENCH_coordinator.json", &doc.to_string_pretty()) {
        Ok(p) => println!("[json] {} (coordinator perf baseline)", p.display()),
        Err(e) => eprintln!("[json] failed to write BENCH_coordinator.json: {e}"),
    }
}

/// Fixed vs adaptive dispatch batch (DESIGN.md S22) on the live virtual
/// fleet: every named scenario under hybrid capacity, knob off then on.
/// The adaptive CC grows batches while downclocked, so the interesting
/// columns are energy and violations at trough exits / surge onsets.
fn batch_knob_sweep() {
    section("perf: batch knob (fixed vs adaptive dispatch batch, hybrid)");
    let mut rows = vec![wavescale::report::row([
        "scenario", "batch", "energy_j", "gain", "violations%", "p99_ms", "wall_ms",
    ])];
    for name in Scenario::NAMES {
        let mut energies = Vec::with_capacity(2);
        for adaptive in [false, true] {
            let spec = SimSpec { adaptive_batch: adaptive, ..SimSpec::golden(name) };
            let out = simtest::run(&spec).expect("batch-knob replay");
            let s = &out.report.stats;
            let worst_p99 = s
                .per_group
                .iter()
                .map(|g| g.p99_latency_s)
                .fold(0.0f64, f64::max);
            energies.push(s.energy_j);
            rows.push(vec![
                name.to_string(),
                if adaptive { "adaptive".into() } else { "fixed".to_string() },
                format!("{:.3}", s.energy_j),
                format!("{:.3}", s.power_gain),
                format!("{:.2}", s.violation_rate * 100.0),
                format!("{:.2}", worst_p99 * 1e3),
                format!("{:.2}", out.wall.as_secs_f64() * 1e3),
            ]);
        }
        println!(
            "  {name:<16} fixed {:8.3} J | adaptive {:8.3} J | delta {:+.2}%",
            energies[0],
            energies[1],
            (energies[1] / energies[0].max(1e-12) - 1.0) * 100.0
        );
    }
    common::emit_csv("BENCH_batch_knob.csv", &rows);
}
