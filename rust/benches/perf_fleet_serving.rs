//! Perf: multi-tenant fleet serving — two benchmark groups live on one
//! sharded coordinator, mixed-tenant offered load, fleet report at the
//! end. Runs with the PJRT backend when artifacts exist, native otherwise.

mod common;

use std::time::{Duration, Instant};

use wavescale::bench_support::section;
use wavescale::coordinator::{FleetServing, FleetServingConfig, GroupConfig};
use wavescale::util::prng::Rng;

fn main() {
    section("perf: fleet serving (2-group mixed tenant)");
    if !common::artifacts_available() {
        println!("(artifacts/ missing — using the native inference backend)");
    }

    let cfg = FleetServingConfig {
        groups: vec![
            GroupConfig { benchmark: "tabla".into(), share: 0.5, n_instances: 2 },
            GroupConfig { benchmark: "diannao".into(), share: 0.5, n_instances: 2 },
        ],
        epoch: Duration::from_millis(100),
        cycles_per_batch: 1.0e4,
        queue_capacity: 16_384,
        ..Default::default()
    };
    let fleet = FleetServing::start(cfg, "artifacts".into()).expect("fleet");

    let mut rng = Rng::new(11);
    let per_group = 2_048usize;
    let payloads: Vec<(usize, Vec<f32>)> = (0..2 * per_group)
        .map(|i| {
            let gi = i % 2;
            (gi, rng.normal_vec_f32(fleet.in_dim(gi)))
        })
        .collect();

    let t0 = Instant::now();
    let mut sent = 0u64;
    for (gi, p) in &payloads {
        if fleet.submit(*gi, p.clone()).is_ok() {
            sent += 1;
        }
    }
    let submit_us = t0.elapsed().as_secs_f64() * 1e6 / payloads.len() as f64;
    println!("submit(): {submit_us:.2} us/request across 2 groups ({sent} accepted)");

    let t0 = Instant::now();
    while fleet.stats().completed < sent {
        if t0.elapsed() > Duration::from_secs(30) {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let registry_snapshot = fleet.registry().snapshot();
    let report = fleet.shutdown().expect("shutdown");
    println!(
        "drained {} requests in {wall:.2} s -> {:.0} req/s fleet-wide",
        report.stats.completed,
        report.stats.completed as f64 / wall
    );
    for g in &report.stats.per_group {
        println!(
            "  {:<10} [{}] done {} | stolen {} | p50 {:.1} ms p99 {:.1} ms | gain {:.2}x | violations {:.1}%",
            g.name,
            g.backend,
            g.completed,
            g.stolen_batches,
            g.p50_latency_s * 1e3,
            g.p99_latency_s * 1e3,
            g.power_gain,
            g.violation_rate * 100.0
        );
    }
    println!(
        "fleet gain {:.2}x | worst violation rate {:.1}% | {} epochs | registry: {registry_snapshot:?}",
        report.stats.power_gain,
        report.stats.violation_rate * 100.0,
        report.stats.epochs
    );
}
