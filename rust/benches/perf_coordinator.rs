//! Perf: serving coordinator — submit/dispatch overhead and end-to-end
//! throughput, across instance counts (sharded-queue scaling check).
//!
//! Uses the PJRT backend when `make artifacts` output exists and the
//! deterministic native backend otherwise, so it runs in any environment.
//! The interesting number is submit() cost: with per-instance shard queues
//! it must stay flat (or improve) as n_instances grows, where the old
//! single global mutex queue degraded under contention. Since ISSUE 8 the
//! shard queue's hot path is a lock-free MPMC ring (DESIGN.md S22) — a
//! submit is one length CAS plus one ring-slot claim, with the staging
//! mutex touched only by consumers — so this sweep doubles as the
//! mutex-vs-ring acceptance gate: the 8-instance µs/req must stay flat or
//! better against the committed baseline.

mod common;

use std::time::{Duration, Instant};

use wavescale::bench_support::section;
use wavescale::coordinator::{Coordinator, ServingConfig};
use wavescale::platform::{build_platform, PlatformConfig, Policy};
use wavescale::util::prng::Rng;
use wavescale::vscale::Mode;

fn run_at(n_instances: usize, payloads: &[Vec<f32>]) -> (f64, f64, u64, u64) {
    let platform = build_platform(
        "tabla",
        PlatformConfig::default(),
        Policy::Dvfs(Mode::Proposed),
    )
    .unwrap();
    let cfg = ServingConfig {
        n_instances,
        epoch: Duration::from_millis(100),
        // Small service time so the bench measures the coordinator, not
        // the simulated FPGA occupancy.
        cycles_per_batch: 1.0e4,
        queue_capacity: 16_384,
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        "artifacts".into(),
        platform.design.clone(),
        platform.optimizer_ref().clone(),
    )
    .expect("coordinator");

    // Submit-side overhead.
    let t0 = Instant::now();
    let mut sent = 0u64;
    for p in payloads {
        if coord.submit(p.clone()).is_ok() {
            sent += 1;
        }
    }
    let submit_us = t0.elapsed().as_secs_f64() * 1e6 / payloads.len() as f64;

    // Drain and measure end-to-end throughput.
    let t0 = Instant::now();
    while coord.stats().completed < sent {
        if t0.elapsed() > Duration::from_secs(30) {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let (stats, records) = coord.shutdown().expect("shutdown");
    println!(
        "n_instances={n_instances:>2} [{}]: submit {submit_us:.2} us/req | drained {} in {wall:.2} s \
         -> {:.0} req/s | p50 {:.1} ms p99 {:.1} ms | stolen {} | CC epochs {}",
        stats.backend,
        stats.completed,
        stats.completed as f64 / wall,
        stats.p50_latency_s * 1e3,
        stats.p99_latency_s * 1e3,
        stats.stolen_batches,
        records.len()
    );
    (submit_us, stats.completed as f64 / wall, stats.completed, stats.stolen_batches)
}

fn main() {
    section("perf: serving coordinator (sharded submit path)");
    if !common::artifacts_available() {
        println!("(artifacts/ missing — using the native inference backend)");
    }

    let mut rng = Rng::new(3);
    // Payload dim is fixed per variant (PJRT artifacts share the same
    // geometry as the native fallback).
    let in_dim = wavescale::coordinator::variant_dims("tabla").0;
    let payloads: Vec<Vec<f32>> = (0..4096).map(|_| rng.normal_vec_f32(in_dim)).collect();

    let (submit2, _tput2, _, _) = run_at(2, &payloads);
    let (submit8, _tput8, _, _) = run_at(8, &payloads);
    println!(
        "submit-path scaling 2 -> 8 instances: {submit2:.2} -> {submit8:.2} us/req ({})",
        if submit8 <= submit2 * 1.10 { "flat or better — sharding holds" } else { "regressed" }
    );
}
