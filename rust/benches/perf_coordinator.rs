//! Perf: serving coordinator — submit/dispatch overhead and end-to-end
//! throughput with real PJRT inference. Requires `make artifacts`.

mod common;

use std::time::{Duration, Instant};

use wavescale::bench_support::section;
use wavescale::coordinator::{Coordinator, ServingConfig};
use wavescale::platform::{build_platform, PlatformConfig, Policy};
use wavescale::util::prng::Rng;
use wavescale::vscale::Mode;

fn main() {
    section("perf: serving coordinator");
    if !common::artifacts_available() {
        println!("(artifacts/ missing — run `make artifacts` first)");
        return;
    }
    let platform = build_platform(
        "tabla",
        PlatformConfig::default(),
        Policy::Dvfs(Mode::Proposed),
    )
    .unwrap();
    let cfg = ServingConfig {
        n_instances: 2,
        epoch: Duration::from_millis(100),
        // Small service time so the bench measures the coordinator, not
        // the simulated FPGA occupancy.
        cycles_per_batch: 1.0e4,
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        "artifacts".into(),
        platform.design.clone(),
        platform.optimizer_ref().clone(),
    )
    .expect("coordinator");

    let mut rng = Rng::new(3);
    let payloads: Vec<Vec<f32>> = (0..4096).map(|_| rng.normal_vec_f32(coord.in_dim)).collect();

    // Submit-side overhead.
    let t0 = Instant::now();
    let mut sent = 0u64;
    for p in &payloads {
        if coord.submit(p.clone()).is_ok() {
            sent += 1;
        }
    }
    let submit_us = t0.elapsed().as_secs_f64() * 1e6 / payloads.len() as f64;
    println!("submit(): {submit_us:.2} us/request ({sent} accepted)");

    // Drain and measure end-to-end throughput.
    let t0 = Instant::now();
    while coord.stats().completed < sent {
        if t0.elapsed() > Duration::from_secs(30) {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    let wall = t0.elapsed().as_secs_f64();
    let (stats, records) = coord.shutdown().expect("shutdown");
    println!(
        "drained {} requests in {wall:.2} s -> {:.0} req/s | p50 {:.1} ms p99 {:.1} ms",
        stats.completed,
        stats.completed as f64 / wall,
        stats.p50_latency_s * 1e3,
        stats.p99_latency_s * 1e3
    );
    println!("CC epochs recorded: {}", records.len());
}
