//! Perf: virtual-time scale sweep — sequential vs conservative-parallel
//! discrete-event engine (DESIGN.md S24) on synthetic fleets of 10, 100,
//! and 1000 tenant groups.
//!
//! Each fleet size replays the same `synthetic-N` scenario twice — once
//! on the sequential `VirtualClock` golden reference, once on
//! `ParallelVirtualClock` — asserts the two traces are **byte-identical**
//! (the equivalence contract `tests/sim_parallel.rs` pins), and reports
//! the wall-clock speedup. Emits `results/BENCH_sim_scale.{json,csv}`;
//! the acceptance target is ≥4x at 100+ groups on 8 cores. Run via
//! `make sim-scale`.

mod common;

use wavescale::bench_support::section;
use wavescale::simtest::{self, SimSpec};
use wavescale::util::json::Json;
use wavescale::workload::Scenario;

/// Group counts swept; override the largest with WAVESCALE_SCALE_MAX
/// (e.g. 100 on small CI runners — the JSON records what actually ran).
const SWEEP: [usize; 3] = [10, 100, 1000];

fn spec_for(n_groups: usize) -> SimSpec {
    SimSpec {
        scenario: format!("synthetic-{n_groups}"),
        // Short horizon, one instance per group: the sweep measures
        // engine scheduling throughput as actor count grows, and 1000
        // groups is already 1000 worker threads.
        epochs: 12,
        n_instances: 1,
        warmup_epochs: 1,
        ..SimSpec::default()
    }
}

fn main() {
    section("perf: virtual-time scale sweep (sequential vs parallel engine)");
    let max = std::env::var("WAVESCALE_SCALE_MAX")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(usize::MAX);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("  ({cores} cores available)");

    // Warm the memoized netlist+STA builds for all five Table-1 bases so
    // the timed rows measure replay, not one-off platform construction.
    simtest::run(&SimSpec { epochs: 1, ..spec_for(5) }).expect("warmup replay");

    let mut rows = vec![wavescale::report::row([
        "groups", "engine", "epochs", "accepted", "completed", "energy_j", "wall_ms", "speedup",
    ])];
    let mut runs = Vec::new();
    for n_groups in SWEEP {
        if n_groups > max {
            println!("  (skipping {n_groups} groups: WAVESCALE_SCALE_MAX={max})");
            continue;
        }
        let spec = spec_for(n_groups);
        let scenario = Scenario::by_name(&spec.scenario, spec.epochs, spec.seed).expect("scenario");

        let seq = simtest::run(&spec).expect("sequential replay");
        let par_spec = SimSpec { parallel: true, ..spec.clone() };
        let par = simtest::run(&par_spec).expect("parallel replay");

        // The whole point of the conservative engine: same bytes, less
        // wall. A mismatch is a determinism bug, not a perf regression.
        let seq_trace = simtest::trace_json(&spec, &scenario, &seq.report).to_string_pretty();
        let par_trace = simtest::trace_json(&spec, &scenario, &par.report).to_string_pretty();
        assert_eq!(seq_trace, par_trace, "parallel trace diverged at {n_groups} groups");

        let seq_ms = seq.wall.as_secs_f64() * 1e3;
        let par_ms = par.wall.as_secs_f64() * 1e3;
        let speedup = seq_ms / par_ms.max(1e-9);
        println!(
            "  {n_groups:>5} groups: sequential {seq_ms:9.1} ms | parallel {par_ms:9.1} ms | \
             {speedup:5.2}x speedup (traces byte-identical)"
        );
        for (engine, out, wall_ms, sp) in
            [("sequential", &seq, seq_ms, 1.0), ("parallel", &par, par_ms, speedup)]
        {
            rows.push(vec![
                n_groups.to_string(),
                engine.to_string(),
                spec.epochs.to_string(),
                out.accepted.to_string(),
                out.report.stats.completed.to_string(),
                format!("{:.3}", out.report.stats.energy_j),
                format!("{wall_ms:.2}"),
                format!("{sp:.3}"),
            ]);
        }
        runs.push(Json::obj(vec![
            ("groups", Json::Num(n_groups as f64)),
            ("epochs", Json::Num(spec.epochs as f64)),
            ("seed", Json::Num(spec.seed as f64)),
            ("accepted", Json::Num(seq.accepted as f64)),
            ("completed", Json::Num(seq.report.stats.completed as f64)),
            ("sequential_wall_ms", Json::Num(seq_ms)),
            ("parallel_wall_ms", Json::Num(par_ms)),
            ("speedup", Json::Num(speedup)),
            ("traces_identical", Json::Bool(true)),
        ]));
        if n_groups >= 100 {
            let verdict = if speedup >= 4.0 { "meets" } else { "below" };
            println!("    target >=4x at 100+ groups on 8 cores: {verdict} ({speedup:.2}x on {cores} cores)");
        }
    }

    common::emit_csv("BENCH_sim_scale.csv", &rows);
    let doc = Json::obj(vec![
        ("bench", Json::Str("perf_sim_scale".into())),
        ("mode", Json::Str(if cfg!(debug_assertions) { "debug" } else { "release" }.into())),
        ("cores", Json::Num(cores as f64)),
        ("target_speedup_at_100_groups", Json::Num(4.0)),
        ("runs", Json::Arr(runs)),
    ]);
    match wavescale::report::write_results("BENCH_sim_scale.json", &doc.to_string_pretty()) {
        Ok(p) => println!("[json] {} (scale-sweep baseline)", p.display()),
        Err(e) => eprintln!("[json] failed to write BENCH_sim_scale.json: {e}"),
    }
}
