//! Perf: platform simulator throughput (steps/second) — the Fig. 10-12
//! inner loop — plus the workload generator.

mod common;

use wavescale::bench_support::{bench_fn, black_box, section};
use wavescale::platform::{build_platform, PlatformConfig, Policy};
use wavescale::vscale::Mode;
use wavescale::workload::{bursty, BurstyConfig};

fn main() {
    section("perf: platform simulator");
    let trace = bursty(&BurstyConfig { steps: 10_000, ..Default::default() });

    let r = bench_fn("bursty trace gen (10k steps)", || {
        black_box(bursty(&BurstyConfig { steps: 10_000, ..Default::default() }))
    });
    println!("{}", r.report());

    for policy in [
        Policy::Dvfs(Mode::Proposed),
        Policy::PowerGating,
        Policy::NominalStatic,
    ] {
        let r = bench_fn(&format!("run 10k steps ({})", policy.name()), || {
            let mut p =
                build_platform("tabla", PlatformConfig::default(), policy).unwrap();
            black_box(p.run(&trace.loads).power_gain)
        });
        let steps_per_sec = 10_000.0 / r.median.as_secs_f64();
        println!("{}", r.report());
        println!("  -> {:.2} M steps/s (incl. platform build)", steps_per_sec / 1e6);
    }

    // Steady-state stepping without rebuild.
    let mut p = build_platform(
        "tabla",
        PlatformConfig::default(),
        Policy::Dvfs(Mode::Proposed),
    )
    .unwrap();
    let r = bench_fn("step() x1000 steady-state", || {
        let mut acc = 0.0;
        for i in 0..1000 {
            acc += p.step(trace.loads[i % trace.loads.len()], None).power_w;
        }
        black_box(acc)
    });
    println!("{}", r.report());
    println!(
        "  -> {:.2} M steps/s steady-state",
        1000.0 / r.median.as_secs_f64() / 1e6
    );
}
