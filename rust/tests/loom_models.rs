//! Loom model checking of the lock-free coordinator core (DESIGN.md S23).
//!
//! Compiled only under `RUSTFLAGS="--cfg loom"` (`make loom`); a plain
//! `cargo test` builds this file to an empty test crate. Each model is
//! explored *exhaustively*: the vendored loom runtime enumerates every
//! schedule of every instrumented operation via depth-first search over
//! scheduling decisions, with no iteration cap. A model passes only when
//! every interleaving upholds its invariant.
//!
//! # Model sizing
//!
//! Exhaustive exploration without partial-order reduction is exponential
//! in instrumented operations, so every model here is a *micro* model:
//! ring capacity 1–2 (the exact-capacity edge is where the races live),
//! one or two operations per thread, three or more threads per the S23
//! checklist. These are the smallest configurations that still contain
//! each race — over-admission needs a full ring plus a racing pop,
//! frontier reaping needs more pushes than physical slots, a lost wakeup
//! needs one waiter and one notifier, and torn publication needs one
//! writer and concurrent fast-path readers. `LOOM_MAX_PREEMPTIONS` can
//! bound exploration for a quick smoke pass (e.g. `=2`), but the CI job
//! and the acceptance bar run unbounded.
//!
//! # Fidelity caveat
//!
//! The vendored runtime is sequentially consistent: it explores every
//! *interleaving* but not weak-memory *reorderings*, so `Relaxed` vs
//! `Acquire` mistakes surface only through interleavings they enable
//! (e.g. a stale bounded-length snapshot), not through store buffering.
//! The analytical pairing argument for each ordering lives in the
//! DESIGN.md S23 audit table; the models verify the protocols above the
//! orderings. The deadlock-timeout rule matters for model 3: a timed
//! condvar wait is woken by timeout only when *no* thread is runnable,
//! and `loom::timeout_fired()` reports whether that rescue ever fired —
//! so asserting `!timeout_fired()` proves the wakeup protocol alone, with
//! no timeout assist, delivered the item in every schedule.
//!
//! These models found a real bug: at capacity 1 the ring allocated a
//! single slot, where a producer's published sequence (`p + 1`) is
//! indistinguishable from "free for position `p + 1`", letting a second
//! unbounded push overwrite an unconsumed request and wedging the reaper.
//! `Ring::new` now clamps the slot count to 2 (see shard.rs).

#![cfg(loom)]

use std::collections::HashSet;
use std::sync::Arc;
use std::time::Duration;

use wavescale::clock::{ticks, ActorScope, Clock, ParallelVirtualClock};
use wavescale::coordinator::{FleetTopology, GroupConfig, Request, ShardQueue, TopologyStore};

fn req(id: u64) -> Request {
    Request { id, payload: vec![0.0; 2], submitted: 0 }
}

fn ids(rs: &[Request]) -> Vec<u64> {
    rs.iter().map(|r| r.id).collect()
}

/// S23 invariant 1: racing bounded pushes never admit past the exact
/// capacity bound, even with a concurrent pop freeing a slot mid-race.
///
/// Capacity 1, two producers (`try_push`) and one consumer (`pop_upto`)
/// — the smallest configuration where the length-guard CAS, the ring
/// claim CAS and the consumer's `fetch_sub` all contend on the same
/// slot. Checks conservation (every admitted request is popped or still
/// queued, exactly once) and the bound (`len <= capacity` once quiesced;
/// mid-flight over-admission would corrupt the slot protocol and show up
/// as a lost or duplicated id).
#[test]
fn bounded_push_never_over_admits() {
    loom::model(|| {
        let q = Arc::new(ShardQueue::new(1));

        let producers: Vec<_> = [1u64, 2]
            .into_iter()
            .map(|id| {
                let q = Arc::clone(&q);
                loom::thread::spawn(move || q.try_push(req(id)).is_ok())
            })
            .collect();
        let consumer = {
            let q = Arc::clone(&q);
            loom::thread::spawn(move || q.pop_upto(1))
        };

        let admitted = producers
            .into_iter()
            .filter(|h| h.join().unwrap())
            .count();
        let popped = consumer.join().unwrap();

        assert!(admitted >= 1, "the first length-guard CAS cannot lose");
        assert!(q.len() <= q.capacity(), "over-admitted: len {} > cap 1", q.len());

        let mut collected = popped;
        collected.extend(q.drain_all());
        assert_eq!(
            collected.len(),
            admitted,
            "admitted {} but recovered {:?}",
            admitted,
            ids(&collected)
        );
        let unique: HashSet<u64> = collected.iter().map(|r| r.id).collect();
        assert_eq!(unique.len(), collected.len(), "duplicated id: {:?}", ids(&collected));
        assert!(q.is_empty());
    });
}

/// S23 invariant 2: per-producer FIFO order survives `overflow_push`
/// frontier reaping.
///
/// Capacity 1 (2 physical slots after the S23 fix), two producers each
/// pushing two requests via `push_unbounded` — four pushes through a
/// two-slot ring force the overflow path: the spilling producer reaps
/// the claimed frontier into staging (spinning through any
/// mid-publish slot) before appending its own request. In every
/// schedule, each producer's second request must drain after its first.
/// At capacity 1 this model also exercised the single-slot ring
/// overwrite bug described in the module docs.
#[test]
fn per_producer_fifo_survives_overflow_reaping() {
    loom::model(|| {
        let q = Arc::new(ShardQueue::new(1));

        let producers: Vec<_> = [100u64, 200]
            .into_iter()
            .map(|base| {
                let q = Arc::clone(&q);
                loom::thread::spawn(move || {
                    q.push_unbounded(req(base + 1));
                    q.push_unbounded(req(base + 2));
                })
            })
            .collect();
        for h in producers {
            h.join().unwrap();
        }

        let drained = ids(&q.drain_all());
        assert_eq!(drained.len(), 4, "dropped a request: {drained:?}");
        for base in [100u64, 200] {
            let per: Vec<u64> = drained.iter().copied().filter(|id| id / 100 == base / 100).collect();
            assert_eq!(
                per,
                vec![base + 1, base + 2],
                "producer {base} order violated in drain {drained:?}"
            );
        }
        assert!(q.is_empty());
    });
}

/// S23 invariant 3: the WaitSlot generation protocol has no lost
/// wakeups in `pop_wait`.
///
/// One producer pushes a single request while a waiter sits in
/// `pop_wait` with a deadline far beyond the model. The classic lost
/// wakeup is notify-before-wait: the producer's `notify_slot` lands
/// between the waiter's empty `take_front` and its condvar wait. The
/// generation counter (sampled *before* the emptiness probe, compared
/// under the slot mutex) must close that window in every schedule.
/// The waiter must always return the item, and must never be rescued by
/// the deadlock-timeout rule — `loom::timeout_fired()` stays false, so
/// the wakeup itself (not the timeout) made progress.
#[test]
fn waitslot_generation_has_no_lost_wakeups() {
    loom::model(|| {
        let q = Arc::new(ShardQueue::new(2));

        let producer = {
            let q = Arc::clone(&q);
            loom::thread::spawn(move || q.try_push(req(7)).unwrap())
        };
        let waiter = {
            let q = Arc::clone(&q);
            loom::thread::spawn(move || q.pop_wait(1, Duration::from_secs(3600)))
        };

        producer.join().unwrap();
        let got = waiter.join().unwrap();

        assert_eq!(ids(&got), vec![7], "pop_wait lost the pushed request");
        assert!(
            !loom::timeout_fired(),
            "waiter only progressed via the deadlock-timeout rescue: lost wakeup"
        );
    });
}

/// S23 invariant 4: a gate + drain racing concurrent pushes never drops
/// a request.
///
/// The Central Controller's migration/fault path gates a shard and
/// drains it while the dispatcher may still be pushing (`try_push`) and
/// the re-dispatch path may be force-feeding it (`push_unbounded`).
/// Gating does not reject pushes — it only parks the worker — so the
/// invariant is conservation: every admitted request is in the CC's
/// drain or still queued for the next epoch's drain, exactly once.
#[test]
fn gate_drain_vs_push_never_drops() {
    loom::model(|| {
        let q = Arc::new(ShardQueue::new(2));

        let pusher = {
            let q = Arc::clone(&q);
            loom::thread::spawn(move || {
                let mut admitted = 0usize;
                if q.try_push(req(1)).is_ok() {
                    admitted += 1;
                }
                q.push_unbounded(req(2));
                admitted + 1
            })
        };
        let cc = {
            let q = Arc::clone(&q);
            loom::thread::spawn(move || {
                q.set_gated(true);
                q.drain_all()
            })
        };

        let admitted = pusher.join().unwrap();
        let drained = cc.join().unwrap();
        let leftover = q.drain_all();

        let mut collected = drained;
        collected.extend(leftover);
        assert_eq!(
            collected.len(),
            admitted,
            "gate/drain dropped a request: admitted {} recovered {:?}",
            admitted,
            ids(&collected)
        );
        let unique: HashSet<u64> = collected.iter().map(|r| r.id).collect();
        assert_eq!(unique.len(), collected.len(), "duplicated id: {:?}", ids(&collected));
        assert!(q.is_empty());
        assert!(q.is_gated(), "gate flag must survive the race");
    });
}

/// S23 invariant 5: `TopologyStore` version/mask publication is never
/// observed torn by the router fast path.
///
/// `migrate` publishes the new hosting mask with a Release store and
/// *then* the new version with a Release store; the router fast path
/// loads version first (cache-invalidation probe), mask second, both
/// Acquire. Two readers race one migration of group 0 from node 0 to
/// node 1. In every schedule each reader must see either layout, never
/// a torn one: a reader that observes the new version must also observe
/// the new mask (mask-before-version publication order), and the mask
/// is always exactly one of the two valid single-host values.
#[test]
fn topology_version_mask_publication_is_never_torn() {
    loom::model(|| {
        let group = GroupConfig {
            benchmark: "g0".to_string(),
            share: 1.0,
            n_instances: 1,
            qos_target: None,
        };
        let topo = FleetTopology::spread(vec![group], 2).unwrap();
        let v0 = topo.version();
        let store = Arc::new(TopologyStore::new(topo));
        assert_eq!(store.hosting_mask(0), 0b01);

        let migrator = {
            let store = Arc::clone(&store);
            loom::thread::spawn(move || store.migrate(0, 0, 1).unwrap())
        };
        let readers: Vec<_> = (0..2)
            .map(|_| {
                let store = Arc::clone(&store);
                loom::thread::spawn(move || {
                    // Router fast path order: version probe, then mask.
                    let v = store.version();
                    let m = store.hosting_mask(0);
                    (v, m)
                })
            })
            .collect();

        migrator.join().unwrap();
        for h in readers {
            let (v, m) = h.join().unwrap();
            assert!(m == 0b01 || m == 0b10, "torn hosting mask {m:#b}");
            if v > v0 {
                assert_eq!(
                    m, 0b10,
                    "reader saw the new version {v} with the old mask {m:#b}"
                );
            }
        }
        assert_eq!(store.version(), v0 + 1);
        assert_eq!(store.hosting_mask(0), 0b10);
    });
}

/// S24 invariant 1: the parallel virtual clock's barrier protocol is
/// schedule-independent — every interleaving of two worker-domain actors
/// racing a control-domain barrier yields the same virtual-time
/// observations the sequential engine would produce.
///
/// The smallest configuration with real domain concurrency: two worker
/// domains (cap 2, so both actors can hold a CPU simultaneously) and one
/// control actor whose 30 ms sleep is the fence. Whatever order loom
/// runs the grants, attaches and parks in, each worker must observe its
/// own domain clock at 10 ms, and the control actor must not resume
/// until both worker events (sequentially ordered before its barrier)
/// have fully executed. A fence bug shows up as a worker reading a
/// control-advanced clock (or vice versa); a lost grant wedges the model
/// and is caught by loom's deadlock detection.
#[test]
fn parallel_clock_barrier_is_schedule_independent() {
    loom::model(|| {
        let c: Arc<dyn Clock> = Arc::new(ParallelVirtualClock::with_workers(2));
        let _me = ActorScope::enter(&c, "control");
        let workers: Vec<_> = (0..2u64)
            .map(|i| {
                let id = c.register_actor_in(&format!("w{i}"), i as usize + 1);
                let c = Arc::clone(&c);
                loom::thread::spawn(move || {
                    let _scope = ActorScope::attach(&c, id);
                    c.sleep(Duration::from_millis(10));
                    c.now()
                })
            })
            .collect();
        c.sleep(Duration::from_millis(30));
        let at_barrier = c.now();
        c.suspend_current();
        for h in workers {
            assert_eq!(
                h.join().unwrap(),
                ticks(Duration::from_millis(10)),
                "worker observed a foreign domain clock"
            );
        }
        c.resume_current();
        assert_eq!(at_barrier, ticks(Duration::from_millis(30)));
        // Post-quiesce view: the maximum over domain clocks.
        assert_eq!(c.now(), ticks(Duration::from_millis(30)));
    });
}

/// S24 invariant 2: a worker-originated cross-domain wakeup is deferred
/// and merged at the next barrier with the notifier's clock, in every
/// schedule — the merge may never be lost, applied twice, or applied
/// with a schedule-dependent stamp.
///
/// One waiter (domain 3) parks on a slot; a control barrier sequences
/// that park before the notifier (domain 1) exists — the precondition
/// under which worker-originated notifies are order-safe (module docs:
/// the coordinator routes all cross-domain notifies through domain 0).
/// The notifier is granted at the registrar's 1 ms clock and notifies
/// after a 7 ms sleep, so in every interleaving the deferred wake must
/// deliver exactly stamp 8 ms to the waiter, with no timeout assist.
#[test]
fn parallel_clock_defers_and_merges_cross_domain_wakeups() {
    loom::model(|| {
        let c: Arc<dyn Clock> = Arc::new(ParallelVirtualClock::with_workers(2));
        let _me = ActorScope::enter(&c, "control");
        let slot = c.new_slot();
        let waiter = {
            let id = c.register_actor_in("waiter", 3);
            let (c, slot) = (Arc::clone(&c), slot.clone());
            loom::thread::spawn(move || {
                let _scope = ActorScope::attach(&c, id);
                let gen = slot.generation();
                c.wait_slot(&slot, gen, Duration::from_secs(3600));
                c.now()
            })
        };
        // Barrier: control runs again only once the waiter has parked.
        c.sleep(Duration::from_millis(1));
        let notifier = {
            let id = c.register_actor_in("notifier", 1);
            let (c, slot) = (Arc::clone(&c), slot.clone());
            loom::thread::spawn(move || {
                let _scope = ActorScope::attach(&c, id);
                c.sleep(Duration::from_millis(7));
                c.notify_slot(&slot);
            })
        };
        c.sleep(Duration::from_millis(50));
        c.suspend_current();
        notifier.join().unwrap();
        let woke_at = waiter.join().unwrap();
        c.resume_current();
        assert_eq!(
            woke_at,
            ticks(Duration::from_millis(8)),
            "deferred wake must carry the notifier's clock through the merge"
        );
        assert!(
            !loom::timeout_fired(),
            "waiter only progressed via the deadlock-timeout rescue: lost deferred wake"
        );
    });
}
