//! Integration: the PJRT runtime against real `make artifacts` output.
//! Every test self-skips when artifacts/ is missing (e.g. `cargo test`
//! before the python build) — `make test` always builds them first.

use wavescale::arch::{BenchmarkSpec, DeviceFamily, TABLE1};
use wavescale::chars::CharLibrary;
use wavescale::netlist::gen::{generate, GenConfig};
use wavescale::power::{DesignPower, PowerParams};
use wavescale::runtime::{DnnClient, Engine, OpQuery, Tensor, VoltageSelectorClient};
use wavescale::sta::{analyze, DelayParams};
use wavescale::util::prng::Rng;
use wavescale::vscale::{Mode, Optimizer};

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Engine::open("artifacts").expect("engine"))
}

#[test]
fn manifest_lists_all_artifacts() {
    let Some(engine) = engine() else { return };
    let m = &engine.manifest;
    for mode in ["prop", "core_only", "bram_only"] {
        assert!(m.artifacts.contains_key(&format!("voltage_opt_{mode}")));
    }
    assert_eq!(m.dnn_variants().len(), 5);
    for spec in TABLE1 {
        assert!(m.artifacts.contains_key(&format!("dnn_{}", spec.name)), "{}", spec.name);
    }
}

#[test]
fn all_dnn_variants_pass_golden() {
    let Some(engine) = engine() else { return };
    for variant in engine.manifest.dnn_variants() {
        let dnn = DnnClient::new(&engine, &variant).expect("client");
        let err = dnn.verify_golden(&engine).expect("golden");
        assert!(err < 1e-3, "dnn_{variant}: max rel err {err}");
    }
}

#[test]
fn dnn_inference_is_deterministic_and_shape_checked() {
    let Some(engine) = engine() else { return };
    let dnn = DnnClient::new(&engine, "tabla").unwrap();
    let mut rng = Rng::new(3);
    let x = rng.normal_vec_f32(dnn.batch * dnn.in_dim);
    let a = dnn.infer(&x).unwrap();
    let b = dnn.infer(&x).unwrap();
    assert_eq!(a, b, "PJRT inference must be deterministic");
    assert_eq!(a.len(), dnn.batch * dnn.out_dim);
    assert!(dnn.infer(&x[1..]).is_err(), "wrong input length must fail");
}

#[test]
fn voltage_selector_matches_native_optimizer_exhaustively() {
    // The AOT'd Pallas kernel and the rust grid search must agree on every
    // benchmark, mode, and a sweep of workload levels: same grid indices.
    let Some(engine) = engine() else { return };
    let chars = CharLibrary::stratix_iv_22nm();
    let vs = VoltageSelectorClient::new(&engine);
    for spec in TABLE1 {
        let dp = DesignPower::from_spec(
            BenchmarkSpec::by_name(spec.name).unwrap(),
            &DeviceFamily::stratix_iv(),
            chars.clone(),
            PowerParams::default(),
        )
        .unwrap();
        let net = generate(spec, &GenConfig { scale: 0.03, seed: 2019, luts_per_lab: 10 });
        let rep = analyze(&net, &DelayParams::default(), 8).unwrap();
        let tables = dp.rail_tables(&rep.cp);
        // Native optimizer WITHOUT multi-path (the artifact is single-path).
        let opt = Optimizer::new(chars.grid(), tables.clone());
        for mode in [Mode::Proposed, Mode::CoreOnly, Mode::BramOnly] {
            let sws: Vec<f64> = (0..16).map(|i| 1.0 + i as f64 * 0.45).collect();
            let queries: Vec<OpQuery> = sws
                .iter()
                .map(|&sw| OpQuery {
                    alpha: tables.op.alpha as f32,
                    beta: tables.op.beta as f32,
                    gamma_l: tables.op.gamma_l as f32,
                    gamma_m: tables.op.gamma_m as f32,
                    sw: sw as f32,
                })
                .collect();
            let got = vs.select(mode, &tables, &queries).expect("select");
            for (choice, &sw) in got.iter().zip(&sws) {
                let want = opt.optimize(sw, mode);
                assert_eq!(
                    (choice.icore, choice.ibram),
                    (want.icore, want.ibram),
                    "{} {mode:?} sw={sw}: pjrt {choice:?} vs native {want:?}",
                    spec.name
                );
                assert!(
                    (choice.power_norm - want.power_norm).abs() < 1e-4,
                    "{} {mode:?} sw={sw}: power {} vs {}",
                    spec.name,
                    choice.power_norm,
                    want.power_norm
                );
            }
        }
    }
}

#[test]
fn executable_validates_inputs() {
    let Some(engine) = engine() else { return };
    let exe = engine.load("voltage_opt_prop").unwrap();
    // Wrong arity.
    assert!(exe.run(&[]).is_err());
    // Wrong element count.
    let bad: Vec<Tensor> = (0..11).map(|_| Tensor::F32(vec![0.0; 3])).collect();
    assert!(exe.run(&bad).is_err());
    // Wrong dtype.
    let mut args: Vec<Tensor> = Vec::new();
    for spec in &exe.meta.args {
        args.push(Tensor::I32(vec![0; spec.elements()]));
    }
    assert!(exe.run(&args).is_err());
}

#[test]
fn unknown_artifact_is_an_error() {
    let Some(engine) = engine() else { return };
    assert!(engine.load("nonexistent").is_err());
    assert!(DnnClient::new(&engine, "nonexistent").is_err());
}

#[test]
fn compile_cache_reuses_executables() {
    let Some(engine) = engine() else { return };
    let t0 = std::time::Instant::now();
    let _a = engine.load("dnn_tabla").unwrap();
    let cold = t0.elapsed();
    let t0 = std::time::Instant::now();
    let _b = engine.load("dnn_tabla").unwrap();
    let warm = t0.elapsed();
    assert!(warm < cold / 10, "cache hit {warm:?} vs cold {cold:?}");
}
