//! Golden-trace tests: every named scenario replays on the virtual clock
//! and its per-epoch CC trace must match the committed JSON under
//! `testdata/golden/` byte-for-byte (DESIGN.md S18).
//!
//! Bootstrap: a missing golden is recorded and reported — commit it (CI
//! fails on drift of tracked goldens via `git diff` after `make golden`).
//! Intentional behavior changes regenerate the suite with `make golden`
//! (`WAVESCALE_UPDATE_GOLDEN=1`).
//!
//! Everything runs inside ONE `#[test]` on purpose: the acceptance
//! criterion times the overnight × 3-policy replay against a wall-clock
//! budget, and sibling tests running in parallel threads (cargo's
//! default) would contend for CPU and flake the timing on small CI
//! runners.

use std::path::Path;
use std::time::{Duration, Instant};

use wavescale::simtest::{self, GoldenStatus, SimSpec};
use wavescale::vscale::CapacityPolicy;
use wavescale::workload::Scenario;

const GOLDEN_DIR: &str = "testdata/golden";

fn check(spec: &SimSpec) {
    match simtest::check_golden(Path::new(GOLDEN_DIR), spec) {
        Ok(GoldenStatus::Matched) => {}
        Ok(GoldenStatus::Recorded) => eprintln!(
            "recorded new golden trace {GOLDEN_DIR}/{}.json — commit it",
            spec.golden_stem()
        ),
        Ok(GoldenStatus::Updated) => eprintln!(
            "updated golden trace {GOLDEN_DIR}/{}.json (WAVESCALE_UPDATE_GOLDEN=1)",
            spec.golden_stem()
        ),
        Err(e) => panic!("{e}"),
    }
}

#[test]
fn golden_traces_and_determinism() {
    // Warm the memoized platform builds so the timed section measures the
    // virtual-time replay, not one-off netlist generation + STA.
    for name in Scenario::NAMES {
        let warm = SimSpec { epochs: 1, ..SimSpec::golden(name) };
        simtest::run(&warm).expect("warmup run");
    }

    // Acceptance: the full overnight scenario under all three capacity
    // policies replays in under a second of wall time (relaxed for
    // unoptimized test builds).
    let t0 = Instant::now();
    for policy in CapacityPolicy::ALL {
        check(&SimSpec { policy, ..SimSpec::golden("overnight") });
    }
    let wall = t0.elapsed();
    let budget = if cfg!(debug_assertions) {
        Duration::from_secs(3)
    } else {
        Duration::from_secs(1)
    };
    assert!(
        wall < budget,
        "overnight x 3 policies took {wall:?} (budget {budget:?}) — virtual time must \
         replay scenarios in milliseconds"
    );

    // Golden coverage for the remaining named scenarios (hybrid capacity).
    for name in Scenario::NAMES {
        if name != "overnight" {
            check(&SimSpec::golden(name));
        }
    }

    // The adaptive path (predictor ensemble + QoS-feedback guardband) on
    // every named scenario — the ISSUE-4 acceptance configuration. Keyed
    // `{scenario}_{policy}_ensemble-adaptive`, so these never collide
    // with the static baselines above.
    for name in Scenario::NAMES {
        check(&SimSpec::golden_adaptive(name));
    }

    same_seed_replays_byte_identically_and_seeds_matter();
    identical_fault_plans_replay_byte_identically();
    virtual_runs_are_independent_of_installed_artifacts();
}

fn same_seed_replays_byte_identically_and_seeds_matter() {
    let spec = SimSpec {
        epochs: 12,
        peak_rps: 1_500.0,
        epoch: Duration::from_millis(25),
        batch_timeout: Duration::from_millis(5),
        ..SimSpec::golden("flash-crowd")
    };
    let scenario = Scenario::by_name(&spec.scenario, spec.epochs, spec.seed).unwrap();
    let a = simtest::run(&spec).unwrap();
    let b = simtest::run(&spec).unwrap();
    let ja = simtest::trace_json(&spec, &scenario, &a.report).to_string_pretty();
    let jb = simtest::trace_json(&spec, &scenario, &b.report).to_string_pretty();
    assert_eq!(ja, jb, "same seed must replay byte-identically");
    assert_eq!(a.accepted, b.accepted);
    // Full stats determinism, not just the trace: latency quantiles and
    // integrated energy are bitwise equal too.
    for (ga, gb) in a.report.stats.per_group.iter().zip(&b.report.stats.per_group) {
        assert_eq!(ga.completed, gb.completed);
        assert_eq!(ga.rejected, gb.rejected);
        assert!(ga.energy_j.to_bits() == gb.energy_j.to_bits(), "{}", ga.name);
        assert!(
            ga.p99_latency_s.to_bits() == gb.p99_latency_s.to_bits(),
            "{}: p99 {} vs {}",
            ga.name,
            ga.p99_latency_s,
            gb.p99_latency_s
        );
    }

    // A different seed must actually change the run — guards against any
    // stochastic source silently ignoring the seed plumbing.
    let other = SimSpec { seed: spec.seed + 1, ..spec.clone() };
    let scenario_other = Scenario::by_name(&other.scenario, other.epochs, other.seed).unwrap();
    let c = simtest::run(&other).unwrap();
    let jc = simtest::trace_json(&other, &scenario_other, &c.report).to_string_pretty();
    assert_ne!(ja, jc, "seed must steer the replay");
}

fn identical_fault_plans_replay_byte_identically() {
    // Fault-injection determinism regression: the same seed AND the same
    // FaultPlan must reproduce the trace JSON byte for byte — including
    // the injected straggler window's per-epoch capacity column — and
    // removing the plan (same seed) must steer the replay.
    let spec = SimSpec::golden("straggler");
    assert!(!spec.faults.is_empty(), "straggler golden must carry its canonical plan");
    let scenario = Scenario::by_name(&spec.scenario, spec.epochs, spec.seed).unwrap();
    let a = simtest::run(&spec).unwrap();
    let b = simtest::run(&spec).unwrap();
    let ja = simtest::trace_json(&spec, &scenario, &a.report).to_string_pretty();
    let jb = simtest::trace_json(&spec, &scenario, &b.report).to_string_pretty();
    assert_eq!(ja, jb, "identical FaultPlan must replay byte-identically");
    // The plan is part of the published trace, and the slowdown shows up
    // in the per-epoch capacity column during its window.
    assert!(ja.contains("\"stragglers\""), "trace must embed the fault plan");
    assert!(
        a.report.epoch_records[0].iter().any(|r| r.slow_factor < 1.0),
        "straggler window must depress the capacity factor"
    );
    let clean = SimSpec { faults: Default::default(), ..spec.clone() };
    let c = simtest::run(&clean).unwrap();
    let jc = simtest::trace_json(&clean, &scenario, &c.report).to_string_pretty();
    assert_ne!(ja, jc, "the fault plan must steer the replay");
    assert!(
        c.report.epoch_records[0].iter().all(|r| r.slow_factor == 1.0),
        "an empty plan must keep the capacity factor at exactly 1.0"
    );
}

fn virtual_runs_are_independent_of_installed_artifacts() {
    // The golden harness forces the native backend; assert that is really
    // what a replay reports, whatever this checkout has under artifacts/.
    let spec = SimSpec {
        epochs: 4,
        epoch: Duration::from_millis(20),
        batch_timeout: Duration::from_millis(5),
        ..SimSpec::golden("diurnal")
    };
    let out = simtest::run(&spec).unwrap();
    for g in &out.report.stats.per_group {
        assert_eq!(g.backend, "native", "{}: golden traces must not depend on PJRT", g.name);
    }
}
