//! Cross-path control-plane equivalence (ISSUE 5 acceptance): the
//! offline simulator and the live virtual-time coordinator share ONE
//! decision engine (`control::GroupController`), so replaying the load
//! sequence a live fleet observed through the offline platform must
//! reproduce the live fleet's decision log **identically** — same
//! forecasts, margins, operating points and predictor names, epoch for
//! epoch, on every named scenario and every capacity policy.
//!
//! The live run goes first because its observed loads are quantized by
//! real request arrivals (`round(trace · share · peak · epoch) / cap`);
//! the offline plant then consumes exactly those loads. Both plants
//! start from the same initial state (nominal frequency, all instances
//! active, no backlog) and use the same capacity/backlog arithmetic, so
//! decision equality is an induction over epochs — any divergence in
//! predictor, guardband, ladder or LUT logic between the two paths
//! breaks it immediately.
//!
//! PR 7 extends the contract to the distributed fleet: spreading the same
//! groups over N node agents (N in {1, 2, 4}) must not move a single
//! decision. Migration-free, each group is hosted on exactly one node and
//! its router delivers every submit there, so the hosted CC observes the
//! same load sequence the 1-node fleet does — the decision log is
//! *invariant in the node count* and still replays offline.

use wavescale::platform::{build_platform, PlatformConfig, Policy};
use wavescale::simtest::{self, SimOutcome, SimSpec};
use wavescale::vscale::{CapacityPolicy, Mode};
use wavescale::workload::Scenario;

/// Run `spec` live, then replay each group's observed loads through an
/// offline platform built with the matching control configuration, and
/// assert the two decision logs are identical. Returns the live outcome
/// so callers can make cross-spec assertions without re-running.
fn assert_paths_agree(spec: &SimSpec) -> SimOutcome {
    let out = simtest::run(spec).expect("live virtual-time replay");
    let scenario = Scenario::by_name(&spec.scenario, spec.epochs, spec.seed).unwrap();
    assert_eq!(out.report.decision_records.len(), scenario.tenants.len());
    for (gi, tenant) in scenario.tenants.iter().enumerate() {
        let live = &out.report.decision_records[gi];
        let loads: Vec<f64> =
            out.report.epoch_records[gi].iter().map(|r| r.load).collect();
        assert_eq!(
            live.len(),
            loads.len(),
            "{}/{}: one decision per CC epoch",
            spec.scenario,
            tenant.benchmark
        );
        assert!(!live.is_empty(), "{}: CC must have run", spec.scenario);

        // The offline plant with the same control configuration: same
        // bins, margin, warmup, predictor, capacity policy and instance
        // count as the live CC (FleetServingConfig defaults).
        let cfg = PlatformConfig {
            n_fpgas: spec.n_instances,
            m_bins: 10,
            margin_t: 0.05,
            warmup_steps: spec.warmup_epochs,
            pg_residual: 0.02,
            // Must mirror FleetServingConfig.max_backlog_steps — the
            // backlog clamp feeds the shared controller's observations.
            max_backlog_steps: 1.0,
            predictor: spec.predictor,
            predictor_period: Scenario::day_period(spec.epochs),
            // Mirror the live per-tenant tier resolution
            // (QosTier::effective): tiers refine only an enabled
            // run-level guardband.
            qos_target: spec.qos_target.map(|d| tenant.qos_target.unwrap_or(d)),
            capacity_policy: spec.policy,
            // Mirror the batch knob; batch_nominal/batch_overhead ride
            // the shared defaults on both paths.
            adaptive_batch: spec.adaptive_batch,
            ..PlatformConfig::default()
        };
        let mut platform =
            build_platform(&tenant.benchmark, cfg, Policy::Hybrid(Mode::Proposed))
                .expect("offline platform");
        for &load in &loads {
            platform.step(load, None);
        }
        assert_eq!(
            platform.decisions(),
            live.as_slice(),
            "{} x {} / {}: offline and live decision sequences diverged",
            spec.scenario,
            spec.policy.name(),
            tenant.benchmark
        );
    }
    out
}

#[test]
fn offline_and_live_decisions_agree_on_every_scenario_and_capacity_policy() {
    // Every named scenario (adversarial ones included) x {dvfs-only,
    // pg-only, hybrid} x {1, 2, 4} serving nodes: the acceptance matrix.
    // Static-margin Markov configuration (the golden default).
    // SimSpec::default carries the empty fault plan — cross-path
    // equivalence is a *fault-free*, migration-free contract, since the
    // offline plant has no fault or topology model; injected runs are
    // covered by tests/sim_faults.rs and scripted migrations by
    // tests/sim_topology.rs.
    for name in Scenario::NAMES {
        for policy in CapacityPolicy::ALL {
            let mut single_node_log = None;
            for n_nodes in [1usize, 2, 4] {
                let spec = SimSpec {
                    scenario: name.to_string(),
                    epochs: 18,
                    policy,
                    n_nodes,
                    ..SimSpec::default()
                };
                let out = assert_paths_agree(&spec);
                // Node-count invariance: the distributed fleet must make
                // the same decisions the single-node fleet does, epoch
                // for epoch, group for group.
                match &single_node_log {
                    None => single_node_log = Some(out.report.decision_records),
                    Some(base) => assert_eq!(
                        &out.report.decision_records,
                        base,
                        "{name} x {}: {n_nodes}-node fleet diverged from 1-node decisions",
                        policy.name()
                    ),
                }
            }
        }
    }
}

#[test]
fn offline_and_live_decisions_agree_with_the_batch_knob_enabled() {
    // ISSUE 8: the batch decision rides the one shared controller, so
    // turning the knob on must not move a single decision out of
    // alignment between the paths. Pure-DVFS runs actually exercise the
    // scaling law (the hybrid can serve a low bin by gating at full
    // frequency, which keeps the batch nominal); the overnight trough
    // forces a downclock, so at least one decided batch must exceed the
    // nominal 16 there.
    let mut saw_scaled_batch = false;
    for (name, policy) in [
        ("overnight", CapacityPolicy::DvfsOnly),
        ("flash-crowd", CapacityPolicy::DvfsOnly),
        ("diurnal", CapacityPolicy::Hybrid),
    ] {
        let spec = SimSpec {
            scenario: name.to_string(),
            epochs: 18,
            policy,
            adaptive_batch: true,
            ..SimSpec::default()
        };
        let out = assert_paths_agree(&spec);
        for group in &out.report.decision_records {
            for d in group {
                assert!(
                    (16..=64).contains(&d.batch),
                    "{name}: decided batch {} outside [nominal, 4x nominal]",
                    d.batch
                );
                saw_scaled_batch |= d.batch > 16;
            }
        }
    }
    assert!(saw_scaled_batch, "no DVFS trough ever scaled the batch above nominal");
}

#[test]
fn partial_batches_charge_only_their_fill_of_the_service_time() {
    // ISSUE 8 satellite: the live worker used to occupy its instance for
    // the full cycles_per_batch (2e5 / 1e8 Hz = 2 ms) even when the
    // dispatched batch held a single request, while the offline model
    // credited fractional batches — sparse traffic paid a 2 ms service
    // floor per request. Occupancy now scales with batch fill
    // (DESIGN.md S22), so under sparse load a dispatch of k <= 4
    // requests costs cycles·(k/16 + 0.1)/(1.1·f) < 1 ms. Warmup spans
    // the whole run so the CC pins nominal frequency and the bound is
    // deterministic.
    let spec = SimSpec {
        epochs: 12,
        peak_rps: 80.0, // ~4 requests per 50 ms epoch: every batch is partial
        warmup_epochs: 12,
        ..SimSpec::default()
    };
    let out = simtest::run(&spec).expect("sparse replay");
    let g = &out.report.stats.per_group[0];
    assert!(g.completed > 0, "sparse run must still serve requests");
    assert!(
        g.p99_latency_s < 1.0e-3,
        "p99 {} s: a partial batch is still being charged the full \
         cycles_per_batch occupancy",
        g.p99_latency_s
    );
}

#[test]
fn offline_and_live_decisions_agree_under_the_adaptive_ensemble() {
    // The adaptive path exercises everything the static one does not:
    // the guardband's boost/decay closed loop walking the margin ladder,
    // per-level LUT selection, and the ensemble's shadow scoring +
    // hysteresis switching — all of which must live in the one shared
    // controller for the logs to stay identical.
    // tiered-tenants additionally pins the per-tenant QoS tier
    // resolution: both paths must route each group's guardband at its
    // own effective target (premium/standard/best-effort).
    for name in ["diurnal", "overnight", "tiered-tenants"] {
        let spec = SimSpec {
            scenario: name.to_string(),
            epochs: 36,
            ..SimSpec::golden_adaptive(name)
        };
        assert_paths_agree(&spec);
    }
}

#[test]
fn live_decision_log_matches_the_published_epoch_trace() {
    // The decision log is the cross-path witness; pin its alignment to
    // the (golden-checked) epoch trace: decision k's operating point is
    // what serves epoch k+1, and decision k's forecast is recorded on
    // epoch k.
    // Adaptive spec so the margin actually moves epoch to epoch — a
    // static margin would make the alignment check vacuous. The batch
    // knob is on so the batch column is pinned under movement too.
    let spec = SimSpec {
        epochs: 24,
        adaptive_batch: true,
        ..SimSpec::golden_adaptive("flash-crowd")
    };
    let out = simtest::run(&spec).unwrap();
    for (records, decisions) in
        out.report.epoch_records.iter().zip(&out.report.decision_records)
    {
        assert_eq!(records.len(), decisions.len());
        // predicted/predictor/margin come from the decision MADE at the
        // same epoch (identical alignment to the offline StepRecord).
        for (k, (rec, d)) in records.iter().zip(decisions).enumerate() {
            assert_eq!(rec.predicted, d.predicted, "epoch {k}: forecast column");
            assert_eq!(rec.margin, d.margin, "epoch {k}: margin column");
            assert_eq!(rec.predictor, d.predictor, "epoch {k}: predictor column");
        }
        // Epoch 0 is served by the startup state (nominal f, all
        // instances, the nominal batch); epoch k >= 1 by the decision
        // made at epoch k-1 — the batch column lags like the operating
        // point, not like the forecast columns.
        assert_eq!(records[0].freq_ratio, 1.0);
        assert_eq!(records[0].batch, 16, "epoch 0 is served at the nominal batch");
        for k in 1..records.len() {
            let served = &records[k].decision;
            let chosen = &decisions[k - 1];
            assert_eq!(served.freq_ratio, chosen.freq_ratio, "epoch {k}: served f");
            assert_eq!(served.n_active, chosen.n_active, "epoch {k}: served active");
            assert_eq!(served.vcore, chosen.vcore, "epoch {k}: served vcore");
            assert_eq!(served.vbram, chosen.vbram, "epoch {k}: served vbram");
            assert_eq!(served.batch, chosen.batch, "epoch {k}: served batch");
        }
    }
}
