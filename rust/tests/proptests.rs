//! Property tests over the coordinator-side invariants (routing, batching,
//! state) and the analytic core, using the in-repo prop harness
//! (DESIGN.md S16). Each property runs across seeded-random cases.

use wavescale::arch::{BenchmarkSpec, DeviceFamily, TABLE1};
use wavescale::chars::{CharLibrary, ResourceClass};
use wavescale::markov::{MarkovPredictor, Predictor};
use wavescale::netlist::gen::{generate, GenConfig};
use wavescale::platform::{build_platform, PlatformConfig, Policy};
use wavescale::power::{DesignPower, PowerParams};
use wavescale::sta::{analyze, cp_delay_at, DelayParams, DelayScales};
use wavescale::util::json::Json;
use wavescale::util::prop::{assert_that, check};
use wavescale::vscale::{Mode, Optimizer};
use wavescale::workload::{bursty, BurstyConfig, Trace};

fn random_optimizer(rng: &mut wavescale::util::prng::Rng) -> Optimizer {
    let chars = CharLibrary::stratix_iv_22nm();
    let spec = rng.choose(TABLE1);
    let dp = DesignPower::from_spec(
        BenchmarkSpec::by_name(spec.name).unwrap(),
        &DeviceFamily::stratix_iv(),
        chars.clone(),
        PowerParams::default(),
    )
    .unwrap();
    let net = generate(spec, &GenConfig { scale: 0.02, seed: rng.next_u64(), luts_per_lab: 10 });
    let rep = analyze(&net, &DelayParams::default(), 8).unwrap();
    Optimizer::new(chars.grid(), dp.rail_tables(&rep.cp)).with_paths(&chars, rep.top_paths)
}

#[test]
fn prop_optimizer_result_is_feasible_and_minimal() {
    check("optimizer feasible+minimal", 40, |rng| {
        let opt = random_optimizer(rng);
        let sw = rng.range(1.0, 6.0);
        let mode = *rng.choose(&[Mode::Proposed, Mode::CoreOnly, Mode::BramOnly]);
        let pt = opt.optimize(sw, mode);
        assert_that(opt.feasible(pt.icore, pt.ibram, sw), "chosen point infeasible")?;
        for i in 0..opt.grid.vcore.len() {
            for j in 0..opt.grid.vbram.len() {
                let allowed = match mode {
                    Mode::Proposed => true,
                    Mode::CoreOnly => j == 0,
                    Mode::BramOnly => i == 0,
                    Mode::FreqOnly => i == 0 && j == 0,
                };
                if allowed && opt.feasible(i, j, sw) {
                    assert_that(
                        opt.power(i, j, sw) >= pt.power_norm - 1e-12,
                        format!("({i},{j}) beats optimum at sw={sw:.2}"),
                    )?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sta_monotone_under_voltage_scaling() {
    check("STA monotone in voltage", 25, |rng| {
        let chars = CharLibrary::stratix_iv_22nm();
        let spec = rng.choose(TABLE1);
        let net =
            generate(spec, &GenConfig { scale: 0.02, seed: rng.next_u64(), luts_per_lab: 10 });
        let d = DelayParams::default();
        let v1 = rng.range(0.55, 0.80);
        let v2 = rng.range(0.55, v1);
        let b1 = rng.range(0.70, 0.95);
        let b2 = rng.range(0.70, b1);
        let hi = cp_delay_at(&net, &d, &chars, v1, b1).map_err(|e| e.to_string())?;
        let lo = cp_delay_at(&net, &d, &chars, v2, b2).map_err(|e| e.to_string())?;
        assert_that(lo >= hi - 1e-9, format!("({v2:.3},{b2:.3}) faster than ({v1:.3},{b1:.3})"))
    });
}

#[test]
fn prop_multipath_model_upper_bounds_single_path() {
    check("multi-path >= single-path delay model", 25, |rng| {
        let chars = CharLibrary::stratix_iv_22nm();
        let spec = rng.choose(TABLE1);
        let net =
            generate(spec, &GenConfig { scale: 0.02, seed: rng.next_u64(), luts_per_lab: 10 });
        let rep = analyze(&net, &DelayParams::default(), 8).map_err(|e| e.to_string())?;
        let s = DelayScales::at(&chars, rng.range(0.55, 0.8), rng.range(0.7, 0.95));
        let single = rep.cp.delay_at(&s);
        let multi = rep.top_paths.iter().map(|p| p.delay_at(&s)).fold(0.0, f64::max);
        assert_that(multi >= single - 1e-9, "cp must be among top paths")
    });
}

#[test]
fn prop_markov_rows_always_stochastic() {
    check("markov transition rows sum to 1", 30, |rng| {
        let m = rng.index(2, 12);
        let mut p = MarkovPredictor::new(m, rng.index(0, 10));
        for _ in 0..rng.index(10, 400) {
            p.observe(rng.f64());
        }
        for (i, row) in p.transition_matrix().iter().enumerate() {
            let s: f64 = row.iter().sum();
            assert_that((s - 1.0).abs() < 1e-9, format!("row {i} sums to {s}"))?;
            assert_that(row.iter().all(|&x| (0.0..=1.0).contains(&x)), "probability range")?;
        }
        let pred = p.predict();
        assert_that((0.0..=1.0).contains(&pred), format!("prediction {pred} out of range"))
    });
}

#[test]
fn prop_platform_conserves_work_and_bounds_state() {
    // Routing/batching/state invariant: delivered work never exceeds
    // capacity, backlog stays within its bound, and no step loses work
    // (delivered + backlog' = load + backlog up to the drop bound).
    check("platform work conservation", 12, |rng| {
        let steps = rng.index(50, 200);
        let trace = bursty(&BurstyConfig {
            steps,
            mean_load: rng.range(0.2, 0.8),
            seed: rng.next_u64(),
            ..Default::default()
        });
        let policy = *rng.choose(&[
            Policy::Dvfs(Mode::Proposed),
            Policy::Dvfs(Mode::CoreOnly),
            Policy::PowerGating,
        ]);
        let mut platform =
            build_platform("tabla", PlatformConfig::default(), policy).map_err(|e| e)?;
        let report = platform.run(&trace.loads);
        let mut backlog = 0.0f64;
        for (rec, &load) in report.records.iter().zip(&trace.loads) {
            assert_that(
                rec.delivered <= rec.freq_ratio + 1e-9,
                format!("step {}: delivered {} > capacity {}", rec.step, rec.delivered, rec.freq_ratio),
            )?;
            assert_that(rec.backlog <= 1.0 + 1e-9, "backlog bound exceeded")?;
            let expect = (load + backlog - rec.delivered).min(1.0);
            assert_that(
                (rec.backlog - expect).abs() < 1e-6,
                format!("step {}: backlog {} != {}", rec.step, rec.backlog, expect),
            )?;
            backlog = rec.backlog;
            assert_that(rec.power_w.is_finite() && rec.power_w > 0.0, "power sane")?;
            assert_that((0.45..=0.80 + 1e-9).contains(&rec.vcore), "vcore in range")?;
            assert_that((0.45..=0.95 + 1e-9).contains(&rec.vbram), "vbram in range")?;
        }
        Ok(())
    });
}

#[test]
fn prop_voltage_grid_snap_inverts_levels() {
    check("grid snap inverts levels", 50, |rng| {
        let grid = CharLibrary::stratix_iv_22nm().grid();
        let i = rng.index(0, grid.vcore.len());
        let j = rng.index(0, grid.vbram.len());
        assert_that(grid.snap_core(grid.vcore[i]) == i, "snap_core")?;
        assert_that(grid.snap_bram(grid.vbram[j]) == j, "snap_bram")
    });
}

#[test]
fn prop_json_round_trips_arbitrary_values() {
    check("json round trip", 60, |rng| {
        fn gen(rng: &mut wavescale::util::prng::Rng, depth: usize) -> Json {
            match if depth == 0 { rng.index(0, 4) } else { rng.index(0, 6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.bool(0.5)),
                2 => Json::Num((rng.normal() * 1e3).round() / 16.0),
                3 => {
                    let n = rng.index(0, 12);
                    Json::Str((0..n).map(|_| char::from(rng.index(32, 127) as u8)).collect())
                }
                4 => Json::Arr((0..rng.index(0, 5)).map(|_| gen(rng, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..rng.index(0, 5))
                        .map(|k| (format!("k{k}"), gen(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        let v = gen(rng, 3);
        let pretty = Json::parse(&v.to_string_pretty()).map_err(|e| e.to_string())?;
        let compact = Json::parse(&v.to_string_compact()).map_err(|e| e.to_string())?;
        assert_that(pretty == v && compact == v, "round trip mismatch")
    });
}

#[test]
fn prop_trace_csv_round_trips() {
    check("workload csv round trip", 20, |rng| {
        let t = bursty(&BurstyConfig {
            steps: rng.index(10, 300),
            mean_load: rng.range(0.1, 0.9),
            seed: rng.next_u64(),
            ..Default::default()
        });
        let u = Trace::from_csv(&t.to_csv(), "x").map_err(|e| e)?;
        assert_that(t.len() == u.len(), "length")?;
        for (a, b) in t.loads.iter().zip(&u.loads) {
            assert_that((a - b).abs() < 1e-5, "value drift")?;
        }
        Ok(())
    });
}

#[test]
fn prop_char_library_shapes_hold_under_param_jitter() {
    // The qualitative §III shapes must be robust to small calibration
    // jitter (a guard against brittle constants).
    check("char shapes robust", 20, |rng| {
        let mut lib = CharLibrary::stratix_iv_22nm();
        lib.logic.vth *= rng.range(0.95, 1.05);
        lib.bram.leak_s *= rng.range(0.9, 1.1);
        lib.routing.flat_frac = (lib.routing.flat_frac * rng.range(0.9, 1.1)).min(0.9);
        let mem_static = lib.static_scale(ResourceClass::Bram, 0.80);
        assert_that(mem_static < 0.35, format!("bram static {mem_static}"))?;
        let logic = lib.delay_scale(ResourceClass::Logic, 0.60);
        let rout = lib.delay_scale(ResourceClass::Routing, 0.60);
        assert_that(logic > rout, "logic must stay more sensitive than routing")
    });
}
