//! Integration: optimizer/LUT behaviour against the full design stack,
//! plus failure-injection on the configuration layer.

use wavescale::arch::{BenchmarkSpec, DeviceFamily, TABLE1};
use wavescale::chars::CharLibrary;
use wavescale::config::{policy_by_name, SimConfig};
use wavescale::netlist::blif::{parse_blif, write_blif};
use wavescale::netlist::gen::{generate, GenConfig};
use wavescale::power::{DesignPower, PowerParams};
use wavescale::sta::{analyze, DelayParams};
use wavescale::util::json::Json;
use wavescale::vscale::{Mode, Optimizer, VoltageLut};

fn optimizer_for(name: &str) -> Optimizer {
    let chars = CharLibrary::stratix_iv_22nm();
    let spec = BenchmarkSpec::by_name(name).unwrap();
    let dp = DesignPower::from_spec(
        spec,
        &DeviceFamily::stratix_iv(),
        chars.clone(),
        PowerParams::default(),
    )
    .unwrap();
    let net = generate(spec, &GenConfig { scale: 0.05, seed: 2019, luts_per_lab: 10 });
    let rep = analyze(&net, &DelayParams::default(), 8).unwrap();
    Optimizer::new(chars.grid(), dp.rail_tables(&rep.cp)).with_paths(&chars, rep.top_paths)
}

#[test]
fn luts_are_monotone_for_all_benchmarks_and_modes() {
    for spec in TABLE1 {
        let opt = optimizer_for(spec.name);
        for mode in [Mode::Proposed, Mode::CoreOnly, Mode::BramOnly] {
            let lut = VoltageLut::build(&opt, 10, 0.05, mode);
            for w in lut.entries.windows(2) {
                assert!(
                    w[0].point.power_norm <= w[1].point.power_norm + 1e-9,
                    "{} {mode:?}: non-monotone LUT",
                    spec.name
                );
                assert!(w[0].point.vcore <= w[1].point.vcore + 1e-9);
            }
        }
    }
}

#[test]
fn deeper_grids_never_hurt() {
    // A policy that can scale both rails must never do worse than the
    // same policy restricted to one rail, across the whole LUT.
    for spec in TABLE1 {
        let opt = optimizer_for(spec.name);
        let prop = VoltageLut::build(&opt, 10, 0.05, Mode::Proposed);
        let core = VoltageLut::build(&opt, 10, 0.05, Mode::CoreOnly);
        let bram = VoltageLut::build(&opt, 10, 0.05, Mode::BramOnly);
        for b in 0..10 {
            let p = prop.entries[b].point.power_norm;
            assert!(p <= core.entries[b].point.power_norm + 1e-9, "{} bin {b}", spec.name);
            assert!(p <= bram.entries[b].point.power_norm + 1e-9, "{} bin {b}", spec.name);
        }
    }
}

#[test]
fn netlist_blif_round_trip_preserves_timing() {
    for spec in &TABLE1[..3] {
        let net = generate(spec, &GenConfig { scale: 0.03, seed: 5, luts_per_lab: 10 });
        let text = write_blif(&net);
        let back = parse_blif(&text).unwrap();
        let d = DelayParams::default();
        let a = analyze(&net, &d, 4).unwrap();
        let b = analyze(&back, &d, 4).unwrap();
        assert!(
            (a.cp.total_ns() - b.cp.total_ns()).abs() < 1e-9,
            "{}: {} vs {}",
            spec.name,
            a.cp.total_ns(),
            b.cp.total_ns()
        );
    }
}

#[test]
fn config_file_round_trip_drives_simulation() {
    let mut cfg = SimConfig::default();
    cfg.benchmark = "proteus".into();
    cfg.policy = policy_by_name("oracle-prop").unwrap();
    cfg.workload.steps = 120;
    let text = cfg.to_json().to_string_pretty();
    let parsed = Json::parse(&text).unwrap();
    let mut cfg2 = SimConfig::default();
    cfg2.apply_json(&parsed).unwrap();
    assert_eq!(cfg2.benchmark, "proteus");
    assert_eq!(cfg2.workload.steps, 120);

    let trace = wavescale::workload::bursty(&cfg2.workload);
    let mut platform =
        wavescale::platform::build_platform(&cfg2.benchmark, cfg2.platform.clone(), cfg2.policy)
            .unwrap();
    let r = platform.run(&trace.loads);
    assert!(r.power_gain > 1.0);
}

#[test]
fn config_rejects_malformed_json() {
    let mut cfg = SimConfig::default();
    assert!(Json::parse("{nope").is_err());
    let bad = Json::parse(r#"{"policy": "warp-drive"}"#).unwrap();
    assert!(cfg.apply_json(&bad).is_err());
    let bad = Json::parse(r#"{"workload": {"hurst": 2.0}}"#).unwrap();
    assert!(cfg.apply_json(&bad).is_err());
}

#[test]
fn rail_tables_match_artifact_grid_dimensions() {
    // The rust grid must stay in lockstep with the python AOT constants
    // (model.NV = 13, model.NM = 19).
    for spec in TABLE1 {
        let opt = optimizer_for(spec.name);
        assert_eq!(opt.tables.dl.len(), 13, "{}", spec.name);
        assert_eq!(opt.tables.dm.len(), 19, "{}", spec.name);
    }
}
