//! Property tests over the virtual-time serving simulation and the shard
//! queue (DESIGN.md S16/S18), using the in-repo `util::prop` harness.
//!
//! The fleet-level properties run the *live* coordinator — real worker
//! and CC threads — on a `VirtualClock`, so hundreds of randomized
//! scenarios replay in seconds and each failure reports a replayable
//! seed (`WAVESCALE_PROP_SEED`). The concurrent ring properties
//! additionally shrink on failure (`util::prop::check_shrink`): the
//! report carries both the original failing shape and the minimal
//! producers/per/cap triple that still breaks.
//!
//! 1. every shard-queue op sequence matches a model queue (FIFO order,
//!    capacity bound, depth mirror);
//! 2. `admitted == completed + failed` at shutdown and the gated-shard
//!    drain never drops a request, for arbitrary scenarios/policies —
//!    with and without a scripted `FaultPlan` injecting board failures,
//!    stragglers and load surges;
//! 3. the same seed replays byte-identically;
//! 4. live hybrid capacity energy is never worse than the better of the
//!    dvfs-only / pg-only baselines (within 1%).

use std::collections::{HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use wavescale::coordinator::{MigrationPlan, Request, ShardQueue};
use wavescale::markov::PredictorKind;
use wavescale::simtest::{self, SimSpec};
use wavescale::util::prng::Rng;
use wavescale::util::prop::{assert_that, check, check_shrink, Shrink};
use wavescale::vscale::CapacityPolicy;
use wavescale::workload::{FaultPlan, Scenario};

fn req(id: u64) -> Request {
    Request { id, payload: vec![], submitted: 0 }
}

#[test]
fn prop_shard_queue_matches_model_under_arbitrary_interleavings() {
    check("shard queue vs model", 200, |rng| {
        let cap = rng.index(1, 17);
        let q = ShardQueue::new(cap);
        let mut model: VecDeque<u64> = VecDeque::new();
        let mut next_id = 0u64;
        let mut unbounded_used = false;
        for _ in 0..rng.index(1, 120) {
            match rng.index(0, 8) {
                // Bounded push: admitted iff the model has room.
                0 | 1 | 2 => {
                    let id = next_id;
                    next_id += 1;
                    match q.try_push(req(id)) {
                        Ok(()) => {
                            assert_that(model.len() < cap, "push accepted past capacity")?;
                            model.push_back(id);
                        }
                        Err(back) => {
                            assert_that(model.len() >= cap, "push refused below capacity")?;
                            assert_that(back.id == id, "refused request handed back intact")?;
                        }
                    }
                }
                // CC drain/re-dispatch path may exceed the bound.
                3 => {
                    let id = next_id;
                    next_id += 1;
                    q.push_unbounded(req(id));
                    model.push_back(id);
                    unbounded_used = true;
                }
                // Home-worker pops keep FIFO order at the front
                // (pop_wait with a zero deadline never blocks).
                4 => {
                    let k = rng.index(0, 6);
                    let got: Vec<u64> = if rng.bool(0.5) {
                        q.pop_upto(k).iter().map(|r| r.id).collect()
                    } else {
                        q.pop_wait(k, Duration::ZERO).iter().map(|r| r.id).collect()
                    };
                    let take = k.min(model.len());
                    let want: Vec<u64> = model.drain(..take).collect();
                    assert_that(got == want, format!("pop {got:?} != {want:?}"))?;
                }
                // Stealing takes from the back, preserving order.
                5 => {
                    let k = rng.index(0, 6);
                    let got: Vec<u64> = q.steal_upto(k).iter().map(|r| r.id).collect();
                    let take = k.min(model.len());
                    let want: Vec<u64> = model.split_off(model.len() - take).into();
                    assert_that(got == want, format!("steal {got:?} != {want:?}"))?;
                }
                6 => {
                    let gated = rng.bool(0.5);
                    q.set_gated(gated);
                    assert_that(q.is_gated() == gated, "gated flag")?;
                }
                _ => {
                    let got: Vec<u64> = q.drain_all().iter().map(|r| r.id).collect();
                    let want: Vec<u64> = model.drain(..).collect();
                    assert_that(got == want, format!("drain {got:?} != {want:?}"))?;
                }
            }
            // The lock-free depth mirror equals the true depth between ops,
            // and the bound holds unless the unbounded path was used.
            assert_that(
                q.len() == model.len(),
                format!("depth mirror {} != model {}", q.len(), model.len()),
            )?;
            assert_that(
                unbounded_used || q.len() <= cap,
                format!("depth {} exceeds capacity {cap}", q.len()),
            )?;
        }
        Ok(())
    });
}

/// Encode (producer, sequence) into a request id so per-producer order is
/// recoverable from any interleaved pop stream.
fn tagged(producer: usize, seq: usize) -> u64 {
    (producer as u64) << 32 | seq as u64
}

/// Randomized shape of a concurrent ring exercise. Shrinks toward fewer
/// producers, fewer requests per producer and a smaller ring, so a
/// failing case minimizes to the tightest schedule that still breaks
/// (floors keep every candidate a meaningful exercise). The failing
/// seed is printed either way, so even an unshrinkably-racy case
/// replays exactly via `WAVESCALE_PROP_SEED`.
#[derive(Clone, Copy, Debug)]
struct RingCase {
    producers: usize,
    per: usize,
    cap: usize,
}

impl Shrink for RingCase {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        for producers in self.producers.shrink() {
            if producers >= 1 {
                out.push(RingCase { producers, ..*self });
            }
        }
        for per in self.per.shrink() {
            if per >= 1 {
                out.push(RingCase { per, ..*self });
            }
        }
        for cap in self.cap.shrink() {
            if cap >= 1 {
                out.push(RingCase { cap, ..*self });
            }
        }
        out
    }
}

#[test]
fn prop_ring_preserves_per_producer_fifo_under_concurrent_pushes() {
    // ISSUE 8 tentpole property: the lock-free ring serializes producers
    // only at the claim CAS, so the strongest order it guarantees is
    // *per-producer* FIFO — every producer's requests come out in the
    // order that producer pushed them, with nothing lost or duplicated,
    // even while a consumer drains concurrently.
    check_shrink(
        "ring per-producer FIFO under contention",
        16,
        // Small rings force the overflow-staging path; larger ones keep
        // most traffic on the lock-free fast path.
        |rng| RingCase {
            producers: rng.index(2, 5),
            per: rng.index(64, 257),
            cap: rng.index(4, 65),
        },
        |case| {
            let RingCase { producers: n_producers, per, cap } = *case;
            let q = Arc::new(ShardQueue::new(cap));
            let handles: Vec<_> = (0..n_producers)
                .map(|p| {
                    let q = q.clone();
                    std::thread::spawn(move || {
                        for s in 0..per {
                            q.push_unbounded(req(tagged(p, s)));
                        }
                    })
                })
                .collect();
            // Single consumer racing the producers (home-worker shape).
            let total = n_producers * per;
            let mut got: Vec<u64> = Vec::with_capacity(total);
            while got.len() < total {
                got.extend(q.pop_upto(16).iter().map(|r| r.id));
            }
            for h in handles {
                h.join().map_err(|_| "producer panicked".to_string())?;
            }
            assert_that(q.len() == 0, "depth mirror nonzero after full drain")?;
            let unique: HashSet<u64> = got.iter().copied().collect();
            assert_that(
                unique.len() == total,
                format!("{} unique of {total}: lost or duplicated requests", unique.len()),
            )?;
            let mut next_seq = vec![0u64; n_producers];
            for id in got {
                let (p, s) = ((id >> 32) as usize, id & 0xffff_ffff);
                assert_that(
                    s == next_seq[p],
                    format!("producer {p}: popped seq {s}, expected {}", next_seq[p]),
                )?;
                next_seq[p] += 1;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ring_capacity_bound_is_exact_under_concurrent_bounded_pushes() {
    // Bounded admission is a backpressure contract: racing try_push
    // callers must never over-admit past the configured capacity, and
    // every accepted request must still be there afterwards.
    check_shrink(
        "ring capacity bound under contention",
        16,
        |rng| RingCase {
            producers: rng.index(2, 6),
            per: rng.index(32, 97),
            cap: rng.index(1, 49),
        },
        |case| {
            let RingCase { producers, per, cap } = *case;
            let q = Arc::new(ShardQueue::new(cap));
            let accepted = Arc::new(AtomicUsize::new(0));
            let handles: Vec<_> = (0..producers)
                .map(|p| {
                    let (q, accepted) = (q.clone(), accepted.clone());
                    std::thread::spawn(move || {
                        for s in 0..per {
                            if q.try_push(req(tagged(p, s))).is_ok() {
                                accepted.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().map_err(|_| "producer panicked".to_string())?;
            }
            let admitted = accepted.load(Ordering::Relaxed);
            assert_that(
                admitted <= cap,
                format!("admitted {admitted} past capacity {cap}"),
            )?;
            assert_that(
                q.len() == admitted,
                format!("depth mirror {} != admitted {admitted}", q.len()),
            )?;
            let drained = q.drain_all();
            let unique: HashSet<u64> = drained.iter().map(|r| r.id).collect();
            assert_that(
                unique.len() == admitted,
                format!("drained {} unique of {admitted} admitted", unique.len()),
            )
        },
    );
}

#[test]
fn prop_ring_drain_never_drops_under_gating_and_failure_churn() {
    // The CC's gate/fail flags race the producers in live fleets; neither
    // flag participates in the queue's memory protocol, so churning them
    // while pushes, steals and pops are in flight must never lose a
    // request: whatever the racing consumers missed, the final drain
    // returns exactly.
    check_shrink(
        "ring conserves work under flag churn",
        12,
        |rng| RingCase {
            producers: rng.index(2, 4),
            per: rng.index(64, 193),
            cap: rng.index(4, 33),
        },
        |case| {
            let RingCase { producers: n_producers, per, cap } = *case;
            let q = Arc::new(ShardQueue::new(cap));
            let stop = Arc::new(AtomicBool::new(false));
            let churn = {
                let (q, stop) = (q.clone(), stop.clone());
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        q.set_gated(true);
                        q.set_failed(true);
                        q.set_failed(false);
                        q.set_gated(false);
                    }
                })
            };
            let producers: Vec<_> = (0..n_producers)
                .map(|p| {
                    let q = q.clone();
                    std::thread::spawn(move || {
                        for s in 0..per {
                            q.push_unbounded(req(tagged(p, s)));
                        }
                    })
                })
                .collect();
            // A racing popper and stealer collect what they can; the drain
            // sweeps the remainder after the producers retire.
            let mut got: Vec<u64> = Vec::new();
            for _ in 0..per {
                got.extend(q.pop_upto(4).iter().map(|r| r.id));
                got.extend(q.steal_upto(2).iter().map(|r| r.id));
            }
            for h in producers {
                h.join().map_err(|_| "producer panicked".to_string())?;
            }
            got.extend(q.drain_all().iter().map(|r| r.id));
            stop.store(true, Ordering::Relaxed);
            churn.join().map_err(|_| "churn thread panicked".to_string())?;
            let total = n_producers * per;
            let unique: HashSet<u64> = got.iter().copied().collect();
            assert_that(
                got.len() == total && unique.len() == total,
                format!(
                    "collected {} ({} unique) of {total}: churn lost or duplicated work",
                    got.len(),
                    unique.len()
                ),
            )?;
            assert_that(q.len() == 0, "depth mirror nonzero after final drain")
        },
    );
}

/// A randomized small scenario spec; every parameter that could matter is
/// drawn from the case rng so failures replay exactly.
fn random_spec(rng: &mut Rng) -> SimSpec {
    let epoch_ms = rng.index(10, 31) as u64;
    SimSpec {
        scenario: (*rng.choose(&Scenario::NAMES)).to_string(),
        epochs: rng.index(3, 6),
        seed: rng.next_u64(),
        peak_rps: rng.range(200.0, 2_500.0),
        n_instances: rng.index(1, 3),
        epoch: Duration::from_millis(epoch_ms),
        batch_timeout: Duration::from_millis(rng.index(2, 9) as u64),
        cycles_per_batch: *rng.choose(&[1.0e4, 1.0e5, 2.0e5]),
        queue_capacity: rng.index(64, 2049),
        policy: *rng.choose(&CapacityPolicy::ALL),
        warmup_epochs: rng.index(0, 3),
        // Conservation/determinism must hold across the whole predictor
        // and guardband configuration space, not just the defaults.
        predictor: *rng.choose(&PredictorKind::ALL),
        qos_target: if rng.bool(0.5) { Some(*rng.choose(&[0.01, 0.05, 0.25])) } else { None },
        // Fault-free by default; the dedicated fault property below draws
        // a scripted plan so the other properties keep their exact
        // no-fault baselines (empty plans are bitwise-neutral).
        faults: FaultPlan::default(),
        // Single-node, migration-free by default for the same reason; the
        // dedicated topology property below draws both.
        n_nodes: 1,
        migrations: MigrationPlan::default(),
        // The batch knob (DESIGN.md S22) rides along in a quarter of the
        // cases: conservation, determinism and the guardband contract
        // must hold with the CC rescaling dispatch batches mid-run.
        adaptive_batch: rng.bool(0.25),
        // Sequential engine by default: these properties pin the golden
        // reference's behavior, and the dedicated equivalence property
        // below runs both engines and diffs the traces.
        parallel: false,
    }
}

/// Randomized shape of a parallel-vs-sequential equivalence case. Shrinks
/// toward shorter horizons, fewer instances and fewer nodes so a failing
/// divergence minimizes to the smallest replay that still splits the
/// engines. Fault-carrying cases keep their epoch/instance counts (the
/// scripted plan is keyed to them); node count always shrinks.
#[derive(Clone, Debug)]
struct EqCase {
    spec: SimSpec,
}

impl Shrink for EqCase {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        let resizable = self.spec.faults.is_empty();
        if resizable {
            for epochs in self.spec.epochs.shrink() {
                if epochs >= 2 {
                    out.push(EqCase { spec: SimSpec { epochs, ..self.spec.clone() } });
                }
            }
            for n_instances in self.spec.n_instances.shrink() {
                if n_instances >= 1 {
                    out.push(EqCase { spec: SimSpec { n_instances, ..self.spec.clone() } });
                }
            }
        }
        for n_nodes in self.spec.n_nodes.shrink() {
            if n_nodes >= 1 {
                out.push(EqCase { spec: SimSpec { n_nodes, ..self.spec.clone() } });
            }
        }
        out
    }
}

#[test]
fn prop_parallel_engine_is_trace_equivalent_to_sequential() {
    // ISSUE 10 tentpole property (DESIGN.md S24): for an arbitrary
    // scenario / policy / predictor / node-count spec — with scripted
    // faults in a third of the cases, and synthetic scale fleets in a
    // quarter — the conservative parallel engine must replay the exact
    // bytes the sequential golden reference produces. The named-matrix
    // version lives in tests/sim_parallel.rs; this one walks the
    // configuration space the matrix cannot enumerate.
    check_shrink(
        "parallel replay == sequential replay",
        24,
        |rng| {
            let mut spec = random_spec(rng);
            spec.epochs = rng.index(3, 7);
            spec.n_nodes = *rng.choose(&[1usize, 1, 2, 4]);
            if rng.bool(0.25) {
                // Synthetic fleets reach group counts (and thus
                // advance-domain counts) no named scenario has.
                spec.scenario = format!("synthetic-{}", rng.index(2, 13));
                spec.n_instances = 1;
            }
            if rng.bool(0.33) {
                let scenario = Scenario::by_name(&spec.scenario, spec.epochs, spec.seed)
                    .expect("generated scenario");
                spec.faults = FaultPlan::scripted(
                    rng.next_u64(),
                    scenario.tenants.len(),
                    spec.n_instances,
                    spec.epochs,
                );
            }
            EqCase { spec }
        },
        |case| {
            let spec = &case.spec;
            let scenario = Scenario::by_name(&spec.scenario, spec.epochs, spec.seed)?;
            let seq = simtest::run(spec).map_err(|e| format!("sequential {spec:?}: {e}"))?;
            let par_spec = SimSpec { parallel: true, ..spec.clone() };
            let par =
                simtest::run(&par_spec).map_err(|e| format!("parallel {par_spec:?}: {e}"))?;
            let js = simtest::trace_json(spec, &scenario, &seq.report).to_string_compact();
            let jp = simtest::trace_json(&par_spec, &scenario, &par.report).to_string_compact();
            assert_that(js == jp, format!("{spec:?}: parallel trace diverged from sequential"))?;
            assert_that(
                seq.accepted == par.accepted,
                format!("{spec:?}: accepted {} vs {}", seq.accepted, par.accepted),
            )?;
            assert_that(
                seq.report.stats.energy_j.to_bits() == par.report.stats.energy_j.to_bits(),
                format!("{spec:?}: engines disagree on integrated energy"),
            )
        },
    );
}

#[test]
fn prop_admitted_equals_completed_plus_failed_and_nothing_is_dropped() {
    check("fleet conserves admitted requests", 100, |rng| {
        let spec = random_spec(rng);
        let out = simtest::run(&spec).map_err(|e| format!("{spec:?}: {e}"))?;
        let mut admitted_total = 0u64;
        for g in &out.report.stats.per_group {
            // The PR-2 shutdown-drain invariant, now property-checked
            // across arbitrary scenarios, policies and gating churn.
            assert_that(
                g.admitted == g.completed + g.failed,
                format!(
                    "{spec:?} {}: admitted {} != completed {} + failed {}",
                    g.name, g.admitted, g.completed, g.failed
                ),
            )?;
            // The native backend cannot fail, so the gated-shard drain
            // must deliver every admitted request to completion.
            assert_that(g.failed == 0, format!("{}: native backend failed", g.name))?;
            admitted_total += g.admitted;
        }
        assert_that(
            admitted_total == out.accepted,
            format!("{spec:?}: accepted {} != admitted {admitted_total}", out.accepted),
        )
    });
}

#[test]
fn prop_fault_injection_preserves_conservation_and_never_drops_work() {
    // Satellite of the fault-injection tentpole: an arbitrary scripted
    // FaultPlan (board failures, stragglers, surges — drawn per case)
    // over an arbitrary scenario/policy/predictor spec must uphold the
    // shutdown-drain invariant. Board failure gates + re-dispatches; it
    // must never lose a request or invent a completion.
    check("faulted fleet conserves admitted requests", 60, |rng| {
        let mut spec = random_spec(rng);
        spec.epochs = rng.index(4, 9);
        let scenario = Scenario::by_name(&spec.scenario, spec.epochs, spec.seed)?;
        spec.faults = FaultPlan::scripted(
            rng.next_u64(),
            scenario.tenants.len(),
            spec.n_instances,
            spec.epochs,
        );
        let out = simtest::run(&spec).map_err(|e| format!("{spec:?}: {e}"))?;
        let mut admitted_total = 0u64;
        for g in &out.report.stats.per_group {
            assert_that(
                g.admitted == g.completed + g.failed,
                format!(
                    "{spec:?} {}: admitted {} != completed {} + failed {}",
                    g.name, g.admitted, g.completed, g.failed
                ),
            )?;
            // Failed boards re-dispatch their queues; the native backend
            // itself cannot fail, so the drain must never drop work.
            assert_that(
                g.failed == 0,
                format!("{spec:?} {}: fault drain dropped {} requests", g.name, g.failed),
            )?;
            admitted_total += g.admitted;
        }
        assert_that(
            admitted_total == out.accepted,
            format!("{spec:?}: accepted {} != admitted {admitted_total}", out.accepted),
        )
    });
}

#[test]
fn prop_migration_conserves_work() {
    // Satellite of the fleet-of-fleets tentpole (DESIGN.md S21.3): an
    // arbitrary coherent scripted MigrationPlan over an arbitrary
    // multi-node spec must uphold the shutdown-drain invariant. A
    // migration gates + drains the source slice and re-dispatches into
    // the destination; it must never lose a request, invent a
    // completion, or perturb determinism.
    check("migrating fleet conserves admitted requests", 30, |rng| {
        let mut spec = random_spec(rng);
        spec.epochs = rng.index(5, 10);
        spec.n_nodes = rng.index(2, 5);
        let scenario = Scenario::by_name(&spec.scenario, spec.epochs, spec.seed)?;
        spec.migrations = MigrationPlan::scripted(
            rng.next_u64(),
            scenario.tenants.len(),
            spec.n_nodes,
            spec.epochs,
        );
        let out = simtest::run(&spec).map_err(|e| format!("{spec:?}: {e}"))?;
        let mut admitted_total = 0u64;
        for g in &out.report.stats.per_group {
            assert_that(
                g.admitted == g.completed + g.failed,
                format!(
                    "{spec:?} {}: admitted {} != completed {} + failed {}",
                    g.name, g.admitted, g.completed, g.failed
                ),
            )?;
            // The native backend cannot fail, so the migration drain must
            // deliver every admitted request to completion: zero drops.
            assert_that(
                g.failed == 0,
                format!("{spec:?} {}: migration dropped {} requests", g.name, g.failed),
            )?;
            admitted_total += g.admitted;
        }
        assert_that(
            admitted_total == out.accepted,
            format!("{spec:?}: accepted {} != admitted {admitted_total}", out.accepted),
        )?;
        // Every scripted move departs before the drive loop ends (the
        // plan leaves the final epochs for the drain), so the executed
        // count must equal the plan exactly.
        assert_that(
            out.report.stats.migrated == spec.migrations.moves.len() as u64,
            format!(
                "{spec:?}: executed {} migrations, plan scripted {}",
                out.report.stats.migrated,
                spec.migrations.moves.len()
            ),
        )?;
        // Migrations stay inside the deterministic replay contract: the
        // same seed over the same plan is bitwise-identical.
        let again = simtest::run(&spec).map_err(|e| format!("{spec:?}: {e}"))?;
        let ja = simtest::trace_json(&spec, &scenario, &out.report).to_string_compact();
        let jb = simtest::trace_json(&spec, &scenario, &again.report).to_string_compact();
        assert_that(ja == jb, format!("{spec:?}: migrating traces diverged"))?;
        assert_that(
            again.report.stats.migrated == out.report.stats.migrated,
            "migration count diverged between identical replays",
        )
    });
}

#[test]
fn prop_same_seed_replays_byte_identically() {
    check("virtual replay deterministic", 100, |rng| {
        let mut spec = random_spec(rng);
        // Keep the doubled runs cheap; determinism is size-independent.
        spec.epochs = rng.index(3, 5);
        spec.n_instances = rng.index(1, 3);
        let scenario = Scenario::by_name(&spec.scenario, spec.epochs, spec.seed)?;
        let a = simtest::run(&spec).map_err(|e| format!("{spec:?}: {e}"))?;
        let b = simtest::run(&spec).map_err(|e| format!("{spec:?}: {e}"))?;
        let ja = simtest::trace_json(&spec, &scenario, &a.report).to_string_compact();
        let jb = simtest::trace_json(&spec, &scenario, &b.report).to_string_compact();
        assert_that(ja == jb, format!("{spec:?}: traces diverged"))?;
        assert_that(a.accepted == b.accepted, "accepted count diverged")?;
        assert_that(
            a.report.stats.energy_j.to_bits() == b.report.stats.energy_j.to_bits(),
            "energy diverged",
        )
    });
}

#[test]
fn prop_adaptive_guardband_never_worse_than_static_on_qos_or_cap() {
    // The guardband's pareto contract (DESIGN.md S7.1), property-checked:
    // with the adaptive guardband enabled, every tenant's violation rate
    // stays within the static-margin baseline's + tolerance — the rate a
    // violation-free decayed window proves is already <= the QoS target —
    // and the applied margin never exceeds the static cap. Tolerance
    // covers one epoch of divergence on short runs (boost timing can
    // shift exactly which epoch a transition violates in).
    check("adaptive violations <= static + tolerance", 30, |rng| {
        let mut spec = random_spec(rng);
        spec.epochs = rng.index(12, 25);
        spec.policy = CapacityPolicy::Hybrid;
        // Compare predictor-identical runs: the Markov chain (the static
        // baseline's predictor) or the conservatively-switching ensemble.
        spec.predictor =
            *rng.choose(&[PredictorKind::Markov, PredictorKind::Ensemble]);
        spec.qos_target = None;
        let stat = simtest::run(&spec).map_err(|e| format!("{spec:?}: {e}"))?;
        let adaptive_spec = SimSpec {
            qos_target: Some(*rng.choose(&[0.01, 0.05, 0.25])),
            ..spec.clone()
        };
        let adaptive =
            simtest::run(&adaptive_spec).map_err(|e| format!("{adaptive_spec:?}: {e}"))?;
        let tolerance = 2.0 / spec.epochs as f64;
        for (gs, ga) in stat
            .report
            .stats
            .per_group
            .iter()
            .zip(&adaptive.report.stats.per_group)
        {
            assert_that(
                ga.violation_rate <= gs.violation_rate + tolerance + 1e-9,
                format!(
                    "{adaptive_spec:?} {}: adaptive violations {} vs static {}",
                    ga.name, ga.violation_rate, gs.violation_rate
                ),
            )?;
        }
        for records in &adaptive.report.epoch_records {
            for r in records {
                assert_that(
                    r.margin <= 0.05 + 1e-12,
                    format!("{adaptive_spec:?}: margin {} above the static cap", r.margin),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ensemble_energy_never_worse_than_the_worst_single_predictor() {
    // The ensemble runs every member shadow-mode and serves with one of
    // them, so its energy must never exceed the worst single predictor's
    // (it could only get there by consistently picking the worst member,
    // which the scoring forbids).
    check("ensemble energy <= worst single predictor", 12, |rng| {
        let mut spec = random_spec(rng);
        spec.epochs = rng.index(8, 13);
        spec.policy = CapacityPolicy::Hybrid;
        spec.qos_target = Some(0.01);
        let energy = |kind: PredictorKind| -> Result<f64, String> {
            let s = SimSpec { predictor: kind, ..spec.clone() };
            simtest::run(&s)
                .map(|o| o.report.stats.energy_j)
                .map_err(|e| format!("{s:?}: {e}"))
        };
        let ensemble = energy(PredictorKind::Ensemble)?;
        let mut worst: f64 = 0.0;
        for kind in [
            PredictorKind::Markov,
            PredictorKind::Periodic,
            PredictorKind::Ewma,
            PredictorKind::LastValue,
        ] {
            worst = worst.max(energy(kind)?);
        }
        assert_that(
            ensemble <= worst * 1.01 + 1e-9,
            format!("{spec:?}: ensemble {ensemble} J > worst single {worst} J + 1%"),
        )
    });
}

#[test]
fn prop_live_hybrid_energy_never_worse_than_baselines() {
    // Fewer cases — each runs the fleet three times — but still a broad
    // sweep; the named-scenario acceptance test in the offline simulator
    // (integration_policies) covers the long-horizon version.
    check("live hybrid <= min(dvfs, pg) + 1%", 40, |rng| {
        let mut spec = random_spec(rng);
        spec.epochs = rng.index(4, 7);
        // Static margin: the hybrid-dominance argument is per-bin at a
        // *fixed* margin level; the guardband's (policy-dependent)
        // margin trajectory is exercised by the other properties. Fixed
        // batch for the same reason — the decided batch follows the
        // frequency, so an adaptive batch would give the dvfs-only
        // baseline policy-dependent extra capacity the per-bin argument
        // does not cover (the batch-policy acceptance test in
        // platform::fleet compares the knob at a fixed policy instead).
        spec.qos_target = None;
        spec.adaptive_batch = false;
        let energy = |policy: CapacityPolicy| -> Result<f64, String> {
            let s = SimSpec { policy, ..spec.clone() };
            simtest::run(&s)
                .map(|o| o.report.stats.energy_j)
                .map_err(|e| format!("{s:?}: {e}"))
        };
        let hybrid = energy(CapacityPolicy::Hybrid)?;
        let dvfs = energy(CapacityPolicy::DvfsOnly)?;
        let pg = energy(CapacityPolicy::GatingOnly)?;
        let best = dvfs.min(pg);
        assert_that(
            hybrid <= best * 1.01 + 1e-9,
            format!("{spec:?}: hybrid {hybrid} J > min(dvfs {dvfs}, pg {pg}) J + 1%"),
        )
    });
}
