//! Parallel-engine equivalence suite (DESIGN.md S24): the conservative
//! parallel discrete-event engine must replay every scenario with the
//! exact bytes the sequential golden reference produces.
//!
//! The sequential `VirtualClock` stays the semantic authority — goldens
//! are recorded against it — and `ParallelVirtualClock` is pinned to it
//! three ways:
//!
//! 1. the full matrix: every named scenario x {hybrid, dvfs-only,
//!    pg-only} x N in {1, 2, 4} nodes replays on both engines and the
//!    trace JSON (plus accepted counts, per-group stats and the bitwise
//!    energy/latency numbers) must match exactly;
//! 2. every committed golden file replays byte-identically on the
//!    parallel engine (tracked files only — bootstrap stays the
//!    sequential suite's job, so this suite never writes);
//! 3. a synthetic scale fleet (more groups than any named scenario, so
//!    dozens of worker advance-domains) round-trips the same way.
//!
//! Everything runs inside ONE `#[test]` on purpose: both engines spawn
//! real worker/CC threads per replay, and sibling tests in parallel
//! (cargo's default) would oversubscribe small CI runners.

use std::path::Path;

use wavescale::simtest::{self, SimSpec};
use wavescale::vscale::CapacityPolicy;
use wavescale::workload::{FaultPlan, Scenario};

const GOLDEN_DIR: &str = "testdata/golden";

/// Replay `spec` on both engines and require byte-identical traces plus
/// bitwise-identical stats. `spec` must be the sequential (golden
/// reference) form; the parallel twin differs only in the engine knob.
fn assert_equivalent(spec: &SimSpec) {
    assert!(!spec.parallel, "pass the sequential reference spec");
    let scenario = Scenario::by_name(&spec.scenario, spec.epochs, spec.seed)
        .unwrap_or_else(|e| panic!("{spec:?}: {e}"));
    let seq = simtest::run(spec).unwrap_or_else(|e| panic!("sequential {spec:?}: {e}"));
    let par_spec = SimSpec { parallel: true, ..spec.clone() };
    let par = simtest::run(&par_spec).unwrap_or_else(|e| panic!("parallel {par_spec:?}: {e}"));

    let js = simtest::trace_json(spec, &scenario, &seq.report).to_string_pretty();
    let jp = simtest::trace_json(&par_spec, &scenario, &par.report).to_string_pretty();
    if js != jp {
        let line = js
            .lines()
            .zip(jp.lines())
            .position(|(a, b)| a != b)
            .map(|i| i + 1)
            .unwrap_or_else(|| js.lines().count().min(jp.lines().count()) + 1);
        panic!("{spec:?}: parallel trace diverged from sequential (first differing line {line})");
    }
    assert_eq!(seq.accepted, par.accepted, "{spec:?}: accepted count diverged");

    // The trace covers the per-epoch CC columns; pin the aggregate stats
    // too, bit for bit — integrated energy and the latency quantiles are
    // exactly the numbers a reordered completion would smear.
    for (gs, gp) in seq.report.stats.per_group.iter().zip(&par.report.stats.per_group) {
        assert_eq!(gs.admitted, gp.admitted, "{spec:?} {}: admitted", gs.name);
        assert_eq!(gs.completed, gp.completed, "{spec:?} {}: completed", gs.name);
        assert_eq!(gs.rejected, gp.rejected, "{spec:?} {}: rejected", gs.name);
        assert_eq!(gs.failed, gp.failed, "{spec:?} {}: failed", gs.name);
        assert!(
            gs.energy_j.to_bits() == gp.energy_j.to_bits(),
            "{spec:?} {}: energy {} vs {}",
            gs.name,
            gs.energy_j,
            gp.energy_j
        );
        assert!(
            gs.p99_latency_s.to_bits() == gp.p99_latency_s.to_bits(),
            "{spec:?} {}: p99 {} vs {}",
            gs.name,
            gs.p99_latency_s,
            gp.p99_latency_s
        );
    }
}

#[test]
fn parallel_engine_matches_the_sequential_reference() {
    // Warm the memoized platform builds (all Table-1 benchmarks appear
    // across the named scenarios) so the matrix measures replays only.
    for name in Scenario::NAMES {
        let warm = SimSpec { epochs: 1, ..SimSpec::golden(name) };
        simtest::run(&warm).expect("warmup run");
    }

    every_scenario_policy_and_node_count_is_trace_equivalent();
    committed_goldens_replay_byte_identically_on_the_parallel_engine();
    synthetic_scale_fleets_are_trace_equivalent();
    parallel_replays_are_deterministic_run_to_run();
}

fn every_scenario_policy_and_node_count_is_trace_equivalent() {
    // The acceptance matrix: 9 scenarios x 3 capacity policies x
    // N in {1, 2, 4} nodes, each replayed on both engines. Short horizon
    // — equivalence is schedule-structural, not length-dependent, and the
    // committed-golden pass below covers the full 48-epoch shape.
    for name in Scenario::NAMES {
        for policy in CapacityPolicy::ALL {
            for n_nodes in [1usize, 2, 4] {
                let spec = SimSpec {
                    scenario: name.to_string(),
                    epochs: 8,
                    policy,
                    n_nodes,
                    // Adversarial scenarios keep their canonical fault
                    // plan in the matrix: gating, re-dispatch and
                    // straggler slowdowns must not break the fence.
                    faults: FaultPlan::for_scenario(name, 1, 2, 8),
                    ..SimSpec::default()
                };
                assert_equivalent(&spec);
            }
        }
    }
}

fn committed_goldens_replay_byte_identically_on_the_parallel_engine() {
    // Tracked goldens are the sequential engine's recorded output; the
    // parallel engine must reproduce the committed files byte for byte.
    // Bootstrap (recording a missing golden) stays sim_golden's job —
    // this pass only ever reads, so it can never mask drift by writing.
    let mut compared = 0usize;
    for name in Scenario::NAMES {
        for spec in [SimSpec::golden(name), SimSpec::golden_adaptive(name)] {
            let path = Path::new(GOLDEN_DIR).join(format!("{}.json", spec.golden_stem()));
            let Ok(existing) = std::fs::read_to_string(&path) else {
                continue; // not bootstrapped in this checkout
            };
            let par_spec = SimSpec { parallel: true, ..spec.clone() };
            let scenario =
                Scenario::by_name(&par_spec.scenario, par_spec.epochs, par_spec.seed).unwrap();
            let out = simtest::run(&par_spec)
                .unwrap_or_else(|e| panic!("parallel {par_spec:?}: {e}"));
            let mut text =
                simtest::trace_json(&par_spec, &scenario, &out.report).to_string_pretty();
            text.push('\n');
            if existing != text {
                let line = existing
                    .lines()
                    .zip(text.lines())
                    .position(|(a, b)| a != b)
                    .map(|i| i + 1)
                    .unwrap_or(0);
                panic!(
                    "parallel replay diverged from committed golden {} \
                     (first differing line {line})",
                    path.display()
                );
            }
            compared += 1;
        }
    }
    if compared == 0 {
        eprintln!(
            "(no committed goldens under {GOLDEN_DIR} — file comparison skipped; \
             the in-memory matrix above still pins equivalence)"
        );
    }
}

fn synthetic_scale_fleets_are_trace_equivalent() {
    // More groups than any named scenario fields (24 worker domains +
    // the control domain), one instance each: the shape the scale sweep
    // (`make sim-scale`) runs at 10/100/1000 groups, kept small here so
    // tier-1 stays fast.
    let spec = SimSpec {
        scenario: "synthetic-24".into(),
        epochs: 6,
        n_instances: 1,
        warmup_epochs: 1,
        ..SimSpec::default()
    };
    assert_equivalent(&spec);
}

fn parallel_replays_are_deterministic_run_to_run() {
    // Equivalence to the sequential engine already implies determinism,
    // but pin it directly too: the failure mode it catches (a racy merge
    // that happens to match sequential once) reports here with a
    // parallel-vs-parallel diff instead of a confusing matrix failure.
    let spec = SimSpec {
        parallel: true,
        epochs: 8,
        ..SimSpec::golden("flash-crowd")
    };
    let scenario = Scenario::by_name(&spec.scenario, spec.epochs, spec.seed).unwrap();
    let a = simtest::run(&spec).unwrap();
    let b = simtest::run(&spec).unwrap();
    let ja = simtest::trace_json(&spec, &scenario, &a.report).to_string_pretty();
    let jb = simtest::trace_json(&spec, &scenario, &b.report).to_string_pretty();
    assert_eq!(ja, jb, "parallel engine must replay byte-identically run to run");
    assert_eq!(a.accepted, b.accepted);
}
