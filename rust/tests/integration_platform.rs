//! Integration: characterization → netlist → STA → power → optimizer →
//! platform simulation, across every Table I benchmark.

use wavescale::arch::TABLE1;
use wavescale::platform::{build_platform, PlatformConfig, Policy};
use wavescale::vscale::Mode;
use wavescale::workload::{bursty, periodic, BurstyConfig};

fn trace() -> Vec<f64> {
    bursty(&BurstyConfig { steps: 500, ..Default::default() }).loads
}

#[test]
fn every_benchmark_simulates_under_every_policy() {
    let t = trace();
    for spec in TABLE1 {
        for policy in [
            Policy::Dvfs(Mode::Proposed),
            Policy::Dvfs(Mode::CoreOnly),
            Policy::Dvfs(Mode::BramOnly),
            Policy::Dvfs(Mode::FreqOnly),
            Policy::DvfsOracle(Mode::Proposed),
            Policy::PowerGating,
            Policy::NominalStatic,
        ] {
            let mut p = build_platform(spec.name, PlatformConfig::default(), policy)
                .unwrap_or_else(|e| panic!("{}: {e}", spec.name));
            let r = p.run(&t);
            assert!(r.avg_power_w.is_finite() && r.avg_power_w > 0.0, "{}", spec.name);
            assert!(r.power_gain >= 0.90, "{} {:?}: gain {}", spec.name, policy, r.power_gain);
            assert_eq!(r.records.len(), t.len());
        }
    }
}

#[test]
fn table2_shape_holds() {
    // The paper's headline ordering on every benchmark:
    // prop > core-only and prop > bram-only; and the bram-only split
    // between memory-heavy (tabla, dnnweaver) and logic-heavy designs.
    let t = trace();
    let gain = |name: &str, policy: Policy| {
        let mut p = build_platform(name, PlatformConfig::default(), policy).unwrap();
        p.run(&t).power_gain
    };
    let mut bram_gains = std::collections::BTreeMap::new();
    for spec in TABLE1 {
        let prop = gain(spec.name, Policy::Dvfs(Mode::Proposed));
        let core = gain(spec.name, Policy::Dvfs(Mode::CoreOnly));
        let bram = gain(spec.name, Policy::Dvfs(Mode::BramOnly));
        assert!(prop > core && prop > bram, "{}: {prop} {core} {bram}", spec.name);
        assert!(prop > 2.5, "{}: prop gain {prop} too small", spec.name);
        bram_gains.insert(spec.name, bram);
    }
    for strong in ["tabla", "dnnweaver"] {
        for weak in ["diannao", "stripes", "proteus"] {
            assert!(
                bram_gains[strong] > bram_gains[weak],
                "bram-only should favour {strong} over {weak}: {bram_gains:?}"
            );
        }
    }
}

#[test]
fn periodic_workload_also_profits() {
    let t = periodic(600, 96, 0.15, 0.85, 0.02, 3);
    let mut p = build_platform(
        "dnnweaver",
        PlatformConfig::default(),
        Policy::Dvfs(Mode::Proposed),
    )
    .unwrap();
    let r = p.run(&t.loads);
    assert!(r.power_gain > 1.5, "gain {}", r.power_gain);
    assert!(r.violation_rate < 0.15, "violations {}", r.violation_rate);
}

#[test]
fn high_load_limits_gain_low_load_maximizes_it() {
    let gain_at = |mean: f64| {
        let t = bursty(&BurstyConfig { steps: 400, mean_load: mean, ..Default::default() });
        let mut p = build_platform("tabla", PlatformConfig::default(), Policy::Dvfs(Mode::Proposed))
            .unwrap();
        p.run(&t.loads).power_gain
    };
    let hi = gain_at(0.9);
    let mid = gain_at(0.5);
    let lo = gain_at(0.15);
    assert!(lo > mid && mid > hi, "gains must fall with load: {lo} {mid} {hi}");
    assert!(hi < 2.0, "little headroom at 90% load: {hi}");
}

#[test]
fn more_fpgas_scale_power_proportionally() {
    let t = trace();
    let avg = |n: usize| {
        let cfg = PlatformConfig { n_fpgas: n, ..Default::default() };
        let mut p = build_platform("tabla", cfg, Policy::NominalStatic).unwrap();
        p.run(&t).avg_power_w
    };
    let p4 = avg(4);
    let p8 = avg(8);
    assert!((p8 / p4 - 2.0).abs() < 0.01, "{p4} {p8}");
}

#[test]
fn warmup_runs_at_nominal() {
    let mut p = build_platform(
        "tabla",
        PlatformConfig { warmup_steps: 10, ..Default::default() },
        Policy::Dvfs(Mode::Proposed),
    )
    .unwrap();
    let r = p.run(&vec![0.2; 50]);
    // During warmup the predictor returns max load -> nominal frequency.
    // (Step 0 frequency was set before any prediction; check steps 1..8.)
    for rec in &r.records[1..8] {
        assert!(rec.freq_ratio > 0.99, "step {}: {}", rec.step, rec.freq_ratio);
    }
    // After warmup it settles near the real load bin.
    for rec in &r.records[20..] {
        assert!(rec.freq_ratio < 0.5, "step {}: {}", rec.step, rec.freq_ratio);
    }
}

#[test]
fn latency_cap_bounds_clock_stretch() {
    // Paper §IV: latency-restricted applications must bound the clock
    // stretch. With cap sw <= 2 the frequency never drops below 0.5.
    let t = trace();
    let cfg = PlatformConfig { latency_cap_sw: Some(2.0), ..Default::default() };
    let mut p = build_platform("tabla", cfg, Policy::Dvfs(Mode::Proposed)).unwrap();
    let r = p.run(&t);
    for rec in &r.records {
        assert!(
            rec.freq_ratio >= 0.5 - 1e-9,
            "step {}: freq {} violates the latency cap",
            rec.step,
            rec.freq_ratio
        );
    }
    // The cap costs power vs the unconstrained run.
    let mut free = build_platform("tabla", PlatformConfig::default(), Policy::Dvfs(Mode::Proposed))
        .unwrap();
    let rf = free.run(&t);
    assert!(r.power_gain <= rf.power_gain + 1e-9, "{} vs {}", r.power_gain, rf.power_gain);
}

#[test]
fn latency_cap_one_means_nominal_frequency() {
    let t = trace();
    let cfg = PlatformConfig { latency_cap_sw: Some(1.0), ..Default::default() };
    let mut p = build_platform("tabla", cfg, Policy::Dvfs(Mode::Proposed)).unwrap();
    let r = p.run(&t);
    for rec in &r.records {
        assert!((rec.freq_ratio - 1.0).abs() < 1e-9);
    }
    // With zero frequency slack there is no voltage headroom either
    // (Eq. 2 binds at sw = 1); the shadow PLL makes this marginally worse
    // than a static platform.
    assert!(r.power_gain >= 0.95, "{}", r.power_gain);
}
