//! Fault-injection acceptance suite (DESIGN.md S20): a mid-run shard
//! failure — plus a straggler window and a correlated load surge — is
//! injected into EVERY named scenario under EVERY capacity policy on the
//! `VirtualClock`, and each run must
//!
//! 1. uphold the conservation invariant `admitted == completed + failed`
//!    per group (with `failed == 0`: the failed board's queue is drained
//!    and re-dispatched, never dropped, and the native backend cannot
//!    fail);
//! 2. replay bitwise run-to-run: the published trace JSON of two runs
//!    with the same seed and the same `FaultPlan` is byte-identical;
//! 3. actually observe the injection: some epoch records a failed board,
//!    and the board has recovered by the final epoch.
//!
//! Cross-path (offline vs live) equivalence is deliberately NOT asserted
//! here — the offline plant has no fault model, so equivalence is a
//! fault-free contract checked by `tests/control_equivalence.rs`.

use wavescale::simtest::{self, SimSpec};
use wavescale::vscale::CapacityPolicy;
use wavescale::workload::{BoardFailure, FaultPlan, Scenario, StragglerWindow, SurgeWindow};

/// An adversarial mid-run plan sized to the fleet layout: the LAST shard
/// of every group fails for the middle third of the run, shard 0 of
/// group 0 straggles at 3x service time over the same stretch, and a
/// 1.5x correlated surge hits every tenant at once.
fn mid_run_plan(n_groups: usize, n_instances: usize, epochs: usize) -> FaultPlan {
    let fail = (epochs / 3).max(1);
    let recover = (epochs * 2 / 3).max(fail + 1);
    FaultPlan {
        board_failures: (0..n_groups)
            .map(|group| BoardFailure {
                group,
                shard: n_instances - 1,
                fail_epoch: fail,
                recover_epoch: recover,
            })
            .collect(),
        stragglers: vec![StragglerWindow {
            group: 0,
            shard: 0,
            from_epoch: fail,
            until_epoch: recover,
            slowdown: 3.0,
        }],
        surges: vec![SurgeWindow { from_epoch: fail, until_epoch: recover, multiplier: 1.5 }],
    }
}

fn assert_conserved(spec: &SimSpec, out: &simtest::SimOutcome) {
    let mut admitted_total = 0u64;
    for g in &out.report.stats.per_group {
        assert_eq!(
            g.admitted,
            g.completed + g.failed,
            "{spec:?} {}: conservation broken under faults",
            g.name
        );
        assert_eq!(g.failed, 0, "{spec:?} {}: fault drain dropped requests", g.name);
        admitted_total += g.admitted;
    }
    assert_eq!(
        admitted_total, out.accepted,
        "{spec:?}: accepted diverged from the per-group admitted sum"
    );
    // The fleet-level re-dispatch counter is the sum of the groups'.
    let redisp: u64 = out.report.stats.per_group.iter().map(|g| g.redispatched).sum();
    assert_eq!(out.report.stats.redispatched, redisp, "{spec:?}: redispatched aggregation");
}

#[test]
fn mid_run_shard_failure_conserves_and_replays_bitwise_on_every_scenario_x_policy() {
    for name in Scenario::NAMES {
        for policy in CapacityPolicy::ALL {
            let mut spec = SimSpec { policy, epochs: 12, ..SimSpec::golden(name) };
            let scenario = Scenario::by_name(name, spec.epochs, spec.seed).unwrap();
            spec.faults =
                mid_run_plan(scenario.tenants.len(), spec.n_instances, spec.epochs);

            let a = simtest::run(&spec).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
            assert_conserved(&spec, &a);

            // The failure must be visible in the published epoch trace —
            // and gone again by the end (recovery un-gates the board).
            for records in &a.report.epoch_records {
                assert!(
                    records.iter().any(|r| r.n_failed >= 1),
                    "{name} x {}: mid-run board failure never observed",
                    policy.name()
                );
                assert_eq!(
                    records.last().unwrap().n_failed,
                    0,
                    "{name} x {}: board must have recovered by the final epoch",
                    policy.name()
                );
            }
            // Group 0's straggler window depresses its capacity factor.
            assert!(
                a.report.epoch_records[0].iter().any(|r| r.slow_factor < 1.0),
                "{name} x {}: straggler window never observed",
                policy.name()
            );

            // Bitwise run-to-run determinism WITH the injected faults.
            let b = simtest::run(&spec).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
            let ja = simtest::trace_json(&spec, &scenario, &a.report).to_string_compact();
            let jb = simtest::trace_json(&spec, &scenario, &b.report).to_string_compact();
            assert_eq!(ja, jb, "{name} x {}: faulted replay diverged", policy.name());
        }
    }
}

#[test]
fn correlated_surge_raises_offered_load_and_nothing_leaks() {
    // A surge-only plan against the identical seed admits strictly more
    // work than the fault-free run (the driver multiplies every tenant's
    // offered load inside the window) and still conserves it all.
    let base = SimSpec { epochs: 10, ..SimSpec::golden("diurnal") };
    let mut surged = base.clone();
    surged.faults = FaultPlan {
        surges: vec![SurgeWindow { from_epoch: 1, until_epoch: 9, multiplier: 2.0 }],
        ..FaultPlan::default()
    };
    let plain = simtest::run(&base).unwrap();
    let spiked = simtest::run(&surged).unwrap();
    assert_conserved(&base, &plain);
    assert_conserved(&surged, &spiked);
    assert!(
        spiked.accepted > plain.accepted,
        "2x surge must admit more work: {} vs {}",
        spiked.accepted,
        plain.accepted
    );
}

#[test]
fn all_boards_failed_falls_back_instead_of_deadlocking() {
    // Adversarial corner: the plan fails EVERY shard of a group at once.
    // The coordinator falls back to serving on the nominal active set
    // (a failed board that still answers beats a wedged drain), so the
    // run completes and conserves rather than deadlocking shutdown.
    let mut spec = SimSpec { epochs: 8, ..SimSpec::golden("overnight") };
    let scenario = Scenario::by_name(&spec.scenario, spec.epochs, spec.seed).unwrap();
    spec.faults = FaultPlan {
        board_failures: (0..scenario.tenants.len())
            .flat_map(|group| {
                (0..spec.n_instances).map(move |shard| BoardFailure {
                    group,
                    shard,
                    fail_epoch: 2,
                    recover_epoch: 6,
                })
            })
            .collect(),
        ..FaultPlan::default()
    };
    let out = simtest::run(&spec).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
    assert_conserved(&spec, &out);
    for records in &out.report.epoch_records {
        assert!(
            records.iter().any(|r| r.n_failed == spec.n_instances),
            "total-outage window never observed"
        );
        assert_eq!(records.last().unwrap().n_failed, 0, "fleet must recover");
    }
}

#[test]
fn scripted_plans_validate_against_the_fleet_layout() {
    // FaultPlan::scripted only emits windows inside the layout it was
    // given, so attaching it to the matching spec always passes start
    // validation — across many seeds.
    for seed in 0..32u64 {
        let mut spec = SimSpec { epochs: 6, ..SimSpec::golden("mixed-tenant") };
        let scenario = Scenario::by_name(&spec.scenario, spec.epochs, spec.seed).unwrap();
        spec.seed = seed;
        spec.faults =
            FaultPlan::scripted(seed, scenario.tenants.len(), spec.n_instances, spec.epochs);
        spec.faults
            .validate(scenario.tenants.len(), spec.n_instances)
            .unwrap_or_else(|e| panic!("seed {seed}: scripted plan invalid: {e}"));
        let out = simtest::run(&spec).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
        assert_conserved(&spec, &out);
    }
}
