//! Integration: the serving coordinator with real PJRT workers.
//! Self-skips when artifacts/ is missing.

use std::time::Duration;

use wavescale::coordinator::{Coordinator, ServingConfig, SubmitError};
use wavescale::platform::{build_platform, PlatformConfig, Policy};
use wavescale::util::prng::Rng;
use wavescale::vscale::Mode;

fn start(cfg: ServingConfig) -> Option<Coordinator> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    let platform = build_platform(
        &cfg.variant.clone(),
        PlatformConfig::default(),
        Policy::Dvfs(cfg.mode),
    )
    .unwrap();
    Some(
        Coordinator::start(
            cfg,
            "artifacts".into(),
            platform.design.clone(),
            platform.optimizer_ref().clone(),
        )
        .expect("coordinator"),
    )
}

#[test]
fn serves_all_submitted_requests() {
    let Some(coord) = start(ServingConfig {
        n_instances: 2,
        epoch: Duration::from_millis(100),
        cycles_per_batch: 1.0e4,
        ..Default::default()
    }) else {
        return;
    };
    let mut rng = Rng::new(1);
    let n = 512;
    for _ in 0..n {
        coord.submit(rng.normal_vec_f32(coord.in_dim)).unwrap();
    }
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    while coord.stats().completed < n && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(20));
    }
    let (stats, records) = coord.shutdown().unwrap();
    assert_eq!(stats.completed, n, "all requests must complete");
    assert_eq!(stats.rejected, 0);
    assert!(stats.p50_latency_s > 0.0);
    assert!(!records.is_empty(), "CC must have recorded epochs");
}

#[test]
fn backpressure_rejects_when_full() {
    let Some(coord) = start(ServingConfig {
        n_instances: 1,
        queue_capacity: 32,
        epoch: Duration::from_millis(100),
        // Very slow simulated FPGA so the queue fills.
        cycles_per_batch: 5.0e7,
        ..Default::default()
    }) else {
        return;
    };
    let mut rng = Rng::new(2);
    let mut saw_full = false;
    for _ in 0..256 {
        if coord.submit(rng.normal_vec_f32(coord.in_dim)) == Err(SubmitError::QueueFull) {
            saw_full = true;
            break;
        }
    }
    assert!(saw_full, "bounded queue must reject under overload");
    let (stats, _) = coord.shutdown().unwrap();
    assert!(stats.rejected > 0);
}

#[test]
fn dvfs_epochs_track_offered_load() {
    let Some(coord) = start(ServingConfig {
        n_instances: 2,
        epoch: Duration::from_millis(80),
        cycles_per_batch: 1.0e4,
        warmup_epochs: 1,
        ..Default::default()
    }) else {
        return;
    };
    let mut rng = Rng::new(3);
    // Busy first phase, idle second phase.
    for _ in 0..600 {
        let _ = coord.submit(rng.normal_vec_f32(coord.in_dim));
        std::thread::sleep(Duration::from_micros(300));
    }
    std::thread::sleep(Duration::from_millis(400));
    let (_stats, records) = coord.shutdown().unwrap();
    assert!(records.len() >= 4, "need epochs: {}", records.len());
    // The last (idle) epochs should run at a lower frequency than the peak.
    let peak = records.iter().map(|r| r.freq_ratio).fold(0.0, f64::max);
    let tail = records.last().unwrap().freq_ratio;
    assert!(tail <= peak, "tail {tail} vs peak {peak}");
    // Voltages are always within the physical grid.
    for r in &records {
        assert!((0.5..=0.8 + 1e-9).contains(&r.vcore), "{r:?}");
        assert!((0.5..=0.95 + 1e-9).contains(&r.vbram), "{r:?}");
        assert!(r.power_w > 0.0);
    }
}
