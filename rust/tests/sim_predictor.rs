//! Acceptance tests for the adaptive predictor ensemble + QoS-feedback
//! guardband on the *live* virtual-time serving path (ISSUE 4).
//!
//! The headline criterion: on all four named scenarios under hybrid
//! capacity (golden-trace parameters), the adaptive ensemble's energy is
//! within 1% of the static-margin Markov baseline while its violation
//! rate stays within 0.5pp — and the adaptive replay is bitwise
//! deterministic run-to-run, like every other simtest spec.

use wavescale::simtest::{self, SimSpec};
use wavescale::workload::Scenario;

#[test]
fn adaptive_ensemble_acceptance_on_all_named_scenarios() {
    for name in Scenario::NAMES {
        let base = simtest::run(&SimSpec::golden(name)).expect("static baseline replay");
        let adaptive =
            simtest::run(&SimSpec::golden_adaptive(name)).expect("adaptive replay");
        let (be, bv) = (base.report.stats.energy_j, base.report.stats.violation_rate);
        let (ae, av) =
            (adaptive.report.stats.energy_j, adaptive.report.stats.violation_rate);
        assert!(
            ae <= be * 1.01,
            "{name}: adaptive ensemble {ae} J vs static markov {be} J (>1% worse)"
        );
        assert!(
            av <= bv + 0.005,
            "{name}: adaptive violations {av} vs static {bv} (+>0.5pp)"
        );
        // The new columns are populated on every epoch record.
        for records in &adaptive.report.epoch_records {
            assert!(!records.is_empty());
            for r in records {
                assert!(!r.predictor.is_empty());
                assert!((0.0..=0.40 + 1e-12).contains(&r.margin), "{name}: {r:?}");
            }
        }
        // Live stats surface the adaptive state.
        for g in &adaptive.report.stats.per_group {
            assert!((0.0..=0.40 + 1e-12).contains(&g.margin_now), "{}", g.name);
            assert!(!g.predictor_now.is_empty());
        }
    }
}

#[test]
fn adaptive_replay_is_bitwise_deterministic() {
    let spec = SimSpec {
        epochs: 12,
        ..SimSpec::golden_adaptive("mixed-tenant")
    };
    let scenario = Scenario::by_name(&spec.scenario, spec.epochs, spec.seed).unwrap();
    let a = simtest::run(&spec).unwrap();
    let b = simtest::run(&spec).unwrap();
    assert_eq!(
        simtest::trace_json(&spec, &scenario, &a.report).to_string_pretty(),
        simtest::trace_json(&spec, &scenario, &b.report).to_string_pretty(),
        "adaptive path must stay byte-identical per seed"
    );
    assert_eq!(a.accepted, b.accepted);
    assert!(
        a.report.stats.energy_j.to_bits() == b.report.stats.energy_j.to_bits(),
        "energy must be bitwise deterministic"
    );
}

#[test]
fn guardband_reacts_on_the_live_path() {
    // A long, loose-target overnight run: the rolling violation window
    // fills and proves the (generous) QoS target, so the margin must
    // decay below the static 5% — while never exceeding the static cap
    // (the default pareto-no-worse contract).
    let spec = SimSpec {
        epochs: 96,
        qos_target: Some(0.25),
        ..SimSpec::golden_adaptive("overnight")
    };
    let out = simtest::run(&spec).unwrap();
    let margins: Vec<f64> = out
        .report
        .epoch_records
        .iter()
        .flat_map(|rs| rs.iter().map(|r| r.margin))
        .collect();
    assert!(!margins.is_empty());
    // Starts at the static margin...
    assert!((margins[0] - 0.05).abs() < 1e-12, "first epoch margin {}", margins[0]);
    // ...decays below it once the window proves the target...
    assert!(
        margins.iter().any(|&m| m < 0.05 - 1e-12),
        "decay must undercut the static margin: {margins:?}"
    );
    // ...and never exceeds the default cap.
    assert!(
        margins.iter().all(|&m| m <= 0.05 + 1e-12),
        "default guardband must never spend more margin than static: {margins:?}"
    );
}
