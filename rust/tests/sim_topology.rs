//! Multi-node topology acceptance suite (DESIGN.md S21): the fleet-of-
//! fleets refactor must not change a single observable number.
//!
//! 1. every named scenario replays on 2- and 4-node fleets with the
//!    conservation invariant (`admitted == completed + failed`, zero
//!    drops) intact, and the per-group epoch trace is **bit-identical to
//!    the 1-node run** — spreading groups over node agents moves where
//!    the work executes, never what the controller decides;
//! 2. the same multi-node seed replays byte-identically run to run;
//! 3. scripted migrations (DESIGN.md S21.3) execute exactly as planned,
//!    conserve all admitted work, and keep the epoch trace travelling
//!    with the controller in order;
//! 4. 1-node specs keep the legacy golden keys and the legacy trace
//!    bytes — no `n_nodes`/`migrations` header fields, no `_n{N}` stem
//!    suffix — so committed goldens never churn.

use wavescale::coordinator::MigrationPlan;
use wavescale::simtest::{self, SimSpec};
use wavescale::workload::Scenario;

fn assert_conserved(spec: &SimSpec, out: &simtest::SimOutcome) {
    let mut admitted_total = 0u64;
    for g in &out.report.stats.per_group {
        assert_eq!(
            g.admitted,
            g.completed + g.failed,
            "{spec:?} {}: conservation broken across nodes",
            g.name
        );
        assert_eq!(g.failed, 0, "{spec:?} {}: topology layer dropped requests", g.name);
        admitted_total += g.admitted;
    }
    assert_eq!(
        admitted_total, out.accepted,
        "{spec:?}: accepted diverged from the per-group admitted sum"
    );
}

#[test]
fn multi_node_fleets_match_the_single_node_trace_on_every_scenario() {
    for name in Scenario::NAMES {
        let base = SimSpec { scenario: name.to_string(), epochs: 10, ..SimSpec::default() };
        let single = simtest::run(&base).unwrap_or_else(|e| panic!("{base:?}: {e}"));
        assert_conserved(&base, &single);
        for n_nodes in [2usize, 4] {
            let spec = SimSpec { n_nodes, ..base.clone() };
            let scenario = Scenario::by_name(name, spec.epochs, spec.seed).unwrap();
            let a = simtest::run(&spec).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
            assert_conserved(&spec, &a);

            // Round-robin spread: group gi lives on node gi % N for the
            // whole migration-free run, and nothing ever moves.
            for (gi, g) in a.report.stats.per_group.iter().enumerate() {
                assert_eq!(
                    g.node_now,
                    format!("node{}", gi % n_nodes),
                    "{name} x {n_nodes} nodes: group {gi} hosted off its home node"
                );
                assert_eq!(g.migrated, 0, "{name}: migration-free run migrated");
            }

            // Node-count invariance, bit for bit: same loads, same
            // decisions, same published epoch records as the 1-node run.
            assert_eq!(
                a.report.epoch_records, single.report.epoch_records,
                "{name} x {n_nodes} nodes: epoch trace diverged from the 1-node fleet"
            );
            assert_eq!(
                a.report.decision_records, single.report.decision_records,
                "{name} x {n_nodes} nodes: decision log diverged from the 1-node fleet"
            );

            // Run-to-run bitwise determinism at N > 1.
            let b = simtest::run(&spec).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
            let ja = simtest::trace_json(&spec, &scenario, &a.report).to_string_compact();
            let jb = simtest::trace_json(&spec, &scenario, &b.report).to_string_compact();
            assert_eq!(ja, jb, "{name} x {n_nodes} nodes: replay diverged");
        }
    }
}

#[test]
fn scripted_migrations_execute_as_planned_and_conserve_work() {
    // A coherent scripted plan over a 3-node mixed-tenant fleet: every
    // move departs where the plan expects (the chained generator
    // guarantees it), so the executed count equals the plan exactly and
    // the drain hands every queued request to the destination.
    for seed in [3u64, 11, 2019] {
        let mut spec = SimSpec {
            scenario: "mixed-tenant".into(),
            epochs: 12,
            n_nodes: 3,
            seed,
            ..SimSpec::default()
        };
        let scenario = Scenario::by_name(&spec.scenario, spec.epochs, spec.seed).unwrap();
        spec.migrations =
            MigrationPlan::scripted(seed, scenario.tenants.len(), spec.n_nodes, spec.epochs);
        spec.migrations
            .validate(scenario.tenants.len(), spec.n_nodes)
            .unwrap_or_else(|e| panic!("seed {seed}: scripted plan invalid: {e}"));

        let out = simtest::run(&spec).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
        assert_conserved(&spec, &out);
        assert_eq!(
            out.report.stats.migrated,
            spec.migrations.moves.len() as u64,
            "{spec:?}: executed migrations diverged from the scripted plan"
        );
        let migrated: u64 = out.report.stats.per_group.iter().map(|g| g.migrated).sum();
        assert_eq!(out.report.stats.migrated, migrated, "{spec:?}: migrated aggregation");

        // The epoch trace travels with the controller: records stay in
        // strictly increasing epoch order across every hand-off (an
        // adoption may cost one epoch of records, never reorder them).
        for (gi, records) in out.report.epoch_records.iter().enumerate() {
            assert!(!records.is_empty(), "{spec:?}: group {gi} trace lost in migration");
            for w in records.windows(2) {
                assert!(
                    w[0].epoch < w[1].epoch,
                    "{spec:?}: group {gi} trace reordered across a hand-off"
                );
            }
        }

        // Migrations stay inside the bitwise replay contract.
        let again = simtest::run(&spec).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
        let ja = simtest::trace_json(&spec, &scenario, &out.report).to_string_compact();
        let jb = simtest::trace_json(&spec, &scenario, &again.report).to_string_compact();
        assert_eq!(ja, jb, "seed {seed}: migrating replay diverged");
    }
}

#[test]
fn single_node_specs_keep_the_legacy_golden_keys_and_trace_bytes() {
    // The 1-node path is the pre-topology coordinator, bit for bit: its
    // golden stem carries no node suffix and its trace JSON carries no
    // topology header fields, so every committed golden survives PR 7
    // unchanged.
    let spec = SimSpec { epochs: 6, ..SimSpec::default() };
    assert_eq!(spec.golden_stem(), "overnight_hybrid");
    let scenario = Scenario::by_name(&spec.scenario, spec.epochs, spec.seed).unwrap();
    let out = simtest::run(&spec).unwrap();
    let text = simtest::trace_json(&spec, &scenario, &out.report).to_string_compact();
    assert!(!text.contains("n_nodes"), "1-node trace must not grow topology fields");
    assert!(!text.contains("migrations"), "1-node trace must not grow a migration field");

    // Multi-node specs get their own golden namespace and do publish the
    // topology header.
    let spec4 = SimSpec { n_nodes: 4, ..spec.clone() };
    assert_eq!(spec4.golden_stem(), "overnight_hybrid_n4");
    let out4 = simtest::run(&spec4).unwrap();
    let text4 = simtest::trace_json(&spec4, &scenario, &out4.report).to_string_compact();
    assert!(text4.contains("\"n_nodes\""), "multi-node trace must record the layout");
    assert!(text4.contains("\"migrations\""), "multi-node trace must record the plan");
}
