//! Integration: live multi-tenant fleet serving end-to-end — shard
//! dispatch, per-shard backpressure, drain-on-shutdown, and the fleet
//! report's per-group QoS aggregation. These tests never self-skip: when
//! `artifacts/` (or the PJRT runtime) is absent the coordinator falls
//! back to the deterministic native backend.

use std::time::Duration;

use wavescale::coordinator::{
    FleetServing, FleetServingConfig, GroupConfig, QueueFull, ServingConfig,
};
use wavescale::platform::{build_platform, PlatformConfig, Policy};
use wavescale::util::prng::Rng;
use wavescale::vscale::Mode;

fn two_group_cfg() -> FleetServingConfig {
    FleetServingConfig {
        groups: vec![
            GroupConfig { benchmark: "tabla".into(), share: 0.5, n_instances: 2 },
            GroupConfig { benchmark: "dnnweaver".into(), share: 0.5, n_instances: 2 },
        ],
        epoch: Duration::from_millis(50),
        cycles_per_batch: 1.0e4,
        warmup_epochs: 0,
        ..Default::default()
    }
}

#[test]
fn fleet_serves_two_groups_and_reports_per_group_qos() {
    let fleet = FleetServing::start(two_group_cfg(), "artifacts".into()).unwrap();
    assert_eq!(fleet.n_groups(), 2);
    assert_eq!(fleet.group_index("tabla"), Some(0));
    assert_eq!(fleet.group_index("dnnweaver"), Some(1));
    assert_eq!(fleet.group_index("nope"), None);
    assert_eq!(fleet.group_names(), vec!["tabla".to_string(), "dnnweaver".to_string()]);

    let mut rng = Rng::new(5);
    let mut sent = [0u64; 2];
    for i in 0..400 {
        let gi = i % 2;
        if fleet.submit(gi, rng.normal_vec_f32(fleet.in_dim(gi))).is_ok() {
            sent[gi] += 1;
        }
    }
    // Let a few DVFS epochs elapse so the CC records per-group decisions.
    std::thread::sleep(Duration::from_millis(220));
    let report = fleet.shutdown().unwrap();

    assert_eq!(report.stats.per_group.len(), 2);
    assert_eq!(report.epoch_records.len(), 2);
    for (gi, g) in report.stats.per_group.iter().enumerate() {
        assert_eq!(g.completed, sent[gi], "{}: all accepted requests complete", g.name);
        assert!((0.0..=1.0).contains(&g.violation_rate), "{}: {}", g.name, g.violation_rate);
        assert!(g.power_gain > 0.5, "{}: gain {}", g.name, g.power_gain);
        assert!(g.epochs >= 1, "{}: CC must have run", g.name);
        assert!(g.p50_latency_s > 0.0 && g.p99_latency_s >= g.p50_latency_s);
        assert!(!report.epoch_records[gi].is_empty());
        // Published operating points stay on the physical grid.
        for r in &report.epoch_records[gi] {
            assert!((0.5..=0.8 + 1e-9).contains(&r.vcore), "{r:?}");
            assert!((0.5..=0.95 + 1e-9).contains(&r.vbram), "{r:?}");
            assert!(r.power_w > 0.0);
        }
    }
    // Fleet aggregates are sums / worst-case of the groups.
    let total: u64 = report.stats.per_group.iter().map(|g| g.completed).sum();
    assert_eq!(report.stats.completed, total);
    let worst = report
        .stats
        .per_group
        .iter()
        .map(|g| g.violation_rate)
        .fold(0.0, f64::max);
    assert!((report.stats.violation_rate - worst).abs() < 1e-12);
}

#[test]
fn per_shard_backpressure_rejects_under_overload() {
    let cfg = FleetServingConfig {
        groups: vec![GroupConfig { benchmark: "tabla".into(), share: 1.0, n_instances: 2 }],
        epoch: Duration::from_millis(100),
        // Tiny total capacity (split across 2 shards) + very slow service.
        queue_capacity: 8,
        cycles_per_batch: 5.0e7,
        ..Default::default()
    };
    let fleet = FleetServing::start(cfg, "artifacts".into()).unwrap();
    let mut rng = Rng::new(2);
    let mut saw_full = false;
    for _ in 0..256 {
        if fleet.submit(0, rng.normal_vec_f32(fleet.in_dim(0))) == Err(QueueFull) {
            saw_full = true;
            break;
        }
    }
    assert!(saw_full, "bounded shards must reject under overload");
    let stats = fleet.stats();
    assert!(stats.rejected > 0);
    // Queued work never exceeds the configured bound.
    assert!(fleet.queue_len(0) <= 8, "queue {}", fleet.queue_len(0));
    let report = fleet.shutdown().unwrap();
    assert!(report.stats.per_group[0].rejected > 0);
}

#[test]
fn single_tenant_coordinator_facade_still_serves() {
    // The legacy Coordinator API rides on the sharded fleet path.
    let platform = build_platform(
        "tabla",
        PlatformConfig::default(),
        Policy::Dvfs(Mode::Proposed),
    )
    .unwrap();
    let coord = wavescale::coordinator::Coordinator::start(
        ServingConfig {
            n_instances: 2,
            epoch: Duration::from_millis(50),
            cycles_per_batch: 1.0e4,
            ..Default::default()
        },
        "artifacts".into(),
        platform.design.clone(),
        platform.optimizer_ref().clone(),
    )
    .unwrap();
    let mut rng = Rng::new(9);
    let n = 128u64;
    for _ in 0..n {
        coord.submit(rng.normal_vec_f32(coord.in_dim)).unwrap();
    }
    std::thread::sleep(Duration::from_millis(150));
    let (stats, records) = coord.shutdown().unwrap();
    assert_eq!(stats.completed, n);
    assert_eq!(stats.rejected, 0);
    assert!(!records.is_empty(), "CC must record epochs");
    assert!(stats.backend == "pjrt" || stats.backend == "native");
}
