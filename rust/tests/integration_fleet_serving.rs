//! Integration: live multi-tenant fleet serving end-to-end — shard
//! dispatch, per-shard backpressure, elastic gating (drain/re-dispatch),
//! drain-on-shutdown, the typed submit errors, and the fleet report's
//! per-group QoS aggregation. These tests never self-skip: when
//! `artifacts/` (or the PJRT runtime) is absent the coordinator falls
//! back to the deterministic native backend.

use std::time::Duration;

use wavescale::coordinator::{
    drive_scenario, DispatchPolicy, FleetServing, FleetServingConfig, GroupConfig,
    ServingConfig, SubmitError,
};
use wavescale::platform::{build_platform, PlatformConfig, Policy};
use wavescale::util::prng::Rng;
use wavescale::vscale::{CapacityPolicy, Mode};
use wavescale::workload::Scenario;

fn two_group_cfg() -> FleetServingConfig {
    FleetServingConfig {
        groups: vec![
            GroupConfig {
                benchmark: "tabla".into(),
                share: 0.5,
                n_instances: 2,
                qos_target: None,
            },
            GroupConfig {
                benchmark: "dnnweaver".into(),
                share: 0.5,
                n_instances: 2,
                qos_target: None,
            },
        ],
        epoch: Duration::from_millis(50),
        cycles_per_batch: 1.0e4,
        warmup_epochs: 0,
        ..Default::default()
    }
}

#[test]
fn fleet_serves_two_groups_and_reports_per_group_qos() {
    let fleet = FleetServing::start(two_group_cfg(), "artifacts".into()).unwrap();
    assert_eq!(fleet.n_groups(), 2);
    assert_eq!(fleet.group_index("tabla"), Some(0));
    assert_eq!(fleet.group_index("dnnweaver"), Some(1));
    assert_eq!(fleet.group_index("nope"), None);
    assert_eq!(fleet.group_names(), vec!["tabla".to_string(), "dnnweaver".to_string()]);

    let mut rng = Rng::new(5);
    let mut sent = [0u64; 2];
    for i in 0..400 {
        let gi = i % 2;
        if fleet.submit(gi, rng.normal_vec_f32(fleet.in_dim(gi))).is_ok() {
            sent[gi] += 1;
        }
    }
    // Let a few DVFS epochs elapse so the CC records per-group decisions.
    std::thread::sleep(Duration::from_millis(220));
    let report = fleet.shutdown().unwrap();

    assert_eq!(report.stats.per_group.len(), 2);
    assert_eq!(report.epoch_records.len(), 2);
    for (gi, g) in report.stats.per_group.iter().enumerate() {
        assert_eq!(g.completed, sent[gi], "{}: all accepted requests complete", g.name);
        assert!((0.0..=1.0).contains(&g.violation_rate), "{}: {}", g.name, g.violation_rate);
        assert!(g.power_gain > 0.5, "{}: gain {}", g.name, g.power_gain);
        assert!(g.epochs >= 1, "{}: CC must have run", g.name);
        assert!(g.p50_latency_s > 0.0 && g.p99_latency_s >= g.p50_latency_s);
        assert!(!report.epoch_records[gi].is_empty());
        // Published operating points stay on the physical grid.
        for r in &report.epoch_records[gi] {
            assert!((0.5..=0.8 + 1e-9).contains(&r.vcore), "{r:?}");
            assert!((0.5..=0.95 + 1e-9).contains(&r.vbram), "{r:?}");
            assert!(r.power_w > 0.0);
        }
    }
    // Fleet aggregates are sums / worst-case of the groups.
    let total: u64 = report.stats.per_group.iter().map(|g| g.completed).sum();
    assert_eq!(report.stats.completed, total);
    let worst = report
        .stats
        .per_group
        .iter()
        .map(|g| g.violation_rate)
        .fold(0.0, f64::max);
    assert!((report.stats.violation_rate - worst).abs() < 1e-12);
}

#[test]
fn per_shard_backpressure_rejects_under_overload() {
    let cfg = FleetServingConfig {
        groups: vec![GroupConfig {
            benchmark: "tabla".into(),
            share: 1.0,
            n_instances: 2,
            qos_target: None,
        }],
        epoch: Duration::from_millis(100),
        // Tiny total capacity (split across 2 shards) + very slow service.
        queue_capacity: 8,
        cycles_per_batch: 5.0e7,
        ..Default::default()
    };
    let fleet = FleetServing::start(cfg, "artifacts".into()).unwrap();
    let mut rng = Rng::new(2);
    let mut saw_full = false;
    for _ in 0..256 {
        if fleet.submit(0, rng.normal_vec_f32(fleet.in_dim(0))) == Err(SubmitError::QueueFull) {
            saw_full = true;
            break;
        }
    }
    assert!(saw_full, "bounded shards must reject under overload");
    let stats = fleet.stats();
    assert!(stats.rejected > 0);
    // Queued work never exceeds the configured bound.
    assert!(fleet.queue_len(0) <= 8, "queue {}", fleet.queue_len(0));
    let report = fleet.shutdown().unwrap();
    assert!(report.stats.per_group[0].rejected > 0);
}

#[test]
fn submit_errors_are_typed_not_panics() {
    let fleet = FleetServing::start(two_group_cfg(), "artifacts".into()).unwrap();
    let in_dim = fleet.in_dim(0);

    // Unknown benchmark name: Err, not the former panic.
    assert_eq!(
        fleet.submit_to("nonexistent", vec![0.0; in_dim]),
        Err(SubmitError::UnknownGroup("nonexistent".into()))
    );
    // Out-of-range group index: Err, not an index panic.
    assert!(matches!(
        fleet.submit(99, vec![0.0; in_dim]),
        Err(SubmitError::UnknownGroup(_))
    ));
    // Wrong-width payload: Err, not the former assert_eq abort.
    assert_eq!(
        fleet.submit(0, vec![0.0; 3]),
        Err(SubmitError::BadPayload { expected: in_dim, got: 3 })
    );
    // Errors render for callers' logs.
    assert!(SubmitError::QueueFull.to_string().contains("capacity"));

    // The happy path still works by name and by index.
    assert!(fleet.submit_to("tabla", vec![0.0; in_dim]).is_ok());
    assert!(fleet.submit(1, vec![0.0; fleet.in_dim(1)]).is_ok());
    let report = fleet.shutdown().unwrap();
    assert_eq!(report.stats.completed, 2);
    assert_eq!(report.stats.rejected, 0, "typed errors must not count as backpressure");
}

#[test]
fn drive_scenario_survives_overlong_epochs() {
    // With a 1 ms fleet epoch the submission loop inevitably overruns the
    // epoch budget; the driver used to panic on `epoch - elapsed`
    // Duration underflow.
    let scenario = Scenario::by_name("overnight", 3, 11).unwrap();
    let cfg = FleetServingConfig {
        groups: scenario
            .tenants
            .iter()
            .map(|t| GroupConfig {
                benchmark: t.benchmark.clone(),
                share: t.share,
                n_instances: 1,
                qos_target: t.qos_target,
            })
            .collect(),
        epoch: Duration::from_millis(1),
        cycles_per_batch: 1.0e4,
        warmup_epochs: 0,
        ..Default::default()
    };
    let fleet = FleetServing::start(cfg, "artifacts".into()).unwrap();
    let accepted = drive_scenario(&fleet, &scenario, 2_000.0, 3);
    let report = fleet.shutdown().unwrap();
    assert_eq!(report.stats.completed, accepted, "drained exactly what was accepted");
}

#[test]
fn gated_shard_requests_are_redispatched_never_dropped() {
    // Elastic manager end-to-end: at ~6% offered load on 4 instances the
    // CC gates most of them; requests already queued on a gated shard
    // (round-robin spread them everywhere) must be drained into active
    // shards and completed, never dropped.
    let cfg = FleetServingConfig {
        groups: vec![GroupConfig {
            benchmark: "tabla".into(),
            share: 1.0,
            n_instances: 4,
            qos_target: None,
        }],
        epoch: Duration::from_millis(40),
        cycles_per_batch: 2.0e5,
        warmup_epochs: 0,
        dispatch: DispatchPolicy::RoundRobin,
        capacity_policy: CapacityPolicy::Hybrid,
        ..Default::default()
    };
    let fleet = FleetServing::start(cfg, "artifacts".into()).unwrap();
    let mut rng = Rng::new(4);
    let mut accepted = 0u64;
    for _ in 0..300 {
        if fleet.submit(0, rng.normal_vec_f32(fleet.in_dim(0))).is_ok() {
            accepted += 1;
        }
        std::thread::sleep(Duration::from_micros(500));
    }
    // Wait until the CC has taken several gating decisions (poll, not a
    // fixed sleep — a starved CC thread on a loaded CI runner would
    // otherwise record no epochs at all).
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    while fleet.stats().per_group[0].epochs < 5 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(20));
    }
    let report = fleet.shutdown().unwrap();
    let g = &report.stats.per_group[0];
    assert_eq!(g.completed, accepted, "gated shards must drain, not drop");
    assert!(
        report.epoch_records[0].iter().any(|r| r.n_active < 4),
        "a ~6% load must gate instances: {:?}",
        report.epoch_records[0]
    );
}

#[test]
fn single_tenant_coordinator_facade_still_serves() {
    // The legacy Coordinator API rides on the sharded fleet path.
    let platform = build_platform(
        "tabla",
        PlatformConfig::default(),
        Policy::Dvfs(Mode::Proposed),
    )
    .unwrap();
    let coord = wavescale::coordinator::Coordinator::start(
        ServingConfig {
            n_instances: 2,
            epoch: Duration::from_millis(50),
            cycles_per_batch: 1.0e4,
            ..Default::default()
        },
        "artifacts".into(),
        platform.design.clone(),
        platform.optimizer_ref().clone(),
    )
    .unwrap();
    let mut rng = Rng::new(9);
    let n = 128u64;
    for _ in 0..n {
        coord.submit(rng.normal_vec_f32(coord.in_dim)).unwrap();
    }
    std::thread::sleep(Duration::from_millis(150));
    let (stats, records) = coord.shutdown().unwrap();
    assert_eq!(stats.completed, n);
    assert_eq!(stats.rejected, 0);
    assert!(!records.is_empty(), "CC must record epochs");
    assert!(stats.backend == "pjrt" || stats.backend == "native");
}
