//! Virtual-time serving simulation + golden-trace harness (DESIGN.md S18).
//!
//! `simtest` replays a named workload scenario against the *live*
//! coordinator ([`FleetServing`]) on a
//! [`VirtualClock`](crate::clock::VirtualClock): workers, the Central
//! Controller and the scenario driver all run as real threads, but time is
//! deterministic discrete-event simulation time, so
//!
//! * a thousand-epoch scenario replays in milliseconds of wall time, and
//! * two runs with the same [`SimSpec`] produce **byte-identical** JSON
//!   epoch traces.
//!
//! `SimSpec.parallel` swaps in the conservative parallel engine
//! ([`ParallelVirtualClock`], DESIGN.md S24), which runs independent
//! tenant groups concurrently between CC-epoch barriers and — by the
//! equivalence contract asserted in `tests/sim_parallel.rs` — produces
//! the *same bytes* as the sequential golden reference.
//!
//! On top of [`run`] sits the golden-trace harness: [`check_golden`]
//! replays a spec, serializes the per-group [`EpochRecord`] trace with
//! [`trace_json`], and compares it against the committed file under
//! `rust/testdata/golden/`. A missing file is *recorded* (first-run
//! bootstrap) and must be committed; a mismatch fails with a pointer to
//! `make golden`, which regenerates the whole suite
//! (`WAVESCALE_UPDATE_GOLDEN=1`).
//!
//! Determinism notes: simulations force the native inference backend (a
//! nonexistent artifacts dir) and the native voltage selector, so traces
//! do not depend on whether `make artifacts` ran; every stochastic input
//! derives from the spec seed (trace generation, per-tenant payload
//! streams); and the virtual scheduler breaks ties by actor id. The
//! built `(design, optimizer)` pairs are memoized per benchmark, so
//! property suites can start hundreds of fleets without re-running
//! netlist generation + STA each time.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};
// detlint: allow(wallclock) -- Instant only feeds SimOutcome::wall (how
// long the test harness took); the simulation runs on VirtualClock
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::clock::{ActorScope, Clock, ParallelVirtualClock, VirtualClock};
use crate::coordinator::{
    drive_scenario, EpochRecord, FleetServing, FleetServingConfig, FleetServingReport,
    MigrationPlan,
};
use crate::markov::PredictorKind;
use crate::platform::{build_platform, PlatformConfig, Policy};
use crate::power::DesignPower;
use crate::util::json::Json;
use crate::vscale::{CapacityPolicy, Mode, Optimizer};
use crate::workload::{FaultPlan, Scenario};

/// An artifacts directory that never exists: simulations always use the
/// deterministic native backend so traces are environment-independent.
const NO_ARTIFACTS: &str = "sim-no-artifacts";

/// Everything that parameterizes one deterministic serving simulation.
#[derive(Clone, Debug)]
pub struct SimSpec {
    /// Named scenario ([`Scenario::NAMES`]).
    pub scenario: String,
    /// Scenario steps == fleet DVFS epochs driven.
    pub epochs: usize,
    /// Seed for trace generation and payload streams.
    pub seed: u64,
    /// Peak offered load (requests/s across the fleet at trace load 1.0).
    pub peak_rps: f64,
    /// Worker instances per tenant group.
    pub n_instances: usize,
    /// Virtual DVFS epoch length.
    pub epoch: Duration,
    /// Worker batch wait (kept a divisor of `epoch` so idle parks stay
    /// cheap in the discrete-event scheduler).
    pub batch_timeout: Duration,
    /// Cycles one batch occupies an instance.
    pub cycles_per_batch: f64,
    /// Total queued requests a group may hold.
    pub queue_capacity: usize,
    /// Capacity policy under test (hybrid / dvfs-only / pg-only).
    pub policy: CapacityPolicy,
    /// Pure-training epochs before predictions are trusted.
    pub warmup_epochs: usize,
    /// Workload predictor driving every group's CC (DESIGN.md S7).
    pub predictor: PredictorKind,
    /// `Some(target)` enables the adaptive QoS-feedback guardband
    /// (DESIGN.md S7.1).
    pub qos_target: Option<f64>,
    /// Deterministic fault-injection schedule (DESIGN.md S20). The
    /// default empty plan is bitwise-neutral; [`SimSpec::golden`]
    /// attaches each adversarial scenario's canonical plan so its golden
    /// trace captures the injected faults.
    pub faults: FaultPlan,
    /// Serving nodes (DESIGN.md S21). The default `1` is the legacy
    /// single-process layout — bit-identical to the pre-topology path,
    /// so every committed golden is keyed to it.
    pub n_nodes: usize,
    /// Deterministic scripted migration schedule (DESIGN.md S21.3); the
    /// default empty plan is bitwise-neutral.
    pub migrations: MigrationPlan,
    /// Let every group's CC scale the dispatch batch with its frequency
    /// decision (DESIGN.md S22). The default `false` pins the nominal
    /// batch, which is bitwise-neutral — committed goldens stay keyed to
    /// the fixed-batch path.
    pub adaptive_batch: bool,
    /// Replay on the conservative parallel engine
    /// ([`ParallelVirtualClock`], DESIGN.md S24) instead of the sequential
    /// golden reference. Every replay builds a fresh engine, and parallel
    /// traces are byte-identical to sequential ones by contract
    /// (`tests/sim_parallel.rs`), so the golden stem — and the trace JSON
    /// — deliberately do not key on this knob.
    pub parallel: bool,
}

impl Default for SimSpec {
    fn default() -> Self {
        SimSpec {
            scenario: "overnight".into(),
            epochs: 24,
            seed: 2019,
            peak_rps: 2_000.0,
            n_instances: 2,
            epoch: Duration::from_millis(50),
            batch_timeout: Duration::from_millis(10),
            cycles_per_batch: 2.0e5,
            queue_capacity: 4096,
            policy: CapacityPolicy::Hybrid,
            warmup_epochs: 2,
            predictor: PredictorKind::Markov,
            qos_target: None,
            faults: FaultPlan::default(),
            n_nodes: 1,
            migrations: MigrationPlan::default(),
            adaptive_batch: false,
            parallel: false,
        }
    }
}

impl SimSpec {
    /// The canonical golden-trace spec for a named scenario: 48 epochs,
    /// seed 2019, hybrid capacity. Golden files are keyed on
    /// `{scenario}_{policy}` so keep these parameters stable.
    pub fn golden(scenario: &str) -> SimSpec {
        // Adversarial scenarios carry their canonical fault plan (group 0
        // of the golden 2-instance layout); every other name resolves to
        // the empty — bitwise-neutral — plan, so legacy goldens are
        // untouched.
        SimSpec {
            scenario: scenario.into(),
            epochs: 48,
            faults: FaultPlan::for_scenario(scenario, 1, 2, 48),
            ..SimSpec::default()
        }
    }

    /// The adaptive-path golden spec: like [`SimSpec::golden`] but with
    /// the predictor ensemble and the QoS-feedback guardband at a 1%
    /// violation-rate target — the configuration the ISSUE-4 acceptance
    /// criterion compares against the static-margin Markov baseline.
    pub fn golden_adaptive(scenario: &str) -> SimSpec {
        SimSpec {
            predictor: PredictorKind::Ensemble,
            qos_target: Some(0.01),
            ..SimSpec::golden(scenario)
        }
    }

    /// File stem of the golden trace for this spec: `{scenario}_{policy}`
    /// for the default static Markov configuration, with a
    /// `_{predictor}[-adaptive]` suffix when the predictor or guardband
    /// differ (so new adaptive goldens never collide with the old keys)
    /// and a `_n{N}` suffix for multi-node layouts (1-node specs keep the
    /// legacy keys — that path is bit-identical to the pre-topology
    /// coordinator, so its goldens must not churn).
    pub fn golden_stem(&self) -> String {
        let base = format!("{}_{}", self.scenario, self.policy.name());
        let base = if self.predictor == PredictorKind::Markov && self.qos_target.is_none() {
            base
        } else {
            format!(
                "{base}_{}{}",
                self.predictor.name(),
                if self.qos_target.is_some() { "-adaptive" } else { "" }
            )
        };
        let base = if self.n_nodes == 1 {
            base
        } else {
            format!("{base}_n{}", self.n_nodes)
        };
        // Adaptive-batch specs get their own key space; fixed-batch (the
        // default) keeps the legacy keys — that path is bit-identical to
        // the pre-batch-knob coordinator, so its goldens must not churn.
        if self.adaptive_batch {
            format!("{base}_abatch")
        } else {
            base
        }
    }
}

/// Result of one simulated replay.
#[derive(Debug)]
pub struct SimOutcome {
    /// Final stats + per-group epoch traces.
    pub report: FleetServingReport,
    /// Submissions the driver got accepted.
    pub accepted: u64,
    /// Wall time the whole replay took (virtual runs: milliseconds).
    pub wall: Duration,
}

/// Memoized `(design, optimizer)` per benchmark: netlist generation + STA
/// are deterministic but expensive, and property suites start hundreds of
/// fleets.
fn built_for(benchmark: &str) -> Result<(DesignPower, Optimizer)> {
    // Synthetic scale-sweep tenants are named `{base}@{suffix}` to keep
    // group names unique; the physical design is the base benchmark, so
    // the build (and the memo entry) keys on it.
    let benchmark = benchmark.split('@').next().unwrap_or(benchmark);
    static CACHE: OnceLock<Mutex<HashMap<String, (DesignPower, Optimizer)>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut map = match cache.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    };
    if let Some(b) = map.get(benchmark) {
        return Ok(b.clone());
    }
    let platform = build_platform(benchmark, PlatformConfig::default(), Policy::Dvfs(Mode::Proposed))
        .map_err(anyhow::Error::msg)?;
    let built = (platform.design.clone(), platform.optimizer_ref().clone());
    map.insert(benchmark.to_string(), built.clone());
    Ok(built)
}

/// Replay `spec` on a fresh [`VirtualClock`] and return the outcome.
pub fn run(spec: &SimSpec) -> Result<SimOutcome> {
    let scenario =
        Scenario::by_name(&spec.scenario, spec.epochs, spec.seed).map_err(anyhow::Error::msg)?;
    run_scenario(spec, &scenario)
}

/// Replay an already-built scenario under `spec`'s fleet parameters.
pub fn run_scenario(spec: &SimSpec, scenario: &Scenario) -> Result<SimOutcome> {
    let t0 = Instant::now(); // detlint: allow(wallclock) -- harness wall time
    // A fresh engine per replay: no scheduler state survives between
    // runs, so a parallel replay can never contaminate a sequential one
    // (or vice versa) inside one process.
    let clock: Arc<dyn Clock> = if spec.parallel {
        Arc::new(ParallelVirtualClock::new())
    } else {
        Arc::new(VirtualClock::new())
    };
    let _driver = ActorScope::enter(&clock, "sim-driver");
    let cfg = FleetServingConfig {
        groups: scenario.group_configs(spec.n_instances),
        epoch: spec.epoch,
        queue_capacity: spec.queue_capacity,
        batch_timeout: spec.batch_timeout,
        cycles_per_batch: spec.cycles_per_batch,
        selector_via_pjrt: false,
        warmup_epochs: spec.warmup_epochs,
        capacity_policy: spec.policy,
        predictor: spec.predictor,
        // Match the scenario generator's day length so the periodic
        // ensemble member trains on the actual cycle.
        predictor_period: Scenario::day_period(spec.epochs),
        qos_target: spec.qos_target,
        faults: Arc::new(spec.faults.clone()),
        nodes: spec.n_nodes,
        migrations: Arc::new(spec.migrations.clone()),
        adaptive_batch: spec.adaptive_batch,
        clock: clock.clone(),
        ..Default::default()
    };
    let mut built = Vec::with_capacity(cfg.groups.len());
    for g in &cfg.groups {
        built.push(built_for(&g.benchmark)?);
    }
    let fleet = FleetServing::start_with(cfg, PathBuf::from(NO_ARTIFACTS), built)?;
    let accepted = drive_scenario(&fleet, scenario, spec.peak_rps, spec.seed);
    let report = fleet.shutdown()?;
    Ok(SimOutcome { report, accepted, wall: t0.elapsed() })
}

fn record_json(r: &EpochRecord) -> Json {
    Json::obj(vec![
        ("epoch", Json::Num(r.epoch as f64)),
        ("load", Json::Num(r.load)),
        ("predicted", Json::Num(r.predicted)),
        ("freq_ratio", Json::Num(r.freq_ratio)),
        ("vcore", Json::Num(r.vcore)),
        ("vbram", Json::Num(r.vbram)),
        ("power_w", Json::Num(r.power_w)),
        ("active", Json::Num(r.n_active as f64)),
        ("predictor", Json::Str(r.predictor.to_string())),
        ("margin", Json::Num(r.margin)),
        ("batch", Json::Num(r.batch as f64)),
        ("failed", Json::Num(r.n_failed as f64)),
        ("slow", Json::Num(r.slow_factor)),
    ])
}

/// Serialize a replay's per-group epoch traces (plus the spec that
/// produced them) into the canonical golden-trace JSON document. Two runs
/// of the same spec serialize to byte-identical strings.
pub fn trace_json(spec: &SimSpec, scenario: &Scenario, report: &FleetServingReport) -> Json {
    let groups: Vec<Json> = scenario
        .tenants
        .iter()
        .zip(&report.epoch_records)
        .map(|(t, records)| {
            Json::obj(vec![
                ("benchmark", Json::Str(t.benchmark.clone())),
                ("share", Json::Num(t.share)),
                ("records", Json::Arr(records.iter().map(record_json).collect())),
            ])
        })
        .collect();
    let mut fields = vec![
        ("scenario", Json::Str(spec.scenario.clone())),
        ("policy", Json::Str(spec.policy.name().to_string())),
        ("predictor", Json::Str(spec.predictor.name().to_string())),
        ("qos_target", spec.qos_target.map(Json::Num).unwrap_or(Json::Null)),
        ("seed", Json::Num(spec.seed as f64)),
        ("epochs", Json::Num(spec.epochs as f64)),
        ("peak_rps", Json::Num(spec.peak_rps)),
        ("n_instances", Json::Num(spec.n_instances as f64)),
        ("epoch_ms", Json::Num(spec.epoch.as_secs_f64() * 1e3)),
        ("faults", spec.faults.to_json()),
    ];
    // Topology fields appear only on multi-node specs: the 1-node path is
    // bit-identical to the pre-topology coordinator, and its committed
    // goldens must stay byte-stable.
    if spec.n_nodes != 1 {
        fields.push(("n_nodes", Json::Num(spec.n_nodes as f64)));
        fields.push(("migrations", spec.migrations.to_json()));
    }
    // Same rule for the batch knob: the fixed-batch path is bit-identical
    // to the pre-batch-knob coordinator, so only `_abatch` specs carry
    // the field.
    if spec.adaptive_batch {
        fields.push(("adaptive_batch", Json::Bool(true)));
    }
    fields.push(("groups", Json::Arr(groups)));
    Json::obj(fields)
}

/// What [`check_golden`] did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GoldenStatus {
    /// The replay matched the committed golden byte-for-byte.
    Matched,
    /// No golden existed; this run recorded one (commit it).
    Recorded,
    /// `WAVESCALE_UPDATE_GOLDEN=1`: the golden was rewritten.
    Updated,
}

/// Replay `spec` and compare its trace against `dir/{scenario}_{policy}.json`.
///
/// * file matches → `Ok(Matched)`;
/// * file missing → record it and return `Ok(Recorded)` (bootstrap —
///   commit the new file);
/// * file differs → `Err` pointing at `make golden`, unless
///   `WAVESCALE_UPDATE_GOLDEN=1` is set, which rewrites it (`Updated`).
pub fn check_golden(dir: &Path, spec: &SimSpec) -> Result<GoldenStatus> {
    let scenario =
        Scenario::by_name(&spec.scenario, spec.epochs, spec.seed).map_err(anyhow::Error::msg)?;
    let outcome = run_scenario(spec, &scenario)?;
    let mut text = trace_json(spec, &scenario, &outcome.report).to_string_pretty();
    text.push('\n');
    let path = dir.join(format!("{}.json", spec.golden_stem()));
    let update = std::env::var("WAVESCALE_UPDATE_GOLDEN").as_deref() == Ok("1");
    match std::fs::read_to_string(&path) {
        Ok(existing) if existing == text => Ok(GoldenStatus::Matched),
        Ok(existing) => {
            if update {
                std::fs::write(&path, &text)?;
                return Ok(GoldenStatus::Updated);
            }
            let line = first_diff_line(&existing, &text);
            anyhow::bail!(
                "golden trace drift for {} (first differing line {line}); \
                 if the change is intentional run `make golden` and commit {}",
                spec.golden_stem(),
                path.display()
            )
        }
        Err(_) => {
            std::fs::create_dir_all(dir)?;
            std::fs::write(&path, &text)?;
            Ok(GoldenStatus::Recorded)
        }
    }
}

fn first_diff_line(a: &str, b: &str) -> usize {
    for (i, (la, lb)) in a.lines().zip(b.lines()).enumerate() {
        if la != lb {
            return i + 1;
        }
    }
    a.lines().count().min(b.lines().count()) + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_stem_is_filename_safe() {
        let spec = SimSpec { policy: CapacityPolicy::GatingOnly, ..SimSpec::golden("diurnal") };
        assert_eq!(spec.golden_stem(), "diurnal_pg-only");
        assert_eq!(SimSpec::golden("overnight").golden_stem(), "overnight_hybrid");
        assert_eq!(SimSpec::golden("overnight").epochs, 48);
        // Adaptive specs get their own key space — they can never clobber
        // the static baselines' goldens.
        assert_eq!(
            SimSpec::golden_adaptive("overnight").golden_stem(),
            "overnight_hybrid_ensemble-adaptive"
        );
        let spec = SimSpec {
            predictor: PredictorKind::Ewma,
            ..SimSpec::golden("diurnal")
        };
        assert_eq!(spec.golden_stem(), "diurnal_hybrid_ewma");
        // Multi-node layouts get their own key space; 1-node keeps the
        // legacy keys so committed goldens never churn.
        let spec = SimSpec { n_nodes: 4, ..SimSpec::golden("diurnal") };
        assert_eq!(spec.golden_stem(), "diurnal_hybrid_n4");
        let spec = SimSpec { n_nodes: 1, ..SimSpec::golden_adaptive("overnight") };
        assert_eq!(spec.golden_stem(), "overnight_hybrid_ensemble-adaptive");
        // The batch knob keys the same way: off (default) is the legacy
        // stem, on appends `_abatch` after every other suffix.
        let spec = SimSpec { adaptive_batch: true, ..SimSpec::golden("diurnal") };
        assert_eq!(spec.golden_stem(), "diurnal_hybrid_abatch");
        let spec = SimSpec {
            adaptive_batch: true,
            n_nodes: 4,
            ..SimSpec::golden("diurnal")
        };
        assert_eq!(spec.golden_stem(), "diurnal_hybrid_n4_abatch");
        // The parallel engine is trace-equivalent by contract, so it
        // shares the sequential stem — goldens are engine-independent.
        let spec = SimSpec { parallel: true, ..SimSpec::golden("diurnal") };
        assert_eq!(spec.golden_stem(), "diurnal_hybrid");
    }

    #[test]
    fn golden_specs_attach_canonical_fault_plans() {
        // Only the three fault-carrying adversarial scenarios inject
        // anything; everything else gets the bitwise-neutral empty plan.
        assert!(SimSpec::golden("overnight").faults.is_empty());
        assert!(SimSpec::golden("tiered-tenants").faults.is_empty());
        assert!(SimSpec::golden("long-replay").faults.is_empty());
        assert_eq!(SimSpec::golden("board-failure").faults.board_failures.len(), 1);
        assert_eq!(SimSpec::golden("straggler").faults.stragglers.len(), 1);
        assert_eq!(SimSpec::golden("correlated-surge").faults.surges.len(), 1);
    }

    #[test]
    fn tiny_sim_conserves_and_is_deterministic() {
        // Smoke-sized: the full suites live in tests/sim_golden.rs and
        // tests/sim_properties.rs.
        let spec = SimSpec {
            epochs: 3,
            peak_rps: 400.0,
            epoch: Duration::from_millis(20),
            batch_timeout: Duration::from_millis(5),
            warmup_epochs: 0,
            ..SimSpec::default()
        };
        let a = run(&spec).unwrap();
        let b = run(&spec).unwrap();
        let scenario = Scenario::by_name(&spec.scenario, spec.epochs, spec.seed).unwrap();
        assert_eq!(
            trace_json(&spec, &scenario, &a.report).to_string_pretty(),
            trace_json(&spec, &scenario, &b.report).to_string_pretty(),
            "same seed must replay byte-identically"
        );
        assert_eq!(a.accepted, b.accepted);
        for g in &a.report.stats.per_group {
            assert_eq!(g.admitted, g.completed + g.failed, "{}: drain invariant", g.name);
        }
        assert_eq!(
            a.report.stats.per_group.iter().map(|g| g.admitted).sum::<u64>(),
            a.accepted
        );
    }
}
