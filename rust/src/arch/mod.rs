//! FPGA architecture model — Stratix-IV-like device family (DESIGN.md S2).
//!
//! The paper maps each benchmark onto "the smallest possible FPGA device"
//! with VTR, after raising I/O pad capacity from 2 to 4 because the
//! accelerators are heavily I/O-bound. We model the same flow: a family of
//! devices with LAB/M9K/M144K/DSP/IO capacities, a utilization type, and a
//! smallest-fitting-device search. The oversized device the I/O demand
//! forces is exactly what makes idle-resource static power significant
//! (paper §VI.B).

pub mod benchmarks;

pub use benchmarks::{BenchmarkSpec, TABLE1};

/// One device of the family. Counts follow Stratix IV GX conventions:
/// a LAB holds [`DeviceFamily::luts_per_lab`] 6-input LUTs.
#[derive(Clone, Debug)]
pub struct Device {
    /// Device name (family part number or synthetic id).
    pub name: &'static str,
    /// Logic array blocks.
    pub labs: usize,
    /// M9K block RAMs.
    pub m9ks: usize,
    /// M144K block RAMs.
    pub m144ks: usize,
    /// DSP hard macros.
    pub dsps: usize,
    /// I/O pads (each holds [`DeviceFamily::io_per_pad`] pins).
    pub io_pads: usize,
    /// Relative routing capacity (switch+connection mux count per LAB).
    pub route_muxes_per_lab: usize,
}

impl Device {
    /// Total LUT capacity of the device.
    pub fn luts(&self, family: &DeviceFamily) -> usize {
        self.labs * family.luts_per_lab
    }

    /// Total routing mux count (leaks on the core rail).
    pub fn route_muxes(&self) -> usize {
        self.labs * self.route_muxes_per_lab
    }
}

/// Post-P&R resource demand of a design (Table I row).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Utilization {
    /// Logic array blocks used.
    pub labs: usize,
    /// DSP macros used.
    pub dsps: usize,
    /// M9K BRAMs used.
    pub m9ks: usize,
    /// M144K BRAMs used.
    pub m144ks: usize,
    /// I/O *pins* (the paper reports pins; pads hold `io_per_pad` pins).
    pub io_pins: usize,
}

/// A family of devices sharing conventions (LUTs/LAB, pins/pad).
#[derive(Clone, Debug)]
pub struct DeviceFamily {
    /// Family name.
    pub name: &'static str,
    /// 6-input LUTs per LAB.
    pub luts_per_lab: usize,
    /// Pins per I/O pad (paper's VTR amendment: 2 -> 4).
    pub io_per_pad: usize,
    /// Devices sorted small -> large.
    pub devices: Vec<Device>,
}

impl DeviceFamily {
    /// Stratix-IV-GX-like family. The two largest members are synthetic
    /// interposer-expanded devices so the I/O-hungriest benchmark
    /// (Stripes, 8797 pins) still maps — the paper's testbed handles this
    /// with its own device choice; what matters downstream is the *ratio*
    /// of used to total resources.
    pub fn stratix_iv() -> Self {
        let d = |name, labs, m9ks, m144ks, dsps, io_pads| Device {
            name,
            labs,
            m9ks,
            m144ks,
            dsps,
            io_pads,
            route_muxes_per_lab: 60,
        };
        DeviceFamily {
            name: "stratix-iv-gx",
            luts_per_lab: 10,
            io_per_pad: 4,
            devices: vec![
                d("S70", 2_904, 462, 16, 48, 372),
                d("S110", 4_160, 660, 16, 64, 488),
                d("S230", 9_120, 1_235, 22, 161, 744),
                d("S290", 11_600, 936, 36, 104, 936),
                d("S530", 21_240, 1_280, 64, 128, 1_120),
                d("S820i", 32_800, 1_920, 96, 192, 1_760),
                d("S1150i", 45_600, 2_640, 128, 256, 2_400),
            ],
        }
    }

    /// Smallest device satisfying every capacity (the VTR mapping rule).
    pub fn smallest_fitting(&self, u: &Utilization) -> Option<&Device> {
        let pads_needed = u.io_pins.div_ceil(self.io_per_pad);
        self.devices.iter().find(|d| {
            d.labs >= u.labs
                && d.dsps >= u.dsps
                && d.m9ks >= u.m9ks
                && d.m144ks >= u.m144ks
                && d.io_pads >= pads_needed
        })
    }

    /// VTR-style minimum custom device: the paper maps each benchmark onto
    /// "the smallest possible FPGA device" that VTR synthesizes — a W×W
    /// fabric with perimeter I/O (4 pads per position after the paper's
    /// capacity amendment) and Stratix-IV column ratios (1 M9K per 16
    /// LABs, 1 M144K per 330, 1 DSP per 166). Heavily I/O-bound designs
    /// therefore land on fabrics far larger than their logic needs — the
    /// idle-leakage opportunity the framework exploits.
    pub fn vtr_min_device(&self, u: &Utilization) -> Device {
        let need = |n: usize, per: f64| ((n as f64 * per).sqrt()).ceil() as usize;
        let w_io = u.io_pins.div_ceil(4 * self.io_per_pad);
        let w = [
            w_io,
            need((u.labs as f64 * 1.15) as usize, 1.0),
            need(u.m9ks, 16.0),
            need(u.m144ks, 330.0),
            need(u.dsps, 166.0),
            4, // minimum fabric
        ]
        .into_iter()
        .max()
        .unwrap();
        let labs = w * w;
        Device {
            name: "vtr-min",
            labs,
            m9ks: labs.div_ceil(16),
            m144ks: labs.div_ceil(330),
            dsps: labs.div_ceil(166),
            io_pads: 4 * w * self.io_per_pad,
            route_muxes_per_lab: 60,
        }
    }

    /// Which capacity binds the mapping (for the utilization report).
    pub fn binding_constraint(&self, u: &Utilization, dev: &Device) -> &'static str {
        let frac = [
            (u.labs as f64 / dev.labs as f64, "labs"),
            (u.dsps as f64 / dev.dsps.max(1) as f64, "dsps"),
            (u.m9ks as f64 / dev.m9ks.max(1) as f64, "m9k"),
            (u.m144ks as f64 / dev.m144ks.max(1) as f64, "m144k"),
            (
                u.io_pins.div_ceil(self.io_per_pad) as f64 / dev.io_pads as f64,
                "io",
            ),
        ];
        frac.iter()
            .max_by(|a, b| a.0.total_cmp(&b.0))
            .unwrap()
            .1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_is_sorted_small_to_large() {
        let f = DeviceFamily::stratix_iv();
        for w in f.devices.windows(2) {
            assert!(w[0].labs <= w[1].labs);
            assert!(w[0].io_pads <= w[1].io_pads);
        }
    }

    #[test]
    fn smallest_fitting_picks_minimum() {
        let f = DeviceFamily::stratix_iv();
        let u = Utilization { labs: 100, dsps: 0, m9ks: 10, m144ks: 1, io_pins: 100 };
        assert_eq!(f.smallest_fitting(&u).unwrap().name, "S70");
    }

    #[test]
    fn io_bound_designs_get_oversized_devices() {
        let f = DeviceFamily::stratix_iv();
        // Stripes: tiny memory demand but 8797 pins -> 2200 pads.
        let u = Utilization { labs: 12_343, dsps: 16, m9ks: 15, m144ks: 1, io_pins: 8_797 };
        let d = f.smallest_fitting(&u).unwrap();
        assert_eq!(d.name, "S1150i");
        assert_eq!(f.binding_constraint(&u, d), "io");
    }

    #[test]
    fn unmappable_returns_none() {
        let f = DeviceFamily::stratix_iv();
        let u = Utilization { labs: 1_000_000, ..Default::default() };
        assert!(f.smallest_fitting(&u).is_none());
    }

    #[test]
    fn all_table1_benchmarks_map() {
        let f = DeviceFamily::stratix_iv();
        for spec in TABLE1 {
            let d = f.smallest_fitting(&spec.utilization());
            assert!(d.is_some(), "{} does not map", spec.name);
        }
    }

    #[test]
    fn luts_count() {
        let f = DeviceFamily::stratix_iv();
        assert_eq!(f.devices[0].luts(&f), 29_040);
    }
}
