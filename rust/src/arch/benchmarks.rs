//! The paper's five DNN-accelerator benchmarks (Table I), plus the
//! netlist-shape hints our synthetic generator needs to reproduce their
//! post-P&R timing (DESIGN.md S3, substitution table §6).

use super::Utilization;

/// One Table I row + generator hints.
#[derive(Clone, Copy, Debug)]
pub struct BenchmarkSpec {
    /// Benchmark name (Table I).
    pub name: &'static str,
    /// Table I LAB count.
    pub labs: usize,
    /// Table I DSP count.
    pub dsps: usize,
    /// Table I M9K count.
    pub m9ks: usize,
    /// Table I M144K count.
    pub m144ks: usize,
    /// Table I I/O pin count.
    pub io_pins: usize,
    /// Table I post-P&R frequency (MHz) — the generator's timing target.
    pub freq_mhz: f64,
    /// Logic depth of the intended critical path (pipeline stages between
    /// registers), tuned so synthetic STA lands near `freq_mhz`.
    pub cp_logic_depth: usize,
    /// Whether a BRAM access sits on the critical path (it does for all
    /// five accelerators — the paper notes the alpha parameters are close).
    pub cp_has_bram: bool,
    /// Whether a DSP macro sits on the critical path.
    pub cp_has_dsp: bool,
    /// Average switching activity of used logic (toggle probability).
    pub activity: f64,
}

impl BenchmarkSpec {
    /// The spec's resource demand as an [`Utilization`] row.
    pub fn utilization(&self) -> Utilization {
        Utilization {
            labs: self.labs,
            dsps: self.dsps,
            m9ks: self.m9ks,
            m144ks: self.m144ks,
            io_pins: self.io_pins,
        }
    }

    /// Look up a Table I row by benchmark name.
    pub fn by_name(name: &str) -> Option<&'static BenchmarkSpec> {
        TABLE1.iter().find(|s| s.name == name)
    }

    /// Nominal clock period in ns.
    pub fn period_ns(&self) -> f64 {
        1_000.0 / self.freq_mhz
    }
}

/// Table I of the paper, verbatim counts.
///
/// `cp_logic_depth` back-solves the benchmark's Fmax with the default
/// delay calibration in `sta::DelayParams` (LUT+route stage ≈ 0.95 ns,
/// BRAM ≈ 2.0 ns, DSP ≈ 2.5 ns): depth ≈ (period − hard-block delays) /
/// stage delay. `sta::tests::table1_fmax_within_tolerance` pins this.
pub const TABLE1: &[BenchmarkSpec] = &[
    BenchmarkSpec {
        name: "tabla",
        labs: 127,
        dsps: 0,
        m9ks: 47,
        m144ks: 1,
        io_pins: 567,
        freq_mhz: 113.0,
        cp_logic_depth: 6,
        cp_has_bram: true,
        cp_has_dsp: false,
        activity: 0.15,
    },
    BenchmarkSpec {
        name: "dnnweaver",
        labs: 730,
        dsps: 1,
        m9ks: 166,
        m144ks: 13,
        io_pins: 1_655,
        freq_mhz: 99.0,
        cp_logic_depth: 7,
        cp_has_bram: true,
        cp_has_dsp: false,
        activity: 0.15,
    },
    BenchmarkSpec {
        name: "diannao",
        labs: 3_430,
        dsps: 112,
        m9ks: 30,
        m144ks: 2,
        io_pins: 4_659,
        freq_mhz: 83.0,
        cp_logic_depth: 7,
        cp_has_bram: true,
        cp_has_dsp: true,
        activity: 0.18,
    },
    BenchmarkSpec {
        name: "stripes",
        labs: 12_343,
        dsps: 16,
        m9ks: 15,
        m144ks: 1,
        io_pins: 8_797,
        freq_mhz: 40.0,
        cp_logic_depth: 22,
        cp_has_bram: true,
        cp_has_dsp: false,
        activity: 0.12,
    },
    BenchmarkSpec {
        name: "proteus",
        labs: 2_702,
        dsps: 144,
        m9ks: 15,
        m144ks: 1,
        io_pins: 5_033,
        freq_mhz: 70.0,
        cp_logic_depth: 9,
        cp_has_bram: true,
        cp_has_dsp: true,
        activity: 0.20,
    },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_counts_are_verbatim() {
        // Spot-check against the paper's Table I.
        let t = BenchmarkSpec::by_name("tabla").unwrap();
        assert_eq!((t.labs, t.dsps, t.m9ks, t.m144ks, t.io_pins), (127, 0, 47, 1, 567));
        assert_eq!(t.freq_mhz, 113.0);
        let s = BenchmarkSpec::by_name("stripes").unwrap();
        assert_eq!((s.labs, s.dsps, s.m9ks, s.m144ks, s.io_pins), (12_343, 16, 15, 1, 8_797));
        assert_eq!(s.freq_mhz, 40.0);
        let d = BenchmarkSpec::by_name("diannao").unwrap();
        assert_eq!(d.dsps, 112);
        let p = BenchmarkSpec::by_name("proteus").unwrap();
        assert_eq!(p.dsps, 144);
        let w = BenchmarkSpec::by_name("dnnweaver").unwrap();
        assert_eq!(w.m144ks, 13);
        assert!(BenchmarkSpec::by_name("nope").is_none());
    }

    #[test]
    fn five_benchmarks() {
        assert_eq!(TABLE1.len(), 5);
    }

    #[test]
    fn period_ns() {
        let t = BenchmarkSpec::by_name("stripes").unwrap();
        assert!((t.period_ns() - 25.0).abs() < 1e-9);
    }
}
