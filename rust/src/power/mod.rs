//! Device-level power model — the VPR-power/COFFE substitute (DESIGN.md S5).
//!
//! Aggregates per-resource dynamic and static power at arbitrary rail
//! voltages and clock frequency:
//!
//! * dynamic: `count · activity · E_toggle(class) · dyn_scale(v) · f`
//! * static:  `count · P_leak(class) · static_scale(v) · temp_factor`,
//!   for **all** device resources — the used design plus the idle fabric
//!   the oversized (I/O-bound) device mapping strands, which is exactly
//!   the leakage the paper's Vcore/Vbram scaling attacks.
//!
//! The model also derives the operating-point parameters the optimizer and
//! the AOT'd Voltage Selector consume: `beta` (BRAM share of total power,
//! Eq. 3), `gamma_l/gamma_m` (dynamic fraction per rail) and the rail-level
//! delay/power tables (`RailTables`) sampled on the DC-DC grid.

use crate::arch::{BenchmarkSpec, Device, DeviceFamily};
use crate::chars::{CharLibrary, ResourceClass};
use crate::sta::PathComposition;

/// Absolute calibration: per-unit power at nominal voltage, 25 °C, and
/// `f_ref_mhz`. Tuned so a fully-utilized large device draws ~20 W (paper
/// §V: "the fully utilized FPGA power consumption is around 20W").
#[derive(Clone, Copy, Debug)]
pub struct PowerParams {
    /// Reference clock (MHz) the dynamic constants are quoted at.
    pub f_ref_mhz: f64,
    /// Dynamic energy proxy: W at f_ref per unit at activity 1.0.
    pub lut_dyn_w: f64,
    /// Dynamic W at f_ref per routed wire segment.
    pub route_seg_dyn_w: f64,
    /// Dynamic W at f_ref per BRAM block.
    pub bram_dyn_w: f64,
    /// Dynamic W at f_ref per DSP macro.
    pub dsp_dyn_w: f64,
    /// Static leakage per unit at nominal voltage and 25 °C.
    pub lut_static_w: f64,
    /// Static W per routing mux.
    pub route_mux_static_w: f64,
    /// Static W per BRAM block.
    pub bram_static_w: f64,
    /// Static W per DSP macro.
    pub dsp_static_w: f64,
    /// M144K blocks count as this many M9K-equivalents.
    pub m144k_factor: f64,
    /// Per-PLL power. The paper's Eq. 4/5 worked example uses 0.1 W
    /// against a 20 W fully-utilized device; boards here span 0.8-20 W,
    /// so the default is a typical 20 mW PLL to keep the overhead ratio
    /// faithful (benches/pll_overhead.rs re-runs Eq. 4/5 with the paper's
    /// own constants).
    pub pll_w: f64,
}

impl Default for PowerParams {
    fn default() -> Self {
        PowerParams {
            // Fitted jointly against Table II of the paper (see
            // DESIGN.md §6 and EXPERIMENTS.md: least-squares over the five
            // benchmarks' prop/core-only/bram-only gains with the stripes
            // device anchored at ~20 W).
            f_ref_mhz: 100.0,
            lut_dyn_w: 6.845e-4,
            route_seg_dyn_w: 2.875e-4,
            bram_dyn_w: 4.553e-2,
            dsp_dyn_w: 3.0e-3,
            lut_static_w: 4.911e-7,
            route_mux_static_w: 9.822e-8,
            bram_static_w: 4.558e-5,
            dsp_static_w: 0.5e-3,
            m144k_factor: 8.0,
            pll_w: 0.01,
        }
    }
}

/// Power split by rail and kind (watts).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PowerBreakdown {
    /// Core-rail dynamic power (W).
    pub core_dyn_w: f64,
    /// Core-rail static power (W).
    pub core_static_w: f64,
    /// BRAM-rail dynamic power (W).
    pub bram_dyn_w: f64,
    /// BRAM-rail static power (W).
    pub bram_static_w: f64,
}

impl PowerBreakdown {
    /// Sum of all four components (W).
    pub fn total_w(&self) -> f64 {
        self.core_dyn_w + self.core_static_w + self.bram_dyn_w + self.bram_static_w
    }

    /// Eq. (3)'s `beta`: BRAM-rail share of total power.
    pub fn beta(&self) -> f64 {
        let t = self.total_w();
        if t <= 0.0 {
            0.0
        } else {
            (self.bram_dyn_w + self.bram_static_w) / t
        }
    }

    /// Dynamic fraction of the core rail.
    pub fn gamma_l(&self) -> f64 {
        let t = self.core_dyn_w + self.core_static_w;
        if t <= 0.0 {
            0.0
        } else {
            self.core_dyn_w / t
        }
    }

    /// Dynamic fraction of the BRAM rail.
    pub fn gamma_m(&self) -> f64 {
        let t = self.bram_dyn_w + self.bram_static_w;
        if t <= 0.0 {
            0.0
        } else {
            self.bram_dyn_w / t
        }
    }
}

/// Operating-point parameters for the Eq. (1)-(3) models.
#[derive(Clone, Copy, Debug)]
pub struct OperatingParams {
    /// Eq. (1): BRAM share of the critical path relative to core delay.
    pub alpha: f64,
    /// Eq. (3): BRAM-rail share of total power.
    pub beta: f64,
    /// Dynamic fraction of the core rail.
    pub gamma_l: f64,
    /// Dynamic fraction of the BRAM rail.
    pub gamma_m: f64,
}

/// Rail-level tables on the DC-DC grid (index 0 = nominal), the input
/// format of both the native optimizer and the AOT Voltage Selector.
#[derive(Clone, Debug)]
pub struct RailTables {
    /// Core-rail delay scale, CP-composition-weighted (logic/routing/DSP).
    pub dl: Vec<f64>,
    /// BRAM delay scale.
    pub dm: Vec<f64>,
    /// Core-rail dynamic power scale per grid level.
    pub pl_dyn: Vec<f64>,
    /// Core-rail static power scale per grid level.
    pub pl_st: Vec<f64>,
    /// BRAM-rail dynamic power scale per grid level.
    pub pm_dyn: Vec<f64>,
    /// BRAM-rail static power scale per grid level.
    pub pm_st: Vec<f64>,
    /// Operating-point parameters of the design behind these tables.
    pub op: OperatingParams,
}

/// Resolved design-on-device power model for one benchmark.
#[derive(Clone, Debug)]
pub struct DesignPower {
    /// Benchmark the model was built for.
    pub spec: &'static BenchmarkSpec,
    /// Device the benchmark is mapped onto.
    pub device: Device,
    /// Characterization library behind the voltage scales.
    pub chars: CharLibrary,
    /// Absolute calibration constants.
    pub params: PowerParams,
    used_luts: f64,
    used_route_segs: f64,
    used_brams: f64,
    used_dsps: f64,
    device_luts: f64,
    device_route_muxes: f64,
    device_brams: f64,
    device_dsps: f64,
}

impl DesignPower {
    /// Map the benchmark onto the VTR-style minimum custom device (the
    /// paper's flow). Use [`DesignPower::from_spec_on_device`] to pin a
    /// specific family device instead.
    pub fn from_spec(
        spec: &'static BenchmarkSpec,
        family: &DeviceFamily,
        chars: CharLibrary,
        params: PowerParams,
    ) -> Result<Self, String> {
        let device = family.vtr_min_device(&spec.utilization());
        Self::from_spec_on_device(spec, family, device, chars, params)
    }

    /// Same, on an explicitly chosen device.
    pub fn from_spec_on_device(
        spec: &'static BenchmarkSpec,
        family: &DeviceFamily,
        device: Device,
        chars: CharLibrary,
        params: PowerParams,
    ) -> Result<Self, String> {
        let u = spec.utilization();
        if device.labs < u.labs || device.m9ks < u.m9ks || device.m144ks < u.m144ks {
            return Err(format!("{} does not fit device {}", spec.name, device.name));
        }
        let used_luts = (spec.labs * family.luts_per_lab) as f64;
        Ok(DesignPower {
            spec,
            chars,
            params,
            used_luts,
            // Average routed segments per LUT fan-in net (~3.2 matches the
            // synthetic generator's expectation).
            used_route_segs: used_luts * 3.2,
            used_brams: spec.m9ks as f64 + spec.m144ks as f64 * params.m144k_factor,
            used_dsps: spec.dsps as f64,
            device_luts: device.luts(family) as f64,
            device_route_muxes: device.route_muxes() as f64,
            device_brams: device.m9ks as f64 + device.m144ks as f64 * params.m144k_factor,
            device_dsps: device.dsps as f64,
            device,
        })
    }

    /// Power at the given rail voltages and clock (used resources toggle;
    /// the whole device leaks).
    pub fn breakdown(&self, vcore: f64, vbram: f64, f_mhz: f64) -> PowerBreakdown {
        let c = &self.chars;
        let p = &self.params;
        let act = self.spec.activity;
        let fr = f_mhz / p.f_ref_mhz;
        let tleak = c.temp_leak_factor();

        let dyn_w = |class: ResourceClass, units: f64, unit_w: f64, v: f64| {
            units * act * unit_w * c.dyn_scale(class, v) * fr
        };
        let st_w = |class: ResourceClass, units: f64, unit_w: f64, v: f64| {
            units * unit_w * c.static_scale(class, v) * tleak
        };

        PowerBreakdown {
            core_dyn_w: dyn_w(ResourceClass::Logic, self.used_luts, p.lut_dyn_w, vcore)
                + dyn_w(ResourceClass::Routing, self.used_route_segs, p.route_seg_dyn_w, vcore)
                + dyn_w(ResourceClass::Dsp, self.used_dsps, p.dsp_dyn_w, vcore),
            core_static_w: st_w(ResourceClass::Logic, self.device_luts, p.lut_static_w, vcore)
                + st_w(
                    ResourceClass::Routing,
                    self.device_route_muxes,
                    p.route_mux_static_w,
                    vcore,
                )
                + st_w(ResourceClass::Dsp, self.device_dsps, p.dsp_static_w, vcore),
            bram_dyn_w: dyn_w(ResourceClass::Bram, self.used_brams, p.bram_dyn_w, vbram),
            bram_static_w: st_w(ResourceClass::Bram, self.device_brams, p.bram_static_w, vbram),
        }
    }

    /// Nominal-voltage power at the benchmark's Table I frequency.
    pub fn nominal(&self) -> PowerBreakdown {
        self.breakdown(
            self.chars.logic.v_nom,
            self.chars.bram.v_nom,
            self.spec.freq_mhz,
        )
    }

    /// Eq. (1)-(3) operating-point parameters from a critical-path
    /// composition (STA) plus this power model.
    pub fn operating_params(&self, cp: &PathComposition) -> OperatingParams {
        let nom = self.nominal();
        OperatingParams {
            alpha: cp.alpha(),
            beta: nom.beta(),
            gamma_l: nom.gamma_l(),
            gamma_m: nom.gamma_m(),
        }
    }

    /// Rail-level tables on the DC-DC grid for this design.
    ///
    /// `dl[i]` weights each core-rail class by its share of the CP's core
    /// delay; `pl_*[i]` weight by the class's share of the rail's power.
    pub fn rail_tables(&self, cp: &PathComposition) -> RailTables {
        let c = &self.chars;
        let p = &self.params;
        let grid = c.grid();
        let core = cp.core_ns().max(1e-12);
        let (wl, wr, wd) = (
            cp.logic_ns / core,
            cp.routing_ns / core,
            cp.dsp_ns / core,
        );

        let dl: Vec<f64> = grid
            .vcore
            .iter()
            .map(|&v| {
                wl * c.delay_scale(ResourceClass::Logic, v)
                    + wr * c.delay_scale(ResourceClass::Routing, v)
                    + wd * c.delay_scale(ResourceClass::Dsp, v)
            })
            .collect();
        let dm: Vec<f64> = grid
            .vbram
            .iter()
            .map(|&v| c.delay_scale(ResourceClass::Bram, v))
            .collect();

        // Core-rail power weights (dynamic and static separately).
        let act = self.spec.activity;
        let dyn_parts = [
            (ResourceClass::Logic, self.used_luts * act * p.lut_dyn_w),
            (ResourceClass::Routing, self.used_route_segs * act * p.route_seg_dyn_w),
            (ResourceClass::Dsp, self.used_dsps * act * p.dsp_dyn_w),
        ];
        let st_parts = [
            (ResourceClass::Logic, self.device_luts * p.lut_static_w),
            (ResourceClass::Routing, self.device_route_muxes * p.route_mux_static_w),
            (ResourceClass::Dsp, self.device_dsps * p.dsp_static_w),
        ];
        let weighted = |parts: &[(ResourceClass, f64)], f: &dyn Fn(ResourceClass, f64) -> f64, v: f64| {
            let total: f64 = parts.iter().map(|(_, w)| w).sum();
            parts.iter().map(|&(cl, w)| w / total.max(1e-18) * f(cl, v)).sum::<f64>()
        };
        let dscale = |cl: ResourceClass, v: f64| c.dyn_scale(cl, v);
        let sscale = |cl: ResourceClass, v: f64| c.static_scale(cl, v);

        let pl_dyn: Vec<f64> =
            grid.vcore.iter().map(|&v| weighted(&dyn_parts, &dscale, v)).collect();
        let pl_st: Vec<f64> =
            grid.vcore.iter().map(|&v| weighted(&st_parts, &sscale, v)).collect();
        let pm_dyn: Vec<f64> = grid
            .vbram
            .iter()
            .map(|&v| c.dyn_scale(ResourceClass::Bram, v))
            .collect();
        let pm_st: Vec<f64> = grid
            .vbram
            .iter()
            .map(|&v| c.static_scale(ResourceClass::Bram, v))
            .collect();

        RailTables {
            dl,
            dm,
            pl_dyn,
            pl_st,
            pm_dyn,
            pm_st,
            op: self.operating_params(cp),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{DeviceFamily, TABLE1};
    use crate::netlist::gen::{generate, GenConfig};
    use crate::sta::{analyze, DelayParams};

    fn dp(name: &str) -> DesignPower {
        DesignPower::from_spec(
            BenchmarkSpec::by_name(name).unwrap(),
            &DeviceFamily::stratix_iv(),
            CharLibrary::stratix_iv_22nm(),
            PowerParams::default(),
        )
        .unwrap()
    }

    #[test]
    fn total_power_is_realistic() {
        // Large I/O-bound designs should draw single-to-low-double-digit
        // watts (paper: fully utilized FPGA ~ 20 W).
        for spec in TABLE1 {
            let d = dp(spec.name);
            let w = d.nominal().total_w();
            assert!(
                (0.3..30.0).contains(&w),
                "{}: nominal power {w:.2} W out of band",
                spec.name
            );
        }
        // Stripes maps to the largest device: it must be the hungriest.
        let stripes = dp("stripes").nominal().total_w();
        let tabla = dp("tabla").nominal().total_w();
        assert!(stripes > 4.0 * tabla, "stripes {stripes:.2} vs tabla {tabla:.2}");
    }

    #[test]
    fn beta_ordering_matches_paper() {
        // Paper Table II: bram-only scaling is strong on Tabla/DnnWeaver
        // (high BRAM power share) and weak on DianNao/Stripes/Proteus.
        let beta = |n: &str| dp(n).nominal().beta();
        for strong in ["tabla", "dnnweaver"] {
            for weak in ["diannao", "stripes", "proteus"] {
                assert!(
                    beta(strong) > beta(weak),
                    "beta({strong})={:.3} should exceed beta({weak})={:.3}",
                    beta(strong),
                    beta(weak)
                );
            }
        }
        // The strong ones sit near or above the paper's "~25% of device
        // power" observation [28]; Table II calibration puts Tabla higher.
        assert!((0.25..0.70).contains(&beta("tabla")), "{}", beta("tabla"));
    }

    #[test]
    fn dynamic_scales_linearly_with_frequency() {
        let d = dp("tabla");
        let a = d.breakdown(0.8, 0.95, 100.0);
        let b = d.breakdown(0.8, 0.95, 50.0);
        assert!((a.core_dyn_w / b.core_dyn_w - 2.0).abs() < 1e-9);
        assert!((a.core_static_w - b.core_static_w).abs() < 1e-12);
    }

    #[test]
    fn static_drops_with_voltage() {
        let d = dp("diannao");
        let hi = d.breakdown(0.8, 0.95, 83.0);
        let lo = d.breakdown(0.65, 0.8, 83.0);
        // Core leakage slope is gentle (Table II calibration); BRAM's is
        // steep (the paper's >75%-by-0.80V claim).
        assert!(lo.core_static_w < 0.80 * hi.core_static_w);
        assert!(lo.bram_static_w < 0.35 * hi.bram_static_w);
        assert!(lo.total_w() < hi.total_w());
    }

    #[test]
    fn rail_tables_are_consistent() {
        let d = dp("tabla");
        let net = generate(d.spec, &GenConfig { scale: 0.05, seed: 2019, luts_per_lab: 10 });
        let r = analyze(&net, &DelayParams::default(), 8).unwrap();
        let t = d.rail_tables(&r.cp);
        assert_eq!(t.dl.len(), 13);
        assert_eq!(t.dm.len(), 19);
        // Normalized at nominal (index 0).
        for series in [&t.dl, &t.dm, &t.pl_dyn, &t.pl_st, &t.pm_dyn, &t.pm_st] {
            assert!((series[0] - 1.0).abs() < 1e-9);
        }
        // Delay tables rise, power tables fall as voltage descends.
        assert!(t.dl.windows(2).all(|w| w[1] >= w[0] - 1e-9));
        assert!(t.dm.windows(2).all(|w| w[1] >= w[0] - 1e-9));
        assert!(t.pl_dyn.windows(2).all(|w| w[1] <= w[0] + 1e-9));
        assert!(t.pm_st.windows(2).all(|w| w[1] <= w[0] + 1e-9));
        // Operating params in range.
        assert!((0.0..1.0).contains(&t.op.beta));
        assert!((0.0..=1.0).contains(&t.op.gamma_l));
        assert!(t.op.alpha > 0.05);
    }

    #[test]
    fn eq5_two_pll_condition_holds() {
        // Paper §V: P_design * t_lock > P_PLL * tau fails for tau >= 2 ms
        // at 20 W / 0.1 W / 10 us — i.e. two PLLs win for practical tau.
        let p_design: f64 = 20.0;
        let p_pll: f64 = 0.1;
        let t_lock: f64 = 10e-6;
        let tau: f64 = 2e-3;
        let one_pll = p_design * t_lock + p_pll * (tau + t_lock);
        let two_pll = 2.0 * p_pll * tau;
        assert!((one_pll - two_pll).abs() / two_pll < 0.55);
        let tau = 1.0;
        assert!(p_design * t_lock + p_pll * (tau + t_lock) < 2.0 * p_pll * tau);
    }
}
