//! # wavescale
//!
//! Workload-aware opportunistic energy efficiency for multi-FPGA platforms —
//! a production-shaped reproduction of Salamat et al., 2019 (cs.AR), built
//! as a three-layer Rust + JAX + Pallas stack (see DESIGN.md).
//!
//! Layer 3 (this crate) owns the platform: characterization library,
//! benchmark netlists + STA, the voltage/frequency optimizer, the Markov
//! workload predictor, the multi-FPGA simulator, and a serving coordinator
//! that executes the AOT-compiled JAX/Pallas artifacts through PJRT.
//! Python (layers 1–2) runs only at build time (`make artifacts`).

#![warn(missing_docs)]

pub mod arch;
pub mod bench_support;
pub mod cli;
pub mod clock;
pub mod config;
pub mod control;
pub mod coordinator;
pub mod simtest;
pub mod metrics;
pub mod report;
pub mod chars;
pub mod netlist;
pub mod platform;
pub mod power;
pub mod runtime;
pub mod sta;
pub mod sync;
pub mod markov;
pub mod util;
pub mod workload;
pub mod vscale;
