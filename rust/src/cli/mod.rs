//! Minimal CLI argument parser (clap is unavailable offline; DESIGN.md S13).
//!
//! Grammar: `wavescale <subcommand> [--flag value] [--switch] [positional]`.
//!
//! Flags are greedy: `--name value` binds the next token unless it starts
//! with `--`, so positionals must precede trailing switches (or use
//! `--flag=value`).

use std::collections::BTreeMap;

/// Parsed command line: subcommand, `--flag value` pairs, `--switch`es
/// and positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First non-flag token (empty when none was given).
    pub subcommand: String,
    /// Non-flag tokens after the subcommand.
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from raw argv (excluding argv[0]).
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare -- is not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.flags.insert(name.to_string(), it.next().unwrap().clone());
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    /// Value of `--name value`, if present.
    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    /// Flag value with a default.
    pub fn flag_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    /// Flag parsed as f64 (`Ok(None)` when absent, `Err` on a bad number).
    pub fn flag_f64(&self, name: &str) -> Result<Option<f64>, String> {
        self.flag(name)
            .map(|v| v.parse::<f64>().map_err(|_| format!("--{name} must be a number")))
            .transpose()
    }

    /// Flag parsed as usize (`Ok(None)` when absent, `Err` on a bad int).
    pub fn flag_usize(&self, name: &str) -> Result<Option<usize>, String> {
        self.flag(name)
            .map(|v| v.parse::<usize>().map_err(|_| format!("--{name} must be an integer")))
            .transpose()
    }

    /// True when `--name` was given as a bare switch (or `--name true`).
    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flag(name) == Some("true")
    }

    /// Flags the command did not consume (typo guard).
    pub fn check_known(&self, known: &[&str]) -> Result<(), String> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                return Err(format!("unknown flag --{k}"));
            }
        }
        for s in &self.switches {
            if !known.contains(&s.as_str()) {
                return Err(format!("unknown switch --{s}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let argv: Vec<String> = s.split_whitespace().map(String::from).collect();
        Args::parse(&argv).unwrap()
    }

    #[test]
    fn subcommand_flags_switches_positionals() {
        let a = parse("simulate trace.csv --benchmark tabla --steps=500 --verbose");
        assert_eq!(a.subcommand, "simulate");
        assert_eq!(a.flag("benchmark"), Some("tabla"));
        assert_eq!(a.flag("steps"), Some("500"));
        assert!(a.switch("verbose"));
        assert_eq!(a.positional, vec!["trace.csv"]);
        // Greedy binding: a positional after a bare flag becomes its value.
        let b = parse("x --verbose trace.csv");
        assert_eq!(b.flag("verbose"), Some("trace.csv"));
    }

    #[test]
    fn typed_flags() {
        let a = parse("x --f 1.5 --n 3");
        assert_eq!(a.flag_f64("f").unwrap(), Some(1.5));
        assert_eq!(a.flag_usize("n").unwrap(), Some(3));
        assert_eq!(a.flag_f64("missing").unwrap(), None);
        let b = parse("x --n abc");
        assert!(b.flag_usize("n").is_err());
    }

    #[test]
    fn trailing_switch_and_check_known() {
        let a = parse("run --fast");
        assert!(a.switch("fast"));
        assert!(a.check_known(&["fast"]).is_ok());
        assert!(a.check_known(&["slow"]).is_err());
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--help");
        assert_eq!(a.subcommand, "");
        assert!(a.switch("help"));
    }
}
