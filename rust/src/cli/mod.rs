//! Minimal CLI argument parser (clap is unavailable offline; DESIGN.md S13).
//!
//! Grammar: `wavescale <subcommand> [--flag value] [--switch] [positional]`.
//!
//! Flags are greedy: `--name value` binds the next token unless it starts
//! with `--`, so positionals must precede trailing switches (or use
//! `--flag=value`).
//!
//! [`ControlFlags`] parses + validates the control-plane flags every
//! simulation-shaped subcommand shares (`--predictor`, `--qos-target`,
//! `--policy`, `--seed`) so their semantics and error messages cannot
//! drift between subcommands.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, `--flag value` pairs, `--switch`es
/// and positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First non-flag token (empty when none was given).
    pub subcommand: String,
    /// Non-flag tokens after the subcommand.
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from raw argv (excluding argv[0]).
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare -- is not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.flags.insert(name.to_string(), it.next().unwrap().clone());
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    /// Value of `--name value`, if present.
    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    /// Flag value with a default.
    pub fn flag_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    /// Flag parsed as f64 (`Ok(None)` when absent, `Err` on a bad number).
    pub fn flag_f64(&self, name: &str) -> Result<Option<f64>, String> {
        self.flag(name)
            .map(|v| v.parse::<f64>().map_err(|_| format!("--{name} must be a number")))
            .transpose()
    }

    /// Flag parsed as usize (`Ok(None)` when absent, `Err` on a bad int).
    pub fn flag_usize(&self, name: &str) -> Result<Option<usize>, String> {
        self.flag(name)
            .map(|v| v.parse::<usize>().map_err(|_| format!("--{name} must be an integer")))
            .transpose()
    }

    /// True when `--name` was given as a bare switch (or `--name true`).
    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flag(name) == Some("true")
    }

    /// Flags the command did not consume (typo guard).
    pub fn check_known(&self, known: &[&str]) -> Result<(), String> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                return Err(format!("unknown flag --{k}"));
            }
        }
        for s in &self.switches {
            if !known.contains(&s.as_str()) {
                return Err(format!("unknown switch --{s}"));
            }
        }
        Ok(())
    }
}

/// The control-plane flags the simulation-shaped subcommands share —
/// `--predictor`, `--qos-target`, `--policy`, `--seed` — parsed and
/// validated in ONE place. `simulate`, `serve-fleet`, `fleet`,
/// `scenario` and `predict` used to hand-roll each of these into their
/// configs separately; now they all call [`ControlFlags::parse`] and
/// apply only the fields they support (unsupported flags are still
/// rejected by each subcommand's [`Args::check_known`] list).
#[derive(Clone, Debug, Default)]
pub struct ControlFlags {
    /// `--predictor <name>`, resolved through
    /// [`PredictorKind::by_name`](crate::markov::PredictorKind::by_name).
    pub predictor: Option<crate::markov::PredictorKind>,
    /// `--qos-target <fraction|tier>`, validated to [0, 1) (a
    /// violation-rate target; presence enables the adaptive guardband).
    /// Tier names `premium` / `standard` / `best-effort` resolve to
    /// their canonical targets via [`QosTier`](crate::control::QosTier).
    pub qos_target: Option<f64>,
    /// `--policy <name>`, resolved through
    /// [`policy_by_name`](crate::config::policy_by_name).
    pub policy: Option<crate::platform::Policy>,
    /// `--seed <n>`.
    pub seed: Option<u64>,
}

impl ControlFlags {
    /// Parse + validate the shared flags from an already-parsed command
    /// line. Absent flags stay `None`; present-but-invalid values error
    /// with the same messages regardless of which subcommand got them.
    pub fn parse(args: &Args) -> Result<ControlFlags, String> {
        let predictor = args
            .flag("predictor")
            .map(crate::markov::PredictorKind::by_name)
            .transpose()?;
        let qos_target = args
            .flag("qos-target")
            .map(|raw| match crate::control::QosTier::by_name(raw) {
                // Tier names resolve to their canonical targets...
                Ok(tier) => Ok(tier.target()),
                // ...anything else must be a fraction in [0, 1).
                Err(_) => match raw.parse::<f64>() {
                    Ok(q) if (0.0..1.0).contains(&q) => Ok(q),
                    _ => Err(
                        "--qos-target must be a violation-rate fraction in [0, 1) \
                         or a tier name (premium, standard, best-effort)"
                            .to_string(),
                    ),
                },
            })
            .transpose()?;
        let policy = args
            .flag("policy")
            .map(crate::config::policy_by_name)
            .transpose()?;
        let seed = args.flag_usize("seed")?.map(|s| s as u64);
        Ok(ControlFlags { predictor, qos_target, policy, seed })
    }

    /// The predictor flag, or `default` when absent.
    pub fn predictor_or(
        &self,
        default: crate::markov::PredictorKind,
    ) -> crate::markov::PredictorKind {
        self.predictor.unwrap_or(default)
    }

    /// The policy flag, or `default` when absent.
    pub fn policy_or(&self, default: crate::platform::Policy) -> crate::platform::Policy {
        self.policy.unwrap_or(default)
    }

    /// The seed flag, or `default` when absent.
    pub fn seed_or(&self, default: u64) -> u64 {
        self.seed.unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let argv: Vec<String> = s.split_whitespace().map(String::from).collect();
        Args::parse(&argv).unwrap()
    }

    #[test]
    fn subcommand_flags_switches_positionals() {
        let a = parse("simulate trace.csv --benchmark tabla --steps=500 --verbose");
        assert_eq!(a.subcommand, "simulate");
        assert_eq!(a.flag("benchmark"), Some("tabla"));
        assert_eq!(a.flag("steps"), Some("500"));
        assert!(a.switch("verbose"));
        assert_eq!(a.positional, vec!["trace.csv"]);
        // Greedy binding: a positional after a bare flag becomes its value.
        let b = parse("x --verbose trace.csv");
        assert_eq!(b.flag("verbose"), Some("trace.csv"));
    }

    #[test]
    fn typed_flags() {
        let a = parse("x --f 1.5 --n 3");
        assert_eq!(a.flag_f64("f").unwrap(), Some(1.5));
        assert_eq!(a.flag_usize("n").unwrap(), Some(3));
        assert_eq!(a.flag_f64("missing").unwrap(), None);
        let b = parse("x --n abc");
        assert!(b.flag_usize("n").is_err());
    }

    #[test]
    fn trailing_switch_and_check_known() {
        let a = parse("run --fast");
        assert!(a.switch("fast"));
        assert!(a.check_known(&["fast"]).is_ok());
        assert!(a.check_known(&["slow"]).is_err());
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--help");
        assert_eq!(a.subcommand, "");
        assert!(a.switch("help"));
    }

    #[test]
    fn control_flags_parse_and_default() {
        use crate::markov::PredictorKind;
        use crate::platform::Policy;
        use crate::vscale::Mode;

        let f = ControlFlags::parse(&parse(
            "simulate --predictor ensemble --qos-target 0.01 --policy hybrid --seed 9",
        ))
        .unwrap();
        assert_eq!(f.predictor, Some(PredictorKind::Ensemble));
        assert_eq!(f.qos_target, Some(0.01));
        assert_eq!(f.policy, Some(Policy::Hybrid(Mode::Proposed)));
        assert_eq!(f.seed, Some(9));

        // Absent flags stay None and the *_or helpers fill defaults.
        let f = ControlFlags::parse(&parse("simulate")).unwrap();
        assert_eq!(f.predictor, None);
        assert_eq!(f.qos_target, None);
        assert_eq!(f.policy_or(Policy::Dvfs(Mode::Proposed)), Policy::Dvfs(Mode::Proposed));
        assert_eq!(f.predictor_or(PredictorKind::Markov), PredictorKind::Markov);
        assert_eq!(f.seed_or(2019), 2019);
    }

    #[test]
    fn qos_target_accepts_tier_names() {
        use crate::control::QosTier;
        for tier in QosTier::ALL {
            let f = ControlFlags::parse(&parse(&format!("x --qos-target {}", tier.name())))
                .unwrap();
            assert_eq!(f.qos_target, Some(tier.target()), "{}", tier.name());
        }
    }

    #[test]
    fn control_flags_reject_bad_values() {
        // Every bad value errors identically no matter which subcommand
        // passed it (the point of the shared builder).
        let bad = [
            "x --predictor nope",
            "x --qos-target 1.5",
            "x --qos-target -0.1",
            "x --qos-target abc",
            "x --policy bogus",
            "x --seed notanumber",
        ];
        for argv in bad {
            assert!(
                ControlFlags::parse(&parse(argv)).is_err(),
                "{argv:?} must be rejected"
            );
        }
        // An unknown flag is the subcommand's check_known job, not ours.
        let a = parse("x --frobnicate 3 --seed 1");
        assert!(ControlFlags::parse(&a).is_ok());
        assert!(a.check_known(&["seed"]).is_err(), "unknown flag still rejected");
    }
}
