//! Workload trace generation — the BURSE [47] substitute (DESIGN.md S8).
//!
//! The paper's evaluation drives the platform with a *bursty, self-similar*
//! synthetic workload: 40% average load, arrival rate λ=1000, Hurst
//! exponent H = 0.76, index of dispersion IDC = 500. We reproduce those
//! statistics with the classical ON/OFF construction: aggregating many
//! sources whose ON/OFF durations are Pareto(a) heavy-tailed yields
//! asymptotically self-similar traffic with H = (3 − a) / 2 (Willinger et
//! al.), and the heavy tails push IDC into the hundreds. `util::stats`
//! provides the estimators (`hurst_rs`, `hurst_variance_time`, `idc`) that
//! validate every generated trace (see tests and `benches/fig10*`).
//!
//! Also here: Poisson, periodic(diurnal), square-wave and CSV replay
//! sources, all normalized to "load relative to expected peak" in [0, 1],
//! and the named multi-tenant [`scenarios`] suite that drives both the
//! simulator and the live coordinator.

pub mod faults;
pub mod scenarios;

pub use faults::{BoardFailure, FaultPlan, StragglerWindow, SurgeWindow};
pub use scenarios::{Scenario, TenantTrace};

use crate::util::prng::Rng;
use crate::util::stats;

/// Bin index of a normalized load over `m` equal-width bins — THE
/// load→bin mapping, shared by the Markov state space
/// (`markov::MarkovPredictor::bin_of`), the voltage LUT key
/// (`vscale::VoltageLut::bin_of`) and the elastic LUT key
/// (`vscale::ElasticLut::bin_of`). Bins are upper-edge inclusive:
/// bin b covers `(b/m, (b+1)/m]`, except bin 0 which also takes load 0.
/// Out-of-range loads clamp into `[0, 1]` first, so every input maps to
/// a valid bin (no panic, no dropped sample).
pub fn bin_of_load(m: usize, load: f64) -> usize {
    ((load.clamp(0.0, 1.0) * m as f64).ceil() as usize).clamp(1, m) - 1
}

/// Upper edge of bin `b` of `m` — the load a platform must be able to
/// serve when it predicts that bin. Inverse of [`bin_of_load`] in the
/// sense that `bin_of_load(m, bin_upper(m, b)) == b` exactly, so bin
/// indices round-trip stably through load space at every boundary.
pub fn bin_upper(m: usize, bin: usize) -> f64 {
    (bin + 1) as f64 / m as f64
}

/// A workload trace: per-time-step load, normalized to expected peak.
#[derive(Clone, Debug)]
pub struct Trace {
    /// Normalized load per step, each in [0, 1].
    pub loads: Vec<f64>,
    /// Human-readable description of the generator and its parameters.
    pub label: String,
}

impl Trace {
    /// Number of steps in the trace.
    pub fn len(&self) -> usize {
        self.loads.len()
    }

    /// True when the trace has no steps.
    pub fn is_empty(&self) -> bool {
        self.loads.is_empty()
    }

    /// Mean load over the trace.
    pub fn mean(&self) -> f64 {
        stats::mean(&self.loads)
    }

    /// Measured self-similarity/burstiness statistics of the trace
    /// (counts are reconstructed at `lambda` arrivals per step at load 1).
    pub fn measured_stats(&self, lambda: f64) -> TraceStats {
        let counts: Vec<f64> = self.loads.iter().map(|l| l * lambda).collect();
        TraceStats {
            mean_load: self.mean(),
            hurst_rs: stats::hurst_rs(&self.loads),
            hurst_vt: stats::hurst_variance_time(&self.loads),
            idc: stats::idc(&counts, 16),
        }
    }

    /// Serialize as a one-column CSV (header + load per line).
    pub fn to_csv(&self) -> String {
        let mut s = String::with_capacity(self.loads.len() * 10 + 16);
        s.push_str("load\n");
        for l in &self.loads {
            s.push_str(&format!("{l:.6}\n"));
        }
        s
    }

    /// Serialize as a two-column `step,load` CSV (the timestamped replay
    /// format; [`Trace::from_csv`] validates that steps are strictly
    /// increasing).
    pub fn to_csv_with_steps(&self) -> String {
        let mut s = String::with_capacity(self.loads.len() * 14 + 16);
        s.push_str("step,load\n");
        for (t, l) in self.loads.iter().enumerate() {
            s.push_str(&format!("{t},{l:.6}\n"));
        }
        s
    }

    /// Parse the CSV formats written by [`Trace::to_csv`] (one `load`
    /// column) and [`Trace::to_csv_with_steps`] (`step,load`). Timestamped
    /// rows must be strictly increasing, and a file must not mix the two
    /// row formats — a row that lost its timestamp, or duplicated /
    /// out-of-order timestamps, are recording bugs, and replaying them
    /// would silently shift or reorder the workload.
    pub fn from_csv(text: &str, label: &str) -> Result<Trace, String> {
        let mut loads = Vec::new();
        let mut last_step: Option<i64> = None;
        let mut has_steps: Option<bool> = None;
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || (i == 0 && matches!(line, "load" | "step,load" | "t,load")) {
                continue;
            }
            let stepped = line.contains(',');
            match has_steps {
                None => has_steps = Some(stepped),
                Some(h) if h != stepped => {
                    return Err(format!(
                        "line {}: mixed timestamped and plain rows",
                        i + 1
                    ));
                }
                Some(_) => {}
            }
            let load_txt = match line.split_once(',') {
                None => line,
                Some((step_txt, load_txt)) => {
                    let step: i64 = step_txt
                        .trim()
                        .parse()
                        .map_err(|_| format!("line {}: bad step {:?}", i + 1, step_txt.trim()))?;
                    if let Some(prev) = last_step {
                        if step <= prev {
                            return Err(format!(
                                "line {}: non-monotonic step {step} after {prev}",
                                i + 1
                            ));
                        }
                    }
                    last_step = Some(step);
                    load_txt.trim()
                }
            };
            let v: f64 = load_txt
                .parse()
                .map_err(|_| format!("line {}: bad load {load_txt:?}", i + 1))?;
            if !(0.0..=1.5).contains(&v) {
                return Err(format!("line {}: load {v} out of range", i + 1));
            }
            loads.push(v.min(1.0));
        }
        if loads.is_empty() {
            return Err("empty trace".into());
        }
        Ok(Trace { loads, label: label.to_string() })
    }
}

/// Measured burstiness/self-similarity statistics of a trace.
#[derive(Clone, Copy, Debug)]
pub struct TraceStats {
    /// Mean normalized load.
    pub mean_load: f64,
    /// Hurst exponent, rescaled-range estimator.
    pub hurst_rs: f64,
    /// Hurst exponent, variance-time estimator.
    pub hurst_vt: f64,
    /// Index of dispersion for counts (Poisson ≈ 1; paper uses 500).
    pub idc: f64,
}

/// Parameters of the bursty self-similar generator (paper §VI.B values as
/// defaults: 40% average load, H = 0.76 → Pareto shape a = 3 − 2H = 1.48).
#[derive(Clone, Copy, Debug)]
pub struct BurstyConfig {
    /// Trace length in steps.
    pub steps: usize,
    /// Target mean normalized load.
    pub mean_load: f64,
    /// Target Hurst exponent in (0.5, 1).
    pub hurst: f64,
    /// Number of superposed ON/OFF sources.
    pub sources: usize,
    /// Mean ON duration in steps (OFF scales to hit `mean_load`).
    pub mean_on: f64,
    /// PRNG seed; identical seeds reproduce the trace exactly.
    pub seed: u64,
}

impl Default for BurstyConfig {
    fn default() -> Self {
        BurstyConfig {
            steps: 1_000,
            mean_load: 0.40,
            hurst: 0.76,
            sources: 32,
            mean_on: 40.0,
            seed: 2019,
        }
    }
}

/// Superposed Pareto-ON/OFF self-similar generator.
pub fn bursty(cfg: &BurstyConfig) -> Trace {
    assert!(cfg.steps >= 1 && cfg.sources >= 1);
    assert!((0.5..1.0).contains(&cfg.hurst), "hurst must be in (0.5, 1)");
    assert!((0.0..=1.0).contains(&cfg.mean_load));
    let a = 3.0 - 2.0 * cfg.hurst; // Pareto shape, 1 < a < 2
    // Pareto(a, xm) mean = a*xm/(a-1); solve xm for the target mean ON.
    let xm_on = cfg.mean_on * (a - 1.0) / a;
    // OFF duration sized so each source is ON with p = mean_load.
    let duty = cfg.mean_load.clamp(0.02, 0.98);
    let mean_off = cfg.mean_on * (1.0 - duty) / duty;
    let xm_off = mean_off * (a - 1.0) / a;

    let mut rng = Rng::new(cfg.seed);
    let mut acc = vec![0.0f64; cfg.steps];
    for s in 0..cfg.sources {
        let mut r = rng.fork(s as u64 + 1);
        let mut t = 0usize;
        // Random initial phase: start ON with probability = duty.
        let mut on = r.bool(duty);
        // Cap durations to keep a single source from freezing the trace.
        let cap = (cfg.steps as f64 / 2.0).max(8.0);
        while t < cfg.steps {
            let dur = if on {
                r.pareto(a, xm_on).min(cap)
            } else {
                r.pareto(a, xm_off).min(cap)
            }
            .round()
            .max(1.0) as usize;
            if on {
                let end = (t + dur).min(cfg.steps);
                for x in &mut acc[t..end] {
                    *x += 1.0;
                }
            }
            t += dur;
            on = !on;
        }
    }
    // Normalize: "expected peak" is all sources ON.
    let peak = cfg.sources as f64;
    let loads: Vec<f64> = acc.iter().map(|&x| (x / peak).min(1.0)).collect();
    Trace {
        loads,
        label: format!(
            "bursty(mean={:.2},H={:.2},src={})",
            cfg.mean_load, cfg.hurst, cfg.sources
        ),
    }
}

/// Poisson arrivals at a stationary mean load (IDC ≈ 1 — the *non*-bursty
/// control case).
pub fn poisson(steps: usize, mean_load: f64, lambda: f64, seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    let loads = (0..steps)
        .map(|_| (rng.poisson(mean_load * lambda) as f64 / lambda).min(1.0))
        .collect();
    Trace { loads, label: format!("poisson(mean={mean_load:.2})") }
}

/// Diurnal pattern: sinusoid with the given period plus Gaussian jitter.
pub fn periodic(steps: usize, period: usize, lo: f64, hi: f64, jitter: f64, seed: u64) -> Trace {
    assert!(period >= 2 && hi >= lo);
    let mut rng = Rng::new(seed);
    let loads = (0..steps)
        .map(|t| {
            let phase = (t % period) as f64 / period as f64 * std::f64::consts::TAU;
            let base = lo + (hi - lo) * 0.5 * (1.0 - phase.cos());
            (base + rng.normal() * jitter).clamp(0.0, 1.0)
        })
        .collect();
    Trace { loads, label: format!("periodic(p={period})") }
}

/// Square wave alternating between two load levels (worst case for
/// smoothing predictors, best case for Markov bins).
pub fn square(steps: usize, period: usize, lo: f64, hi: f64) -> Trace {
    assert!(period >= 2);
    let loads = (0..steps)
        .map(|t| if (t / (period / 2)) % 2 == 0 { lo } else { hi })
        .map(|l| l.clamp(0.0, 1.0))
        .collect();
    Trace { loads, label: format!("square(p={period})") }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bin_mapping_is_stable_at_exact_boundaries() {
        // Satellite audit of the load→bin mapping: load 0.0, 1.0 and every
        // interior bin edge must map deterministically and round-trip
        // through bin_upper, for the bin counts the LUTs actually use.
        for m in [2usize, 4, 10, 16] {
            assert_eq!(bin_of_load(m, 0.0), 0, "m={m}: zero load is bin 0");
            assert_eq!(bin_of_load(m, 1.0), m - 1, "m={m}: full load is the top bin");
            // Out-of-range inputs clamp instead of panicking/overflowing.
            assert_eq!(bin_of_load(m, -0.5), 0);
            assert_eq!(bin_of_load(m, 7.3), m - 1);
            assert_eq!(bin_of_load(m, f64::NAN), 0, "NaN clamps to 0 (defined, not UB)");
            for b in 0..m {
                let upper = bin_upper(m, b);
                // Upper-edge inclusive: the edge belongs to its own bin...
                assert_eq!(bin_of_load(m, upper), b, "m={m} b={b}: edge round-trip");
                // ...and the next representable load above it to the next.
                if b + 1 < m {
                    assert_eq!(
                        bin_of_load(m, upper + 1e-12),
                        b + 1,
                        "m={m} b={b}: just past the edge"
                    );
                }
                // Just below the edge stays in the bin.
                assert_eq!(bin_of_load(m, upper - 1e-12), b, "m={m} b={b}: just under");
            }
        }
        assert_eq!(bin_upper(10, 9), 1.0);
    }

    #[test]
    fn bin_mapping_agrees_with_markov_state_space() {
        // The Markov chain's state space delegates here; a drift between
        // the two would desynchronize predictions from LUT keys.
        let p = crate::markov::MarkovPredictor::new(10, 0);
        for i in 0..=1000 {
            let load = i as f64 / 1000.0;
            assert_eq!(p.bin_of(load), bin_of_load(10, load), "load {load}");
        }
        assert_eq!(p.bin_upper(3), bin_upper(10, 3));
    }

    #[test]
    fn bursty_hits_target_mean() {
        let t = bursty(&BurstyConfig { steps: 20_000, ..Default::default() });
        assert!((t.mean() - 0.40).abs() < 0.06, "mean {}", t.mean());
        assert!(t.loads.iter().all(|&l| (0.0..=1.0).contains(&l)));
    }

    #[test]
    fn bursty_is_self_similar_near_h076() {
        // The headline property: H ≈ 0.76 (paper §VI.B). Estimators are
        // noisy, so accept a band around the target.
        let t = bursty(&BurstyConfig { steps: 32_768, ..Default::default() });
        let s = t.measured_stats(1_000.0);
        assert!(
            (0.62..0.95).contains(&s.hurst_rs),
            "R/S Hurst {:.3} not in band",
            s.hurst_rs
        );
        assert!(
            (0.62..0.98).contains(&s.hurst_vt),
            "VT Hurst {:.3} not in band",
            s.hurst_vt
        );
    }

    #[test]
    fn bursty_idc_is_large() {
        // IDC = 500 in the paper at λ = 1000; heavy-tailed ON/OFF should
        // put the measured IDC well into the hundreds.
        let t = bursty(&BurstyConfig { steps: 32_768, ..Default::default() });
        let s = t.measured_stats(1_000.0);
        assert!(s.idc > 100.0, "IDC {:.0} too small", s.idc);
    }

    #[test]
    fn poisson_is_not_bursty() {
        let t = poisson(20_000, 0.4, 1_000.0, 1);
        let s = t.measured_stats(1_000.0);
        assert!((t.mean() - 0.4).abs() < 0.02);
        assert!(s.idc < 30.0, "Poisson IDC {:.1} should be small", s.idc);
        assert!(s.hurst_vt < 0.65, "Poisson Hurst {:.2}", s.hurst_vt);
    }

    #[test]
    fn bursty_deterministic_per_seed() {
        let a = bursty(&BurstyConfig::default());
        let b = bursty(&BurstyConfig::default());
        assert_eq!(a.loads, b.loads);
        let c = bursty(&BurstyConfig { seed: 1, ..Default::default() });
        assert_ne!(a.loads, c.loads);
    }

    #[test]
    fn periodic_and_square_shapes() {
        let p = periodic(240, 24, 0.1, 0.9, 0.0, 0);
        assert!((p.loads[0] - 0.1).abs() < 1e-9);
        assert!((p.loads[12] - 0.9).abs() < 1e-9);
        let s = square(100, 10, 0.2, 0.8);
        assert_eq!(s.loads[0], 0.2);
        assert_eq!(s.loads[5], 0.8);
        assert_eq!(s.loads[10], 0.2);
    }

    #[test]
    fn csv_round_trip() {
        let t = bursty(&BurstyConfig { steps: 200, ..Default::default() });
        let csv = t.to_csv();
        let u = Trace::from_csv(&csv, "replayed").unwrap();
        assert_eq!(t.len(), u.len());
        for (a, b) in t.loads.iter().zip(&u.loads) {
            assert!((a - b).abs() < 1e-5);
        }
        assert!(Trace::from_csv("load\nnope\n", "x").is_err());
        assert!(Trace::from_csv("load\n7.5\n", "x").is_err());
        assert!(Trace::from_csv("", "x").is_err());
    }

    #[test]
    fn timestamped_csv_round_trips_and_validates_monotonicity() {
        let t = bursty(&BurstyConfig { steps: 150, ..Default::default() });
        let csv = t.to_csv_with_steps();
        assert!(csv.starts_with("step,load\n"));
        let u = Trace::from_csv(&csv, "replayed").unwrap();
        assert_eq!(t.len(), u.len());
        for (a, b) in t.loads.iter().zip(&u.loads) {
            assert!((a - b).abs() < 1e-5);
        }
        // Non-monotonic, duplicated, and malformed timestamps all refuse.
        let err = Trace::from_csv("step,load\n0,0.5\n2,0.4\n1,0.3\n", "x").unwrap_err();
        assert!(err.contains("non-monotonic"), "{err}");
        let err = Trace::from_csv("step,load\n5,0.5\n5,0.4\n", "x").unwrap_err();
        assert!(err.contains("non-monotonic"), "{err}");
        assert!(Trace::from_csv("step,load\nx,0.5\n", "x").is_err());
        assert!(Trace::from_csv("step,load\n0,oops\n", "x").is_err());
        // Header-only is still an empty trace.
        assert!(Trace::from_csv("step,load\n", "x").is_err());
        // A row that lost its timestamp must not bypass the monotonicity
        // check (it would silently shift every later load by one epoch) —
        // and the converse mix is refused too.
        let err = Trace::from_csv("step,load\n1,0.5\n0.7\n2,0.4\n", "x").unwrap_err();
        assert!(err.contains("mixed"), "{err}");
        let err = Trace::from_csv("load\n0.5\n2,0.4\n", "x").unwrap_err();
        assert!(err.contains("mixed"), "{err}");
        // Gaps are fine as long as order is strict.
        let u = Trace::from_csv("step,load\n10,0.1\n20,0.2\n35,0.3\n", "x").unwrap();
        assert_eq!(u.loads, vec![0.1, 0.2, 0.3]);
    }

    #[test]
    fn multi_day_timestamped_csv_round_trips_for_long_replay() {
        // The long-horizon replay path (`long-replay` scenario): a full
        // week of 96-step diurnal days through the timestamped format.
        // Quantization to the CSV's 6 decimals must stay within 1e-6 and
        // the periodic day structure must survive the round trip exactly.
        let days = 7;
        let t = periodic(96 * days, 96, 0.08, 0.92, 0.0, 42);
        let csv = t.to_csv_with_steps();
        assert_eq!(csv.lines().count(), 96 * days + 1, "header + one row per step");
        let u = Trace::from_csv(&csv, "week-replay").unwrap();
        assert_eq!(u.len(), 96 * days);
        for (a, b) in t.loads.iter().zip(&u.loads) {
            assert!((a - b).abs() < 1e-6, "CSV quantization must stay under 1e-6");
        }
        // The day structure survives exactly: with no jitter, step k and
        // step k + 96 (days - 1) replay the identical load.
        for k in 0..96 {
            assert_eq!(u.loads[k], u.loads[k + 96 * (days - 1)], "step {k}");
        }
    }

    #[test]
    fn long_horizon_csv_rejects_duplicate_and_overlapping_stamps() {
        // A multi-day recording with a duplicated day boundary (the
        // classic double-logged midnight) must refuse, pointing at the
        // offending line.
        let mut csv = String::from("step,load\n");
        for d in 0..3 {
            for s in 0..96 {
                csv.push_str(&format!("{},0.5\n", d * 96 + s));
            }
            // Day 1's recorder re-emits its last stamp at rollover.
            if d == 1 {
                csv.push_str(&format!("{},0.5\n", d * 96 + 95));
            }
        }
        let err = Trace::from_csv(&csv, "x").unwrap_err();
        assert!(err.contains("non-monotonic step 191 after 191"), "{err}");
        assert!(err.contains("line 194"), "{err}");
        // An overlapping splice — day 2 restarts inside day 1 — refuses
        // too, even though each fragment is individually monotonic.
        let spliced = "step,load\n0,0.1\n96,0.2\n97,0.3\n50,0.4\n51,0.5\n";
        let err = Trace::from_csv(spliced, "x").unwrap_err();
        assert!(err.contains("non-monotonic step 50 after 97"), "{err}");
    }
}
