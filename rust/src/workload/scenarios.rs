//! Named end-to-end serving scenarios (DESIGN.md S8.2).
//!
//! A [`Scenario`] bundles per-tenant workload traces with the fleet group
//! layout (benchmark + traffic share), so the *same* named scenario can
//! drive both the offline simulator (`platform::fleet::Fleet::run_scenario`)
//! and the live sharded coordinator (`coordinator::FleetServing`, see
//! `examples/fleet_serving.rs` and the `scenario` / `serve-fleet` CLI
//! subcommands).
//!
//! The built-in suite covers the operating regimes the paper's framework
//! targets (§VI): a diurnal datacenter day, a flash-crowd spike, a mixed
//! multi-tenant bursty day, and a low-utilization overnight valley, plus
//! CSV replay for real traces.

use super::{bursty, periodic, poisson, BurstyConfig, Trace};

/// One tenant's slice of a scenario: which benchmark group serves it, its
/// provisioned share of the fleet, and its offered-load trace.
#[derive(Clone, Debug)]
pub struct TenantTrace {
    /// Benchmark group that serves this tenant (Table I name).
    pub benchmark: String,
    /// Fraction of fleet capacity provisioned for this tenant.
    pub share: f64,
    /// Normalized offered load per step/epoch.
    pub trace: Trace,
}

/// A named multi-tenant workload scenario.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Scenario name (see [`Scenario::NAMES`]).
    pub name: String,
    /// One-line description for reports.
    pub description: String,
    /// Per-tenant traces; shares sum to 1.
    pub tenants: Vec<TenantTrace>,
}

impl Scenario {
    /// Names accepted by [`Scenario::by_name`].
    pub const NAMES: [&'static str; 4] =
        ["diurnal", "flash-crowd", "mixed-tenant", "overnight"];

    /// Build a named scenario.
    pub fn by_name(name: &str, steps: usize, seed: u64) -> Result<Scenario, String> {
        Ok(match name {
            "diurnal" => Scenario::diurnal(steps, seed),
            "flash-crowd" => Scenario::flash_crowd(steps, seed),
            "mixed-tenant" => Scenario::mixed_tenant(steps, seed),
            "overnight" => Scenario::overnight(steps, seed),
            other => {
                return Err(format!(
                    "unknown scenario {other} (known: {})",
                    Scenario::NAMES.join(", ")
                ))
            }
        })
    }

    /// Steps per "day" the named generators use for a run of `steps`
    /// epochs: a 96-step day for long runs, half the run (min 2) for
    /// short ones. The single source for this choice — the periodic
    /// predictor member must train on the same cycle, so `simtest` and
    /// the `serve-fleet` CLI derive their `predictor_period` from here
    /// instead of re-deriving the formula.
    pub fn day_period(steps: usize) -> usize {
        if steps >= 192 {
            96
        } else {
            (steps / 2).max(2)
        }
    }

    /// Every named scenario at the given size, in [`Scenario::NAMES`]
    /// order — the iteration surface behind the capacity-policy
    /// comparison tests and the `hybrid_capacity` bench.
    pub fn all(steps: usize, seed: u64) -> Vec<Scenario> {
        Scenario::NAMES
            .iter()
            .filter_map(|name| Scenario::by_name(name, steps, seed).ok())
            .collect()
    }

    /// Two groups with anti-phased day/night sinusoids: user-facing Tabla
    /// peaks when batch-style DianNao is in its valley and vice versa —
    /// the complementary-tenant packing datacenters aim for.
    pub fn diurnal(steps: usize, seed: u64) -> Scenario {
        let period = Scenario::day_period(steps);
        let day = periodic(steps, period, 0.10, 0.85, 0.02, seed);
        let mut night = periodic(steps, period, 0.15, 0.80, 0.02, seed ^ 0x5ca1e);
        night.loads.rotate_left((period / 2).min(night.loads.len()));
        night.label = format!("periodic(p={period},shifted)");
        Scenario {
            name: "diurnal".into(),
            description: "anti-phased day/night sinusoids across two tenants".into(),
            tenants: vec![
                TenantTrace { benchmark: "tabla".into(), share: 0.5, trace: day },
                TenantTrace { benchmark: "diannao".into(), share: 0.5, trace: night },
            ],
        }
    }

    /// A quiet Poisson baseline torn open by a flash crowd on the
    /// user-facing tenant: a near-peak plateau over ~15% of the run with
    /// sharp ramps. The background tenant stays steady.
    pub fn flash_crowd(steps: usize, seed: u64) -> Scenario {
        let mut front = poisson(steps, 0.22, 1_000.0, seed);
        let spike_start = steps * 2 / 5;
        let spike_len = (steps * 3 / 20).max(1);
        let ramp = (spike_len / 6).max(1);
        for t in spike_start..(spike_start + spike_len).min(steps) {
            let into = t - spike_start;
            let left = spike_start + spike_len - 1 - t;
            let edge = into.min(left);
            let level = if edge < ramp {
                0.3 + 0.65 * (edge + 1) as f64 / ramp as f64
            } else {
                0.95
            };
            let cur = front.loads[t];
            front.loads[t] = cur.max(level.min(1.0));
        }
        front.label = "poisson+flash-crowd".into();
        let back = poisson(steps, 0.30, 1_000.0, seed ^ 0xbeef);
        Scenario {
            name: "flash-crowd".into(),
            description: "near-peak spike on the user-facing tenant over a quiet baseline"
                .into(),
            tenants: vec![
                TenantTrace { benchmark: "tabla".into(), share: 0.6, trace: front },
                TenantTrace { benchmark: "dnnweaver".into(), share: 0.4, trace: back },
            ],
        }
    }

    /// Three tenants with different burstiness and mean loads sharing the
    /// fleet — the paper's Fig. 7 "different users" deployment.
    pub fn mixed_tenant(steps: usize, seed: u64) -> Scenario {
        let a = bursty(&BurstyConfig { steps, mean_load: 0.40, seed, ..Default::default() });
        let b = bursty(&BurstyConfig {
            steps,
            mean_load: 0.55,
            seed: seed.wrapping_add(1),
            ..Default::default()
        });
        let period = Scenario::day_period(steps);
        let c = periodic(steps, period, 0.15, 0.75, 0.03, seed.wrapping_add(2));
        Scenario {
            name: "mixed-tenant".into(),
            description: "three tenants with distinct burstiness/mean sharing one fleet"
                .into(),
            tenants: vec![
                TenantTrace { benchmark: "tabla".into(), share: 0.40, trace: a },
                TenantTrace { benchmark: "diannao".into(), share: 0.35, trace: b },
                TenantTrace { benchmark: "stripes".into(), share: 0.25, trace: c },
            ],
        }
    }

    /// Deep overnight valley: every tenant idles near 10% load — the
    /// regime where voltage scaling's advantage over power gating is
    /// smallest and the crash-voltage floor binds (paper §III).
    pub fn overnight(steps: usize, seed: u64) -> Scenario {
        let a = bursty(&BurstyConfig { steps, mean_load: 0.08, seed, ..Default::default() });
        let b = bursty(&BurstyConfig {
            steps,
            mean_load: 0.12,
            seed: seed.wrapping_add(7),
            ..Default::default()
        });
        Scenario {
            name: "overnight".into(),
            description: "low-utilization overnight valley across both tenants".into(),
            tenants: vec![
                TenantTrace { benchmark: "tabla".into(), share: 0.5, trace: a },
                TenantTrace { benchmark: "dnnweaver".into(), share: 0.5, trace: b },
            ],
        }
    }

    /// Build a replay scenario from `(benchmark, share, csv_text)` rows —
    /// each CSV in the [`Trace::to_csv`] format.
    pub fn replay(name: &str, specs: &[(&str, f64, &str)]) -> Result<Scenario, String> {
        let mut tenants = Vec::with_capacity(specs.len());
        for (benchmark, share, csv) in specs {
            tenants.push(TenantTrace {
                benchmark: benchmark.to_string(),
                share: *share,
                trace: Trace::from_csv(csv, &format!("{benchmark}-replay"))?,
            });
        }
        let s = Scenario {
            name: name.to_string(),
            description: "CSV replay".into(),
            tenants,
        };
        s.validate()?;
        Ok(s)
    }

    /// Steps every tenant has a load for (min across tenants).
    pub fn steps(&self) -> usize {
        self.tenants.iter().map(|t| t.trace.len()).min().unwrap_or(0)
    }

    /// `(benchmark, share)` rows, the layout `platform::fleet::Fleet` and
    /// `coordinator::FleetServing` are built from.
    pub fn groups(&self) -> Vec<(String, f64)> {
        self.tenants
            .iter()
            .map(|t| (t.benchmark.clone(), t.share))
            .collect()
    }

    /// Check structural invariants: at least one tenant, positive shares
    /// summing to ~1, and non-empty traces.
    pub fn validate(&self) -> Result<(), String> {
        if self.tenants.is_empty() {
            return Err(format!("scenario {}: no tenants", self.name));
        }
        let sum: f64 = self.tenants.iter().map(|t| t.share).sum();
        if (sum - 1.0).abs() > 1e-6 {
            return Err(format!("scenario {}: shares sum to {sum}, expected 1", self.name));
        }
        for t in &self.tenants {
            if t.share <= 0.0 {
                return Err(format!("scenario {}: {} share must be positive", self.name, t.benchmark));
            }
            if t.trace.is_empty() {
                return Err(format!("scenario {}: {} trace is empty", self.name, t.benchmark));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_named_scenarios_validate() {
        for name in Scenario::NAMES {
            let s = Scenario::by_name(name, 400, 2019).unwrap();
            s.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(s.steps(), 400, "{name}");
            assert!(s.tenants.len() >= 2, "{name} must be multi-tenant");
            for t in &s.tenants {
                assert!(t.trace.loads.iter().all(|&l| (0.0..=1.0).contains(&l)));
            }
        }
        assert!(Scenario::by_name("nope", 100, 0).is_err());
    }

    #[test]
    fn all_returns_every_named_scenario_in_order() {
        let all = Scenario::all(64, 7);
        assert_eq!(all.len(), Scenario::NAMES.len());
        for (s, name) in all.iter().zip(Scenario::NAMES) {
            assert_eq!(s.name, name);
            assert_eq!(s.steps(), 64);
        }
    }

    #[test]
    fn diurnal_tenants_are_anti_phased() {
        let s = Scenario::diurnal(384, 1);
        let a = &s.tenants[0].trace.loads;
        let b = &s.tenants[1].trace.loads;
        // When tabla peaks, diannao should be near its valley.
        let peak_a = (0..a.len()).max_by(|&i, &j| a[i].partial_cmp(&a[j]).unwrap()).unwrap();
        assert!(a[peak_a] > 0.7, "tabla peak {}", a[peak_a]);
        assert!(b[peak_a] < 0.45, "diannao at tabla's peak: {}", b[peak_a]);
    }

    #[test]
    fn flash_crowd_has_a_spike_and_a_quiet_baseline() {
        let s = Scenario::flash_crowd(400, 3);
        let front = &s.tenants[0].trace.loads;
        let spike_max = front.iter().copied().fold(0.0, f64::max);
        assert!(spike_max >= 0.95, "spike must near-saturate: {spike_max}");
        // Before the spike the load is low.
        let pre: f64 = front[..100].iter().sum::<f64>() / 100.0;
        assert!(pre < 0.4, "pre-spike mean {pre}");
        // The spike plateau sits where it was constructed.
        assert!(front[400 * 2 / 5 + 10] > 0.9);
    }

    #[test]
    fn overnight_is_low_utilization() {
        let s = Scenario::overnight(2_000, 5);
        for t in &s.tenants {
            assert!(t.trace.mean() < 0.2, "{}: mean {}", t.benchmark, t.trace.mean());
        }
    }

    #[test]
    fn replay_round_trips_and_validates() {
        let t = bursty(&BurstyConfig { steps: 64, ..Default::default() });
        let csv = t.to_csv();
        let s = Scenario::replay("replayed", &[("tabla", 0.5, &csv), ("diannao", 0.5, &csv)])
            .unwrap();
        assert_eq!(s.steps(), 64);
        assert_eq!(s.groups()[0].0, "tabla");
        assert!(Scenario::replay("bad", &[("tabla", 0.5, &csv)]).is_err());
        assert!(Scenario::replay("bad", &[("tabla", 1.0, "load\nnope\n")]).is_err());
    }

    #[test]
    fn replay_rejects_malformed_empty_and_non_monotonic_tenants() {
        let good = bursty(&BurstyConfig { steps: 32, ..Default::default() }).to_csv();
        // One malformed tenant poisons the whole replay scenario.
        let err = Scenario::replay(
            "bad",
            &[("tabla", 0.5, &good), ("diannao", 0.5, "load\n0.2\nnot-a-load\n")],
        )
        .unwrap_err();
        assert!(err.contains("bad load"), "{err}");
        // Empty CSV file.
        let err = Scenario::replay("bad", &[("tabla", 1.0, "")]).unwrap_err();
        assert!(err.contains("empty"), "{err}");
        // Header-only CSV is still empty.
        assert!(Scenario::replay("bad", &[("tabla", 1.0, "step,load\n")]).is_err());
        // Non-monotonic timestamps in a timestamped trace.
        let err = Scenario::replay(
            "bad",
            &[("tabla", 1.0, "step,load\n0,0.4\n3,0.5\n2,0.6\n")],
        )
        .unwrap_err();
        assert!(err.contains("non-monotonic"), "{err}");
        // Out-of-range load value.
        assert!(Scenario::replay("bad", &[("tabla", 1.0, "load\n7.5\n")]).is_err());
    }

    #[test]
    fn replay_reproduces_the_generator_bin_sequence() {
        // generate → write CSV (both formats) → replay → the Markov
        // predictor sees the identical bin sequence, so a replayed trace
        // drives the CC exactly like the generated original.
        let t = bursty(&BurstyConfig { steps: 256, seed: 77, ..Default::default() });
        let p = crate::markov::MarkovPredictor::new(10, 0);
        for csv in [t.to_csv(), t.to_csv_with_steps()] {
            let s = Scenario::replay(
                "replayed",
                &[("tabla", 0.5, csv.as_str()), ("diannao", 0.5, csv.as_str())],
            )
            .unwrap();
            for tenant in &s.tenants {
                let bins_orig: Vec<usize> = t.loads.iter().map(|&l| p.bin_of(l)).collect();
                let bins_replay: Vec<usize> =
                    tenant.trace.loads.iter().map(|&l| p.bin_of(l)).collect();
                assert_eq!(bins_orig, bins_replay, "{}", tenant.benchmark);
            }
        }
    }
}
