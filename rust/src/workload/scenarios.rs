//! Named end-to-end serving scenarios (DESIGN.md S8.2).
//!
//! A [`Scenario`] bundles per-tenant workload traces with the fleet group
//! layout (benchmark + traffic share), so the *same* named scenario can
//! drive both the offline simulator (`platform::fleet::Fleet::run_scenario`)
//! and the live sharded coordinator (`coordinator::FleetServing`, see
//! `examples/fleet_serving.rs` and the `scenario` / `serve-fleet` CLI
//! subcommands).
//!
//! The built-in suite covers the operating regimes the paper's framework
//! targets (§VI): a diurnal datacenter day, a flash-crowd spike, a mixed
//! multi-tenant bursty day, and a low-utilization overnight valley, plus
//! CSV replay for real traces — and the adversarial suite (DESIGN.md
//! S20): board failures, stragglers, correlated surges, QoS-tiered
//! tenants and a long-horizon timestamped-CSV replay. The adversarial
//! fault windows themselves live in a [`FaultPlan`](super::FaultPlan)
//! attached by the harness (`simtest::SimSpec::golden` /
//! `FaultPlan::for_scenario`), so the *workload* side of every scenario
//! stays a plain multi-tenant trace bundle both control paths can drive.

use crate::control::QosTier;

use super::{bursty, periodic, poisson, BurstyConfig, Trace};

/// One tenant's slice of a scenario: which benchmark group serves it, its
/// provisioned share of the fleet, and its offered-load trace.
#[derive(Clone, Debug)]
pub struct TenantTrace {
    /// Benchmark group that serves this tenant (Table I name).
    pub benchmark: String,
    /// Fraction of fleet capacity provisioned for this tenant.
    pub share: f64,
    /// Normalized offered load per step/epoch.
    pub trace: Trace,
    /// Per-tenant QoS tier target (DESIGN.md S20): refines the run-level
    /// `qos_target` when the adaptive guardband is enabled
    /// ([`QosTier::effective`]); inert under the static baselines.
    pub qos_target: Option<f64>,
}

/// A named multi-tenant workload scenario.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Scenario name (see [`Scenario::NAMES`]).
    pub name: String,
    /// One-line description for reports.
    pub description: String,
    /// Per-tenant traces; shares sum to 1.
    pub tenants: Vec<TenantTrace>,
}

impl Scenario {
    /// Names accepted by [`Scenario::by_name`]: the four operating-regime
    /// scenarios, then the five adversarial ones (DESIGN.md S20).
    pub const NAMES: [&'static str; 9] = [
        "diurnal",
        "flash-crowd",
        "mixed-tenant",
        "overnight",
        "board-failure",
        "straggler",
        "correlated-surge",
        "tiered-tenants",
        "long-replay",
    ];

    /// Build a named scenario.
    pub fn by_name(name: &str, steps: usize, seed: u64) -> Result<Scenario, String> {
        Ok(match name {
            "diurnal" => Scenario::diurnal(steps, seed),
            "flash-crowd" => Scenario::flash_crowd(steps, seed),
            "mixed-tenant" => Scenario::mixed_tenant(steps, seed),
            "overnight" => Scenario::overnight(steps, seed),
            "board-failure" => Scenario::board_failure(steps, seed),
            "straggler" => Scenario::straggler(steps, seed),
            "correlated-surge" => Scenario::correlated_surge(steps, seed),
            "tiered-tenants" => Scenario::tiered_tenants(steps, seed),
            "long-replay" => Scenario::long_replay(steps, seed),
            other => {
                // `synthetic-N` builds an N-group scale-sweep fleet (see
                // [`Scenario::synthetic_fleet`]); any N is accepted, so
                // the name is parsed rather than listed in NAMES.
                if let Some(n) =
                    other.strip_prefix("synthetic-").and_then(|s| s.parse::<usize>().ok())
                {
                    return Ok(Scenario::synthetic_fleet(n, steps, seed));
                }
                return Err(format!(
                    "unknown scenario {other} (known: {}, synthetic-N)",
                    Scenario::NAMES.join(", ")
                ));
            }
        })
    }

    /// Steps per "day" the named generators use for a run of `steps`
    /// epochs: a 96-step day for long runs, half the run (min 2) for
    /// short ones. The single source for this choice — the periodic
    /// predictor member must train on the same cycle, so `simtest` and
    /// the `serve-fleet` CLI derive their `predictor_period` from here
    /// instead of re-deriving the formula.
    pub fn day_period(steps: usize) -> usize {
        if steps >= 192 {
            96
        } else {
            (steps / 2).max(2)
        }
    }

    /// The fleet group configs this scenario's tenants imply, one group
    /// per tenant at `n_instances` workers each. Shared by the
    /// `serve-fleet` CLI and the `simtest` harness so the two serving
    /// paths build identical fleets from a scenario.
    pub fn group_configs(&self, n_instances: usize) -> Vec<crate::coordinator::GroupConfig> {
        self.tenants
            .iter()
            .map(|t| crate::coordinator::GroupConfig {
                benchmark: t.benchmark.clone(),
                share: t.share,
                n_instances,
                // Tenant QoS tiers refine an enabled run-level guardband.
                qos_target: t.qos_target,
            })
            .collect()
    }

    /// Every named scenario at the given size, in [`Scenario::NAMES`]
    /// order — the iteration surface behind the capacity-policy
    /// comparison tests and the `hybrid_capacity` bench.
    pub fn all(steps: usize, seed: u64) -> Vec<Scenario> {
        Scenario::NAMES
            .iter()
            .filter_map(|name| Scenario::by_name(name, steps, seed).ok())
            .collect()
    }

    /// Two groups with anti-phased day/night sinusoids: user-facing Tabla
    /// peaks when batch-style DianNao is in its valley and vice versa —
    /// the complementary-tenant packing datacenters aim for.
    pub fn diurnal(steps: usize, seed: u64) -> Scenario {
        let period = Scenario::day_period(steps);
        let day = periodic(steps, period, 0.10, 0.85, 0.02, seed);
        let mut night = periodic(steps, period, 0.15, 0.80, 0.02, seed ^ 0x5ca1e);
        night.loads.rotate_left((period / 2).min(night.loads.len()));
        night.label = format!("periodic(p={period},shifted)");
        Scenario {
            name: "diurnal".into(),
            description: "anti-phased day/night sinusoids across two tenants".into(),
            tenants: vec![
                TenantTrace { benchmark: "tabla".into(), share: 0.5, trace: day, qos_target: None },
                TenantTrace { benchmark: "diannao".into(), share: 0.5, trace: night, qos_target: None },
            ],
        }
    }

    /// A quiet Poisson baseline torn open by a flash crowd on the
    /// user-facing tenant: a near-peak plateau over ~15% of the run with
    /// sharp ramps. The background tenant stays steady.
    pub fn flash_crowd(steps: usize, seed: u64) -> Scenario {
        let mut front = poisson(steps, 0.22, 1_000.0, seed);
        let spike_start = steps * 2 / 5;
        let spike_len = (steps * 3 / 20).max(1);
        let ramp = (spike_len / 6).max(1);
        for t in spike_start..(spike_start + spike_len).min(steps) {
            let into = t - spike_start;
            let left = spike_start + spike_len - 1 - t;
            let edge = into.min(left);
            let level = if edge < ramp {
                0.3 + 0.65 * (edge + 1) as f64 / ramp as f64
            } else {
                0.95
            };
            let cur = front.loads[t];
            front.loads[t] = cur.max(level.min(1.0));
        }
        front.label = "poisson+flash-crowd".into();
        let back = poisson(steps, 0.30, 1_000.0, seed ^ 0xbeef);
        Scenario {
            name: "flash-crowd".into(),
            description: "near-peak spike on the user-facing tenant over a quiet baseline"
                .into(),
            tenants: vec![
                TenantTrace { benchmark: "tabla".into(), share: 0.6, trace: front, qos_target: None },
                TenantTrace { benchmark: "dnnweaver".into(), share: 0.4, trace: back, qos_target: None },
            ],
        }
    }

    /// Three tenants with different burstiness and mean loads sharing the
    /// fleet — the paper's Fig. 7 "different users" deployment.
    pub fn mixed_tenant(steps: usize, seed: u64) -> Scenario {
        let a = bursty(&BurstyConfig { steps, mean_load: 0.40, seed, ..Default::default() });
        let b = bursty(&BurstyConfig {
            steps,
            mean_load: 0.55,
            seed: seed.wrapping_add(1),
            ..Default::default()
        });
        let period = Scenario::day_period(steps);
        let c = periodic(steps, period, 0.15, 0.75, 0.03, seed.wrapping_add(2));
        Scenario {
            name: "mixed-tenant".into(),
            description: "three tenants with distinct burstiness/mean sharing one fleet"
                .into(),
            tenants: vec![
                TenantTrace { benchmark: "tabla".into(), share: 0.40, trace: a, qos_target: None },
                TenantTrace { benchmark: "diannao".into(), share: 0.35, trace: b, qos_target: None },
                TenantTrace { benchmark: "stripes".into(), share: 0.25, trace: c, qos_target: None },
            ],
        }
    }

    /// Deep overnight valley: every tenant idles near 10% load — the
    /// regime where voltage scaling's advantage over power gating is
    /// smallest and the crash-voltage floor binds (paper §III).
    pub fn overnight(steps: usize, seed: u64) -> Scenario {
        let a = bursty(&BurstyConfig { steps, mean_load: 0.08, seed, ..Default::default() });
        let b = bursty(&BurstyConfig {
            steps,
            mean_load: 0.12,
            seed: seed.wrapping_add(7),
            ..Default::default()
        });
        Scenario {
            name: "overnight".into(),
            description: "low-utilization overnight valley across both tenants".into(),
            tenants: vec![
                TenantTrace { benchmark: "tabla".into(), share: 0.5, trace: a, qos_target: None },
                TenantTrace { benchmark: "dnnweaver".into(), share: 0.5, trace: b, qos_target: None },
            ],
        }
    }

    /// Two steady Poisson tenants — deliberately unspectacular load so
    /// the golden/property signal of the `board-failure` runs is the
    /// injected failure window ([`FaultPlan::for_scenario`]: the first
    /// group loses its last shard for the middle third of the run), not
    /// workload churn.
    ///
    /// [`FaultPlan::for_scenario`]: super::FaultPlan::for_scenario
    pub fn board_failure(steps: usize, seed: u64) -> Scenario {
        let a = poisson(steps, 0.35, 1_000.0, seed);
        let b = poisson(steps, 0.30, 1_000.0, seed ^ 0xb0a2d);
        Scenario {
            name: "board-failure".into(),
            description: "steady tenants; a board fails mid-run and later recovers".into(),
            tenants: vec![
                TenantTrace { benchmark: "tabla".into(), share: 0.5, trace: a, qos_target: None },
                TenantTrace { benchmark: "diannao".into(), share: 0.5, trace: b, qos_target: None },
            ],
        }
    }

    /// A user-facing Poisson tenant over a diurnal background; the
    /// canonical plan slows one of the first group's shards 4× for the
    /// middle half of the run (backend latency spike — the datacenter
    /// straggler case).
    pub fn straggler(steps: usize, seed: u64) -> Scenario {
        let front = poisson(steps, 0.30, 1_000.0, seed);
        let period = Scenario::day_period(steps);
        let back = periodic(steps, period, 0.15, 0.70, 0.02, seed ^ 0x57a6);
        Scenario {
            name: "straggler".into(),
            description: "one shard runs 4x slow mid-run under steady demand".into(),
            tenants: vec![
                TenantTrace { benchmark: "tabla".into(), share: 0.55, trace: front, qos_target: None },
                TenantTrace { benchmark: "stripes".into(), share: 0.45, trace: back, qos_target: None },
            ],
        }
    }

    /// Three moderately-loaded tenants whose *offered demand* is
    /// multiplied fleet-wide by the canonical plan's surge window — the
    /// correlated cross-tenant flash event. The traces themselves stay
    /// baseline: the surge lives in the [`FaultPlan`](super::FaultPlan)
    /// so offline replays of the same scenario see the un-surged
    /// workload.
    pub fn correlated_surge(steps: usize, seed: u64) -> Scenario {
        let a = poisson(steps, 0.30, 1_000.0, seed);
        let period = Scenario::day_period(steps);
        let b = periodic(steps, period, 0.12, 0.65, 0.02, seed ^ 0x5139e);
        let c = poisson(steps, 0.25, 1_000.0, seed ^ 0xc0de);
        Scenario {
            name: "correlated-surge".into(),
            description: "all tenants surge together to 1.8x demand mid-run".into(),
            tenants: vec![
                TenantTrace { benchmark: "tabla".into(), share: 0.40, trace: a, qos_target: None },
                TenantTrace { benchmark: "diannao".into(), share: 0.35, trace: b, qos_target: None },
                TenantTrace { benchmark: "dnnweaver".into(), share: 0.25, trace: c, qos_target: None },
            ],
        }
    }

    /// Three tenants with explicit QoS tiers: a latency-critical premium
    /// tenant, a standard tenant, and a best-effort batch tenant whose
    /// relaxed guardband target lets its group decay margin faster. The
    /// tiers refine the run-level `qos_target` only when the adaptive
    /// guardband is on ([`QosTier::effective`]), so static-baseline
    /// replays of this scenario are bit-identical to an untiered one.
    pub fn tiered_tenants(steps: usize, seed: u64) -> Scenario {
        let premium = poisson(steps, 0.35, 1_000.0, seed);
        let period = Scenario::day_period(steps);
        let standard = periodic(steps, period, 0.15, 0.75, 0.02, seed ^ 0x71e2);
        let batch = periodic(steps, period, 0.20, 0.60, 0.01, seed ^ 0xba7c4);
        Scenario {
            name: "tiered-tenants".into(),
            description: "premium/standard/best-effort tenants with per-tier QoS targets"
                .into(),
            tenants: vec![
                TenantTrace {
                    benchmark: "tabla".into(),
                    share: 0.40,
                    trace: premium,
                    qos_target: Some(QosTier::Premium.target()),
                },
                TenantTrace {
                    benchmark: "diannao".into(),
                    share: 0.35,
                    trace: standard,
                    qos_target: Some(QosTier::Standard.target()),
                },
                TenantTrace {
                    benchmark: "stripes".into(),
                    share: 0.25,
                    trace: batch,
                    qos_target: Some(QosTier::BestEffort.target()),
                },
            ],
        }
    }

    /// Long-horizon replay through the timestamped-CSV path: both
    /// tenants' traces are generated, serialized (`step,load` for the
    /// diurnal tenant, plain `load` for the Poisson one), and parsed
    /// back through [`Trace::from_csv`] — so every run of this scenario
    /// exercises the exact recording formats a production trace archive
    /// would replay, including the 6-decimal quantization.
    pub fn long_replay(steps: usize, seed: u64) -> Scenario {
        let period = Scenario::day_period(steps);
        let front = periodic(steps, period, 0.12, 0.82, 0.02, seed);
        let back = poisson(steps, 0.28, 1_000.0, seed ^ 0x10e9);
        // Round-trip both serialization formats. The CSVs are produced by
        // the serializers `from_csv` is the documented inverse of, so a
        // parse failure here is a format regression, not bad input.
        let front = Trace::from_csv(&front.to_csv_with_steps(), "long-replay-diurnal")
            .expect("to_csv_with_steps output must parse");
        let back = Trace::from_csv(&back.to_csv(), "long-replay-poisson")
            .expect("to_csv output must parse");
        Scenario {
            name: "long-replay".into(),
            description: "multi-day diurnal archive replayed via the timestamped CSV path"
                .into(),
            tenants: vec![
                TenantTrace { benchmark: "tabla".into(), share: 0.5, trace: front, qos_target: None },
                TenantTrace { benchmark: "dnnweaver".into(), share: 0.5, trace: back, qos_target: None },
            ],
        }
    }

    /// A synthetic `n_groups`-tenant fleet for scale sweeps (the
    /// `perf_sim_scale` bench and `serve-fleet --parallel`, DESIGN.md
    /// S24). Tenants are named `{base}@{index:04}` — group names must be
    /// unique, and only the five Table-1 designs physically exist, so the
    /// base benchmark cycles through Table 1 while the `@` suffix keeps
    /// names distinct (the backend and build memo key on the base; see
    /// `coordinator::backend::variant_dims`). Each tenant gets an equal
    /// share and its own seeded trace, cycling the three generator
    /// families so a big fleet mixes diurnal, Poisson, and bursty demand.
    /// Not part of [`Scenario::NAMES`]: golden suites iterate those, and
    /// a thousand-group golden would be all bulk and no signal.
    pub fn synthetic_fleet(n_groups: usize, steps: usize, seed: u64) -> Scenario {
        const BASES: [&str; 5] = ["tabla", "dnnweaver", "diannao", "stripes", "proteus"];
        let n_groups = n_groups.max(1);
        let share = 1.0 / n_groups as f64;
        let period = Scenario::day_period(steps);
        let tenants = (0..n_groups)
            .map(|i| {
                let tseed = seed.wrapping_add(0x9e37_79b9_7f4a_7c15u64.wrapping_mul(i as u64 + 1));
                let trace = match i % 3 {
                    0 => periodic(steps, period, 0.10, 0.80, 0.02, tseed),
                    1 => poisson(steps, 0.30, 1_000.0, tseed),
                    _ => bursty(&BurstyConfig {
                        steps,
                        mean_load: 0.25,
                        seed: tseed,
                        ..Default::default()
                    }),
                };
                TenantTrace {
                    benchmark: format!("{}@{i:04}", BASES[i % BASES.len()]),
                    share,
                    trace,
                    qos_target: None,
                }
            })
            .collect();
        Scenario {
            name: format!("synthetic-{n_groups}"),
            description: format!("{n_groups} synthetic tenants cycling Table-1 designs"),
            tenants,
        }
    }

    /// Build a replay scenario from `(benchmark, share, csv_text)` rows —
    /// each CSV in the [`Trace::to_csv`] format.
    pub fn replay(name: &str, specs: &[(&str, f64, &str)]) -> Result<Scenario, String> {
        let mut tenants = Vec::with_capacity(specs.len());
        for (benchmark, share, csv) in specs {
            tenants.push(TenantTrace {
                benchmark: benchmark.to_string(),
                share: *share,
                trace: Trace::from_csv(csv, &format!("{benchmark}-replay"))?,
                qos_target: None,
            });
        }
        let s = Scenario {
            name: name.to_string(),
            description: "CSV replay".into(),
            tenants,
        };
        s.validate()?;
        Ok(s)
    }

    /// Steps every tenant has a load for (min across tenants).
    pub fn steps(&self) -> usize {
        self.tenants.iter().map(|t| t.trace.len()).min().unwrap_or(0)
    }

    /// `(benchmark, share)` rows, the layout `platform::fleet::Fleet` and
    /// `coordinator::FleetServing` are built from.
    pub fn groups(&self) -> Vec<(String, f64)> {
        self.tenants
            .iter()
            .map(|t| (t.benchmark.clone(), t.share))
            .collect()
    }

    /// Check structural invariants: at least one tenant, positive shares
    /// summing to ~1, and non-empty traces.
    pub fn validate(&self) -> Result<(), String> {
        if self.tenants.is_empty() {
            return Err(format!("scenario {}: no tenants", self.name));
        }
        let sum: f64 = self.tenants.iter().map(|t| t.share).sum();
        if (sum - 1.0).abs() > 1e-6 {
            return Err(format!("scenario {}: shares sum to {sum}, expected 1", self.name));
        }
        for t in &self.tenants {
            if t.share <= 0.0 {
                return Err(format!("scenario {}: {} share must be positive", self.name, t.benchmark));
            }
            if t.trace.is_empty() {
                return Err(format!("scenario {}: {} trace is empty", self.name, t.benchmark));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_named_scenarios_validate() {
        for name in Scenario::NAMES {
            let s = Scenario::by_name(name, 400, 2019).unwrap();
            s.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(s.steps(), 400, "{name}");
            assert!(s.tenants.len() >= 2, "{name} must be multi-tenant");
            for t in &s.tenants {
                assert!(t.trace.loads.iter().all(|&l| (0.0..=1.0).contains(&l)));
            }
        }
        assert!(Scenario::by_name("nope", 100, 0).is_err());
    }

    #[test]
    fn all_returns_every_named_scenario_in_order() {
        let all = Scenario::all(64, 7);
        assert_eq!(all.len(), Scenario::NAMES.len());
        for (s, name) in all.iter().zip(Scenario::NAMES) {
            assert_eq!(s.name, name);
            assert_eq!(s.steps(), 64);
        }
    }

    #[test]
    fn diurnal_tenants_are_anti_phased() {
        let s = Scenario::diurnal(384, 1);
        let a = &s.tenants[0].trace.loads;
        let b = &s.tenants[1].trace.loads;
        // When tabla peaks, diannao should be near its valley.
        let peak_a = (0..a.len()).max_by(|&i, &j| a[i].total_cmp(&a[j])).unwrap();
        assert!(a[peak_a] > 0.7, "tabla peak {}", a[peak_a]);
        assert!(b[peak_a] < 0.45, "diannao at tabla's peak: {}", b[peak_a]);
    }

    #[test]
    fn flash_crowd_has_a_spike_and_a_quiet_baseline() {
        let s = Scenario::flash_crowd(400, 3);
        let front = &s.tenants[0].trace.loads;
        let spike_max = front.iter().copied().fold(0.0, f64::max);
        assert!(spike_max >= 0.95, "spike must near-saturate: {spike_max}");
        // Before the spike the load is low.
        let pre: f64 = front[..100].iter().sum::<f64>() / 100.0;
        assert!(pre < 0.4, "pre-spike mean {pre}");
        // The spike plateau sits where it was constructed.
        assert!(front[400 * 2 / 5 + 10] > 0.9);
    }

    #[test]
    fn overnight_is_low_utilization() {
        let s = Scenario::overnight(2_000, 5);
        for t in &s.tenants {
            assert!(t.trace.mean() < 0.2, "{}: mean {}", t.benchmark, t.trace.mean());
        }
    }

    #[test]
    fn tiered_tenants_declare_ordered_tiers() {
        let s = Scenario::tiered_tenants(240, 2019);
        let tiers: Vec<f64> = s.tenants.iter().map(|t| t.qos_target.unwrap()).collect();
        assert_eq!(
            tiers,
            vec![
                QosTier::Premium.target(),
                QosTier::Standard.target(),
                QosTier::BestEffort.target()
            ],
            "strictest tier first, batch tier last"
        );
        // Every other named scenario leaves tenants untiered.
        for name in Scenario::NAMES {
            if name != "tiered-tenants" {
                let s = Scenario::by_name(name, 48, 2019).unwrap();
                assert!(
                    s.tenants.iter().all(|t| t.qos_target.is_none()),
                    "{name} must not declare tiers"
                );
            }
        }
    }

    #[test]
    fn long_replay_goes_through_both_csv_formats() {
        let s = Scenario::long_replay(480, 2019);
        assert_eq!(s.steps(), 480);
        assert_eq!(s.tenants[0].trace.label, "long-replay-diurnal");
        assert_eq!(s.tenants[1].trace.label, "long-replay-poisson");
        // The replayed loads are the 6-decimal quantization of the
        // generated ones — identical to regenerating and re-parsing.
        let period = Scenario::day_period(480);
        let fresh = periodic(480, period, 0.12, 0.82, 0.02, 2019);
        for (replayed, orig) in s.tenants[0].trace.loads.iter().zip(&fresh.loads) {
            assert!((replayed - orig).abs() < 1e-6);
        }
    }

    #[test]
    fn adversarial_scenarios_stay_moderate_without_their_fault_plans() {
        // The fault windows live in the FaultPlan, not the traces: the
        // workload side of the fault-carrying scenarios must stay
        // moderate so the injected fault is the dominant signal.
        for name in ["board-failure", "straggler", "correlated-surge"] {
            let s = Scenario::by_name(name, 400, 2019).unwrap();
            for t in &s.tenants {
                let mean = t.trace.mean();
                assert!(
                    (0.05..0.6).contains(&mean),
                    "{name}/{}: mean {mean} out of the moderate band",
                    t.benchmark
                );
            }
        }
    }

    #[test]
    fn synthetic_fleet_scales_and_validates() {
        for n in [1, 10, 137] {
            let s = Scenario::synthetic_fleet(n, 48, 2019);
            s.validate().unwrap_or_else(|e| panic!("n={n}: {e}"));
            assert_eq!(s.tenants.len(), n);
            assert_eq!(s.steps(), 48);
            // Unique names (fleet validation rejects duplicates) keyed on
            // real Table-1 bases.
            let mut names: Vec<&str> =
                s.tenants.iter().map(|t| t.benchmark.as_str()).collect();
            names.sort_unstable();
            names.dedup();
            assert_eq!(names.len(), n, "names must be unique");
            for t in &s.tenants {
                let base = t.benchmark.split('@').next().unwrap();
                assert!(
                    ["tabla", "dnnweaver", "diannao", "stripes", "proteus"].contains(&base),
                    "{}",
                    t.benchmark
                );
            }
        }
        // Deterministic in the seed.
        let a = Scenario::synthetic_fleet(10, 48, 7);
        let b = Scenario::synthetic_fleet(10, 48, 7);
        for (ta, tb) in a.tenants.iter().zip(&b.tenants) {
            assert_eq!(ta.trace.loads, tb.trace.loads);
        }
        // `synthetic-N` resolves through by_name like any named scenario.
        let s = Scenario::by_name("synthetic-25", 48, 7).unwrap();
        assert_eq!(s.tenants.len(), 25);
        assert!(Scenario::by_name("synthetic-x", 48, 7).is_err());
    }

    #[test]
    fn replay_round_trips_and_validates() {
        let t = bursty(&BurstyConfig { steps: 64, ..Default::default() });
        let csv = t.to_csv();
        let s = Scenario::replay("replayed", &[("tabla", 0.5, &csv), ("diannao", 0.5, &csv)])
            .unwrap();
        assert_eq!(s.steps(), 64);
        assert_eq!(s.groups()[0].0, "tabla");
        assert!(Scenario::replay("bad", &[("tabla", 0.5, &csv)]).is_err());
        assert!(Scenario::replay("bad", &[("tabla", 1.0, "load\nnope\n")]).is_err());
    }

    #[test]
    fn replay_rejects_malformed_empty_and_non_monotonic_tenants() {
        let good = bursty(&BurstyConfig { steps: 32, ..Default::default() }).to_csv();
        // One malformed tenant poisons the whole replay scenario.
        let err = Scenario::replay(
            "bad",
            &[("tabla", 0.5, &good), ("diannao", 0.5, "load\n0.2\nnot-a-load\n")],
        )
        .unwrap_err();
        assert!(err.contains("bad load"), "{err}");
        // Empty CSV file.
        let err = Scenario::replay("bad", &[("tabla", 1.0, "")]).unwrap_err();
        assert!(err.contains("empty"), "{err}");
        // Header-only CSV is still empty.
        assert!(Scenario::replay("bad", &[("tabla", 1.0, "step,load\n")]).is_err());
        // Non-monotonic timestamps in a timestamped trace.
        let err = Scenario::replay(
            "bad",
            &[("tabla", 1.0, "step,load\n0,0.4\n3,0.5\n2,0.6\n")],
        )
        .unwrap_err();
        assert!(err.contains("non-monotonic"), "{err}");
        // Out-of-range load value.
        assert!(Scenario::replay("bad", &[("tabla", 1.0, "load\n7.5\n")]).is_err());
    }

    #[test]
    fn replay_reproduces_the_generator_bin_sequence() {
        // generate → write CSV (both formats) → replay → the Markov
        // predictor sees the identical bin sequence, so a replayed trace
        // drives the CC exactly like the generated original.
        let t = bursty(&BurstyConfig { steps: 256, seed: 77, ..Default::default() });
        let p = crate::markov::MarkovPredictor::new(10, 0);
        for csv in [t.to_csv(), t.to_csv_with_steps()] {
            let s = Scenario::replay(
                "replayed",
                &[("tabla", 0.5, csv.as_str()), ("diannao", 0.5, csv.as_str())],
            )
            .unwrap();
            for tenant in &s.tenants {
                let bins_orig: Vec<usize> = t.loads.iter().map(|&l| p.bin_of(l)).collect();
                let bins_replay: Vec<usize> =
                    tenant.trace.loads.iter().map(|&l| p.bin_of(l)).collect();
                assert_eq!(bins_orig, bins_replay, "{}", tenant.benchmark);
            }
        }
    }
}
