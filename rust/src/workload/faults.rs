//! Deterministic fault-injection plans (DESIGN.md S20).
//!
//! A [`FaultPlan`] is a seed-driven, epoch-indexed schedule of the three
//! adversarial conditions a production multi-FPGA fleet sees on top of
//! well-behaved load curves:
//!
//! * **board failures** — a shard goes dark for a window of epochs and
//!   later recovers ([`BoardFailure`]);
//! * **stragglers** — a shard's backend service time inflates by a
//!   multiplicative slowdown for a window ([`StragglerWindow`]);
//! * **correlated surges** — every tenant's offered load is multiplied
//!   by a common factor for a window ([`SurgeWindow`]).
//!
//! The plan is *pure data*: the coordinator's CC gates/drains failed
//! shards, workers stretch their service sleeps, and the scenario driver
//! scales its per-step targets, all by querying the plan at the current
//! epoch index. Because every query on an **empty plan** returns exactly
//! `1.0` (and IEEE-754 guarantees `x * 1.0 == x` bitwise) or reports no
//! failure, attaching an empty plan reproduces the fault-free simulation
//! byte-for-byte — no special-case branches needed for the existing
//! golden traces.

use crate::util::json::Json;
use crate::util::prng::Rng;

/// One shard is down for the epoch window `[fail_epoch, recover_epoch)`.
#[derive(Clone, Debug, PartialEq)]
pub struct BoardFailure {
    /// Fleet group (tenant) index.
    pub group: usize,
    /// Shard index within the group.
    pub shard: usize,
    /// First epoch the board is failed (CC applies it at the epoch
    /// boundary, so epoch 0 — served before any CC pass — never fails).
    pub fail_epoch: usize,
    /// First epoch the board is healthy again (exclusive end).
    pub recover_epoch: usize,
}

/// One shard's backend service time is inflated by `slowdown` for the
/// epoch window `[from_epoch, until_epoch)`.
#[derive(Clone, Debug, PartialEq)]
pub struct StragglerWindow {
    /// Fleet group (tenant) index.
    pub group: usize,
    /// Shard index within the group.
    pub shard: usize,
    /// First epoch of the latency spike.
    pub from_epoch: usize,
    /// First epoch past the spike (exclusive end).
    pub until_epoch: usize,
    /// Service-time multiplier, ≥ 1 (4.0 = a 4× straggler).
    pub slowdown: f64,
}

/// Every tenant's offered load is multiplied by `multiplier` for the
/// epoch window `[from_epoch, until_epoch)` — a correlated cross-tenant
/// surge (flash event hitting the whole fleet at once).
#[derive(Clone, Debug, PartialEq)]
pub struct SurgeWindow {
    /// First epoch of the surge.
    pub from_epoch: usize,
    /// First epoch past the surge (exclusive end).
    pub until_epoch: usize,
    /// Demand multiplier, > 0 (1.8 = 80% extra offered load).
    pub multiplier: f64,
}

/// A deterministic schedule of injected faults for one simulation run.
///
/// The default (empty) plan injects nothing and is bitwise-neutral: every
/// multiplier query returns exactly `1.0` and no board ever fails.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Board-down windows.
    pub board_failures: Vec<BoardFailure>,
    /// Latency-spike windows.
    pub stragglers: Vec<StragglerWindow>,
    /// Correlated demand-surge windows.
    pub surges: Vec<SurgeWindow>,
}

impl FaultPlan {
    /// True when the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.board_failures.is_empty() && self.stragglers.is_empty() && self.surges.is_empty()
    }

    /// Is `shard` of `group` failed at `epoch`?
    pub fn board_failed(&self, group: usize, shard: usize, epoch: usize) -> bool {
        self.board_failures.iter().any(|f| {
            f.group == group
                && f.shard == shard
                && (f.fail_epoch..f.recover_epoch).contains(&epoch)
        })
    }

    /// Number of failed shards of `group` at `epoch`, over `n_instances`.
    pub fn failed_count(&self, group: usize, n_instances: usize, epoch: usize) -> usize {
        (0..n_instances)
            .filter(|&s| self.board_failed(group, s, epoch))
            .count()
    }

    /// Service-time multiplier for `shard` of `group` at `epoch`: the max
    /// of all overlapping straggler windows, or exactly `1.0`.
    pub fn straggler_slowdown(&self, group: usize, shard: usize, epoch: usize) -> f64 {
        self.stragglers
            .iter()
            .filter(|w| {
                w.group == group
                    && w.shard == shard
                    && (w.from_epoch..w.until_epoch).contains(&epoch)
            })
            .fold(1.0, |acc, w| acc.max(w.slowdown))
    }

    /// Offered-load multiplier at `epoch`: the product of all overlapping
    /// surge windows, or exactly `1.0`.
    pub fn surge_multiplier(&self, epoch: usize) -> f64 {
        self.surges
            .iter()
            .filter(|w| (w.from_epoch..w.until_epoch).contains(&epoch))
            .fold(1.0, |acc, w| acc * w.multiplier)
    }

    /// Mean service-rate factor of the given active shard set of `group`
    /// at `epoch` — the CC's capacity model for stragglers: a 4×-slowed
    /// shard contributes 1/4 of a healthy shard's rate. Exactly `1.0`
    /// when no straggler window overlaps (and for an empty set).
    pub fn capacity_factor(&self, group: usize, active: &[usize], epoch: usize) -> f64 {
        if self.stragglers.is_empty() || active.is_empty() {
            return 1.0;
        }
        let sum: f64 = active
            .iter()
            .map(|&s| 1.0 / self.straggler_slowdown(group, s, epoch))
            .sum();
        sum / active.len() as f64
    }

    /// Check structural invariants against a fleet layout: indices in
    /// range, non-empty windows, slowdowns ≥ 1, multipliers finite and
    /// positive.
    pub fn validate(&self, n_groups: usize, n_instances: usize) -> Result<(), String> {
        for f in &self.board_failures {
            if f.group >= n_groups || f.shard >= n_instances {
                return Err(format!(
                    "board failure ({}, {}) out of fleet {n_groups}x{n_instances}",
                    f.group, f.shard
                ));
            }
            if f.fail_epoch >= f.recover_epoch {
                return Err(format!(
                    "board failure window [{}, {}) is empty",
                    f.fail_epoch, f.recover_epoch
                ));
            }
        }
        for w in &self.stragglers {
            if w.group >= n_groups || w.shard >= n_instances {
                return Err(format!(
                    "straggler ({}, {}) out of fleet {n_groups}x{n_instances}",
                    w.group, w.shard
                ));
            }
            if w.from_epoch >= w.until_epoch {
                return Err(format!(
                    "straggler window [{}, {}) is empty",
                    w.from_epoch, w.until_epoch
                ));
            }
            if !(w.slowdown.is_finite() && w.slowdown >= 1.0) {
                return Err(format!("straggler slowdown {} must be >= 1", w.slowdown));
            }
        }
        for w in &self.surges {
            if w.from_epoch >= w.until_epoch {
                return Err(format!(
                    "surge window [{}, {}) is empty",
                    w.from_epoch, w.until_epoch
                ));
            }
            if !(w.multiplier.is_finite() && w.multiplier > 0.0) {
                return Err(format!("surge multiplier {} must be positive", w.multiplier));
            }
        }
        Ok(())
    }

    /// A randomized-but-deterministic plan for property tests: the same
    /// seed over the same fleet layout reproduces the plan exactly. At
    /// most one failure + one straggler per group and one fleet-wide
    /// surge, all with windows inside `[1, epochs]`, so any layout yields
    /// a valid plan.
    pub fn scripted(seed: u64, n_groups: usize, n_instances: usize, epochs: usize) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xfau64.rotate_left(56));
        let mut plan = FaultPlan::default();
        let last = epochs.max(2);
        for g in 0..n_groups {
            let mut r = rng.fork(g as u64 + 1);
            if r.bool(0.7) {
                let fail = r.index(1, last);
                plan.board_failures.push(BoardFailure {
                    group: g,
                    shard: r.index(0, n_instances.max(1)),
                    fail_epoch: fail,
                    recover_epoch: r.index(fail + 1, last + 2),
                });
            }
            if r.bool(0.6) {
                let from = r.index(1, last);
                plan.stragglers.push(StragglerWindow {
                    group: g,
                    shard: r.index(0, n_instances.max(1)),
                    from_epoch: from,
                    until_epoch: r.index(from + 1, last + 2),
                    slowdown: r.range(1.5, 6.0),
                });
            }
        }
        if rng.bool(0.5) {
            let from = rng.index(1, last);
            plan.surges.push(SurgeWindow {
                from_epoch: from,
                until_epoch: rng.index(from + 1, last + 2),
                multiplier: rng.range(1.2, 2.0),
            });
        }
        plan
    }

    /// The canonical plan a named scenario carries in its golden trace:
    /// `board-failure`, `straggler` and `correlated-surge` each inject
    /// their headline fault mid-run; every other scenario (including the
    /// four legacy names) gets the empty — bitwise-neutral — plan.
    pub fn for_scenario(
        name: &str,
        n_groups: usize,
        n_instances: usize,
        epochs: usize,
    ) -> FaultPlan {
        let mut plan = FaultPlan::default();
        if n_groups == 0 || n_instances == 0 || epochs == 0 {
            return plan;
        }
        match name {
            "board-failure" => {
                // The last shard of the first group goes dark for the
                // middle third of the run, then recovers.
                let fail = (epochs / 3).max(1);
                plan.board_failures.push(BoardFailure {
                    group: 0,
                    shard: n_instances - 1,
                    fail_epoch: fail,
                    recover_epoch: (epochs * 2 / 3).max(fail + 1),
                });
            }
            "straggler" => {
                // Shard 0 of the first group runs 4x slow for the middle
                // half of the run.
                let from = (epochs / 4).max(1);
                plan.stragglers.push(StragglerWindow {
                    group: 0,
                    shard: 0,
                    from_epoch: from,
                    until_epoch: (epochs * 3 / 4).max(from + 1),
                    slowdown: 4.0,
                });
            }
            "correlated-surge" => {
                // All tenants surge together to 1.8x demand mid-run.
                let from = (epochs * 2 / 5).max(1);
                plan.surges.push(SurgeWindow {
                    from_epoch: from,
                    until_epoch: (epochs * 3 / 5).max(from + 1),
                    multiplier: 1.8,
                });
            }
            _ => {}
        }
        plan
    }

    /// Deterministic JSON rendering for trace headers — an empty plan
    /// serializes to empty arrays so legacy goldens that never carried a
    /// plan read unambiguously.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "board_failures",
                Json::Arr(
                    self.board_failures
                        .iter()
                        .map(|f| {
                            Json::obj(vec![
                                ("group", Json::Num(f.group as f64)),
                                ("shard", Json::Num(f.shard as f64)),
                                ("fail_epoch", Json::Num(f.fail_epoch as f64)),
                                ("recover_epoch", Json::Num(f.recover_epoch as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "stragglers",
                Json::Arr(
                    self.stragglers
                        .iter()
                        .map(|w| {
                            Json::obj(vec![
                                ("group", Json::Num(w.group as f64)),
                                ("shard", Json::Num(w.shard as f64)),
                                ("from_epoch", Json::Num(w.from_epoch as f64)),
                                ("until_epoch", Json::Num(w.until_epoch as f64)),
                                ("slowdown", Json::Num(w.slowdown)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "surges",
                Json::Arr(
                    self.surges
                        .iter()
                        .map(|w| {
                            Json::obj(vec![
                                ("from_epoch", Json::Num(w.from_epoch as f64)),
                                ("until_epoch", Json::Num(w.until_epoch as f64)),
                                ("multiplier", Json::Num(w.multiplier)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_bitwise_neutral() {
        let p = FaultPlan::default();
        assert!(p.is_empty());
        for epoch in 0..8 {
            assert!(!p.board_failed(0, 0, epoch));
            assert_eq!(p.straggler_slowdown(1, 1, epoch).to_bits(), 1.0f64.to_bits());
            assert_eq!(p.surge_multiplier(epoch).to_bits(), 1.0f64.to_bits());
            assert_eq!(p.capacity_factor(0, &[0, 1, 2], epoch).to_bits(), 1.0f64.to_bits());
        }
        p.validate(0, 0).unwrap();
    }

    #[test]
    fn windows_are_half_open_and_indexed() {
        let p = FaultPlan {
            board_failures: vec![BoardFailure {
                group: 1,
                shard: 0,
                fail_epoch: 3,
                recover_epoch: 6,
            }],
            stragglers: vec![StragglerWindow {
                group: 0,
                shard: 1,
                from_epoch: 2,
                until_epoch: 4,
                slowdown: 4.0,
            }],
            surges: vec![SurgeWindow { from_epoch: 5, until_epoch: 7, multiplier: 1.5 }],
        };
        p.validate(2, 2).unwrap();
        assert!(!p.board_failed(1, 0, 2));
        assert!(p.board_failed(1, 0, 3));
        assert!(p.board_failed(1, 0, 5));
        assert!(!p.board_failed(1, 0, 6), "recover epoch is exclusive");
        assert!(!p.board_failed(0, 0, 4), "wrong group never fails");
        assert!(!p.board_failed(1, 1, 4), "wrong shard never fails");
        assert_eq!(p.failed_count(1, 2, 4), 1);
        assert_eq!(p.failed_count(1, 2, 6), 0);
        assert_eq!(p.straggler_slowdown(0, 1, 2), 4.0);
        assert_eq!(p.straggler_slowdown(0, 1, 4), 1.0);
        assert_eq!(p.straggler_slowdown(0, 0, 3), 1.0);
        assert_eq!(p.surge_multiplier(5), 1.5);
        assert_eq!(p.surge_multiplier(7), 1.0);
        // One 4x shard + one healthy shard: mean rate (1 + 1/4) / 2.
        assert!((p.capacity_factor(0, &[0, 1], 3) - 0.625).abs() < 1e-12);
        assert_eq!(p.capacity_factor(0, &[0], 3), 1.0);
    }

    #[test]
    fn overlapping_windows_compose() {
        let p = FaultPlan {
            board_failures: vec![],
            stragglers: vec![
                StragglerWindow { group: 0, shard: 0, from_epoch: 1, until_epoch: 5, slowdown: 2.0 },
                StragglerWindow { group: 0, shard: 0, from_epoch: 3, until_epoch: 6, slowdown: 3.0 },
            ],
            surges: vec![
                SurgeWindow { from_epoch: 1, until_epoch: 4, multiplier: 1.5 },
                SurgeWindow { from_epoch: 2, until_epoch: 3, multiplier: 2.0 },
            ],
        };
        assert_eq!(p.straggler_slowdown(0, 0, 2), 2.0);
        assert_eq!(p.straggler_slowdown(0, 0, 3), 3.0, "max of overlapping slowdowns");
        assert!((p.surge_multiplier(2) - 3.0).abs() < 1e-12, "surges multiply");
        assert_eq!(p.surge_multiplier(3), 1.5);
    }

    #[test]
    fn validate_rejects_bad_plans() {
        let out_of_range = FaultPlan {
            board_failures: vec![BoardFailure { group: 2, shard: 0, fail_epoch: 1, recover_epoch: 2 }],
            ..Default::default()
        };
        assert!(out_of_range.validate(2, 2).is_err());
        let empty_window = FaultPlan {
            stragglers: vec![StragglerWindow {
                group: 0,
                shard: 0,
                from_epoch: 5,
                until_epoch: 5,
                slowdown: 2.0,
            }],
            ..Default::default()
        };
        assert!(empty_window.validate(1, 1).is_err());
        let speedup = FaultPlan {
            stragglers: vec![StragglerWindow {
                group: 0,
                shard: 0,
                from_epoch: 1,
                until_epoch: 2,
                slowdown: 0.5,
            }],
            ..Default::default()
        };
        assert!(speedup.validate(1, 1).is_err(), "slowdown < 1 is a speedup, refuse");
        let bad_surge = FaultPlan {
            surges: vec![SurgeWindow { from_epoch: 1, until_epoch: 2, multiplier: -1.0 }],
            ..Default::default()
        };
        assert!(bad_surge.validate(1, 1).is_err());
    }

    #[test]
    fn scripted_plans_are_deterministic_and_valid() {
        for seed in 0..50u64 {
            let a = FaultPlan::scripted(seed, 3, 2, 12);
            let b = FaultPlan::scripted(seed, 3, 2, 12);
            assert_eq!(a, b, "seed {seed} must reproduce the plan");
            a.validate(3, 2).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        }
        // Tiny layouts still produce valid plans.
        FaultPlan::scripted(7, 1, 1, 3).validate(1, 1).unwrap();
        assert_ne!(
            FaultPlan::scripted(1, 3, 2, 12),
            FaultPlan::scripted(2, 3, 2, 12),
            "seed must steer the plan"
        );
    }

    #[test]
    fn canonical_scenario_plans() {
        let p = FaultPlan::for_scenario("board-failure", 2, 2, 48);
        assert_eq!(p.board_failures.len(), 1);
        assert!(p.stragglers.is_empty() && p.surges.is_empty());
        assert!(p.board_failed(0, 1, 20));
        assert!(!p.board_failed(0, 1, 40), "board recovers");
        p.validate(2, 2).unwrap();

        let p = FaultPlan::for_scenario("straggler", 2, 2, 48);
        assert_eq!(p.stragglers.len(), 1);
        assert_eq!(p.straggler_slowdown(0, 0, 24), 4.0);
        p.validate(2, 2).unwrap();

        let p = FaultPlan::for_scenario("correlated-surge", 3, 2, 48);
        assert_eq!(p.surges.len(), 1);
        assert!((p.surge_multiplier(24) - 1.8).abs() < 1e-12);
        p.validate(3, 2).unwrap();

        // Legacy + fault-free adversarial scenarios carry the empty plan.
        for name in ["diurnal", "flash-crowd", "mixed-tenant", "overnight", "tiered-tenants", "long-replay"] {
            assert!(FaultPlan::for_scenario(name, 2, 2, 48).is_empty(), "{name}");
        }
        // Tiny runs still yield non-empty, valid windows.
        FaultPlan::for_scenario("board-failure", 1, 1, 2).validate(1, 1).unwrap();
        FaultPlan::for_scenario("straggler", 1, 1, 2).validate(1, 1).unwrap();
        FaultPlan::for_scenario("correlated-surge", 1, 1, 2).validate(1, 1).unwrap();
    }

    #[test]
    fn json_rendering_is_deterministic() {
        let p = FaultPlan::for_scenario("board-failure", 2, 2, 48);
        let a = p.to_json().to_string_compact();
        let b = p.to_json().to_string_compact();
        assert_eq!(a, b);
        assert!(a.contains("\"fail_epoch\": 16"), "{a}");
        let empty = FaultPlan::default().to_json().to_string_compact();
        assert!(empty.contains("\"board_failures\": []"), "{empty}");
    }
}
