//! Pre-characterized delay/power-vs-voltage library of FPGA resources —
//! the COFFE + 22nm-PTM SPICE substitute (DESIGN.md S1, §6).
//!
//! The paper characterizes four resource classes (Figs. 1–3): logic (LUTs),
//! routing (switch boxes / connection-block muxes), on-chip BRAM, and DSP
//! hard macros. Logic/routing/DSP share the `Vcore` rail (0.80 V nominal);
//! BRAM has its own high-threshold `Vbram` rail (0.95 V nominal).
//! Configuration-SRAM and I/O rails are never scaled (paper §III).
//!
//! Behavioural models, calibrated to reproduce the figures' shapes:
//!   delay:   alpha-power-law `(v/v0)·((v0-vth)/(v-vth))^a` blended with a
//!            voltage-insensitive fraction (pass-transistor routing with
//!            boosted gates; BRAM peripheral timing) plus an exponential
//!            failure knee (sense-amp margin for BRAM, crash for logic).
//!   dynamic: CV²f  → `(v/v0)²` per toggle.
//!   static:  subthreshold+DIBL leakage `(v/v0)·exp((v-v0)/s)`, with an
//!            Arrhenius-ish temperature factor (datacenter boards run hot).
//!
//! Every query is normalized to the class's nominal voltage so the rest of
//! the stack works in scale factors; absolute calibration (ns / W) lives in
//! `arch`/`power`.

pub mod model;

pub use model::{CharLibrary, ClassParams, ResourceClass, VoltageGrid};
