//! Analytic resource models and the characterization tables they generate.

use crate::util::json::Json;

/// The four characterized resource classes of the paper (Figs. 1–3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ResourceClass {
    /// LUTs / LAB internals (Vcore rail).
    Logic,
    /// Switch boxes and connection-block muxes (Vcore rail).
    Routing,
    /// On-chip block RAM (dedicated Vbram rail, high-threshold process).
    Bram,
    /// DSP hard macros (Vcore rail).
    Dsp,
}

impl ResourceClass {
    /// All four classes, in the paper's figure order.
    pub const ALL: [ResourceClass; 4] = [
        ResourceClass::Logic,
        ResourceClass::Routing,
        ResourceClass::Bram,
        ResourceClass::Dsp,
    ];

    /// Lower-case class name (matches the paper's figure legends).
    pub fn name(self) -> &'static str {
        match self {
            ResourceClass::Logic => "logic",
            ResourceClass::Routing => "routing",
            ResourceClass::Bram => "memory",
            ResourceClass::Dsp => "dsp",
        }
    }

    /// True if the class is powered from the BRAM rail.
    pub fn on_bram_rail(self) -> bool {
        matches!(self, ResourceClass::Bram)
    }
}

/// Per-class behavioural model parameters (the "SPICE deck").
#[derive(Clone, Copy, Debug)]
pub struct ClassParams {
    /// Nominal rail voltage (0.80 V core / 0.95 V bram).
    pub v_nom: f64,
    /// Effective threshold voltage of the delay path.
    pub vth: f64,
    /// Alpha-power-law velocity-saturation exponent.
    pub alpha_pow: f64,
    /// Voltage-insensitive fraction of the delay (0..1).
    pub flat_frac: f64,
    /// Failure-knee center voltage (sense-amp margin / functional crash).
    pub knee_v: f64,
    /// Failure-knee width (V).
    pub knee_w: f64,
    /// Leakage exponential slope (V per e-fold, subthreshold + DIBL).
    pub leak_s: f64,
    /// Below this voltage the class is non-functional (delay = inf).
    pub v_crash: f64,
}

impl ClassParams {
    fn delay_raw(&self, v: f64) -> f64 {
        if v < self.v_crash {
            return f64::INFINITY;
        }
        let od = (v - self.vth).max(1e-3);
        let od0 = self.v_nom - self.vth;
        let ap = (v / self.v_nom) * (od0 / od).powf(self.alpha_pow);
        let base = self.flat_frac + (1.0 - self.flat_frac) * ap;
        let knee = 1.0 + (-(v - self.knee_v) / self.knee_w).exp();
        base * knee
    }
}

/// The DC-DC converter's reachable voltage points for both rails
/// (25 mV resolution, 0.45–1.0 V range; ref. [39] of the paper).
/// Index 0 is the nominal voltage; ascending index = descending voltage.
#[derive(Clone, Debug)]
pub struct VoltageGrid {
    /// Core-rail levels, nominal first, descending.
    pub vcore: Vec<f64>,
    /// BRAM-rail levels, nominal first, descending.
    pub vbram: Vec<f64>,
    /// Converter step size (V).
    pub step: f64,
}

impl VoltageGrid {
    /// Build both rails' level lists from nominal down to `v_floor`.
    pub fn new(vcore_nom: f64, vbram_nom: f64, v_floor: f64, step: f64) -> Self {
        let levels = |nom: f64| {
            let n = ((nom - v_floor) / step).round() as usize + 1;
            (0..n).map(|i| nom - step * i as f64).collect::<Vec<f64>>()
        };
        VoltageGrid { vcore: levels(vcore_nom), vbram: levels(vbram_nom), step }
    }

    /// Snap an arbitrary voltage to the nearest grid index for a rail.
    pub fn snap_core(&self, v: f64) -> usize {
        snap(&self.vcore, v)
    }

    /// Snap an arbitrary voltage to the nearest BRAM-rail grid index.
    pub fn snap_bram(&self, v: f64) -> usize {
        snap(&self.vbram, v)
    }
}

fn snap(levels: &[f64], v: f64) -> usize {
    let mut best = 0;
    let mut best_d = f64::INFINITY;
    for (i, &l) in levels.iter().enumerate() {
        let d = (l - v).abs();
        if d < best_d {
            best_d = d;
            best = i;
        }
    }
    best
}

/// The characterization library: per-class scale-factor queries plus the
/// sampled tables the optimizer and the AOT'd Voltage Selector consume.
#[derive(Clone, Debug)]
pub struct CharLibrary {
    /// Logic (LUT/LAB) class parameters.
    pub logic: ClassParams,
    /// Routing (switch/connection mux) class parameters.
    pub routing: ClassParams,
    /// BRAM class parameters (own rail).
    pub bram: ClassParams,
    /// DSP hard-macro class parameters.
    pub dsp: ClassParams,
    /// Junction temperature in °C (leakage scales exponentially with it;
    /// datacenter FPGA boards run hot — paper §I cites [16]).
    pub temp_c: f64,
    /// Leakage e-folding temperature delta (°C).
    pub temp_s: f64,
}

/// Nominal core-rail voltage (V).
pub const VCORE_NOM: f64 = 0.80;
/// Nominal BRAM-rail voltage (V).
pub const VBRAM_NOM: f64 = 0.95;
/// Functional crash floor for every class (V).
pub const V_CRASH: f64 = 0.50;
/// DC-DC converter resolution (V).
pub const V_STEP: f64 = 0.025;

impl CharLibrary {
    /// Default calibration: Stratix-IV-like fabric on a 22 nm predictive
    /// process at 45 °C board temperature. Constants are tuned so the
    /// generated tables reproduce the shapes of the paper's Figs. 1–3 (see
    /// chars::tests and benches/fig1..fig3).
    pub fn stratix_iv_22nm() -> Self {
        CharLibrary {
            logic: ClassParams {
                v_nom: VCORE_NOM,
                vth: 0.32,
                alpha_pow: 1.22,
                flat_frac: 0.00,
                knee_v: 0.505,
                knee_w: 0.012,
                leak_s: 0.505,
                v_crash: V_CRASH,
            },
            routing: ClassParams {
                v_nom: VCORE_NOM,
                vth: 0.18,
                alpha_pow: 1.10,
                flat_frac: 0.25,
                knee_v: 0.500,
                knee_w: 0.012,
                leak_s: 0.565,
                v_crash: V_CRASH,
            },
            bram: ClassParams {
                v_nom: VBRAM_NOM,
                vth: 0.30,
                alpha_pow: 1.20,
                flat_frac: 0.55,
                knee_v: 0.72,
                knee_w: 0.030,
                leak_s: 0.110,
                v_crash: V_CRASH,
            },
            dsp: ClassParams {
                v_nom: VCORE_NOM,
                vth: 0.32,
                alpha_pow: 1.25,
                flat_frac: 0.10,
                knee_v: 0.505,
                knee_w: 0.012,
                leak_s: 0.505,
                v_crash: V_CRASH,
            },
            temp_c: 45.0,
            temp_s: 30.0,
        }
    }

    /// The behavioural parameters of a class.
    pub fn params(&self, class: ResourceClass) -> &ClassParams {
        match class {
            ResourceClass::Logic => &self.logic,
            ResourceClass::Routing => &self.routing,
            ResourceClass::Bram => &self.bram,
            ResourceClass::Dsp => &self.dsp,
        }
    }

    /// Delay scale factor at voltage `v`, normalized to 1.0 at the class's
    /// nominal rail voltage. `inf` below the crash voltage.
    pub fn delay_scale(&self, class: ResourceClass, v: f64) -> f64 {
        let p = self.params(class);
        p.delay_raw(v) / p.delay_raw(p.v_nom)
    }

    /// Dynamic energy-per-toggle scale (CV²), normalized at nominal.
    pub fn dyn_scale(&self, class: ResourceClass, v: f64) -> f64 {
        let p = self.params(class);
        (v / p.v_nom).powi(2)
    }

    /// Static power scale (v·I_leak(v)), normalized at nominal, including
    /// the library's temperature factor (which cancels in the ratio — it
    /// matters only for absolute watts in `power`).
    pub fn static_scale(&self, class: ResourceClass, v: f64) -> f64 {
        let p = self.params(class);
        (v / p.v_nom) * ((v - p.v_nom) / p.leak_s).exp()
    }

    /// Absolute leakage temperature multiplier vs 25 °C.
    pub fn temp_leak_factor(&self) -> f64 {
        ((self.temp_c - 25.0) / self.temp_s).exp()
    }

    /// The DC-DC grid both rails can reach.
    pub fn grid(&self) -> VoltageGrid {
        VoltageGrid::new(VCORE_NOM, VBRAM_NOM, V_CRASH, V_STEP)
    }

    /// Sample a per-class scale table over the grid of the class's rail.
    pub fn delay_table(&self, class: ResourceClass) -> Vec<f64> {
        self.rail_levels(class)
            .iter()
            .map(|&v| self.delay_scale(class, v))
            .collect()
    }

    /// Sample the dynamic-power scale table over the class's rail grid.
    pub fn dyn_table(&self, class: ResourceClass) -> Vec<f64> {
        self.rail_levels(class).iter().map(|&v| self.dyn_scale(class, v)).collect()
    }

    /// Sample the static-power scale table over the class's rail grid.
    pub fn static_table(&self, class: ResourceClass) -> Vec<f64> {
        self.rail_levels(class)
            .iter()
            .map(|&v| self.static_scale(class, v))
            .collect()
    }

    fn rail_levels(&self, class: ResourceClass) -> Vec<f64> {
        let g = self.grid();
        if class.on_bram_rail() {
            g.vbram
        } else {
            g.vcore
        }
    }

    // ------------------------ serialization ------------------------

    /// Serialize every class's parameters (plus temperature) to JSON.
    pub fn to_json(&self) -> Json {
        let class = |p: &ClassParams| {
            Json::obj(vec![
                ("v_nom", Json::Num(p.v_nom)),
                ("vth", Json::Num(p.vth)),
                ("alpha_pow", Json::Num(p.alpha_pow)),
                ("flat_frac", Json::Num(p.flat_frac)),
                ("knee_v", Json::Num(p.knee_v)),
                ("knee_w", Json::Num(p.knee_w)),
                ("leak_s", Json::Num(p.leak_s)),
                ("v_crash", Json::Num(p.v_crash)),
            ])
        };
        Json::obj(vec![
            ("logic", class(&self.logic)),
            ("routing", class(&self.routing)),
            ("bram", class(&self.bram)),
            ("dsp", class(&self.dsp)),
            ("temp_c", Json::Num(self.temp_c)),
            ("temp_s", Json::Num(self.temp_s)),
        ])
    }

    /// Inverse of [`CharLibrary::to_json`].
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let class = |name: &str| -> Result<ClassParams, String> {
            let o = v.get(name).ok_or_else(|| format!("missing class {name}"))?;
            let f = |k: &str| -> Result<f64, String> {
                o.get(k)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("missing {name}.{k}"))
            };
            Ok(ClassParams {
                v_nom: f("v_nom")?,
                vth: f("vth")?,
                alpha_pow: f("alpha_pow")?,
                flat_frac: f("flat_frac")?,
                knee_v: f("knee_v")?,
                knee_w: f("knee_w")?,
                leak_s: f("leak_s")?,
                v_crash: f("v_crash")?,
            })
        };
        Ok(CharLibrary {
            logic: class("logic")?,
            routing: class("routing")?,
            bram: class("bram")?,
            dsp: class("dsp")?,
            temp_c: v.get("temp_c").and_then(Json::as_f64).unwrap_or(45.0),
            temp_s: v.get("temp_s").and_then(Json::as_f64).unwrap_or(30.0),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lib() -> CharLibrary {
        CharLibrary::stratix_iv_22nm()
    }

    #[test]
    fn normalized_at_nominal() {
        let l = lib();
        for c in ResourceClass::ALL {
            let v0 = l.params(c).v_nom;
            assert!((l.delay_scale(c, v0) - 1.0).abs() < 1e-12, "{c:?}");
            assert!((l.dyn_scale(c, v0) - 1.0).abs() < 1e-12);
            assert!((l.static_scale(c, v0) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn delay_monotone_decreasing_voltage_increases_delay() {
        let l = lib();
        for c in ResourceClass::ALL {
            let levels = if c.on_bram_rail() {
                l.grid().vbram
            } else {
                l.grid().vcore
            };
            let mut prev = 0.0;
            for &v in &levels {
                let d = l.delay_scale(c, v);
                assert!(d >= prev - 1e-9, "{c:?} delay not monotone at {v}");
                prev = d;
            }
        }
    }

    #[test]
    fn fig1_memory_delay_flat_then_spike() {
        // Paper §III: 0.95 -> 0.80 V has a relatively small effect on BRAM
        // delay; below ~0.75 V it spikes.
        let l = lib();
        let at = |v| l.delay_scale(ResourceClass::Bram, v);
        assert!(at(0.80) < 1.25, "bram delay at 0.80 V: {}", at(0.80));
        assert!(at(0.70) > 1.8, "bram delay at 0.70 V should spike: {}", at(0.70));
    }

    #[test]
    fn fig1_routing_tolerant_logic_sensitive() {
        let l = lib();
        let logic = l.delay_scale(ResourceClass::Logic, 0.60);
        let routing = l.delay_scale(ResourceClass::Routing, 0.60);
        assert!(
            logic > 1.25 * routing,
            "logic ({logic}) should degrade much faster than routing ({routing})"
        );
        assert!(routing < 1.45, "routing at 0.60 V: {routing}");
    }

    #[test]
    fn fig3_memory_static_drops_75pct_by_080() {
        // Paper §III: Vbram 0.95 -> 0.80 V cuts BRAM static power > 75 %.
        let l = lib();
        let s = l.static_scale(ResourceClass::Bram, 0.80);
        assert!(s < 0.25, "bram static at 0.80 V: {s}");
        assert!(s > 0.05, "should not be a total collapse: {s}");
    }

    #[test]
    fn dynamic_power_is_v_squared() {
        let l = lib();
        let d = l.dyn_scale(ResourceClass::Logic, 0.40);
        assert!((d - 0.25).abs() < 1e-12);
    }

    #[test]
    fn crash_voltage_is_infinite_delay() {
        let l = lib();
        for c in ResourceClass::ALL {
            assert!(l.delay_scale(c, 0.49).is_infinite(), "{c:?}");
            assert!(l.delay_scale(c, 0.51).is_finite(), "{c:?}");
        }
    }

    #[test]
    fn grid_dimensions_match_artifacts() {
        // Must agree with python/compile/model.py NV/NM.
        let g = lib().grid();
        assert_eq!(g.vcore.len(), 13);
        assert_eq!(g.vbram.len(), 19);
        assert!((g.vcore[0] - 0.80).abs() < 1e-12);
        assert!((g.vbram[0] - 0.95).abs() < 1e-12);
        assert!((g.vcore[12] - 0.50).abs() < 1e-9);
        assert!((g.vbram[18] - 0.50).abs() < 1e-9);
    }

    #[test]
    fn grid_snap() {
        let g = lib().grid();
        assert_eq!(g.snap_core(0.80), 0);
        assert_eq!(g.snap_core(0.791), 0);
        // 0.762 is nearer to 0.750 (idx 2) than to 0.775 (idx 1).
        assert_eq!(g.snap_core(0.762), 2);
        assert_eq!(g.snap_bram(0.50), 18);
    }

    #[test]
    fn temperature_raises_leakage() {
        let mut l = lib();
        let base = l.temp_leak_factor();
        l.temp_c = 65.0;
        assert!(l.temp_leak_factor() > base * 1.5);
    }

    #[test]
    fn json_round_trip() {
        let l = lib();
        let j = l.to_json();
        let l2 = CharLibrary::from_json(&j).unwrap();
        for c in ResourceClass::ALL {
            for v in [0.95, 0.8, 0.65, 0.55] {
                assert!((l.delay_scale(c, v) - l2.delay_scale(c, v)).abs() < 1e-12);
            }
        }
        assert!(CharLibrary::from_json(&Json::Null).is_err());
    }

    #[test]
    fn tables_have_grid_length() {
        let l = lib();
        assert_eq!(l.delay_table(ResourceClass::Logic).len(), 13);
        assert_eq!(l.delay_table(ResourceClass::Bram).len(), 19);
        assert_eq!(l.static_table(ResourceClass::Routing).len(), 13);
        assert_eq!(l.dyn_table(ResourceClass::Bram).len(), 19);
    }
}
