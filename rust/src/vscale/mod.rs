//! The paper's core contribution: power-aware timing analysis that picks
//! the minimum-power `(Vcore, Vbram)` pair meeting a workload-stretched
//! timing constraint (DESIGN.md S6).
//!
//! Native (rust) implementation of the same Eq. (1)-(3) grid search the
//! AOT'd Pallas Voltage Selector performs — used for baselines, LUT
//! construction at "design synthesis" time, and as the cross-check oracle
//! for the PJRT artifact. On top of the single-composition model it
//! supports a multi-path feasibility refinement: voltage scaling can
//! promote an originally non-critical path (paper §II), so feasibility is
//! checked against all top-K STA path compositions.

pub mod elastic;

pub use elastic::{CapacityPolicy, ElasticChoice, ElasticConfig, ElasticLut};

use crate::chars::{CharLibrary, ResourceClass, VoltageGrid};
use crate::power::RailTables;
use crate::sta::PathComposition;

/// Which rail(s) a policy may scale. Mirrors the artifact variants.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Mode {
    /// The proposed technique: both rails jointly.
    Proposed,
    /// Scale `Vcore` only (Zhao et al. / Levine et al. style).
    CoreOnly,
    /// Scale `Vbram` only (Salami et al. style).
    BramOnly,
    /// Scale frequency only, both voltages nominal.
    FreqOnly,
}

impl Mode {
    /// Every mode, proposed first.
    pub const ALL: [Mode; 4] = [Mode::Proposed, Mode::CoreOnly, Mode::BramOnly, Mode::FreqOnly];

    /// CLI/report name of the mode.
    pub fn name(self) -> &'static str {
        match self {
            Mode::Proposed => "prop",
            Mode::CoreOnly => "core-only",
            Mode::BramOnly => "bram-only",
            Mode::FreqOnly => "freq-only",
        }
    }

    /// The AOT artifact that implements this mode (FreqOnly needs none).
    pub fn artifact(self) -> Option<&'static str> {
        match self {
            Mode::Proposed => Some("voltage_opt_prop"),
            Mode::CoreOnly => Some("voltage_opt_core_only"),
            Mode::BramOnly => Some("voltage_opt_bram_only"),
            Mode::FreqOnly => None,
        }
    }
}

/// A chosen operating point on the DC-DC grid.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct VoltagePoint {
    /// Core-rail grid index (0 = nominal).
    pub icore: usize,
    /// BRAM-rail grid index (0 = nominal).
    pub ibram: usize,
    /// Core-rail voltage (V).
    pub vcore: f64,
    /// BRAM-rail voltage (V).
    pub vbram: f64,
    /// Total power, normalized to nominal-voltage nominal-frequency = 1.
    pub power_norm: f64,
}

/// Grid optimizer over rail-level tables (single-composition Eq. (1)-(3)),
/// optionally refined by multi-path feasibility.
#[derive(Clone, Debug)]
pub struct Optimizer {
    /// The DC-DC grid both rails can reach.
    pub grid: VoltageGrid,
    /// Rail-level delay/power tables of the design.
    pub tables: RailTables,
    /// Optional near-critical path set for the multi-path check; delays in
    /// ns at nominal voltage, plus the per-class scale tables to evaluate
    /// them (sampled from the characterization library).
    paths: Option<MultiPath>,
}

#[derive(Clone, Debug)]
struct MultiPath {
    paths: Vec<PathComposition>,
    cp_total_ns: f64,
    dlogic: Vec<f64>,
    drout: Vec<f64>,
    ddsp: Vec<f64>,
    dbram: Vec<f64>,
}

impl Optimizer {
    /// Build a single-composition optimizer over the given tables.
    pub fn new(grid: VoltageGrid, tables: RailTables) -> Self {
        Optimizer { grid, tables, paths: None }
    }

    /// Enable the multi-path feasibility refinement.
    pub fn with_paths(mut self, chars: &CharLibrary, paths: Vec<PathComposition>) -> Self {
        let cp_total_ns = paths
            .iter()
            .map(PathComposition::total_ns)
            .fold(0.0, f64::max);
        let sample = |cl: ResourceClass, levels: &[f64]| -> Vec<f64> {
            levels.iter().map(|&v| chars.delay_scale(cl, v)).collect()
        };
        self.paths = Some(MultiPath {
            cp_total_ns,
            dlogic: sample(ResourceClass::Logic, &self.grid.vcore),
            drout: sample(ResourceClass::Routing, &self.grid.vcore),
            ddsp: sample(ResourceClass::Dsp, &self.grid.vcore),
            dbram: sample(ResourceClass::Bram, &self.grid.vbram),
            paths,
        });
        self
    }

    /// Eq. (2): does grid point (i, j) meet timing at slack factor `sw`?
    pub fn feasible(&self, i: usize, j: usize, sw: f64) -> bool {
        let t = &self.tables;
        let single =
            t.dl[i] + t.op.alpha * t.dm[j] <= (1.0 + t.op.alpha) * sw + 1e-12;
        if !single {
            return false;
        }
        match &self.paths {
            None => true,
            Some(mp) => {
                let budget = mp.cp_total_ns * sw + 1e-12;
                mp.paths.iter().all(|p| {
                    p.logic_ns * mp.dlogic[i]
                        + p.routing_ns * mp.drout[i]
                        + p.dsp_ns * mp.ddsp[i]
                        + p.bram_ns * mp.dbram[j]
                        <= budget
                })
            }
        }
    }

    /// Eq. (3): normalized total power at grid point (i, j), clock scaled
    /// to `f = f_nom / sw`.
    pub fn power(&self, i: usize, j: usize, sw: f64) -> f64 {
        let t = &self.tables;
        let fr = 1.0 / sw;
        let p_core = t.op.gamma_l * t.pl_dyn[i] * fr + (1.0 - t.op.gamma_l) * t.pl_st[i];
        let p_bram = t.op.gamma_m * t.pm_dyn[j] * fr + (1.0 - t.op.gamma_m) * t.pm_st[j];
        (1.0 - t.op.beta) * p_core + t.op.beta * p_bram
    }

    /// Exhaustive minimum-power search on the grid (the paper's "accurate
    /// timing *and power* analysis under multiple voltage scaling").
    /// `sw < 1` is clamped to 1 (a platform never runs above nominal).
    pub fn optimize(&self, sw: f64, mode: Mode) -> VoltagePoint {
        let sw = sw.max(1.0);
        let (ni, nj) = (self.grid.vcore.len(), self.grid.vbram.len());
        let (irange, jrange): (std::ops::Range<usize>, std::ops::Range<usize>) = match mode {
            Mode::Proposed => (0..ni, 0..nj),
            Mode::CoreOnly => (0..ni, 0..1),
            Mode::BramOnly => (0..1, 0..nj),
            Mode::FreqOnly => (0..1, 0..1),
        };
        let mut best = (0usize, 0usize, f64::INFINITY);
        for i in irange {
            for j in jrange.clone() {
                if !self.feasible(i, j, sw) {
                    continue;
                }
                let p = self.power(i, j, sw);
                if p < best.2 {
                    best = (i, j, p);
                }
            }
        }
        debug_assert!(
            best.2.is_finite(),
            "nominal grid point must always be feasible for sw >= 1"
        );
        VoltagePoint {
            icore: best.0,
            ibram: best.1,
            vcore: self.grid.vcore[best.0],
            vbram: self.grid.vbram[best.1],
            power_norm: best.2,
        }
    }

    /// Power-gating baseline: `ceil(n·load)` of `n` boards at nominal V/f,
    /// the rest gated to `residual` of nominal power. Normalized per-board.
    pub fn power_gating(load: f64, n: usize, residual: f64) -> f64 {
        let load = load.clamp(0.0, 1.0);
        let active = (load * n as f64).ceil().min(n as f64);
        (active + (n as f64 - active) * residual) / n as f64
    }

    /// Paper's Fig. 4 "PG" idealization (node count scales linearly).
    pub fn power_gating_ideal(load: f64) -> f64 {
        load.clamp(0.0, 1.0).max(1e-3)
    }
}

/// Bin index for a normalized load in [0, 1] over `m` equal-width bins
/// (upper-edge inclusive). Delegates to the crate-wide
/// [`workload::bin_of_load`](crate::workload::bin_of_load) — the single
/// source of truth for workload binning — so `VoltageLut::bin_of`,
/// `ElasticLut::bin_of` and the Markov state space can never drift apart
/// (the hybrid-vs-baseline comparisons depend on identical boundaries).
pub(crate) fn bin_index(m: usize, load: f64) -> usize {
    crate::workload::bin_of_load(m, load)
}

/// "Design synthesis"-time lookup table: per workload bin, the optimal
/// voltage pair and frequency ratio (paper §V: "the optimal operating
/// voltage(s) of each frequency is calculated during the design synthesis
/// stage and stored in the memory").
#[derive(Clone, Debug)]
pub struct VoltageLut {
    /// Voltage mode the LUT was optimized for.
    pub mode: Mode,
    /// Throughput margin t (paper §IV.A, default 5%).
    pub margin_t: f64,
    /// entries[b] serves workloads in bin b of m equal-width bins; the
    /// frequency is sized for the bin's *upper* edge times (1 + t).
    pub entries: Vec<LutEntry>,
}

/// One LUT row: a workload bin's frequency and optimal voltage pair.
#[derive(Clone, Copy, Debug)]
pub struct LutEntry {
    /// f / f_nom this bin runs at.
    pub freq_ratio: f64,
    /// Minimum-power feasible voltage pair at that frequency.
    pub point: VoltagePoint,
}

impl VoltageLut {
    /// Build the per-bin LUT (no latency restriction).
    pub fn build(opt: &Optimizer, m_bins: usize, margin_t: f64, mode: Mode) -> Self {
        Self::build_with_latency_cap(opt, m_bins, margin_t, mode, f64::INFINITY)
    }

    /// Build with a latency restriction (paper §IV): the clock period may
    /// be stretched at most `latency_cap_sw` times nominal, regardless of
    /// how low the workload bin is.
    pub fn build_with_latency_cap(
        opt: &Optimizer,
        m_bins: usize,
        margin_t: f64,
        mode: Mode,
        latency_cap_sw: f64,
    ) -> Self {
        assert!(m_bins >= 1);
        assert!(latency_cap_sw >= 1.0, "latency cap must allow nominal speed");
        let entries = (0..m_bins)
            .map(|b| {
                let upper = (b + 1) as f64 / m_bins as f64;
                let freq_ratio = (upper * (1.0 + margin_t))
                    .max(1.0 / latency_cap_sw)
                    .min(1.0);
                let sw = 1.0 / freq_ratio;
                LutEntry { freq_ratio, point: opt.optimize(sw, mode) }
            })
            .collect();
        VoltageLut { mode, margin_t, entries }
    }

    /// Number of workload bins M.
    pub fn m_bins(&self) -> usize {
        self.entries.len()
    }

    /// Bin index for a normalized load in [0, 1].
    pub fn bin_of(&self, load: f64) -> usize {
        bin_index(self.entries.len(), load)
    }

    /// The LUT row serving a normalized load.
    pub fn entry_for_load(&self, load: f64) -> &LutEntry {
        &self.entries[self.bin_of(load)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{BenchmarkSpec, DeviceFamily};
    use crate::chars::CharLibrary;
    use crate::netlist::gen::{generate, GenConfig};
    use crate::power::{DesignPower, PowerParams};
    use crate::sta::{analyze, DelayParams};
    use crate::util::prop;

    fn optimizer(name: &str) -> Optimizer {
        let chars = CharLibrary::stratix_iv_22nm();
        let spec = BenchmarkSpec::by_name(name).unwrap();
        let dp = DesignPower::from_spec(
            spec,
            &DeviceFamily::stratix_iv(),
            chars.clone(),
            PowerParams::default(),
        )
        .unwrap();
        let net = generate(spec, &GenConfig { scale: 0.05, seed: 2019, luts_per_lab: 10 });
        let rep = analyze(&net, &DelayParams::default(), 8).unwrap();
        Optimizer::new(chars.grid(), dp.rail_tables(&rep.cp))
            .with_paths(&chars, rep.top_paths.clone())
    }

    #[test]
    fn sw1_stays_at_nominal_power_or_better() {
        let o = optimizer("tabla");
        let p = o.optimize(1.0, Mode::Proposed);
        assert!(p.power_norm <= 1.0 + 1e-9);
        // At sw = 1 there is no slack: frequencies match nominal, so the
        // chosen point must still meet timing with zero stretch.
        assert!(o.feasible(p.icore, p.ibram, 1.0));
    }

    #[test]
    fn chosen_point_is_always_feasible_and_optimal() {
        let o = optimizer("dnnweaver");
        prop::check("optimizer picks feasible grid minimum", 60, |rng| {
            let sw = rng.range(1.0, 8.0);
            let mode = *rng.choose(&Mode::ALL);
            let pt = o.optimize(sw, mode);
            prop::assert_that(o.feasible(pt.icore, pt.ibram, sw), "infeasible pick")?;
            // No feasible grid point may beat it (restricted to the mode).
            for i in 0..o.grid.vcore.len() {
                for j in 0..o.grid.vbram.len() {
                    let allowed = match mode {
                        Mode::Proposed => true,
                        Mode::CoreOnly => j == 0,
                        Mode::BramOnly => i == 0,
                        Mode::FreqOnly => i == 0 && j == 0,
                    };
                    if allowed && o.feasible(i, j, sw) {
                        prop::assert_that(
                            o.power(i, j, sw) >= pt.power_norm - 1e-12,
                            format!("({i},{j}) beats optimizer at sw={sw}"),
                        )?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn power_monotone_in_slack() {
        let o = optimizer("diannao");
        for mode in Mode::ALL {
            let mut prev = f64::INFINITY;
            for step in 1..20 {
                let sw = 1.0 + step as f64 * 0.35;
                let p = o.optimize(sw, mode).power_norm;
                assert!(p <= prev + 1e-12, "{mode:?} not monotone at sw={sw}");
                prev = p;
            }
        }
    }

    #[test]
    fn proposed_dominates_single_rail() {
        let o = optimizer("proteus");
        for step in 0..25 {
            let sw = 1.0 + step as f64 * 0.3;
            let p = o.optimize(sw, Mode::Proposed).power_norm;
            let c = o.optimize(sw, Mode::CoreOnly).power_norm;
            let b = o.optimize(sw, Mode::BramOnly).power_norm;
            let f = o.optimize(sw, Mode::FreqOnly).power_norm;
            assert!(p <= c + 1e-12 && p <= b + 1e-12, "sw={sw}");
            assert!(c <= f + 1e-12 && b <= f + 1e-12, "voltage scaling beats freq-only");
        }
    }

    #[test]
    fn crash_voltage_bounds_the_gain() {
        // Paper §III: at very low workloads the crash voltage prevents
        // further reduction and power gating wins.
        let o = optimizer("tabla");
        let deep = o.optimize(50.0, Mode::Proposed);
        let deeper = o.optimize(500.0, Mode::Proposed);
        // Voltages bottom out at the crash floor.
        assert!(deep.vcore >= 0.5 - 1e-9 && deep.vbram >= 0.5 - 1e-9);
        assert!(deeper.vcore >= 0.5 - 1e-9 && deeper.vbram >= 0.5 - 1e-9);
        assert!(deeper.power_norm <= deep.power_norm + 1e-12);
        // The static floor keeps power strictly positive...
        assert!(deeper.power_norm > 0.005, "{}", deeper.power_norm);
        // ...so ideal power gating wins at very low workloads (§III).
        assert!(deeper.power_norm > Optimizer::power_gating_ideal(1.0 / 500.0));
    }

    #[test]
    fn power_gating_models() {
        assert!((Optimizer::power_gating(0.5, 10, 0.0) - 0.5).abs() < 1e-12);
        // ceil: 0.41 load on 10 boards keeps 5 on.
        assert!((Optimizer::power_gating(0.41, 10, 0.0) - 0.5).abs() < 1e-12);
        // residual leakage of gated boards.
        assert!((Optimizer::power_gating(0.5, 10, 0.1) - 0.55).abs() < 1e-12);
        assert_eq!(Optimizer::power_gating(2.0, 4, 0.0), 1.0);
    }

    #[test]
    fn lut_bins_and_lookup() {
        let o = optimizer("tabla");
        let lut = VoltageLut::build(&o, 10, 0.05, Mode::Proposed);
        assert_eq!(lut.m_bins(), 10);
        assert_eq!(lut.bin_of(0.0), 0);
        assert_eq!(lut.bin_of(0.05), 0);
        assert_eq!(lut.bin_of(0.11), 1);
        assert_eq!(lut.bin_of(1.0), 9);
        // Higher bins -> higher frequency -> >= power.
        for w in lut.entries.windows(2) {
            assert!(w[0].freq_ratio <= w[1].freq_ratio + 1e-12);
            assert!(w[0].point.power_norm <= w[1].point.power_norm + 1e-9);
        }
        // Top bin runs at nominal frequency.
        assert!((lut.entries[9].freq_ratio - 1.0).abs() < 1e-12);
    }

    #[test]
    fn multi_path_can_bind() {
        // A second path heavy on BRAM must restrict Vbram even when the
        // nominal CP is logic-heavy.
        let chars = CharLibrary::stratix_iv_22nm();
        let spec = BenchmarkSpec::by_name("tabla").unwrap();
        let dp = DesignPower::from_spec(
            spec,
            &DeviceFamily::stratix_iv(),
            chars.clone(),
            PowerParams::default(),
        )
        .unwrap();
        let net = generate(spec, &GenConfig { scale: 0.05, seed: 2019, luts_per_lab: 10 });
        let rep = analyze(&net, &DelayParams::default(), 8).unwrap();
        let tables = dp.rail_tables(&rep.cp);

        let single = Optimizer::new(chars.grid(), tables.clone());
        // Synthetic second path: nearly all BRAM, just under the CP.
        let bram_heavy = PathComposition {
            logic_ns: 0.4,
            routing_ns: 0.4,
            bram_ns: rep.cp.total_ns() - 1.0,
            dsp_ns: 0.0,
        };
        let multi = Optimizer::new(chars.grid(), tables)
            .with_paths(&chars, vec![rep.cp, bram_heavy]);
        let sw = 2.0;
        let a = single.optimize(sw, Mode::Proposed);
        let b = multi.optimize(sw, Mode::Proposed);
        assert!(
            b.vbram >= a.vbram,
            "multi-path must be at least as conservative on Vbram: {a:?} vs {b:?}"
        );
        assert!(b.power_norm >= a.power_norm - 1e-12);
    }
}
