//! Elastic capacity: joint power-gating + DVFS optimization (DESIGN.md
//! S6.1).
//!
//! The paper's §III observation is that voltage/frequency scaling bottoms
//! out at the crash-voltage floor, below which power gating wins — and
//! `crash_voltage_bounds_the_gain` (mod.rs tests) proves our optimizer
//! does hit that floor. The [`ElasticLut`] therefore searches the *joint*
//! space each workload bin: how many instances stay active (the rest
//! gated to a `residual` power fraction) **and** which `(Vcore, Vbram, f)`
//! point the active instances run at. Concentrating a low fleet load onto
//! fewer instances raises their per-instance utilization back into the
//! regime where voltage scaling is effective, while the gated remainder
//! pay only leakage — the joint sleep/scale policy argued for in
//! arXiv:2311.11015 and the FPGA datacenter survey arXiv:2309.12884.
//!
//! [`CapacityPolicy`] restricts the search so the same machinery yields
//! the two baselines: `DvfsOnly` (all instances active; identical to
//! [`VoltageLut`](super::VoltageLut)) and `GatingOnly` (active instances
//! pinned at nominal V/f; identical to
//! [`Optimizer::power_gating`](super::Optimizer::power_gating)). By
//! construction the hybrid entry is never worse than either baseline for
//! the same bin: the full-active candidate *is* the DVFS-only choice, and
//! for the gating-only active count the optimizer can only lower power
//! relative to nominal V/f.

use super::{Mode, Optimizer, VoltagePoint};

/// Which capacity dimensions the elastic manager may move.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CapacityPolicy {
    /// All instances stay active; only V/f scale (the PR-1 behaviour).
    DvfsOnly,
    /// Active instances pinned at nominal V/f; only the count scales
    /// (conventional power gating).
    GatingOnly,
    /// Joint search over active count and V/f (the elastic manager).
    Hybrid,
}

impl CapacityPolicy {
    /// Every policy, hybrid last (report order: baselines first).
    pub const ALL: [CapacityPolicy; 3] =
        [CapacityPolicy::DvfsOnly, CapacityPolicy::GatingOnly, CapacityPolicy::Hybrid];

    /// CLI/report name of the policy.
    pub fn name(self) -> &'static str {
        match self {
            CapacityPolicy::DvfsOnly => "dvfs-only",
            CapacityPolicy::GatingOnly => "pg-only",
            CapacityPolicy::Hybrid => "hybrid",
        }
    }

    /// Resolve a CLI name (`dvfs`, `pg`, `hybrid`, ...).
    pub fn by_name(name: &str) -> Result<CapacityPolicy, String> {
        Ok(match name {
            "dvfs" | "dvfs-only" => CapacityPolicy::DvfsOnly,
            "pg" | "pg-only" | "gating" => CapacityPolicy::GatingOnly,
            "hybrid" => CapacityPolicy::Hybrid,
            other => return Err(format!("unknown capacity policy {other}")),
        })
    }
}

/// Parameters of an elastic LUT build.
#[derive(Clone, Copy, Debug)]
pub struct ElasticConfig {
    /// Workload bins M (equal width over [0, 1] fleet load).
    pub m_bins: usize,
    /// Throughput margin t (capacity sized for bin upper edge × (1 + t)).
    pub margin_t: f64,
    /// Voltage mode of the active instances' grid search.
    pub mode: Mode,
    /// Instances in the group/platform the LUT manages.
    pub n_instances: usize,
    /// Residual power fraction (of nominal) drawn by a gated instance.
    pub residual: f64,
    /// Which capacity dimensions the search may move.
    pub policy: CapacityPolicy,
    /// Latency restriction: active instances' clock period may stretch at
    /// most this factor (`f64::INFINITY` disables the cap).
    pub latency_cap_sw: f64,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig {
            m_bins: 10,
            margin_t: 0.05,
            mode: Mode::Proposed,
            n_instances: 4,
            residual: 0.02,
            policy: CapacityPolicy::Hybrid,
            latency_cap_sw: f64::INFINITY,
        }
    }
}

/// One elastic operating configuration: how many instances serve, at what
/// frequency and voltages, and what the whole fleet then draws.
#[derive(Clone, Copy, Debug)]
pub struct ElasticChoice {
    /// Instances left active (the other `n - n_active` are gated).
    pub n_active: usize,
    /// f / f_nom of the active instances.
    pub freq_ratio: f64,
    /// Minimum-power feasible voltage pair of the active instances.
    pub point: VoltagePoint,
    /// Fleet power normalized per instance at nominal V/f: active
    /// instances at `point.power_norm`, gated instances at `residual`.
    pub fleet_power_norm: f64,
}

/// Per-bin elastic LUT: the design-synthesis-time table the Central
/// Controller reads each epoch (the elastic generalization of
/// [`VoltageLut`](super::VoltageLut)).
#[derive(Clone, Debug)]
pub struct ElasticLut {
    /// Build parameters the table was computed for.
    pub cfg: ElasticConfig,
    /// `entries[b]` serves workloads in bin b of `cfg.m_bins` equal-width
    /// bins; capacity is sized for the bin's *upper* edge × (1 + t).
    pub entries: Vec<ElasticChoice>,
}

impl ElasticLut {
    /// Build the per-bin table. The search cost is
    /// `m_bins × n_instances` grid optimizations — still design-synthesis
    /// time, never on the serving path.
    pub fn build(opt: &Optimizer, cfg: &ElasticConfig) -> ElasticLut {
        assert!(cfg.m_bins >= 1, "need at least one workload bin");
        assert!(cfg.n_instances >= 1, "need at least one instance");
        assert!(
            (0.0..=1.0).contains(&cfg.residual),
            "gated residual must be a fraction of nominal power"
        );
        assert!(cfg.latency_cap_sw >= 1.0, "latency cap must allow nominal speed");
        let entries = (0..cfg.m_bins)
            .map(|b| {
                let upper = (b + 1) as f64 / cfg.m_bins as f64;
                let target = (upper * (1.0 + cfg.margin_t)).min(1.0);
                Self::optimize(opt, cfg, target)
            })
            .collect();
        ElasticLut { cfg: *cfg, entries }
    }

    /// Minimum-power configuration whose fleet capacity
    /// `(n_active / n) · freq_ratio` covers `target` (normalized fleet
    /// load, capacity-margin already applied by the caller).
    pub fn optimize(opt: &Optimizer, cfg: &ElasticConfig, target: f64) -> ElasticChoice {
        let n = cfg.n_instances;
        let target = target.clamp(1e-3, 1.0);
        let fr_floor = (1.0 / cfg.latency_cap_sw).min(1.0);
        let fr_of = |n_active: usize| -> Option<f64> {
            let fr = target * n as f64 / n_active as f64;
            if fr > 1.0 + 1e-9 {
                return None; // too few instances to cover the load
            }
            Some(fr.max(fr_floor).min(1.0))
        };
        let candidate = |n_active: usize, fr: f64, point: VoltagePoint| -> ElasticChoice {
            let gated = (n - n_active) as f64;
            let fleet_power_norm =
                (n_active as f64 * point.power_norm + gated * cfg.residual) / n as f64;
            ElasticChoice { n_active, freq_ratio: fr, point, fleet_power_norm }
        };
        match cfg.policy {
            CapacityPolicy::DvfsOnly => {
                let fr = fr_of(n).unwrap_or(1.0);
                candidate(n, fr, opt.optimize(1.0 / fr, cfg.mode))
            }
            CapacityPolicy::GatingOnly => {
                // ceil(target · n) instances at nominal V/f, rest gated —
                // Optimizer::power_gating as a live policy.
                let n_active = ((target * n as f64).ceil() as usize).clamp(1, n);
                let nominal = VoltagePoint {
                    icore: 0,
                    ibram: 0,
                    vcore: opt.grid.vcore[0],
                    vbram: opt.grid.vbram[0],
                    power_norm: opt.power(0, 0, 1.0),
                };
                candidate(n_active, 1.0, nominal)
            }
            CapacityPolicy::Hybrid => {
                // Descending scan prefers more active instances on ties,
                // so gating only happens when it strictly saves power and
                // the full-active candidate (== DVFS-only) is the default.
                let mut best: Option<ElasticChoice> = None;
                for n_active in (1..=n).rev() {
                    let Some(fr) = fr_of(n_active) else { continue };
                    let c = candidate(n_active, fr, opt.optimize(1.0 / fr, cfg.mode));
                    if best
                        .as_ref()
                        .map(|b| c.fleet_power_norm < b.fleet_power_norm - 1e-12)
                        .unwrap_or(true)
                    {
                        best = Some(c);
                    }
                }
                // n_active = n is always feasible (target <= 1).
                best.unwrap_or_else(|| {
                    candidate(n, 1.0, opt.optimize(1.0, cfg.mode))
                })
            }
        }
    }

    /// Number of workload bins M.
    pub fn m_bins(&self) -> usize {
        self.entries.len()
    }

    /// Bin index for a normalized fleet load in [0, 1] — shares the
    /// crate-private `bin_index` helper with
    /// [`VoltageLut::bin_of`](super::VoltageLut::bin_of) so live elastic
    /// decisions and the offline baselines use identical bin boundaries.
    pub fn bin_of(&self, load: f64) -> usize {
        super::bin_index(self.entries.len(), load)
    }

    /// The elastic configuration serving a normalized fleet load.
    pub fn entry_for_load(&self, load: f64) -> &ElasticChoice {
        &self.entries[self.bin_of(load)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{BenchmarkSpec, DeviceFamily};
    use crate::chars::CharLibrary;
    use crate::netlist::gen::{generate, GenConfig};
    use crate::power::{DesignPower, PowerParams};
    use crate::sta::{analyze, DelayParams};

    fn optimizer(name: &str) -> Optimizer {
        let chars = CharLibrary::stratix_iv_22nm();
        let spec = BenchmarkSpec::by_name(name).unwrap();
        let dp = DesignPower::from_spec(
            spec,
            &DeviceFamily::stratix_iv(),
            chars.clone(),
            PowerParams::default(),
        )
        .unwrap();
        let net = generate(spec, &GenConfig { scale: 0.05, seed: 2019, luts_per_lab: 10 });
        let rep = analyze(&net, &DelayParams::default(), 8).unwrap();
        Optimizer::new(chars.grid(), dp.rail_tables(&rep.cp))
            .with_paths(&chars, rep.top_paths.clone())
    }

    fn luts(opt: &Optimizer) -> (ElasticLut, ElasticLut, ElasticLut) {
        let base = ElasticConfig { n_instances: 4, ..Default::default() };
        let mk = |policy| ElasticLut::build(opt, &ElasticConfig { policy, ..base });
        (
            mk(CapacityPolicy::DvfsOnly),
            mk(CapacityPolicy::GatingOnly),
            mk(CapacityPolicy::Hybrid),
        )
    }

    #[test]
    fn hybrid_never_worse_than_either_baseline_per_bin() {
        let opt = optimizer("tabla");
        let (dvfs, pg, hybrid) = luts(&opt);
        for b in 0..hybrid.m_bins() {
            let h = hybrid.entries[b].fleet_power_norm;
            assert!(
                h <= dvfs.entries[b].fleet_power_norm + 1e-12,
                "bin {b}: hybrid {h} vs dvfs {}",
                dvfs.entries[b].fleet_power_norm
            );
            assert!(
                h <= pg.entries[b].fleet_power_norm + 1e-12,
                "bin {b}: hybrid {h} vs pg {}",
                pg.entries[b].fleet_power_norm
            );
        }
    }

    #[test]
    fn hybrid_gates_below_the_crash_floor_and_matches_dvfs_at_peak() {
        let opt = optimizer("tabla");
        let (dvfs, _, hybrid) = luts(&opt);
        // Lowest bin: the crash-voltage floor binds DVFS (§III), so the
        // hybrid must gate instances and strictly beat DVFS-only.
        let low = &hybrid.entries[0];
        assert!(low.n_active < 4, "low bin must gate: {low:?}");
        assert!(
            low.fleet_power_norm < dvfs.entries[0].fleet_power_norm - 1e-9,
            "hybrid {low:?} vs dvfs {:?}",
            dvfs.entries[0]
        );
        // Top bin needs every instance: identical to DVFS-only.
        let top = hybrid.entries.last().unwrap();
        assert_eq!(top.n_active, 4);
        assert!((top.freq_ratio - dvfs.entries.last().unwrap().freq_ratio).abs() < 1e-12);
        assert!(
            (top.fleet_power_norm - dvfs.entries.last().unwrap().fleet_power_norm).abs()
                < 1e-12
        );
    }

    #[test]
    fn every_entry_covers_its_bin_capacity() {
        let opt = optimizer("dnnweaver");
        let (dvfs, pg, hybrid) = luts(&opt);
        for lut in [&dvfs, &pg, &hybrid] {
            let m = lut.m_bins() as f64;
            for (b, e) in lut.entries.iter().enumerate() {
                let target = (((b + 1) as f64 / m) * (1.0 + lut.cfg.margin_t)).min(1.0);
                let cap = e.n_active as f64 / lut.cfg.n_instances as f64 * e.freq_ratio;
                assert!(
                    cap >= target - 1e-9,
                    "{:?} bin {b}: capacity {cap} < target {target}",
                    lut.cfg.policy
                );
                assert!(e.n_active >= 1 && e.n_active <= lut.cfg.n_instances);
            }
        }
    }

    #[test]
    fn gating_only_matches_the_offline_power_gating_formula() {
        let opt = optimizer("tabla");
        let cfg = ElasticConfig {
            n_instances: 10,
            policy: CapacityPolicy::GatingOnly,
            ..Default::default()
        };
        let lut = ElasticLut::build(&opt, &cfg);
        for (b, e) in lut.entries.iter().enumerate() {
            let target = (((b + 1) as f64 / 10.0) * (1.0 + cfg.margin_t)).min(1.0);
            let want = Optimizer::power_gating(target, 10, cfg.residual);
            assert!(
                (e.fleet_power_norm - want).abs() < 1e-12,
                "bin {b}: {} vs {want}",
                e.fleet_power_norm
            );
            assert!((e.point.power_norm - 1.0).abs() < 1e-12, "PG runs at nominal");
            assert!((e.freq_ratio - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn latency_cap_floors_the_active_frequency() {
        let opt = optimizer("tabla");
        let cfg = ElasticConfig {
            n_instances: 4,
            latency_cap_sw: 2.0,
            ..Default::default()
        };
        let lut = ElasticLut::build(&opt, &cfg);
        for e in &lut.entries {
            assert!(e.freq_ratio >= 0.5 - 1e-12, "{e:?} violates the 2x stretch cap");
        }
    }

    #[test]
    fn bin_lookup_mirrors_voltage_lut() {
        let opt = optimizer("tabla");
        let lut = ElasticLut::build(&opt, &ElasticConfig::default());
        assert_eq!(lut.m_bins(), 10);
        assert_eq!(lut.bin_of(0.0), 0);
        assert_eq!(lut.bin_of(0.05), 0);
        assert_eq!(lut.bin_of(0.11), 1);
        assert_eq!(lut.bin_of(1.0), 9);
        // Monotone cost: a higher bin's feasible set is a subset of a
        // lower bin's (at pointwise higher frequency), so its minimum
        // power can never be cheaper.
        for w in lut.entries.windows(2) {
            assert!(w[0].fleet_power_norm <= w[1].fleet_power_norm + 1e-9);
        }
    }

    #[test]
    fn capacity_policy_names_round_trip() {
        for p in CapacityPolicy::ALL {
            assert_eq!(CapacityPolicy::by_name(p.name()).unwrap(), p);
        }
        assert!(CapacityPolicy::by_name("nope").is_err());
    }
}
