//! Time source abstraction for the live coordinator (DESIGN.md S18).
//!
//! The serving path used to be hard-wired to wall-clock time
//! (`std::thread::sleep` / `Instant::now()` inside workers, the Central
//! Controller epoch loop and the scenario driver), so a 24 h diurnal trace
//! replayed in real time and integration tests resorted to 10-second
//! deadlines and sleeps. Everything time-shaped now goes through a
//! [`Clock`]:
//!
//! * [`WallClock`] — real time; `sleep` is `std::thread::sleep`, waits are
//!   plain condvar waits. The default for `serve-fleet` and the single
//!   process-wide epoch means [`Tick`]s from different `WallClock` values
//!   are comparable.
//! * [`VirtualClock`] — deterministic discrete-event simulation time. Every
//!   thread that touches the clock is a registered *actor*; exactly one
//!   actor runs at a time and virtual time advances only when the running
//!   actor parks (sleeps or waits on a [`WaitSlot`]). The next actor is the
//!   lowest-id Ready actor, else the parked actor with the earliest
//!   `(deadline, id)`. With all stochastic inputs seeded, an entire
//!   multi-thread serving run — submissions, dispatch, stealing, gating,
//!   CC epochs — is a deterministic function of the seed: a thousand-epoch
//!   scenario replays in milliseconds and two runs produce byte-identical
//!   traces (`simtest`, DESIGN.md S18).
//! * [`ParallelVirtualClock`] — the conservative domain-parallel twin of
//!   `VirtualClock` (DESIGN.md S24): actors are partitioned into
//!   advance-domains via [`Clock::register_actor_in`] and independent
//!   domains advance concurrently between control-domain barriers, with
//!   traces byte-identical to the sequential engine (the golden
//!   reference — see `tests/sim_parallel.rs`).
//!
//! Blocking-wait integration uses a *generation counter* instead of an
//! atomically-released mutex: the waiter samples [`WaitSlot::generation`],
//! re-checks its condition, then calls [`Clock::wait_slot`] with the
//! sampled generation — if a notify landed in between, the wait returns
//! immediately, so no wakeup can be lost and the queue lock is never held
//! across a park.

use std::collections::BTreeMap;
// detlint: allow(hash-collection) -- `threads` maps ThreadId -> ActorId for
// lookup only; scheduling scans iterate `actors` (a BTreeMap), never this.
use std::collections::HashMap;
// detlint: allow(std-sync-bypass) -- OnceLock guards the process-wide wall
// epoch `Instant`; it is not a model-checked primitive and loom has no
// equivalent (the wall epoch is irrelevant under virtual-time replay).
use std::sync::OnceLock;
use std::thread::ThreadId;
use std::time::{Duration, Instant};

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{Arc, Condvar, Mutex, MutexGuard};

mod parallel;

pub use parallel::ParallelVirtualClock;

/// A point in time, in nanoseconds since the clock's epoch (process start
/// for [`WallClock`], simulation start for [`VirtualClock`]).
pub type Tick = u64;

/// Identifier of a registered [`VirtualClock`] actor (0 under wall time,
/// where actors are a no-op concept).
pub type ActorId = u64;

/// Convert a `Duration` to [`Tick`] nanoseconds (saturating).
pub fn ticks(d: Duration) -> Tick {
    u64::try_from(d.as_nanos()).unwrap_or(Tick::MAX)
}

/// Convert [`Tick`] nanoseconds back to a `Duration`.
pub fn to_duration(t: Tick) -> Duration {
    Duration::from_nanos(t)
}

/// The DVFS-epoch index a clock reading falls in — the shared
/// time→epoch mapping the workers use to query a
/// [`FaultPlan`](crate::workload::FaultPlan) at the epoch the CC indexed
/// it by. Zero-length epochs clamp to 1 ns so the division is defined.
pub fn epoch_index(now: Tick, epoch: Duration) -> usize {
    (now / ticks(epoch).max(1)) as usize
}

/// The shared wall-clock epoch: all [`WallClock`] values measure from the
/// same process-wide instant so their ticks are mutually comparable.
fn wall_epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// A notifiable event source for condvar-style waits routed through a
/// [`Clock`] (one per [`ShardQueue`](crate::coordinator::ShardQueue)).
///
/// The `generation` counter increments on every notify; waiters pass the
/// generation they observed *before* re-checking their condition, so a
/// notify that races the check makes the wait return immediately.
#[derive(Debug)]
pub struct WaitSlot {
    /// Slot id inside a [`VirtualClock`] (0 under wall time).
    id: u64,
    gen: AtomicU64,
    mu: Mutex<()>,
    cv: Condvar,
}

impl WaitSlot {
    fn with_id(id: u64) -> Self {
        WaitSlot { id, gen: AtomicU64::new(0), mu: Mutex::new(()), cv: Condvar::new() }
    }

    /// Current notify generation; sample before checking the condition the
    /// wait protects, then pass to [`Clock::wait_slot`].
    pub fn generation(&self) -> u64 {
        self.gen.load(Ordering::SeqCst)
    }

    /// Take the slot's (contentless) mutex, recovering from poisoning.
    fn locked(&self) -> MutexGuard<'_, ()> {
        match self.mu.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }
}

/// The time source every time-shaped coordinator operation goes through.
///
/// Actor registration is a three-step protocol so ids are deterministic:
/// the *spawning* thread calls [`Clock::register_actor`] (in program
/// order), hands the id into the new thread, which binds itself with
/// [`Clock::attach_actor`] and unbinds with [`Clock::detach_actor`] on
/// exit (use [`ActorScope`] for RAII). Under [`WallClock`] all of this is
/// a no-op.
pub trait Clock: Send + Sync + std::fmt::Debug {
    /// Nanoseconds since the clock's epoch.
    fn now(&self) -> Tick;

    /// Block the calling actor for `d` (virtual: parks and lets time
    /// advance; wall: `std::thread::sleep`).
    fn sleep(&self, d: Duration);

    /// Create a wait slot bound to this clock.
    fn new_slot(&self) -> Arc<WaitSlot>;

    /// Block until `slot` is notified past `observed_gen` or `timeout`
    /// elapses. Returns immediately when the generation already moved —
    /// sample [`WaitSlot::generation`] *before* checking the condition the
    /// wait protects (see the module docs on lost wakeups).
    fn wait_slot(&self, slot: &WaitSlot, observed_gen: u64, timeout: Duration);

    /// Wake every waiter on `slot` (increments the generation).
    fn notify_slot(&self, slot: &WaitSlot);

    /// Allocate an actor id on the *spawning* thread (deterministic,
    /// program-order ids). No-op (returns 0) under wall time.
    ///
    /// Ids are handed out strictly in call order on the registering
    /// thread — golden-trace ordering depends on this, and both virtual
    /// engines assert it so sequential and parallel registrations can
    /// never drift ([`VirtualClock`] ties, e.g., worker claim priority to
    /// actor id via registration order).
    fn register_actor(&self, _name: &str) -> ActorId {
        0
    }

    /// [`Clock::register_actor`], targeted at advance-domain `domain` of a
    /// parallel engine. Domain 0 is the control domain (scenario drivers,
    /// CC epoch loops); domains > 0 hold independent worker pools. Clocks
    /// without domains (wall time, the sequential [`VirtualClock`]) ignore
    /// the domain — so callers can tag domains unconditionally and the
    /// sequential golden reference still sees identical registration
    /// order.
    fn register_actor_in(&self, name: &str, _domain: usize) -> ActorId {
        self.register_actor(name)
    }

    /// Bind the calling thread to a registered actor; under virtual time
    /// this blocks until the scheduler first runs the actor.
    fn attach_actor(&self, _id: ActorId) {}

    /// Unbind and remove the actor (call from its own thread on exit).
    fn detach_actor(&self, _id: ActorId) {}

    /// Temporarily remove the calling actor from scheduling so it can
    /// block on something outside the clock (e.g. `JoinHandle::join`).
    fn suspend_current(&self) {}

    /// Re-enter scheduling after [`Clock::suspend_current`]; blocks until
    /// the scheduler runs this actor again.
    fn resume_current(&self) {}

    /// Whether the calling thread is a registered actor (always true under
    /// wall time, where registration is a no-op).
    fn current_is_actor(&self) -> bool {
        true
    }

    /// True for deterministic simulation time.
    fn is_virtual(&self) -> bool {
        false
    }
}

/// A shared handle to the process-wide wall clock.
pub fn wall() -> Arc<dyn Clock> {
    Arc::new(WallClock)
}

/// Real time: `now` counts from a process-wide epoch, `sleep` is
/// `std::thread::sleep`, slot waits are plain condvar waits. Actor
/// registration is a no-op.
#[derive(Clone, Copy, Debug, Default)]
pub struct WallClock;

impl Clock for WallClock {
    fn now(&self) -> Tick {
        ticks(wall_epoch().elapsed())
    }

    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }

    fn new_slot(&self) -> Arc<WaitSlot> {
        Arc::new(WaitSlot::with_id(0))
    }

    fn wait_slot(&self, slot: &WaitSlot, observed_gen: u64, timeout: Duration) {
        // Cap so `Instant + timeout` cannot overflow on absurd timeouts.
        let timeout = timeout.min(Duration::from_secs(365 * 24 * 3600));
        let deadline = Instant::now() + timeout;
        let mut guard = slot.locked();
        while slot.generation() == observed_gen {
            let now = Instant::now();
            if now >= deadline {
                return;
            }
            guard = match slot.cv.wait_timeout(guard, deadline - now) {
                Ok((g, _)) => g,
                Err(poisoned) => poisoned.into_inner().0,
            };
        }
    }

    fn notify_slot(&self, slot: &WaitSlot) {
        slot.gen.fetch_add(1, Ordering::SeqCst);
        // Serialize with a waiter between its generation check and its
        // condvar wait: taking the slot mutex here means the notify cannot
        // fall into that window unseen.
        let guard = slot.locked();
        slot.cv.notify_all();
        drop(guard);
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ActorState {
    /// Runnable; waiting to be picked by the scheduler.
    Ready,
    /// The single currently-running actor.
    Running,
    /// Blocked until `deadline` or a notify on `slot`.
    Parked { deadline: Tick, slot: Option<u64> },
    /// Out of the scheduling set (blocked outside the clock, e.g. join).
    Suspended,
}

#[derive(Debug)]
struct Actor {
    name: String,
    state: ActorState,
}

#[derive(Debug)]
struct Sched {
    now: Tick,
    next_actor: ActorId,
    next_slot: u64,
    running: Option<ActorId>,
    /// BTreeMap so scheduling scans are in deterministic id order.
    actors: BTreeMap<ActorId, Actor>,
    threads: HashMap<ThreadId, ActorId>,
}

/// Deterministic discrete-event simulation time.
///
/// Exactly one registered actor runs at a time; the rest block inside the
/// clock. When the running actor parks, the scheduler picks the lowest-id
/// Ready actor, else advances `now` to the earliest parked
/// `(deadline, id)` and runs that actor. Notifies flip parked waiters to
/// Ready without advancing time. Because every scheduling decision is a
/// pure function of (actor ids, deadlines, notify order), a run whose
/// stochastic inputs are seeded is bit-for-bit reproducible.
#[derive(Debug)]
pub struct VirtualClock {
    sched: Mutex<Sched>,
    cv: Condvar,
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl VirtualClock {
    /// A fresh simulation clock at tick 0 with no actors.
    pub fn new() -> Self {
        VirtualClock {
            sched: Mutex::new(Sched {
                now: 0,
                next_actor: 1,
                next_slot: 1,
                running: None,
                actors: BTreeMap::new(),
                threads: HashMap::new(),
            }),
            cv: Condvar::new(),
        }
    }

    fn locked(&self) -> MutexGuard<'_, Sched> {
        match self.sched.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Pick the next actor to run (requires `running == None`). Ready
    /// actors win in id order; otherwise time advances to the earliest
    /// parked `(deadline, id)`. Panics when every actor is parked without
    /// a finite deadline — a genuine deadlock in the simulated system.
    fn schedule(sched: &mut Sched) {
        if sched.running.is_some() {
            return;
        }
        let ready = sched
            .actors
            .iter()
            .find(|(_, a)| a.state == ActorState::Ready)
            .map(|(&id, _)| id);
        if let Some(id) = ready {
            if let Some(a) = sched.actors.get_mut(&id) {
                a.state = ActorState::Running;
            }
            sched.running = Some(id);
            return;
        }
        let mut best: Option<(Tick, ActorId)> = None;
        for (&id, a) in &sched.actors {
            if let ActorState::Parked { deadline, .. } = a.state {
                let better = match best {
                    None => true,
                    Some(b) => (deadline, id) < b,
                };
                if better {
                    best = Some((deadline, id));
                }
            }
        }
        if let Some((deadline, id)) = best {
            assert!(
                deadline != Tick::MAX,
                "virtual clock deadlock: every actor is parked without a finite deadline: {:?}",
                sched.actors.values().map(|a| a.name.clone()).collect::<Vec<_>>()
            );
            if deadline > sched.now {
                sched.now = deadline;
            }
            if let Some(a) = sched.actors.get_mut(&id) {
                a.state = ActorState::Running;
            }
            sched.running = Some(id);
        }
        // All suspended (or none left): the next resume/attach reschedules.
    }

    fn current(sched: &Sched) -> Option<ActorId> {
        sched.threads.get(&std::thread::current().id()).copied()
    }

    fn current_or_panic(sched: &Sched, op: &str) -> ActorId {
        match Self::current(sched) {
            Some(id) => id,
            None => panic!(
                "VirtualClock::{op} from a thread that is not a registered actor; \
                 enter the clock first (clock::ActorScope::enter)"
            ),
        }
    }

    /// Park the current actor with the given state, hand the CPU to the
    /// scheduler, and block until this actor is Running again.
    fn park_and_wait(&self, mut guard: MutexGuard<'_, Sched>, id: ActorId, state: ActorState) {
        if let Some(a) = guard.actors.get_mut(&id) {
            a.state = state;
        }
        if guard.running == Some(id) {
            guard.running = None;
        }
        Self::schedule(&mut guard);
        self.cv.notify_all();
        self.block_until_running(guard, id);
    }

    fn block_until_running(&self, mut guard: MutexGuard<'_, Sched>, id: ActorId) {
        loop {
            if guard.actors.get(&id).map(|a| a.state) == Some(ActorState::Running) {
                return;
            }
            guard = match self.cv.wait(guard) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Tick {
        self.locked().now
    }

    fn sleep(&self, d: Duration) {
        let guard = self.locked();
        let id = Self::current_or_panic(&guard, "sleep");
        let deadline = guard.now.saturating_add(ticks(d));
        self.park_and_wait(guard, id, ActorState::Parked { deadline, slot: None });
    }

    fn new_slot(&self) -> Arc<WaitSlot> {
        let mut guard = self.locked();
        let id = guard.next_slot;
        guard.next_slot += 1;
        Arc::new(WaitSlot::with_id(id))
    }

    fn wait_slot(&self, slot: &WaitSlot, observed_gen: u64, timeout: Duration) {
        let guard = self.locked();
        // Generation moves only under the scheduler lock (notify_slot), so
        // this check cannot race a notify.
        if slot.generation() != observed_gen {
            return;
        }
        let id = Self::current_or_panic(&guard, "wait_slot");
        let deadline = guard.now.saturating_add(ticks(timeout));
        self.park_and_wait(guard, id, ActorState::Parked { deadline, slot: Some(slot.id) });
    }

    fn notify_slot(&self, slot: &WaitSlot) {
        let mut guard = self.locked();
        slot.gen.fetch_add(1, Ordering::SeqCst);
        for a in guard.actors.values_mut() {
            if let ActorState::Parked { slot: Some(sid), .. } = a.state {
                if sid == slot.id {
                    a.state = ActorState::Ready;
                }
            }
        }
        // The notifier normally keeps running; schedule only when no actor
        // holds the CPU (a notify from a suspended/unregistered thread).
        if guard.running.is_none() {
            Self::schedule(&mut guard);
            self.cv.notify_all();
        }
    }

    fn register_actor(&self, name: &str) -> ActorId {
        let mut guard = self.locked();
        let id = guard.next_actor;
        guard.next_actor += 1;
        // Program-order allocation: every id is strictly greater than all
        // ids already handed out, even when registrations from the driving
        // thread interleave with attaches/detaches of earlier actors.
        // Golden ordering (and sequential/parallel equivalence) depends on
        // this, so assert it rather than documenting it.
        debug_assert!(
            guard.actors.last_key_value().map_or(true, |(&last, _)| id > last),
            "actor id {id} not in program order"
        );
        guard.actors.insert(id, Actor { name: name.to_string(), state: ActorState::Ready });
        id
    }

    fn attach_actor(&self, id: ActorId) {
        let mut guard = self.locked();
        guard.threads.insert(std::thread::current().id(), id);
        if guard.running.is_none() {
            Self::schedule(&mut guard);
            self.cv.notify_all();
        }
        self.block_until_running(guard, id);
    }

    fn detach_actor(&self, id: ActorId) {
        let mut guard = self.locked();
        guard.actors.remove(&id);
        guard.threads.retain(|_, v| *v != id);
        if guard.running == Some(id) {
            guard.running = None;
            Self::schedule(&mut guard);
            self.cv.notify_all();
        }
    }

    fn suspend_current(&self) {
        let mut guard = self.locked();
        let Some(id) = Self::current(&guard) else { return };
        if let Some(a) = guard.actors.get_mut(&id) {
            a.state = ActorState::Suspended;
        }
        if guard.running == Some(id) {
            guard.running = None;
        }
        Self::schedule(&mut guard);
        self.cv.notify_all();
        // Deliberately do not block: the caller is about to wait on
        // something outside the clock (thread joins) while the remaining
        // actors drain.
    }

    fn resume_current(&self) {
        let guard = self.locked();
        let Some(id) = Self::current(&guard) else { return };
        self.park_and_wait(guard, id, ActorState::Ready);
    }

    fn current_is_actor(&self) -> bool {
        Self::current(&self.locked()).is_some()
    }

    fn is_virtual(&self) -> bool {
        true
    }
}

/// RAII actor registration: detaches (and removes) the actor on drop so a
/// worker that returns early never wedges the scheduler.
pub struct ActorScope {
    clock: Arc<dyn Clock>,
    id: ActorId,
}

impl ActorScope {
    /// Register the calling thread as a new actor and enter scheduling.
    /// Call once on the driving thread before starting a fleet under
    /// [`VirtualClock`]; a no-op scope under [`WallClock`].
    pub fn enter(clock: &Arc<dyn Clock>, name: &str) -> ActorScope {
        let id = clock.register_actor(name);
        ActorScope::attach(clock, id)
    }

    /// Bind the calling thread to an actor pre-registered (in
    /// deterministic order) by the spawning thread.
    pub fn attach(clock: &Arc<dyn Clock>, id: ActorId) -> ActorScope {
        clock.attach_actor(id);
        ActorScope { clock: clock.clone(), id }
    }

    /// The bound actor id.
    pub fn id(&self) -> ActorId {
        self.id
    }
}

impl Drop for ActorScope {
    fn drop(&mut self) {
        self.clock.detach_actor(self.id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_index_maps_ticks_to_cc_epochs() {
        let epoch = Duration::from_millis(50);
        assert_eq!(epoch_index(0, epoch), 0);
        assert_eq!(epoch_index(ticks(epoch) - 1, epoch), 0);
        assert_eq!(epoch_index(ticks(epoch), epoch), 1);
        assert_eq!(epoch_index(ticks(epoch) * 7 + 1, epoch), 7);
        // Degenerate epoch lengths stay defined (clamped to 1 ns).
        assert_eq!(epoch_index(5, Duration::ZERO), 5);
    }

    #[test]
    fn wall_now_is_monotonic_and_shared() {
        let a = WallClock;
        let b = WallClock;
        let t0 = a.now();
        std::thread::sleep(Duration::from_millis(2));
        let t1 = b.now();
        assert!(t1 > t0, "epoch must be shared across instances");
    }

    #[test]
    fn wall_wait_slot_times_out_and_wakes_on_notify() {
        let c = WallClock;
        let slot = c.new_slot();
        // Stale generation: returns immediately.
        let t0 = Instant::now();
        c.wait_slot(&slot, slot.generation().wrapping_sub(1), Duration::from_secs(5));
        assert!(t0.elapsed() < Duration::from_millis(200));
        // Timeout path.
        let t0 = Instant::now();
        c.wait_slot(&slot, slot.generation(), Duration::from_millis(20));
        assert!(t0.elapsed() >= Duration::from_millis(15));
        // Notify path.
        let slot2 = slot.clone();
        let gen = slot.generation();
        // detlint: allow(thread-spawn) -- wall-clock test; no simulated time
        let h = std::thread::spawn(move || {
            let t0 = Instant::now();
            WallClock.wait_slot(&slot2, gen, Duration::from_secs(10));
            t0.elapsed()
        });
        std::thread::sleep(Duration::from_millis(30));
        c.notify_slot(&slot);
        assert!(h.join().unwrap() < Duration::from_secs(5));
    }

    #[test]
    fn virtual_sleep_advances_time_deterministically() {
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let _me = ActorScope::enter(&clock, "main");
        assert_eq!(clock.now(), 0);
        clock.sleep(Duration::from_millis(30));
        assert_eq!(clock.now(), ticks(Duration::from_millis(30)));
        clock.sleep(Duration::from_micros(1500));
        assert_eq!(clock.now(), ticks(Duration::from_micros(31_500)));
    }

    #[test]
    fn virtual_two_actors_interleave_by_deadline() {
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let _me = ActorScope::enter(&clock, "main");
        let id = clock.register_actor("child");
        let c2 = clock.clone();
        // detlint: allow(thread-spawn) -- actor pre-registered above; the
        // thread attaches before touching simulated time
        let child = std::thread::spawn(move || {
            let _scope = ActorScope::attach(&c2, id);
            let mut ticks_seen = Vec::new();
            for _ in 0..3 {
                c2.sleep(Duration::from_millis(10));
                ticks_seen.push(c2.now());
            }
            ticks_seen
        });
        // Main sleeps past all three child wakeups; the child must have
        // observed exactly 10/20/30 ms.
        clock.sleep(Duration::from_millis(100));
        clock.suspend_current();
        let seen = child.join().unwrap();
        clock.resume_current();
        let ms = |m: u64| ticks(Duration::from_millis(m));
        assert_eq!(seen, vec![ms(10), ms(20), ms(30)]);
        assert_eq!(clock.now(), ms(100));
    }

    #[test]
    fn virtual_notify_wakes_slot_waiter_before_deadline() {
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let _me = ActorScope::enter(&clock, "main");
        let slot = clock.new_slot();
        let id = clock.register_actor("waiter");
        let c2 = clock.clone();
        let s2 = slot.clone();
        // detlint: allow(thread-spawn) -- actor pre-registered above; the
        // thread attaches before touching simulated time
        let h = std::thread::spawn(move || {
            let _scope = ActorScope::attach(&c2, id);
            let gen = s2.generation();
            c2.wait_slot(&s2, gen, Duration::from_secs(60));
            c2.now()
        });
        clock.sleep(Duration::from_millis(25));
        clock.notify_slot(&slot);
        clock.suspend_current();
        let woke_at = h.join().unwrap();
        clock.resume_current();
        assert_eq!(woke_at, ticks(Duration::from_millis(25)), "notify, not timeout, must wake");
    }

    #[test]
    fn virtual_stale_generation_returns_without_parking() {
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let _me = ActorScope::enter(&clock, "main");
        let slot = clock.new_slot();
        let gen = slot.generation();
        clock.notify_slot(&slot);
        // The notify above advanced the generation, so this must not park
        // (parking alone would deadlock: no other actor exists).
        clock.wait_slot(&slot, gen, Duration::from_secs(60));
        assert_eq!(clock.now(), 0);
    }

    #[test]
    fn virtual_ready_ties_resolve_by_actor_id() {
        // Two actors parked to the same deadline run in id order.
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let _me = ActorScope::enter(&clock, "main");
        let order = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for tag in ["a", "b"] {
            let id = clock.register_actor(tag);
            let c2 = clock.clone();
            let ord = order.clone();
            let tag = tag.to_string();
            // detlint: allow(thread-spawn) -- actor pre-registered above;
            // the thread attaches before touching simulated time
            handles.push(std::thread::spawn(move || {
                let _scope = ActorScope::attach(&c2, id);
                c2.sleep(Duration::from_millis(5));
                ord.lock().unwrap().push(tag);
            }));
        }
        clock.sleep(Duration::from_millis(50));
        clock.suspend_current();
        for h in handles {
            h.join().unwrap();
        }
        clock.resume_current();
        assert_eq!(*order.lock().unwrap(), vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn actor_ids_allocate_in_program_order_across_engines_and_domains() {
        // The documented contract: ids are handed out strictly in call
        // order on the registering thread, even when plain registrations
        // interleave with parallel-mode (domain-tagged) registrations and
        // with attach/detach churn of earlier actors. Golden ordering in
        // both engines depends on it.
        let clocks: [Arc<dyn Clock>; 2] =
            [Arc::new(VirtualClock::new()), Arc::new(ParallelVirtualClock::with_workers(2))];
        for clock in clocks {
            // The driver enters first (the coordinator invariant: all
            // registration happens while the driving actor runs).
            let me = ActorScope::enter(&clock, "main");
            let a = clock.register_actor("a");
            let b = clock.register_actor_in("b", 3);
            // Churn: an attach of an earlier actor between allocations
            // (it blocks until the driver parks, like a spawned worker).
            let c2 = clock.clone();
            let bh = {
                // detlint: allow(thread-spawn) -- actor pre-registered
                // above; the thread attaches before touching simulated time
                std::thread::spawn(move || {
                    let _scope = ActorScope::attach(&c2, b);
                })
            };
            let c = clock.register_actor_in("c", 1);
            let d = clock.register_actor("d");
            assert!(
                me.id() < a && a < b && b < c && c < d,
                "ids must be strictly increasing: {} {a} {b} {c} {d}",
                me.id()
            );
            // Registered-but-never-attached actors would wedge the drain
            // below once the scheduler picks them; retire them first.
            for id in [a, c, d] {
                clock.detach_actor(id);
            }
            clock.suspend_current();
            bh.join().unwrap();
            clock.resume_current();
            let e = clock.register_actor("e");
            assert!(e > d, "attach/detach churn must not recycle ids");
            clock.detach_actor(e);
        }
    }
}
