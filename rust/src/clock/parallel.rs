//! Conservative parallel discrete-event engine (DESIGN.md S24).
//!
//! [`ParallelVirtualClock`] is the throughput twin of the deliberately
//! sequential [`VirtualClock`](super::VirtualClock): actors are
//! partitioned into *advance-domains* at registration
//! ([`Clock::register_actor_in`]), and actors from **different** domains
//! may hold the CPU simultaneously, so a thousand-group fleet sweep uses
//! every core instead of one. Replays stay bitwise-identical to the
//! sequential engine because the scheduler is *conservative*: it only
//! runs an event concurrently when no earlier event anywhere in the
//! system could possibly affect it.
//!
//! # Domain partition rule
//!
//! Domain 0 is the **control domain**: the scenario driver, every node
//! CC, and any actor registered through plain
//! [`Clock::register_actor`]. Domains `d > 0` hold worker pools whose
//! actors touch only domain-local state (their group's shards, counters,
//! histogram) plus commuting shared atomics. The coordinator maps group
//! `gi`'s workers — across all nodes — to domain `gi + 1`
//! (`coordinator::node::spawn_worker`). The soundness obligation on
//! callers: **all cross-domain interaction originates from domain 0**
//! (submits, gating, drains, slot notifies), which in this codebase is
//! an audited structural property — workers never notify a slot and
//! never read another group's order-sensitive state.
//!
//! # Barrier protocol
//!
//! Each domain has its own virtual time `now[d]`, the stamp of its last
//! grant. Scheduling is a fence against the control domain's next event
//! `E0` (its lowest-id Ready actor, else its earliest parked
//! `(deadline, id)`):
//!
//! * a worker-domain candidate runs concurrently (up to the configured
//!   worker cap) while the *sequential* scheduler would run it before
//!   `E0` — Ready candidates beat any parked `E0`; parked candidates
//!   need `(deadline, id) < (deadline0, id0)` lexicographically;
//! * when no worker candidate may start and nothing is running, the
//!   control candidate is granted **exclusively** (an epoch barrier):
//!   every event ordered before it has fully executed, so the control
//!   actor observes exactly the sequential prefix;
//! * cross-domain wakeups raised by non-control actors are deferred and
//!   merged at the next barrier in `(deadline, actor id)` order (inert
//!   for the coordinator workload, where only control notifies across
//!   domains, but it keeps the engine safe for arbitrary actor graphs).
//!
//! # Equivalence sketch
//!
//! Project the sequential schedule onto one domain: because domains
//! interact only through control-originated events, the projection is
//! itself the domain's local sequential schedule, and a domain actor's
//! `now()` reads equal its own last grant stamp. The fence grants a
//! worker event only when every sequentially-earlier event has run, and
//! grants control events exclusively, so each domain executes exactly
//! its projection and control observes exactly the sequential global
//! state at every barrier — traces are byte-identical, which
//! `tests/sim_parallel.rs` asserts over every scenario × policy × node
//! count, and a randomized property in `tests/sim_properties.rs`
//! shrinks any counterexample. The worker cap only throttles real
//! concurrency (grantable sets commute); with a cap of 1 the engine
//! degenerates to the exact sequential event order.

use std::collections::BTreeMap;
// detlint: allow(hash-collection) -- `threads` maps ThreadId -> ActorId for
// lookup only (same contract as VirtualClock); scheduling scans iterate
// `actors` (a BTreeMap), never this.
use std::collections::HashMap;
use std::thread::ThreadId;
use std::time::Duration;

use crate::sync::atomic::Ordering;
use crate::sync::{Arc, Condvar, Mutex, MutexGuard};

use super::{ticks, ActorId, Clock, Tick, WaitSlot};

/// Scheduling state of one parallel-clock actor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum PState {
    /// Runnable; `at` is the virtual time of the event that made it so
    /// (its wake deadline, or the notifier's clock), the stamp its
    /// domain time advances to on grant.
    Ready { at: Tick },
    /// Holding the CPU (at most one per domain).
    Running,
    /// Blocked until `deadline` or a notify on `slot`.
    Parked { deadline: Tick, slot: Option<u64> },
    /// Out of the scheduling set (blocked outside the clock).
    Suspended,
}

#[derive(Debug)]
struct PActor {
    name: String,
    domain: usize,
    state: PState,
    /// Per-actor condvar (all bound to the one scheduler mutex): a grant
    /// wakes exactly its target instead of `notify_all`-ing a
    /// thousand-actor herd on every scheduling step.
    cv: Arc<Condvar>,
}

/// A domain's next event under the sequential rule: its lowest-id Ready
/// actor, else its earliest `(deadline, id)` parked actor.
#[derive(Clone, Copy, Debug)]
struct Cand {
    id: ActorId,
    time: Tick,
    ready: bool,
}

/// A cross-domain wakeup raised by a non-control actor, parked until the
/// next barrier (see module docs — the deterministic merge rule).
#[derive(Clone, Copy, Debug)]
struct DeferredWake {
    at: Tick,
    slot: u64,
}

#[derive(Debug)]
struct PSched {
    /// Domain-local virtual time: stamp of the domain's last grant.
    now: Vec<Tick>,
    /// Whether the domain currently has a Running actor.
    busy: Vec<bool>,
    next_actor: ActorId,
    next_slot: u64,
    /// Total Running actors (all domains).
    n_running: usize,
    /// BTreeMap so candidate scans are in deterministic id order.
    actors: BTreeMap<ActorId, PActor>,
    threads: HashMap<ThreadId, ActorId>,
    deferred: Vec<DeferredWake>,
}

/// Deterministic discrete-event time with conservative domain-parallel
/// execution. Drop-in for [`VirtualClock`](super::VirtualClock) — same
/// actor protocol, same traces (see the module docs for the equivalence
/// argument) — but actors registered into distinct domains via
/// [`Clock::register_actor_in`] run concurrently between control-domain
/// barriers.
#[derive(Debug)]
pub struct ParallelVirtualClock {
    sched: Mutex<PSched>,
    /// Cap on concurrently Running worker-domain actors. Purely a
    /// throughput knob: grantable sets commute, so the cap (and the
    /// machine's core count) never changes a trace.
    workers: usize,
}

impl Default for ParallelVirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl ParallelVirtualClock {
    /// A fresh parallel simulation clock at tick 0 with no actors, with
    /// the worker cap matching the machine's available parallelism.
    pub fn new() -> Self {
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8);
        Self::with_workers(workers)
    }

    /// A fresh clock capping concurrently-running worker actors at
    /// `workers` (clamped to ≥ 1). `with_workers(1)` executes the exact
    /// sequential event order — useful for bisecting a suspected
    /// equivalence break.
    pub fn with_workers(workers: usize) -> Self {
        ParallelVirtualClock {
            sched: Mutex::new(PSched {
                now: vec![0],
                busy: vec![false],
                next_actor: 1,
                next_slot: 1,
                n_running: 0,
                actors: BTreeMap::new(),
                threads: HashMap::new(),
                deferred: Vec::new(),
            }),
            workers: workers.max(1),
        }
    }

    /// The configured worker cap.
    pub fn worker_cap(&self) -> usize {
        self.workers
    }

    fn locked(&self) -> MutexGuard<'_, PSched> {
        match self.sched.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    fn current(sched: &PSched) -> Option<ActorId> {
        sched.threads.get(&std::thread::current().id()).copied()
    }

    fn current_or_panic(sched: &PSched, op: &str) -> ActorId {
        match Self::current(sched) {
            Some(id) => id,
            None => panic!(
                "ParallelVirtualClock::{op} from a thread that is not a registered actor; \
                 enter the clock first (clock::ActorScope::enter)"
            ),
        }
    }

    /// The sequential-rule candidate of `domain`: lowest-id Ready actor,
    /// else earliest `(deadline, id)` parked actor.
    fn domain_candidate(sched: &PSched, domain: usize) -> Option<Cand> {
        let mut best: Option<(Tick, ActorId)> = None;
        for (&id, a) in sched.actors.iter().filter(|(_, a)| a.domain == domain) {
            match a.state {
                // BTreeMap iteration is id-ascending, so the first Ready
                // actor seen is the lowest-id one — and Ready beats any
                // parked deadline under the sequential rule.
                PState::Ready { at } => return Some(Cand { id, time: at, ready: true }),
                PState::Parked { deadline, .. } => {
                    if best.map_or(true, |b| (deadline, id) < b) {
                        best = Some((deadline, id));
                    }
                }
                _ => {}
            }
        }
        best.map(|(time, id)| Cand { id, time, ready: false })
    }

    /// Would the sequential scheduler run worker candidate `w` before the
    /// control domain's next event `c0`? (The conservative fence.)
    fn fence_allows(c0: Option<&Cand>, w: &Cand) -> bool {
        match c0 {
            // No pending control event: the worker event has no earlier
            // cross-domain cause left to wait for.
            None => true,
            // Ready-vs-Ready resolves by id; a parked worker never
            // overtakes a Ready control actor.
            Some(c) if c.ready => w.ready && w.id < c.id,
            // Ready beats parked; parked-vs-parked is (deadline, id).
            Some(c) => w.ready || (w.time, w.id) < (c.time, c.id),
        }
    }

    /// Move `id` to Running, advance its domain clock to the grant stamp,
    /// and wake its thread.
    fn grant(sched: &mut PSched, id: ActorId) {
        let Some(a) = sched.actors.get_mut(&id) else { return };
        let at = match a.state {
            PState::Ready { at } => at,
            PState::Parked { deadline, .. } => deadline,
            // Running/Suspended actors are never selected as candidates.
            _ => return,
        };
        a.state = PState::Running;
        let domain = a.domain;
        let cv = a.cv.clone();
        if at > sched.now[domain] {
            sched.now[domain] = at;
        }
        sched.busy[domain] = true;
        sched.n_running += 1;
        cv.notify_all();
    }

    /// Apply the deferred cross-domain wakeups in deterministic
    /// `(deadline, actor id)` merge order. A wake flips only actors still
    /// parked on the slot, so when several wakes target one actor the
    /// earliest stamp wins — independent of raise order.
    fn apply_deferred(sched: &mut PSched) {
        let mut pending = std::mem::take(&mut sched.deferred);
        pending.sort_by_key(|w| (w.at, w.slot));
        for w in pending {
            for a in sched.actors.values_mut() {
                if let PState::Parked { slot: Some(sid), .. } = a.state {
                    if sid == w.slot {
                        a.state = PState::Ready { at: w.at };
                    }
                }
            }
        }
    }

    /// The scheduler: grant every worker-domain candidate the fence
    /// admits (up to the worker cap), and when the system quiesces with
    /// nothing admissible, grant the control candidate exclusively — the
    /// barrier. Panics on a genuine simulated deadlock, mirroring
    /// [`VirtualClock`](super::VirtualClock)'s contract.
    fn dispatch(&self, sched: &mut PSched) {
        // A running control actor IS the fence: its whole step happens
        // before anything sequenced after it may start.
        if sched.busy[0] {
            return;
        }
        loop {
            let c0 = Self::domain_candidate(sched, 0);
            let mut grantable: Vec<Cand> = Vec::new();
            for d in 1..sched.now.len() {
                if sched.busy[d] {
                    continue;
                }
                if let Some(w) = Self::domain_candidate(sched, d) {
                    // An infinite park is never a grant; it either waits
                    // out the fence or participates in deadlock below.
                    if (w.ready || w.time != Tick::MAX) && Self::fence_allows(c0.as_ref(), &w)
                    {
                        grantable.push(w);
                    }
                }
            }
            // Deterministic grant order: earliest (time, id) first. Order
            // among concurrent grants is trace-neutral (distinct domains
            // commute); sorting just makes the cap bite predictably.
            grantable.sort_by_key(|c| (c.time, c.id));
            let mut granted = false;
            for w in grantable {
                if sched.n_running >= self.workers {
                    break;
                }
                Self::grant(sched, w.id);
                granted = true;
            }
            if granted || sched.n_running > 0 {
                return;
            }
            // Quiesced and nothing admitted ahead of the fence. Merge any
            // deferred cross-domain wakeups first — they may produce a
            // Ready actor that the sequential rule runs before c0.
            if !sched.deferred.is_empty() {
                Self::apply_deferred(sched);
                continue;
            }
            match c0 {
                Some(c) if c.ready || c.time != Tick::MAX => {
                    Self::grant(sched, c.id);
                }
                _ => {
                    // No control event and no admissible worker: actors
                    // parked without a finite deadline are a genuine
                    // deadlock; an empty/suspended-only registry is the
                    // quiescent state (next attach/resume reschedules).
                    let stuck: Vec<&str> = sched
                        .actors
                        .values()
                        .filter(|a| matches!(a.state, PState::Parked { .. } | PState::Ready { .. }))
                        .map(|a| a.name.as_str())
                        .collect();
                    assert!(
                        stuck.is_empty(),
                        "virtual clock deadlock: every actor is parked without a finite \
                         deadline: {stuck:?}"
                    );
                }
            }
            return;
        }
    }

    /// Park the current actor with `state`, hand the CPU back to the
    /// scheduler, and block until this actor is Running again.
    fn park_and_wait(&self, mut guard: MutexGuard<'_, PSched>, id: ActorId, state: PState) {
        let Some(a) = guard.actors.get_mut(&id) else { return };
        let was_running = a.state == PState::Running;
        let domain = a.domain;
        let cv = a.cv.clone();
        a.state = state;
        if was_running {
            guard.busy[domain] = false;
            guard.n_running -= 1;
        }
        self.dispatch(&mut guard);
        self.block_until_running(guard, id, &cv);
    }

    fn block_until_running(&self, mut guard: MutexGuard<'_, PSched>, id: ActorId, cv: &Condvar) {
        loop {
            match guard.actors.get(&id).map(|a| a.state) {
                Some(PState::Running) => return,
                // Removed while blocked (a shutdown racing a barrier):
                // unblock rather than wait on a condvar nobody signals.
                None => return,
                _ => {}
            }
            guard = match cv.wait(guard) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
    }

    /// The running actor's domain and local time; for a non-actor (or
    /// suspended) caller, the global quiesce view `max(now[d])` — what
    /// the sequential global clock reads once every domain has advanced.
    fn observed_now(sched: &PSched) -> Tick {
        match Self::current(sched).and_then(|id| sched.actors.get(&id)) {
            Some(a) if a.state == PState::Running => sched.now[a.domain],
            _ => sched.now.iter().copied().max().unwrap_or(0),
        }
    }
}

impl Clock for ParallelVirtualClock {
    fn now(&self) -> Tick {
        Self::observed_now(&self.locked())
    }

    fn sleep(&self, d: Duration) {
        let guard = self.locked();
        let id = Self::current_or_panic(&guard, "sleep");
        let Some(a) = guard.actors.get(&id) else { return };
        let deadline = guard.now[a.domain].saturating_add(ticks(d));
        self.park_and_wait(guard, id, PState::Parked { deadline, slot: None });
    }

    fn new_slot(&self) -> Arc<WaitSlot> {
        let mut guard = self.locked();
        let id = guard.next_slot;
        guard.next_slot += 1;
        Arc::new(WaitSlot::with_id(id))
    }

    fn wait_slot(&self, slot: &WaitSlot, observed_gen: u64, timeout: Duration) {
        let guard = self.locked();
        // Generations move only under the scheduler lock (notify_slot),
        // so this check cannot race a notify.
        if slot.generation() != observed_gen {
            return;
        }
        let id = Self::current_or_panic(&guard, "wait_slot");
        let Some(a) = guard.actors.get(&id) else { return };
        let deadline = guard.now[a.domain].saturating_add(ticks(timeout));
        self.park_and_wait(guard, id, PState::Parked { deadline, slot: Some(slot.id) });
    }

    fn notify_slot(&self, slot: &WaitSlot) {
        let mut guard = self.locked();
        slot.gen.fetch_add(1, Ordering::SeqCst);
        let notifier = Self::current(&guard)
            .and_then(|id| guard.actors.get(&id))
            .filter(|a| a.state == PState::Running)
            .map(|a| a.domain);
        let at = match notifier {
            Some(d) => guard.now[d],
            // External (unregistered/suspended) notifier: behaves like
            // control at the global quiesce time.
            None => guard.now.iter().copied().max().unwrap_or(0),
        };
        match notifier {
            Some(d) if d != 0 => {
                // A worker-domain notifier wakes same-domain waiters
                // immediately (domain-local order is sequential anyway);
                // cross-domain waiters are deferred to the next barrier
                // and merged in (deadline, id) order — see module docs.
                let mut cross = false;
                for a in guard.actors.values_mut() {
                    if let PState::Parked { slot: Some(sid), .. } = a.state {
                        if sid == slot.id {
                            if a.domain == d {
                                a.state = PState::Ready { at };
                            } else {
                                cross = true;
                            }
                        }
                    }
                }
                if cross {
                    guard.deferred.push(DeferredWake { at, slot: slot.id });
                }
            }
            _ => {
                // Control (or external) notifier runs at the fence, where
                // every worker event before `at` has executed: flip every
                // waiter Ready at the notifier's clock, exactly the
                // sequential semantics.
                for a in guard.actors.values_mut() {
                    if let PState::Parked { slot: Some(sid), .. } = a.state {
                        if sid == slot.id {
                            a.state = PState::Ready { at };
                        }
                    }
                }
            }
        }
        // The notifier normally keeps running; dispatch only when no
        // actor holds a CPU (a notify from outside the actor set).
        if guard.n_running == 0 {
            self.dispatch(&mut guard);
        }
    }

    fn register_actor(&self, name: &str) -> ActorId {
        self.register_actor_in(name, 0)
    }

    fn register_actor_in(&self, name: &str, domain: usize) -> ActorId {
        let mut guard = self.locked();
        let id = guard.next_actor;
        guard.next_actor += 1;
        // Ids must be handed out in program order on the registering
        // thread — golden ordering depends on it (see the Clock docs).
        debug_assert!(
            guard.actors.last_key_value().map_or(true, |(&last, _)| id > last),
            "actor id {id} not in program order"
        );
        while guard.now.len() <= domain {
            guard.now.push(0);
            guard.busy.push(false);
        }
        // A new actor first runs at its registrar's clock (the driver
        // registers the whole fleet before starting it, so in practice
        // this is tick 0) — same stamp the sequential engine would grant.
        let at = Self::observed_now(&guard);
        guard.actors.insert(
            id,
            PActor {
                name: name.to_string(),
                domain,
                state: PState::Ready { at },
                cv: Arc::new(Condvar::new()),
            },
        );
        id
    }

    fn attach_actor(&self, id: ActorId) {
        let mut guard = self.locked();
        guard.threads.insert(std::thread::current().id(), id);
        let cv = guard.actors.get(&id).map(|a| a.cv.clone());
        self.dispatch(&mut guard);
        if let Some(cv) = cv {
            self.block_until_running(guard, id, &cv);
        }
    }

    fn detach_actor(&self, id: ActorId) {
        let mut guard = self.locked();
        if let Some(a) = guard.actors.remove(&id) {
            if a.state == PState::Running {
                guard.busy[a.domain] = false;
                guard.n_running -= 1;
            }
            // Unblock anyone waiting to observe this actor's state (a
            // joiner racing the exit sees the None arm above).
            a.cv.notify_all();
        }
        guard.threads.retain(|_, v| *v != id);
        self.dispatch(&mut guard);
    }

    fn suspend_current(&self) {
        let mut guard = self.locked();
        let Some(id) = Self::current(&guard) else { return };
        if let Some(a) = guard.actors.get_mut(&id) {
            let was_running = a.state == PState::Running;
            let domain = a.domain;
            a.state = PState::Suspended;
            if was_running {
                guard.busy[domain] = false;
                guard.n_running -= 1;
            }
        }
        self.dispatch(&mut guard);
        // Deliberately no block: the caller is about to wait on something
        // outside the clock (thread joins) while the rest drains.
    }

    fn resume_current(&self) {
        let guard = self.locked();
        let Some(id) = Self::current(&guard) else { return };
        // Re-enter at the global quiesce time: every domain the suspended
        // actor waited out (joins) has advanced past its last event.
        let at = guard.now.iter().copied().max().unwrap_or(0);
        self.park_and_wait(guard, id, PState::Ready { at });
    }

    fn current_is_actor(&self) -> bool {
        Self::current(&self.locked()).is_some()
    }

    fn is_virtual(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::super::ActorScope;
    use super::*;

    fn clock(workers: usize) -> Arc<dyn Clock> {
        Arc::new(ParallelVirtualClock::with_workers(workers))
    }

    /// Register `name` into `domain` and run `body` on a new actor
    /// thread. The spawn is the sanctioned pre-registered pattern.
    fn actor<T: Send + 'static>(
        c: &Arc<dyn Clock>,
        name: &str,
        domain: usize,
        body: impl FnOnce(Arc<dyn Clock>) -> T + Send + 'static,
    ) -> std::thread::JoinHandle<T> {
        let id = c.register_actor_in(name, domain);
        let c = c.clone();
        // detlint: allow(thread-spawn) -- actor pre-registered above; the
        // thread attaches before touching simulated time
        std::thread::spawn(move || {
            let _scope = ActorScope::attach(&c, id);
            body(c.clone())
        })
    }

    #[test]
    fn sleep_advances_domain_time_deterministically() {
        let c = clock(4);
        let _me = ActorScope::enter(&c, "main");
        assert_eq!(c.now(), 0);
        c.sleep(Duration::from_millis(30));
        assert_eq!(c.now(), ticks(Duration::from_millis(30)));
    }

    #[test]
    fn worker_domains_advance_between_control_barriers() {
        for workers in [1, 4] {
            let c = clock(workers);
            let _me = ActorScope::enter(&c, "main");
            let ms = |m: u64| ticks(Duration::from_millis(m));
            let mut handles = Vec::new();
            for (i, tag) in ["a", "b", "c"].iter().enumerate() {
                handles.push(actor(&c, tag, i + 1, |c| {
                    let mut seen = Vec::new();
                    for _ in 0..3 {
                        c.sleep(Duration::from_millis(10));
                        seen.push(c.now());
                    }
                    seen
                }));
            }
            // The control barrier at 100 ms fences every worker event.
            c.sleep(Duration::from_millis(100));
            c.suspend_current();
            for h in handles {
                assert_eq!(h.join().unwrap(), vec![ms(10), ms(20), ms(30)]);
            }
            c.resume_current();
            assert_eq!(c.now(), ms(100));
        }
    }

    #[test]
    fn control_notify_wakes_cross_domain_waiter_at_notify_time() {
        let c = clock(4);
        let _me = ActorScope::enter(&c, "main");
        let slot = c.new_slot();
        let s2 = slot.clone();
        let h = actor(&c, "waiter", 1, move |c| {
            let gen = s2.generation();
            c.wait_slot(&s2, gen, Duration::from_secs(60));
            c.now()
        });
        c.sleep(Duration::from_millis(25));
        c.notify_slot(&slot);
        c.suspend_current();
        let woke_at = h.join().unwrap();
        c.resume_current();
        assert_eq!(woke_at, ticks(Duration::from_millis(25)), "notify, not timeout, must wake");
    }

    #[test]
    fn worker_cross_domain_wakeups_merge_at_the_barrier_in_order() {
        // A worker-domain notifier raises a cross-domain wakeup for a
        // waiter in another domain; the wake is deferred and merged at
        // the next barrier carrying the notifier's clock. The 1 ms
        // control barrier between spawning the two sequences the park
        // before the notifier exists — worker-originated cross-domain
        // notifies are only order-safe across a fence (see module docs;
        // the coordinator routes all of its through domain 0).
        let c = clock(4);
        let _me = ActorScope::enter(&c, "main");
        let slot = c.new_slot();
        let s2 = slot.clone();
        let waiter = actor(&c, "waiter", 3, move |c| {
            let gen = s2.generation();
            c.wait_slot(&s2, gen, Duration::from_secs(60));
            c.now()
        });
        // Barrier: control runs again only once the waiter has parked.
        c.sleep(Duration::from_millis(1));
        let s3 = slot.clone();
        let notifier = actor(&c, "notifier", 1, move |c| {
            // Granted at the registrar's clock (1 ms), so the notify —
            // and the deferred wake's stamp — lands at 8 ms.
            c.sleep(Duration::from_millis(7));
            c.notify_slot(&s3);
        });
        // The barrier at 51 ms merges the deferred wake (stamp 8 ms).
        c.sleep(Duration::from_millis(50));
        c.suspend_current();
        notifier.join().unwrap();
        let woke_at = waiter.join().unwrap();
        c.resume_current();
        assert_eq!(woke_at, ticks(Duration::from_millis(8)), "merge must keep the raise stamp");
    }

    #[test]
    fn zero_actor_domains_are_inert() {
        // Registering into a sparse domain space (only domains 0 and 5
        // populated) must not wedge or perturb scheduling.
        let c = clock(2);
        let _me = ActorScope::enter(&c, "main");
        let h = actor(&c, "lonely", 5, |c| {
            c.sleep(Duration::from_millis(10));
            c.now()
        });
        c.sleep(Duration::from_millis(20));
        c.suspend_current();
        assert_eq!(h.join().unwrap(), ticks(Duration::from_millis(10)));
        c.resume_current();
        assert_eq!(c.now(), ticks(Duration::from_millis(20)));
    }

    #[test]
    fn sole_actor_of_a_domain_can_exit_mid_epoch() {
        // A domain whose only actor detaches between barriers leaves an
        // empty domain behind; control must keep advancing past it.
        let c = clock(4);
        let _me = ActorScope::enter(&c, "main");
        let h = actor(&c, "ephemeral", 2, |c| {
            c.sleep(Duration::from_millis(5));
            // ActorScope drop detaches here, mid-epoch.
        });
        c.sleep(Duration::from_millis(40));
        c.suspend_current();
        h.join().unwrap();
        c.resume_current();
        assert_eq!(c.now(), ticks(Duration::from_millis(40)));
    }

    #[test]
    fn shutdown_racing_a_barrier_drains_cleanly() {
        // Suspend (the shutdown join pattern) while workers still hold
        // pending events: the workers must drain to completion and the
        // resumed control actor observes the global quiesce time.
        let c = clock(4);
        let _me = ActorScope::enter(&c, "main");
        let mut handles = Vec::new();
        for i in 0..4 {
            handles.push(actor(&c, &format!("w{i}"), i + 1, move |c| {
                for _ in 0..=i {
                    c.sleep(Duration::from_millis(10));
                }
                c.now()
            }));
        }
        c.suspend_current();
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join().unwrap(), ticks(Duration::from_millis(10 * (i as u64 + 1))));
        }
        c.resume_current();
        // Global quiesce: the slowest worker finished at 40 ms.
        assert_eq!(c.now(), ticks(Duration::from_millis(40)));
    }

    #[test]
    fn ready_ties_resolve_by_actor_id_within_a_domain() {
        let c = clock(4);
        let _me = ActorScope::enter(&c, "main");
        let order = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for tag in ["a", "b"] {
            let ord = order.clone();
            let tag = tag.to_string();
            handles.push(actor(&c, &tag, 1, move |c| {
                c.sleep(Duration::from_millis(5));
                ord.lock().unwrap().push(tag);
            }));
        }
        c.sleep(Duration::from_millis(50));
        c.suspend_current();
        for h in handles {
            h.join().unwrap();
        }
        c.resume_current();
        assert_eq!(*order.lock().unwrap(), vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn stale_generation_returns_without_parking() {
        let c = clock(2);
        let _me = ActorScope::enter(&c, "main");
        let slot = c.new_slot();
        let gen = slot.generation();
        c.notify_slot(&slot);
        c.wait_slot(&slot, gen, Duration::from_secs(60));
        assert_eq!(c.now(), 0);
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn all_infinite_parks_panic_like_the_sequential_engine() {
        let c = clock(2);
        let _me = ActorScope::enter(&c, "main");
        let slot = c.new_slot();
        let gen = slot.generation();
        // Sole actor parking forever with no possible notifier.
        c.wait_slot(&slot, gen, Duration::from_nanos(Tick::MAX));
    }
}
