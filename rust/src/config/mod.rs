//! Typed configuration: defaults → JSON file → CLI flag overrides
//! (DESIGN.md S12). Serialization uses the in-repo JSON module.

use crate::platform::{PlatformConfig, Policy};
use crate::util::json::Json;
use crate::vscale::Mode;
use crate::workload::BurstyConfig;

/// Top-level experiment configuration for `wavescale simulate`.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Benchmark name (Table I).
    pub benchmark: String,
    /// Power-management policy to simulate.
    pub policy: Policy,
    /// Platform/simulator knobs.
    pub platform: PlatformConfig,
    /// Workload generator knobs.
    pub workload: BurstyConfig,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            benchmark: "tabla".into(),
            policy: Policy::Dvfs(Mode::Proposed),
            platform: PlatformConfig::default(),
            workload: BurstyConfig::default(),
        }
    }
}

/// Resolve a CLI mode name (`prop`, `core-only`, ...) to a [`Mode`].
pub fn mode_by_name(name: &str) -> Result<Mode, String> {
    Ok(match name {
        "prop" | "proposed" => Mode::Proposed,
        "core-only" | "core" => Mode::CoreOnly,
        "bram-only" | "bram" => Mode::BramOnly,
        "freq-only" | "freq" => Mode::FreqOnly,
        other => return Err(format!("unknown mode {other}")),
    })
}

/// Resolve a CLI policy name (`prop`, `pg`, `oracle-prop`, `hybrid`,
/// `hybrid-core-only`, ...) to a [`Policy`].
pub fn policy_by_name(name: &str) -> Result<Policy, String> {
    Ok(match name {
        "power-gating" | "pg" => Policy::PowerGating,
        "nominal" => Policy::NominalStatic,
        "hybrid" => Policy::Hybrid(Mode::Proposed),
        other => {
            if let Some(m) = other.strip_prefix("oracle-") {
                Policy::DvfsOracle(mode_by_name(m)?)
            } else if let Some(m) = other.strip_prefix("hybrid-") {
                Policy::Hybrid(mode_by_name(m)?)
            } else {
                Policy::Dvfs(mode_by_name(other)?)
            }
        }
    })
}

impl SimConfig {
    /// Apply a parsed JSON object on top of the current values.
    pub fn apply_json(&mut self, v: &Json) -> Result<(), String> {
        if let Some(b) = v.get("benchmark").and_then(Json::as_str) {
            self.benchmark = b.to_string();
        }
        if let Some(p) = v.get("policy").and_then(Json::as_str) {
            self.policy = policy_by_name(p)?;
        }
        if let Some(p) = v.get("platform") {
            let f = |k: &str| p.get(k).and_then(Json::as_f64);
            let u = |k: &str| p.get(k).and_then(Json::as_usize);
            if let Some(x) = u("n_fpgas") {
                self.platform.n_fpgas = x;
            }
            if let Some(x) = f("tau_s") {
                self.platform.tau_s = x;
            }
            if let Some(x) = u("m_bins") {
                self.platform.m_bins = x;
            }
            if let Some(x) = f("margin_t") {
                self.platform.margin_t = x;
            }
            if let Some(x) = u("warmup_steps") {
                self.platform.warmup_steps = x;
            }
            if let Some(x) = p.get("dual_pll").and_then(Json::as_bool) {
                self.platform.dual_pll = x;
            }
            if let Some(x) = f("pll_lock_us") {
                self.platform.pll_lock_us = x;
            }
            if let Some(x) = f("pg_residual") {
                self.platform.pg_residual = x;
            }
            if let Some(x) = p.get("predictor").and_then(Json::as_str) {
                self.platform.predictor = crate::markov::PredictorKind::by_name(x)?;
            }
            if let Some(x) = u("predictor_period") {
                self.platform.predictor_period = x;
            }
            // `qos_target: null` (or a negative number) disables the
            // adaptive guardband; a fraction in (0, 1) enables it.
            if let Some(q) = p.get("qos_target") {
                self.platform.qos_target = q.as_f64().filter(|x| *x >= 0.0);
            }
            if let Some(x) = p.get("capacity_policy").and_then(Json::as_str) {
                self.platform.capacity_policy = crate::vscale::CapacityPolicy::by_name(x)?;
            }
        }
        if let Some(w) = v.get("workload") {
            let f = |k: &str| w.get(k).and_then(Json::as_f64);
            if let Some(x) = w.get("steps").and_then(Json::as_usize) {
                self.workload.steps = x;
            }
            if let Some(x) = f("mean_load") {
                self.workload.mean_load = x;
            }
            if let Some(x) = f("hurst") {
                self.workload.hurst = x;
            }
            if let Some(x) = w.get("sources").and_then(Json::as_usize) {
                self.workload.sources = x;
            }
            if let Some(x) = f("mean_on") {
                self.workload.mean_on = x;
            }
            if let Some(x) = w.get("seed").and_then(Json::as_usize) {
                self.workload.seed = x as u64;
            }
        }
        self.validate()
    }

    /// Check cross-field invariants (bins, margin, hurst, benchmark name).
    pub fn validate(&self) -> Result<(), String> {
        if self.platform.n_fpgas == 0 {
            return Err("n_fpgas must be >= 1".into());
        }
        if self.platform.m_bins < 2 {
            return Err("m_bins must be >= 2".into());
        }
        if !(0.0..1.0).contains(&self.platform.margin_t) {
            return Err("margin_t must be in [0, 1)".into());
        }
        if let Some(q) = self.platform.qos_target {
            if !(0.0..1.0).contains(&q) {
                return Err("qos_target must be a violation-rate fraction in [0, 1)".into());
            }
        }
        if self.platform.predictor_period == 0 {
            return Err("predictor_period must be >= 1".into());
        }
        if !(0.5..1.0).contains(&self.workload.hurst) {
            return Err("hurst must be in (0.5, 1)".into());
        }
        if crate::arch::BenchmarkSpec::by_name(&self.benchmark).is_none() {
            return Err(format!("unknown benchmark {}", self.benchmark));
        }
        Ok(())
    }

    /// Serialize to the JSON shape [`SimConfig::apply_json`] accepts.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("benchmark", Json::Str(self.benchmark.clone())),
            ("policy", Json::Str(self.policy.name())),
            (
                "platform",
                Json::obj(vec![
                    ("n_fpgas", Json::Num(self.platform.n_fpgas as f64)),
                    ("tau_s", Json::Num(self.platform.tau_s)),
                    ("m_bins", Json::Num(self.platform.m_bins as f64)),
                    ("margin_t", Json::Num(self.platform.margin_t)),
                    ("warmup_steps", Json::Num(self.platform.warmup_steps as f64)),
                    ("dual_pll", Json::Bool(self.platform.dual_pll)),
                    ("pll_lock_us", Json::Num(self.platform.pll_lock_us)),
                    ("pg_residual", Json::Num(self.platform.pg_residual)),
                    (
                        "predictor",
                        Json::Str(self.platform.predictor.name().to_string()),
                    ),
                    (
                        "predictor_period",
                        Json::Num(self.platform.predictor_period as f64),
                    ),
                    (
                        "qos_target",
                        self.platform.qos_target.map(Json::Num).unwrap_or(Json::Null),
                    ),
                    (
                        "capacity_policy",
                        Json::Str(self.platform.capacity_policy.name().to_string()),
                    ),
                ]),
            ),
            (
                "workload",
                Json::obj(vec![
                    ("steps", Json::Num(self.workload.steps as f64)),
                    ("mean_load", Json::Num(self.workload.mean_load)),
                    ("hurst", Json::Num(self.workload.hurst)),
                    ("sources", Json::Num(self.workload.sources as f64)),
                    ("mean_on", Json::Num(self.workload.mean_on)),
                    ("seed", Json::Num(self.workload.seed as f64)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        SimConfig::default().validate().unwrap();
    }

    #[test]
    fn json_round_trip() {
        let mut c = SimConfig::default();
        c.benchmark = "stripes".into();
        c.platform.n_fpgas = 8;
        c.workload.mean_load = 0.3;
        c.platform.predictor = crate::markov::PredictorKind::Ensemble;
        c.platform.qos_target = Some(0.02);
        c.platform.capacity_policy = crate::vscale::CapacityPolicy::GatingOnly;
        let j = c.to_json();
        let mut d = SimConfig::default();
        d.apply_json(&j).unwrap();
        assert_eq!(d.benchmark, "stripes");
        assert_eq!(d.platform.n_fpgas, 8);
        assert!((d.workload.mean_load - 0.3).abs() < 1e-12);
        assert_eq!(d.platform.predictor, crate::markov::PredictorKind::Ensemble);
        assert_eq!(d.platform.qos_target, Some(0.02));
        assert_eq!(d.platform.capacity_policy, crate::vscale::CapacityPolicy::GatingOnly);
        // The default (qos_target absent/null) round-trips to None.
        let c = SimConfig::default();
        let mut d = SimConfig::default();
        d.platform.qos_target = Some(0.5);
        d.apply_json(&c.to_json()).unwrap();
        assert_eq!(d.platform.qos_target, None, "null disables the guardband");
    }

    #[test]
    fn policy_names_round_trip() {
        for name in [
            "prop", "core-only", "bram-only", "freq-only", "pg", "nominal", "oracle-prop",
            "hybrid", "hybrid-prop", "hybrid-core-only",
        ] {
            let p = policy_by_name(name).unwrap();
            // Round-trip through the canonical name.
            policy_by_name(&p.name()).unwrap();
        }
        assert!(policy_by_name("bogus").is_err());
    }

    #[test]
    fn validation_rejects_bad_values() {
        let mut c = SimConfig::default();
        c.benchmark = "nope".into();
        assert!(c.validate().is_err());
        let mut c = SimConfig::default();
        c.platform.m_bins = 1;
        assert!(c.validate().is_err());
        let mut c = SimConfig::default();
        c.workload.hurst = 1.2;
        assert!(c.validate().is_err());
        let mut c = SimConfig::default();
        c.platform.qos_target = Some(1.5);
        assert!(c.validate().is_err(), "qos_target must be a fraction");
        let mut c = SimConfig::default();
        c.platform.predictor_period = 0;
        assert!(c.validate().is_err());
    }
}
