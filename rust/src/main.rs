//! wavescale CLI — launcher for the multi-FPGA DVFS platform.
//!
//! Subcommands:
//!   characterize  dump the resource characterization tables (Figs. 1-3)
//!   sta           generate a benchmark netlist and report timing (Table I)
//!   lut           build the synthesis-time voltage LUT for a benchmark
//!   simulate      run the platform simulator over a workload trace
//!   predict       exercise the Markov predictor on a generated workload
//!   serve         live serving demo: PJRT inference + DVFS epochs
//!   artifacts     verify AOT artifacts against their golden data

use wavescale::arch::{BenchmarkSpec, DeviceFamily, TABLE1};
use wavescale::chars::{CharLibrary, ResourceClass};
use wavescale::cli::{Args, ControlFlags};
use wavescale::config::SimConfig;
use wavescale::markov::Predictor;
use wavescale::netlist::gen::{generate, GenConfig};
use wavescale::platform::{build_platform, Policy};
use wavescale::power::{DesignPower, PowerParams};
use wavescale::report::{table, write_results};
use wavescale::runtime::{DnnClient, Engine};
use wavescale::sta::{analyze, DelayParams};
use wavescale::util::json::Json;
use wavescale::vscale::{Mode, VoltageLut};
use wavescale::workload;

const USAGE: &str = "\
wavescale — workload-aware opportunistic energy efficiency for multi-FPGA platforms

USAGE: wavescale <SUBCOMMAND> [FLAGS]

SUBCOMMANDS:
  characterize                      dump delay/power-vs-voltage tables
  sta        --benchmark <name>     netlist + timing report (Table I row)
  lut        --benchmark <name> --mode <prop|core-only|bram-only>
  simulate   --benchmark <name>
             --policy <prop|core-only|bram-only|pg|nominal|oracle-prop|hybrid>
             [--steps N] [--mean-load X] [--n-fpgas N] [--seed N]
             [--predictor ensemble|markov|periodic|ewma|last-value]
             [--qos-target X]  (enables the adaptive guardband at a
             violation-rate target; default keeps the static t% margin)
             [--config file.json] [--csv out.csv]
  predict    [--steps N] [--bins M] [--kind bursty|periodic|poisson|square]
             [--predictor name]  (default: side-by-side of all predictors)
  serve      --artifacts <dir> [--variant name] [--instances N]
             [--epochs N] [--epoch-ms N] [--rps N]
  artifacts  --artifacts <dir>      compile + golden-check all artifacts
  fleet      --groups tabla:0.4,diannao:0.6 [--policy prop] [--steps N]
  scenario   --name <diurnal|flash-crowd|mixed-tenant|overnight|
             board-failure|straggler|correlated-surge|tiered-tenants|
             long-replay>
             [--steps N] [--seed N] [--policy prop]  (offline fleet sim;
             also reports dvfs-only vs pg-only vs hybrid side by side)
  serve-fleet --scenario <name> [--instances N] [--epochs N]
             [--epoch-ms N] [--rps N] [--artifacts dir]
             [--capacity dvfs|pg|hybrid] [--virtual-time] [--seed N]
             [--predictor ensemble|markov|...]
             [--qos-target X|premium|standard|best-effort] [--faults]
             (live elastic coordinator; --virtual-time replays the
             scenario deterministically in simulated time — thousands of
             epochs per wall-second, bit-identical per seed; --faults
             injects the scenario's canonical FaultPlan — board
             failures, stragglers, correlated surges)
             [--nodes N] (spread the groups round-robin over N node
             agents; submits are routed by the fleet topology)
             [--parallel] [--parallel-workers K] (with --virtual-time:
             replay on the conservative parallel engine — independent
             groups advance concurrently between CC-epoch barriers,
             traces byte-identical to sequential, DESIGN.md S24 — then
             rerun the sequential reference and print the speedup;
             scenario `synthetic-N` builds an N-group synthetic fleet
             for scale sweeps)
  topology   --scenario <name> [--nodes N] [--instances N] [--epochs N]
             (run a short virtual-time fleet and print the live
             TopologySnapshot as JSON — DESIGN.md S21.4 schema)
  experiment <fig1|fig2|fig3|fig4|fig5|fig6|fig8|table1|fig10|fig11|fig12|table2|pll|hybrid|predictor>
             re-run a paper experiment (same code as `cargo bench`)
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn run(argv: &[String]) -> Result<(), String> {
    let args = Args::parse(argv)?;
    if args.switch("help") || args.subcommand.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    match args.subcommand.as_str() {
        "characterize" => characterize(&args),
        "sta" => sta_cmd(&args),
        "lut" => lut_cmd(&args),
        "simulate" => simulate(&args),
        "predict" => predict(&args),
        "serve" => serve(&args),
        "artifacts" => artifacts_cmd(&args),
        "fleet" => fleet_cmd(&args),
        "scenario" => scenario_cmd(&args),
        "serve-fleet" => serve_fleet_cmd(&args),
        "topology" => topology_cmd(&args),
        "experiment" => experiment_cmd(&args),
        other => Err(format!("unknown subcommand {other}\n{USAGE}")),
    }
}

fn characterize(args: &Args) -> Result<(), String> {
    args.check_known(&["json"])?;
    let lib = CharLibrary::stratix_iv_22nm();
    if args.switch("json") {
        println!("{}", lib.to_json().to_string_pretty());
        return Ok(());
    }
    let grid = lib.grid();
    let mut rows = vec![wavescale::report::row([
        "rail_v", "d_logic", "d_route", "d_dsp", "d_bram", "st_logic", "st_bram",
    ])];
    for i in 0..grid.vbram.len() {
        let vb = grid.vbram[i];
        let vc = if i < grid.vcore.len() { grid.vcore[i] } else { f64::NAN };
        let fmt = |x: f64| {
            if x.is_nan() {
                "-".to_string()
            } else if x.is_infinite() {
                "inf".to_string()
            } else {
                format!("{x:.3}")
            }
        };
        rows.push(vec![
            format!("{vc:.3}/{vb:.3}"),
            fmt(if vc.is_nan() { f64::NAN } else { lib.delay_scale(ResourceClass::Logic, vc) }),
            fmt(if vc.is_nan() { f64::NAN } else { lib.delay_scale(ResourceClass::Routing, vc) }),
            fmt(if vc.is_nan() { f64::NAN } else { lib.delay_scale(ResourceClass::Dsp, vc) }),
            fmt(lib.delay_scale(ResourceClass::Bram, vb)),
            fmt(if vc.is_nan() { f64::NAN } else { lib.static_scale(ResourceClass::Logic, vc) }),
            fmt(lib.static_scale(ResourceClass::Bram, vb)),
        ]);
    }
    print!("{}", table(&rows));
    Ok(())
}

fn sta_cmd(args: &Args) -> Result<(), String> {
    args.check_known(&["benchmark", "scale", "seed"])?;
    let name = args.flag_or("benchmark", "tabla");
    let spec = BenchmarkSpec::by_name(name).ok_or_else(|| format!("unknown benchmark {name}"))?;
    let scale = args.flag_f64("scale")?.unwrap_or(0.05);
    let seed = ControlFlags::parse(args)?.seed_or(2019);
    let net = generate(spec, &GenConfig { scale, seed, luts_per_lab: 10 });
    let rep = analyze(&net, &DelayParams::default(), 8)?;
    let c = net.counts();
    println!("benchmark {name} (scale {scale}):");
    println!(
        "  netlist: {} LUTs, {} BRAMs, {} DSPs, {} in, {} out, {} routed segments",
        c.luts, c.brams, c.dsps, c.inputs, c.outputs, c.routed_segments
    );
    println!(
        "  fmax {:.1} MHz (Table I: {:.1} MHz), cp {:.2} ns, alpha {:.3}",
        rep.fmax_mhz,
        spec.freq_mhz,
        rep.cp.total_ns(),
        rep.cp.alpha()
    );
    println!(
        "  cp decomposition: logic {:.2} ns, routing {:.2} ns, bram {:.2} ns, dsp {:.2} ns",
        rep.cp.logic_ns, rep.cp.routing_ns, rep.cp.bram_ns, rep.cp.dsp_ns
    );
    println!("  near-critical paths tracked: {}", rep.top_paths.len());
    Ok(())
}

fn lut_cmd(args: &Args) -> Result<(), String> {
    args.check_known(&["benchmark", "mode", "bins", "margin"])?;
    let name = args.flag_or("benchmark", "tabla");
    let mode = wavescale::config::mode_by_name(args.flag_or("mode", "prop"))?;
    let bins = args.flag_usize("bins")?.unwrap_or(10);
    let margin = args.flag_f64("margin")?.unwrap_or(0.05);
    let platform = build_platform(name, Default::default(), Policy::Dvfs(mode))?;
    let opt = platform.optimizer_ref();
    let lut = VoltageLut::build(opt, bins, margin, mode);
    let mut rows = vec![wavescale::report::row([
        "bin", "load_range", "freq_ratio", "vcore", "vbram", "power_norm",
    ])];
    for (b, e) in lut.entries.iter().enumerate() {
        rows.push(vec![
            format!("{b}"),
            format!("({:.2}, {:.2}]", b as f64 / bins as f64, (b + 1) as f64 / bins as f64),
            format!("{:.3}", e.freq_ratio),
            format!("{:.3}", e.point.vcore),
            format!("{:.3}", e.point.vbram),
            format!("{:.4}", e.point.power_norm),
        ]);
    }
    print!("{}", table(&rows));
    Ok(())
}

fn simulate(args: &Args) -> Result<(), String> {
    args.check_known(&[
        "benchmark", "policy", "steps", "mean-load", "n-fpgas", "seed", "config", "csv",
        "trace", "predictor", "qos-target",
    ])?;
    let mut cfg = SimConfig::default();
    if let Some(path) = args.flag("config") {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let json = Json::parse(&text).map_err(|e| e.to_string())?;
        cfg.apply_json(&json)?;
    }
    if let Some(b) = args.flag("benchmark") {
        cfg.benchmark = b.to_string();
    }
    if let Some(s) = args.flag_usize("steps")? {
        cfg.workload.steps = s;
    }
    if let Some(m) = args.flag_f64("mean-load")? {
        cfg.workload.mean_load = m;
    }
    if let Some(n) = args.flag_usize("n-fpgas")? {
        cfg.platform.n_fpgas = n;
    }
    // Shared control-plane flags (one builder for every subcommand).
    let flags = ControlFlags::parse(args)?;
    if let Some(p) = flags.policy {
        cfg.policy = p;
    }
    if let Some(s) = flags.seed {
        cfg.workload.seed = s;
    }
    if let Some(p) = flags.predictor {
        cfg.platform.predictor = p;
    }
    if flags.qos_target.is_some() {
        cfg.platform.qos_target = flags.qos_target;
    }
    cfg.validate()?;

    let trace = match args.flag("trace") {
        Some(path) => {
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            workload::Trace::from_csv(&text, path)?
        }
        None => workload::bursty(&cfg.workload),
    };
    let mut platform = build_platform(&cfg.benchmark, cfg.platform.clone(), cfg.policy)?;
    let report = platform.run(&trace.loads);

    println!("benchmark {} | policy {} | {} steps | mean load {:.3}",
        cfg.benchmark, report.policy, trace.len(), trace.mean());
    println!(
        "  avg power {:.3} W (nominal {:.3} W) -> power gain {:.2}x",
        report.avg_power_w, report.nominal_power_w, report.power_gain
    );
    println!(
        "  energy {:.1} J (PLL {:.2} J) | QoS violations {} ({:.2}%) | mispredictions {}",
        report.energy_j,
        report.pll_energy_j,
        report.qos_violations,
        report.violation_rate * 100.0,
        report.mispredictions
    );
    if let Some(csv_path) = args.flag("csv") {
        let mut rows = vec![wavescale::report::row([
            "step", "load", "predicted", "predictor", "margin", "freq_ratio", "vcore",
            "vbram", "active", "power_w", "qos_violation",
        ])];
        for r in &report.records {
            rows.push(vec![
                r.step.to_string(),
                format!("{:.4}", r.load),
                format!("{:.4}", r.predicted),
                r.predictor.to_string(),
                format!("{:.3}", r.margin),
                format!("{:.4}", r.freq_ratio),
                format!("{:.3}", r.vcore),
                format!("{:.3}", r.vbram),
                format!("{:.0}", r.active_boards),
                format!("{:.4}", r.power_w),
                (r.qos_violation as u8).to_string(),
            ]);
        }
        let path = write_results(csv_path, &wavescale::report::csv(&rows))
            .map_err(|e| e.to_string())?;
        println!("  per-step trace written to {}", path.display());
    }
    Ok(())
}

fn predict(args: &Args) -> Result<(), String> {
    args.check_known(&["steps", "bins", "kind", "seed", "predictor"])?;
    let flags = ControlFlags::parse(args)?;
    let steps = args.flag_usize("steps")?.unwrap_or(2000);
    let bins = args.flag_usize("bins")?.unwrap_or(10);
    let seed = flags.seed_or(7);
    let kind = args.flag_or("kind", "bursty");
    // The cyclic generators' period doubles as the periodic predictor's
    // training cycle — a mismatched period would misreport it as poor on
    // exactly the workloads it should win.
    let (trace, period) = match kind {
        "bursty" => (
            workload::bursty(&workload::BurstyConfig { steps, seed, ..Default::default() }),
            96,
        ),
        "poisson" => (workload::poisson(steps, 0.4, 1000.0, seed), 96),
        "periodic" => (workload::periodic(steps, 96, 0.15, 0.85, 0.03, seed), 96),
        "square" => (workload::square(steps, 50, 0.2, 0.8), 50),
        other => return Err(format!("unknown workload kind {other}")),
    };
    let kinds: Vec<wavescale::markov::PredictorKind> = match flags.predictor {
        Some(kind) => vec![kind],
        None => wavescale::markov::PredictorKind::ALL.to_vec(),
    };
    println!("workload {} ({} steps, mean {:.3})", trace.label, trace.len(), trace.mean());
    let mut rows = vec![wavescale::report::row([
        "predictor", "exact-bin%", "coverage%", "under%", "active-at-end",
    ])];
    for k in kinds {
        let mut p = k.build(bins, 20, period);
        let (mut covered, mut exact, mut under, mut total) = (0usize, 0usize, 0usize, 0usize);
        for (i, &load) in trace.loads.iter().enumerate() {
            if i > 20 {
                total += 1;
                let pred = p.predict();
                if workload::bin_of_load(bins, pred) == workload::bin_of_load(bins, load) {
                    exact += 1;
                }
                if workload::bin_of_load(bins, pred) < workload::bin_of_load(bins, load) {
                    under += 1;
                }
                if pred * 1.05 >= load {
                    covered += 1;
                }
            }
            p.observe(load);
        }
        rows.push(vec![
            k.name().to_string(),
            format!("{:.1}", 100.0 * exact as f64 / total.max(1) as f64),
            format!("{:.1}", 100.0 * covered as f64 / total.max(1) as f64),
            format!("{:.1}", 100.0 * under as f64 / total.max(1) as f64),
            p.active_name().to_string(),
        ]);
    }
    print!("{}", table(&rows));
    Ok(())
}

fn serve(args: &Args) -> Result<(), String> {
    args.check_known(&["artifacts", "variant", "instances", "epochs", "epoch-ms", "rps", "mode"])?;
    let dir = args.flag_or("artifacts", "artifacts");
    let variant = args.flag_or("variant", "tabla").to_string();
    let n_instances = args.flag_usize("instances")?.unwrap_or(2);
    let epochs = args.flag_usize("epochs")?.unwrap_or(10);
    let epoch_ms = args.flag_usize("epoch-ms")?.unwrap_or(200);
    let rps = args.flag_f64("rps")?.unwrap_or(2000.0);
    let mode = wavescale::config::mode_by_name(args.flag_or("mode", "prop"))?;

    let platform = build_platform(&variant, Default::default(), Policy::Dvfs(mode))?;
    let design = platform.design.clone();
    let optimizer = platform.optimizer_ref().clone();

    let cfg = wavescale::coordinator::ServingConfig {
        variant: variant.clone(),
        n_instances,
        epoch: std::time::Duration::from_millis(epoch_ms as u64),
        mode,
        ..Default::default()
    };
    let coord = wavescale::coordinator::Coordinator::start(
        cfg,
        std::path::PathBuf::from(dir),
        design,
        optimizer,
    )
    .map_err(|e| e.to_string())?;

    println!("serving dnn_{variant} on {n_instances} instances for {epochs} epochs...");
    let mut rng = wavescale::util::prng::Rng::new(42);
    let total = std::time::Duration::from_millis((epochs * epoch_ms) as u64);
    // detlint: allow(wallclock) -- live serve mode paces real traffic on
    // real time; nothing here feeds the replayable decision log
    let start = std::time::Instant::now();
    let mut sent = 0u64;
    while start.elapsed() < total {
        // Sinusoidal offered load between 20% and 100% of rps.
        let phase = start.elapsed().as_secs_f64() / total.as_secs_f64();
        let rate = rps * (0.6 - 0.4 * (phase * std::f64::consts::TAU).cos());
        let _ = coord.submit(rng.normal_vec_f32(coord.in_dim));
        sent += 1;
        std::thread::sleep(std::time::Duration::from_secs_f64(1.0 / rate.max(1.0)));
    }
    let (stats, records) = coord.shutdown().map_err(|e| e.to_string())?;
    println!(
        "  submitted {sent} | completed {} | rejected {} | p50 {:.1} ms | p99 {:.1} ms",
        stats.completed,
        stats.rejected,
        stats.p50_latency_s * 1e3,
        stats.p99_latency_s * 1e3
    );
    println!(
        "  energy {:.2} J vs nominal {:.2} J -> power gain {:.2}x over {} epochs",
        stats.energy_j, stats.nominal_energy_j, stats.power_gain, stats.epochs
    );
    for r in records.iter().take(6) {
        println!(
            "    epoch {:>2}: load {:.2} predicted {:.2} freq {:.2} vcore {:.3} vbram {:.3} {:.2} W",
            r.epoch, r.load, r.predicted, r.freq_ratio, r.vcore, r.vbram, r.power_w
        );
    }
    Ok(())
}

fn artifacts_cmd(args: &Args) -> Result<(), String> {
    args.check_known(&["artifacts"])?;
    let dir = args.flag_or("artifacts", "artifacts");
    let engine = Engine::open(dir).map_err(|e| e.to_string())?;
    println!(
        "PJRT platform: {} | manifest: {} artifacts (jax {})",
        engine.platform_name(),
        engine.manifest.artifacts.len(),
        engine.manifest.jax_version
    );
    for variant in engine.manifest.dnn_variants() {
        let dnn = DnnClient::new(&engine, &variant).map_err(|e| e.to_string())?;
        let err = dnn.verify_golden(&engine).map_err(|e| e.to_string())?;
        println!("  dnn_{variant}: golden max rel err {err:.2e} {}",
            if err < 1e-3 { "OK" } else { "FAIL" });
        if err >= 1e-3 {
            return Err(format!("dnn_{variant} golden check failed"));
        }
    }
    // Cross-check one voltage selection against the native optimizer.
    let spec = TABLE1[0];
    let chars = CharLibrary::stratix_iv_22nm();
    let design = DesignPower::from_spec(
        BenchmarkSpec::by_name(spec.name).unwrap(),
        &DeviceFamily::stratix_iv(),
        chars.clone(),
        PowerParams::default(),
    )?;
    let net = generate(&spec, &GenConfig { scale: 0.05, seed: 2019, luts_per_lab: 10 });
    let rep = analyze(&net, &DelayParams::default(), 8)?;
    let tables = design.rail_tables(&rep.cp);
    let opt = wavescale::vscale::Optimizer::new(chars.grid(), tables.clone());
    let vs = wavescale::runtime::VoltageSelectorClient::new(&engine);
    let q = wavescale::runtime::OpQuery {
        alpha: tables.op.alpha as f32,
        beta: tables.op.beta as f32,
        gamma_l: tables.op.gamma_l as f32,
        gamma_m: tables.op.gamma_m as f32,
        sw: 2.5,
    };
    let got = vs
        .select(Mode::Proposed, &tables, &[q])
        .map_err(|e| e.to_string())?[0];
    let want = opt.optimize(2.5, Mode::Proposed);
    println!(
        "  voltage_opt_prop: pjrt ({:.3}, {:.3}) vs native ({:.3}, {:.3}) {}",
        got.vcore,
        got.vbram,
        want.vcore,
        want.vbram,
        if got.icore == want.icore && got.ibram == want.ibram { "OK" } else { "FAIL" }
    );
    Ok(())
}

fn fleet_cmd(args: &Args) -> Result<(), String> {
    args.check_known(&["groups", "policy", "steps", "mean-load", "seed"])?;
    let spec = args.flag_or("groups", "tabla:0.4,diannao:0.35,stripes:0.25");
    let mut groups: Vec<(&str, f64)> = Vec::new();
    for part in spec.split(',') {
        let (name, share) = part
            .split_once(':')
            .ok_or_else(|| format!("bad group spec {part:?} (want name:share)"))?;
        groups.push((name, share.parse().map_err(|_| format!("bad share in {part:?}"))?));
    }
    let flags = ControlFlags::parse(args)?;
    let policy = flags.policy_or(Policy::Dvfs(Mode::Proposed));
    let steps = args.flag_usize("steps")?.unwrap_or(600);
    let mean = args.flag_f64("mean-load")?.unwrap_or(0.4);
    let seed = flags.seed_or(2019);
    let trace = workload::bursty(&wavescale::workload::BurstyConfig {
        steps,
        mean_load: mean,
        seed,
        ..Default::default()
    });
    let mut fleet = wavescale::platform::fleet::Fleet::new(
        &groups,
        Default::default(),
        policy,
    )?;
    let r = fleet.run(&trace.loads);
    let mut rows = vec![wavescale::report::row([
        "group", "share", "nominal_W", "avg_W", "gain", "violations%",
    ])];
    for (g, (name, rep)) in fleet.groups.iter().zip(&r.per_group) {
        rows.push(vec![
            name.clone(),
            format!("{:.2}", g.share),
            format!("{:.2}", rep.nominal_power_w),
            format!("{:.2}", rep.avg_power_w),
            format!("{:.2}x", rep.power_gain),
            format!("{:.1}", rep.violation_rate * 100.0),
        ]);
    }
    rows.push(vec![
        "fleet".into(),
        "1.00".into(),
        format!("{:.2}", r.nominal_power_w),
        format!("{:.2}", r.avg_power_w),
        format!("{:.2}x", r.power_gain),
        format!("{:.1}", r.violation_rate * 100.0),
    ]);
    print!("{}", table(&rows));
    Ok(())
}

fn scenario_cmd(args: &Args) -> Result<(), String> {
    args.check_known(&["name", "steps", "seed", "policy"])?;
    let flags = ControlFlags::parse(args)?;
    let name = args.flag_or("name", "mixed-tenant");
    let steps = args.flag_usize("steps")?.unwrap_or(600);
    let seed = flags.seed_or(2019);
    let policy = flags.policy_or(Policy::Dvfs(Mode::Proposed));
    let scenario = wavescale::workload::Scenario::by_name(name, steps, seed)?;
    println!("scenario {name}: {} ({} steps)", scenario.description, scenario.steps());

    let mut fleet = wavescale::platform::fleet::Fleet::from_scenario(
        &scenario,
        Default::default(),
        policy,
    )?;
    let r = fleet.run_scenario(&scenario)?;
    let mut rows = vec![wavescale::report::row([
        "group", "share", "mean_load", "nominal_W", "avg_W", "gain", "violations%",
    ])];
    for (tenant, (gname, rep)) in scenario.tenants.iter().zip(&r.per_group) {
        rows.push(vec![
            gname.clone(),
            format!("{:.2}", tenant.share),
            format!("{:.3}", tenant.trace.mean()),
            format!("{:.2}", rep.nominal_power_w),
            format!("{:.2}", rep.avg_power_w),
            format!("{:.2}x", rep.power_gain),
            format!("{:.1}", rep.violation_rate * 100.0),
        ]);
    }
    rows.push(vec![
        "fleet".into(),
        "1.00".into(),
        "-".into(),
        format!("{:.2}", r.nominal_power_w),
        format!("{:.2}", r.avg_power_w),
        format!("{:.2}x", r.power_gain),
        format!("{:.1}", r.violation_rate * 100.0),
    ]);
    print!("{}", table(&rows));

    // Elastic capacity manager: the same scenario under the three
    // capacity policies, side by side (DESIGN.md S6.1).
    let mode = match policy {
        Policy::Dvfs(m) | Policy::DvfsOracle(m) | Policy::Hybrid(m) => m,
        _ => Mode::Proposed,
    };
    print_capacity_comparison(&scenario, Default::default(), mode)?;
    Ok(())
}

/// Print the DVFS-only / PG-only / hybrid side-by-side for a scenario
/// (shared by the `scenario` and `serve-fleet` subcommands). `cfg` must
/// mirror the run being compared against (instance count, residual, ...).
fn print_capacity_comparison(
    scenario: &wavescale::workload::Scenario,
    cfg: wavescale::platform::PlatformConfig,
    mode: Mode,
) -> Result<(), String> {
    let n_fpgas = cfg.n_fpgas;
    let reports =
        wavescale::platform::fleet::Fleet::compare_capacity_policies(scenario, cfg, mode)?;
    let mut rows = vec![wavescale::report::row([
        "capacity_policy", "avg_W", "energy_J", "gain", "violations%",
    ])];
    for (name, r) in &reports {
        rows.push(vec![
            name.clone(),
            format!("{:.2}", r.avg_power_w),
            format!("{:.1}", r.energy_j()),
            format!("{:.2}x", r.power_gain),
            format!("{:.1}", r.violation_rate * 100.0),
        ]);
    }
    println!(
        "\ncapacity policies on {} (offline sim, same traces, {} instances/group):",
        scenario.name, n_fpgas
    );
    print!("{}", table(&rows));
    Ok(())
}

fn serve_fleet_cmd(args: &Args) -> Result<(), String> {
    args.check_known(&[
        "scenario", "instances", "epochs", "epoch-ms", "rps", "mode", "artifacts", "seed",
        "capacity", "virtual-time", "predictor", "qos-target", "faults", "nodes", "parallel",
        "parallel-workers",
    ])?;
    let flags = ControlFlags::parse(args)?;
    let name = args.flag_or("scenario", "mixed-tenant");
    let n_instances = args.flag_usize("instances")?.unwrap_or(2);
    let n_nodes = args.flag_usize("nodes")?.unwrap_or(1);
    let epochs = args.flag_usize("epochs")?.unwrap_or(12);
    let epoch_ms = args.flag_usize("epoch-ms")?.unwrap_or(150);
    let rps = args.flag_f64("rps")?.unwrap_or(3000.0);
    let mode = wavescale::config::mode_by_name(args.flag_or("mode", "prop"))?;
    let capacity = wavescale::vscale::CapacityPolicy::by_name(args.flag_or("capacity", "hybrid"))?;
    let predictor = flags.predictor_or(wavescale::markov::PredictorKind::Markov);
    let qos_target = flags.qos_target;
    let seed = flags.seed_or(7);
    let virtual_time = args.switch("virtual-time");
    // Bit-identical-per-seed replay must not depend on which artifacts are
    // installed, so virtual time always serves through the deterministic
    // native backend (a directory that never exists), like `simtest`.
    let dir = if virtual_time {
        if args.flag("artifacts").is_some() {
            println!("(--virtual-time ignores --artifacts: deterministic native backend)");
        }
        "sim-no-artifacts"
    } else {
        args.flag_or("artifacts", "artifacts")
    };

    // --parallel swaps the sequential discrete-event engine for the
    // conservative parallel one (DESIGN.md S24); traces are byte-identical
    // by the equivalence contract, only the wall clock changes.
    let parallel_workers = args.flag_usize("parallel-workers")?;
    let parallel = args.switch("parallel") || parallel_workers.is_some();
    if parallel && !virtual_time {
        return Err("--parallel/--parallel-workers require --virtual-time".into());
    }

    let scenario = wavescale::workload::Scenario::by_name(name, epochs, seed)?;
    // --faults injects the scenario's canonical fault plan (the one the
    // golden traces pin); scenarios without one get an empty — and
    // bitwise-neutral — plan.
    let faults = if args.switch("faults") {
        wavescale::workload::FaultPlan::for_scenario(
            name,
            scenario.tenants.len(),
            n_instances,
            epochs,
        )
    } else {
        wavescale::workload::FaultPlan::default()
    };
    // One full serving run (fresh fleet, fresh clock). Under
    // --virtual-time the whole fleet runs on a deterministic
    // discrete-event clock: the replay is bit-identical per --seed and a
    // long scenario takes milliseconds instead of epochs x epoch-ms of
    // wall time (DESIGN.md S18). `par` picks the engine; returns
    // (accepted, report, wall seconds).
    let run_once = |par: bool| -> Result<
        (u64, wavescale::coordinator::FleetServingReport, f64),
        String,
    > {
        let clock: std::sync::Arc<dyn wavescale::clock::Clock> = if !virtual_time {
            wavescale::clock::wall()
        } else if par {
            match parallel_workers {
                Some(k) => {
                    std::sync::Arc::new(wavescale::clock::ParallelVirtualClock::with_workers(k))
                }
                None => std::sync::Arc::new(wavescale::clock::ParallelVirtualClock::new()),
            }
        } else {
            std::sync::Arc::new(wavescale::clock::VirtualClock::new())
        };
        let _driver = virtual_time
            .then(|| wavescale::clock::ActorScope::enter(&clock, "serve-fleet"));
        let cfg = wavescale::coordinator::FleetServingConfig {
            groups: scenario.group_configs(n_instances),
            faults: std::sync::Arc::new(faults.clone()),
            epoch: std::time::Duration::from_millis(epoch_ms as u64),
            mode,
            capacity_policy: capacity,
            predictor,
            predictor_period: wavescale::workload::Scenario::day_period(epochs),
            qos_target,
            nodes: n_nodes,
            // The PJRT selector round-trip is skipped in virtual time so
            // the trace cannot depend on which artifacts are installed.
            selector_via_pjrt: !virtual_time,
            clock: clock.clone(),
            ..Default::default()
        };
        let fleet = wavescale::coordinator::FleetServing::start(cfg, dir.into())
            .map_err(|e| e.to_string())?;
        // detlint: allow(wallclock) -- wall-time is reporting-only here
        // (run duration / speedup lines); the scenario itself runs on the
        // fleet's clock
        let wall_start = std::time::Instant::now();
        let accepted = wavescale::coordinator::drive_scenario(&fleet, &scenario, rps, seed);
        let report = fleet.shutdown().map_err(|e| e.to_string())?;
        Ok((accepted, report, wall_start.elapsed().as_secs_f64()))
    };

    println!(
        "serving scenario {name}: {} groups x {n_instances} instances on {n_nodes} node(s), \
         {epochs} epochs, capacity policy {}, predictor {}{}{}",
        scenario.tenants.len(),
        capacity.name(),
        predictor.name(),
        match qos_target {
            Some(q) => format!(" (adaptive guardband, QoS target {:.1}%)", q * 100.0),
            None => String::new(),
        },
        if parallel {
            ", parallel virtual time"
        } else if virtual_time {
            ", virtual time"
        } else {
            ""
        }
    );
    if args.switch("faults") {
        if faults.is_empty() {
            println!("(--faults: {name} has no canonical fault plan; running fault-free)");
        } else {
            println!(
                "fault plan: {} board failure(s), {} straggler window(s), {} surge(s)",
                faults.board_failures.len(),
                faults.stragglers.len(),
                faults.surges.len()
            );
        }
    }

    let (accepted, report, wall_s) = run_once(parallel)?;

    println!("accepted {accepted} submissions");
    if virtual_time {
        println!(
            "replayed {:.1} s of virtual time in {:.0} ms wall (seed {seed}; reruns are \
             bit-identical)",
            (epochs + 1) as f64 * epoch_ms as f64 / 1e3,
            wall_s * 1e3
        );
    }
    if parallel {
        // Rerun on the sequential golden reference: the speedup line is
        // the tentpole number, and the summary comparison is a cheap
        // determinism cross-check (the full byte-equality contract lives
        // in tests/sim_parallel.rs).
        let (seq_accepted, seq_report, seq_wall_s) = run_once(false)?;
        let equal = seq_accepted == accepted
            && seq_report.stats.energy_j.to_bits() == report.stats.energy_j.to_bits()
            && seq_report
                .stats
                .per_group
                .iter()
                .zip(&report.stats.per_group)
                .all(|(a, b)| a.admitted == b.admitted && a.completed == b.completed);
        println!(
            "parallel speedup: {:.2}x (parallel {:.0} ms vs sequential {:.0} ms wall; \
             summaries {})",
            seq_wall_s / wall_s.max(1e-9),
            wall_s * 1e3,
            seq_wall_s * 1e3,
            if equal { "identical" } else { "DIVERGED — determinism bug, please report" }
        );
    }
    print!("{}", table(&wavescale::coordinator::fleet_report_rows(&report.stats)));
    let s = &report.stats;
    println!(
        "energy {:.2} J vs nominal {:.2} J over {} epochs",
        s.energy_j, s.nominal_energy_j, s.epochs
    );
    // Offline side-by-side of the three capacity policies on the same
    // scenario and the same per-group instance count as the live run,
    // so every serve-fleet run shows what the hybrid buys.
    let offline_cfg = wavescale::platform::PlatformConfig {
        n_fpgas: n_instances,
        ..Default::default()
    };
    print_capacity_comparison(&scenario, offline_cfg, mode)?;
    Ok(())
}

/// `topology` — spin up a virtual-time fleet on N node agents, replay a few
/// epochs of the scenario, and print the live [`TopologySnapshot`] as JSON
/// (DESIGN.md S21.4). The run is deterministic per seed, so the snapshot is
/// stable enough to diff in scripts.
fn topology_cmd(args: &Args) -> Result<(), String> {
    args.check_known(&["scenario", "nodes", "instances", "epochs", "seed"])?;
    let name = args.flag_or("scenario", "mixed-tenant");
    let n_nodes = args.flag_usize("nodes")?.unwrap_or(2);
    let n_instances = args.flag_usize("instances")?.unwrap_or(2);
    let epochs = args.flag_usize("epochs")?.unwrap_or(4);
    let seed = args.flag_usize("seed")?.unwrap_or(7) as u64;

    let clock: std::sync::Arc<dyn wavescale::clock::Clock> =
        std::sync::Arc::new(wavescale::clock::VirtualClock::new());
    let _driver = wavescale::clock::ActorScope::enter(&clock, "topology");

    let scenario = wavescale::workload::Scenario::by_name(name, epochs, seed)?;
    let cfg = wavescale::coordinator::FleetServingConfig {
        groups: scenario.group_configs(n_instances),
        epoch: std::time::Duration::from_millis(50),
        nodes: n_nodes,
        selector_via_pjrt: false,
        clock: clock.clone(),
        ..Default::default()
    };
    // The deterministic native backend: a directory that never exists.
    let fleet = wavescale::coordinator::FleetServing::start(cfg, "sim-no-artifacts".into())
        .map_err(|e| e.to_string())?;
    wavescale::coordinator::drive_scenario(&fleet, &scenario, 1000.0, seed);
    let snapshot = fleet.topology_snapshot();
    fleet.shutdown().map_err(|e| e.to_string())?;
    println!("{}", snapshot.to_json().to_string_pretty());
    Ok(())
}

fn experiment_cmd(args: &Args) -> Result<(), String> {
    let id = args
        .positional
        .first()
        .ok_or("experiment needs an id (e.g. fig10, table2)")?;
    let bench = match id.as_str() {
        "fig1" => "fig1_delay",
        "fig2" => "fig2_dynamic_power",
        "fig3" => "fig3_static_power",
        "fig4" => "fig4_workload",
        "fig5" => "fig5_alpha",
        "fig6" => "fig6_beta",
        "fig8" => "fig8_markov",
        "table1" => "table1_utilization",
        "fig10" => "fig10_tabla_trace",
        "fig11" => "fig11_voltage_trace",
        "fig12" => "fig12_accelerators",
        "table2" => "table2_summary",
        "pll" => "pll_overhead",
        "hybrid" => "hybrid_capacity",
        "predictor" => "perf_predictor",
        other => return Err(format!("unknown experiment {other}")),
    };
    // The experiments live as bench binaries so `cargo bench` regenerates
    // everything; this subcommand is the single-experiment launcher.
    let status = std::process::Command::new("cargo")
        .args(["bench", "--offline", "--bench", bench])
        .status()
        .map_err(|e| format!("failed to launch cargo: {e}"))?;
    if !status.success() {
        return Err(format!("experiment {id} failed"));
    }
    Ok(())
}
