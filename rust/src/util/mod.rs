//! Foundation utilities built in-repo (the environment vendors only the
//! `xla` crate closure — no `rand`, `serde`, `proptest`, ... — so these are
//! first-class substrates, see DESIGN.md §6/S12/S16/S17).

pub mod json;
pub mod prng;
pub mod prop;
pub mod stats;
