//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Supports the full JSON grammar (RFC 8259): objects, arrays, strings with
//! escapes incl. `\uXXXX` (+ surrogate pairs), numbers, bools, null. Object
//! key order is preserved so serialization round-trips deterministically.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Json)>),
}

/// Parse failure with its byte offset.
#[derive(Debug)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset of the failure in the input.
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------- accessors ----------

    /// Object member by key (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` chained over a dotted path, e.g. `"meta.hlo.bytes"`.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    /// The value as a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The value as a non-negative integer.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    /// The value as a string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The value as a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The value as an array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The value as ordered object members.
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object contents as a map (for order-insensitive comparison).
    pub fn as_map(&self) -> Option<BTreeMap<&str, &Json>> {
        self.as_obj()
            .map(|o| o.iter().map(|(k, v)| (k.as_str(), v)).collect())
    }

    // ---------- constructors ----------

    /// Build an object from `(key, value)` pairs.
    pub fn obj(kvs: Vec<(&str, Json)>) -> Json {
        Json::Obj(kvs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a numeric array.
    pub fn num_arr(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ---------- parsing ----------

    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---------- serialization ----------

    /// Serialize with two-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(0));
        out
    }

    /// Serialize on one line.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(a) => write_seq(out, indent, '[', ']', a.len(), |out, i, ind| {
                a[i].write(out, ind)
            }),
            Json::Obj(o) => write_seq(out, indent, '{', '}', o.len(), |out, i, ind| {
                write_str(&o[i].0, out);
                out.push_str(": ");
                o[i].1.write(out, ind);
            }),
        }
    }
}

fn write_num(x: f64, out: &mut String) {
    if !x.is_finite() {
        // JSON has no inf/nan; emit null (callers shouldn't serialize these).
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 9.0e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_seq(
    out: &mut String,
    indent: Option<usize>,
    open: char,
    close: char,
    n: usize,
    mut item: impl FnMut(&mut String, usize, Option<usize>),
) {
    out.push(open);
    if n == 0 {
        out.push(close);
        return;
    }
    let child = indent.map(|d| d + 1);
    for i in 0..n {
        if let Some(d) = child {
            out.push('\n');
            out.push_str(&"  ".repeat(d));
        }
        item(out, i, child);
        if i + 1 < n {
            out.push(',');
            if child.is_none() {
                out.push(' ');
            }
        }
    }
    if let Some(d) = indent {
        out.push('\n');
        out.push_str(&"  ".repeat(d));
    }
    out.push(close);
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        self.ws();
        let mut kvs = Vec::new();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            kvs.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        self.ws();
        let mut items = Vec::new();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let code = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                hi
                            };
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("invalid codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // Re-decode the UTF-8 sequence starting at i-1.
                    let start = self.i - 1;
                    let text = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.i = start + ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e3 ").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": null}"#).unwrap();
        assert_eq!(v.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.path("a").unwrap().as_arr().unwrap()[2].path("b"),
            Some(&Json::Bool(false))
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn string_escapes_round_trip() {
        let s = "line\nquote\"back\\slash\ttab\u{1F600}é";
        let json = Json::Str(s.to_string()).to_string_compact();
        assert_eq!(Json::parse(&json).unwrap(), Json::Str(s.to_string()));
    }

    #[test]
    fn unicode_escape_and_surrogates() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é\u{1F600}".into())
        );
    }

    #[test]
    fn round_trip_pretty_and_compact() {
        let v = Json::obj(vec![
            ("x", Json::Num(1.25)),
            ("y", Json::Arr(vec![Json::Bool(true), Json::Null])),
            ("s", Json::Str("a\"b".into())),
            ("empty", Json::Obj(vec![])),
        ]);
        for text in [v.to_string_pretty(), v.to_string_compact()] {
            assert_eq!(Json::parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string_compact(), "3");
        assert_eq!(Json::Num(3.5).to_string_compact(), "3.5");
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "\"abc", "tru", "{\"a\" 1}", "[1] x", "{'a':1}"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
          "version": 1,
          "artifacts": {
            "voltage_opt_prop": {
              "path": "voltage_opt_prop.hlo.txt",
              "args": [{"shape": [13], "dtype": "f32"}],
              "meta": {"nv": 13, "batch": 64}
            }
          }
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.path("version").unwrap().as_usize(), Some(1));
        assert_eq!(
            v.path("artifacts.voltage_opt_prop.meta.nv").unwrap().as_usize(),
            Some(13)
        );
    }
}
