//! Minimal property-testing harness (proptest is unavailable offline).
//!
//! `check` runs a property over `n` seeded-random cases; on failure it
//! reports the failing seed so the case can be replayed deterministically:
//!
//! ```text
//! use wavescale::util::prop;
//! prop::check("sort is idempotent", 100, |rng| {
//!     let mut v: Vec<u64> = (0..rng.index(1, 50)).map(|_| rng.next_u64()).collect();
//!     v.sort_unstable();
//!     let w = {
//!         let mut w = v.clone();
//!         w.sort_unstable();
//!         w
//!     };
//!     prop::assert_that(v == w, "double sort differs")
//! });
//! ```

use crate::util::prng::Rng;

/// Result of a single property case.
pub type CaseResult = Result<(), String>;

/// Convenience assertion for property bodies.
pub fn assert_that(cond: bool, msg: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Assert two f64s agree to a tolerance.
pub fn assert_close(a: f64, b: f64, tol: f64, label: &str) -> CaseResult {
    if (a - b).abs() <= tol + tol * a.abs().max(b.abs()) {
        Ok(())
    } else {
        Err(format!("{label}: {a} != {b} (tol {tol})"))
    }
}

/// Run `property` over `n` cases derived from a base seed (env
/// `WAVESCALE_PROP_SEED` overrides for replay). Panics with the failing
/// seed + message on the first failure.
pub fn check(name: &str, n: usize, mut property: impl FnMut(&mut Rng) -> CaseResult) {
    let base = std::env::var("WAVESCALE_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0x5EED_2019);
    for case in 0..n {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed on case {case}/{n} \
                 (replay with WAVESCALE_PROP_SEED={base}, case seed {seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always ok", 25, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails' failed")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |rng| {
            assert_that(rng.f64() < 0.5, "value too large")
        });
    }

    #[test]
    fn assert_close_tolerance() {
        assert!(assert_close(1.0, 1.0 + 1e-12, 1e-9, "x").is_ok());
        assert!(assert_close(1.0, 1.1, 1e-3, "x").is_err());
    }
}
