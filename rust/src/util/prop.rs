//! Minimal property-testing harness (proptest is unavailable offline).
//!
//! `check` runs a property over `n` seeded-random cases; on failure it
//! reports the failing seed so the case can be replayed deterministically:
//!
//! ```text
//! use wavescale::util::prop;
//! prop::check("sort is idempotent", 100, |rng| {
//!     let mut v: Vec<u64> = (0..rng.index(1, 50)).map(|_| rng.next_u64()).collect();
//!     v.sort_unstable();
//!     let w = {
//!         let mut w = v.clone();
//!         w.sort_unstable();
//!         w
//!     };
//!     prop::assert_that(v == w, "double sort differs")
//! });
//! ```
//!
//! `check_shrink` splits a property into an input generator and a
//! predicate over that input; when a case fails, the harness greedily
//! shrinks the input through [`Shrink`] candidates and reports both the
//! original and the minimal failing input alongside the replay seed:
//!
//! ```text
//! prop::check_shrink(
//!     "sum is monotone",
//!     100,
//!     |rng| (0..rng.index(1, 50)).map(|_| rng.index(0, 10)).collect::<Vec<usize>>(),
//!     |v| prop::assert_that(v.iter().sum::<usize>() >= v.len() / 2, "sum too small"),
//! );
//! ```

use crate::util::prng::Rng;

/// Result of a single property case.
pub type CaseResult = Result<(), String>;

/// Convenience assertion for property bodies.
pub fn assert_that(cond: bool, msg: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Assert two f64s agree to a tolerance.
pub fn assert_close(a: f64, b: f64, tol: f64, label: &str) -> CaseResult {
    if (a - b).abs() <= tol + tol * a.abs().max(b.abs()) {
        Ok(())
    } else {
        Err(format!("{label}: {a} != {b} (tol {tol})"))
    }
}

/// Run `property` over `n` cases derived from a base seed (env
/// `WAVESCALE_PROP_SEED` overrides for replay). Panics with the failing
/// seed + message on the first failure.
pub fn check(name: &str, n: usize, mut property: impl FnMut(&mut Rng) -> CaseResult) {
    let base = std::env::var("WAVESCALE_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0x5EED_2019);
    for case in 0..n {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed on case {case}/{n} \
                 (replay with WAVESCALE_PROP_SEED={base}, case seed {seed}): {msg}"
            );
        }
    }
}

/// Inputs that can propose strictly smaller variants of themselves, for
/// `check_shrink`'s failure minimization. Candidates should be ordered
/// most-aggressive first (the harness takes the first that still fails).
pub trait Shrink: Clone + std::fmt::Debug {
    /// Strictly smaller candidate inputs; empty when already minimal.
    fn shrink(&self) -> Vec<Self>;
}

fn shrink_unsigned(v: u64) -> Vec<u64> {
    let mut out: Vec<u64> = [0, v / 2, v.saturating_sub(1)]
        .into_iter()
        .filter(|&c| c < v)
        .collect();
    out.sort_unstable();
    out.dedup();
    out
}

macro_rules! shrink_uint {
    ($($t:ty),*) => {$(
        impl Shrink for $t {
            fn shrink(&self) -> Vec<Self> {
                shrink_unsigned(*self as u64).into_iter().map(|v| v as $t).collect()
            }
        }
    )*};
}
shrink_uint!(usize, u64, u32, u16, u8);

impl Shrink for bool {
    fn shrink(&self) -> Vec<Self> {
        if *self { vec![false] } else { Vec::new() }
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let n = self.len();
        let mut out = Vec::new();
        if n == 0 {
            return out;
        }
        // Structural shrinks first (empty, halves, single removals),
        // then element-wise shrinks.
        out.push(Vec::new());
        if n > 1 {
            out.push(self[..n / 2].to_vec());
            out.push(self[n / 2..].to_vec());
        }
        for i in 0..n {
            let mut v = self.clone();
            v.remove(i);
            out.push(v);
        }
        for i in 0..n {
            for s in self[i].shrink() {
                let mut v = self.clone();
                v[i] = s;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> =
            self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<Self> {
        let (a, b, c) = self;
        let mut out: Vec<Self> = Vec::new();
        out.extend(a.shrink().into_iter().map(|a| (a, b.clone(), c.clone())));
        out.extend(b.shrink().into_iter().map(|b| (a.clone(), b, c.clone())));
        out.extend(c.shrink().into_iter().map(|c| (a.clone(), b.clone(), c)));
        out
    }
}

/// Property re-evaluations the shrink loop may spend per failing case.
/// A bound, not a target: greedy descent usually converges in far fewer,
/// and the budget is only ever spent on an already-failing case.
const MAX_SHRINK_EVALS: usize = 2048;

/// Like [`check`], but with the case split into `gen` (rng → input) and
/// `property` (input → result) so a failing input can be minimized: the
/// harness greedily adopts the first [`Shrink`] candidate that still
/// fails, repeating until no candidate fails or the eval budget runs
/// out, then panics with the original input, the minimal input, and the
/// replay seed (`WAVESCALE_PROP_SEED`).
///
/// Racy properties shrink best-effort: a candidate whose failure is a
/// narrow interleaving may pass its single re-run and be skipped, so the
/// reported minimum is an upper bound on the true minimal case — the
/// original failing input is always printed for exact replay.
pub fn check_shrink<T: Shrink>(
    name: &str,
    n: usize,
    gen: impl Fn(&mut Rng) -> T,
    property: impl Fn(&T) -> CaseResult,
) {
    let base = std::env::var("WAVESCALE_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0x5EED_2019);
    for case in 0..n {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        let msg = match property(&input) {
            Ok(()) => continue,
            Err(msg) => msg,
        };

        let mut minimal = input.clone();
        let mut min_msg = msg.clone();
        let mut steps = 0usize;
        let mut evals = 0usize;
        'descend: loop {
            for cand in minimal.shrink() {
                if evals >= MAX_SHRINK_EVALS {
                    break 'descend;
                }
                evals += 1;
                if let Err(m) = property(&cand) {
                    minimal = cand;
                    min_msg = m;
                    steps += 1;
                    continue 'descend;
                }
            }
            break;
        }
        panic!(
            "property '{name}' failed on case {case}/{n} \
             (replay with WAVESCALE_PROP_SEED={base}, case seed {seed})\n\
             original input: {input:?}\n\
             original failure: {msg}\n\
             shrunk input ({steps} steps, {evals} evals): {minimal:?}\n\
             shrunk failure: {min_msg}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always ok", 25, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails' failed")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |rng| {
            assert_that(rng.f64() < 0.5, "value too large")
        });
    }

    #[test]
    fn assert_close_tolerance() {
        assert!(assert_close(1.0, 1.0 + 1e-12, 1e-9, "x").is_ok());
        assert!(assert_close(1.0, 1.1, 1e-3, "x").is_err());
    }

    #[test]
    fn unsigned_shrink_proposes_strictly_smaller_unique_candidates() {
        assert_eq!(5usize.shrink(), vec![0, 2, 4]);
        assert_eq!(2usize.shrink(), vec![0, 1]);
        assert_eq!(1usize.shrink(), vec![0]);
        assert!(0usize.shrink().is_empty());
        assert_eq!(true.shrink(), vec![false]);
        assert!(false.shrink().is_empty());
    }

    #[test]
    fn vec_shrink_covers_structure_and_elements() {
        let cands = vec![4usize, 1].shrink();
        assert!(cands.contains(&vec![]), "empty candidate missing");
        assert!(cands.contains(&vec![4]), "half candidates missing");
        assert!(cands.contains(&vec![1]), "removal candidates missing");
        assert!(cands.contains(&vec![2, 1]), "element shrink missing");
        assert!(cands.iter().all(|c| c != &vec![4, 1]), "no-op candidate");
    }

    #[test]
    fn check_shrink_passing_property_never_shrinks() {
        check_shrink("always ok", 25, |rng| rng.index(0, 100), |_| Ok(()));
    }

    /// A deterministic failure ("no element may reach 3") must minimize
    /// all the way to the boundary: greedy descent through empty / half /
    /// removal / element candidates always reaches `[3]`.
    #[test]
    fn check_shrink_minimizes_to_the_boundary() {
        let caught = std::panic::catch_unwind(|| {
            check_shrink(
                "all elements below 3",
                8,
                |rng| {
                    (0..rng.index(3, 10))
                        .map(|_| rng.index(0, 100))
                        .collect::<Vec<usize>>()
                },
                |v| assert_that(v.iter().all(|&x| x < 3), "element >= 3"),
            );
        });
        let msg = caught
            .expect_err("the property must fail")
            .downcast::<String>()
            .expect("panic payload is the formatted report");
        assert!(msg.contains("replay with WAVESCALE_PROP_SEED="), "{msg}");
        assert!(msg.contains("original input:"), "{msg}");
        assert!(msg.contains("shrunk input"), "{msg}");
        assert!(msg.contains("[3]"), "expected the minimal input [3] in: {msg}");
    }
}
