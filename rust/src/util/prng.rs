//! Deterministic PRNG: xoshiro256++ seeded through SplitMix64.
//!
//! Replacement for the unavailable `rand` crate. All stochastic components
//! (workload generation, netlist synthesis, request payloads, property
//! tests) draw from this so every experiment is reproducible from a seed.

/// xoshiro256++ (Blackman & Vigna). Passes BigCrush; 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the last Box-Muller draw.
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child stream (for per-component seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64-bit output of the generator.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    pub fn index(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli draw with success probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (spare cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid ln(0).
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.gauss_spare = Some(r * s);
        r * c
    }

    /// Normal draw with the given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with the given rate (mean 1/rate).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -(1.0 - self.f64()).ln() / rate
    }

    /// Pareto with shape `a` and minimum `xm` (heavy-tailed; the ON/OFF
    /// source durations behind self-similar traffic, Hurst H = (3-a)/2).
    pub fn pareto(&mut self, a: f64, xm: f64) -> f64 {
        debug_assert!(a > 0.0 && xm > 0.0);
        xm / (1.0 - self.f64()).powf(1.0 / a)
    }

    /// Poisson via inversion (small lambda) or normal approximation.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        debug_assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            let x = self.normal_with(lambda, lambda.sqrt());
            return x.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Uniformly pick one element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(0, xs.len())]
    }

    /// Vector of standard-normal f32s (request payloads, parameters).
    pub fn normal_vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.f64()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var {var}");
    }

    #[test]
    fn below_is_unbiased_and_bounded() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let m = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn pareto_minimum_and_heavy_tail() {
        let mut r = Rng::new(17);
        let mut above10 = 0;
        for _ in 0..100_000 {
            let x = r.pareto(1.5, 1.0);
            assert!(x >= 1.0);
            if x > 10.0 {
                above10 += 1;
            }
        }
        // P(X > 10) = 10^-1.5 ~ 3.16%
        assert!((above10 as f64 / 100_000.0 - 0.0316).abs() < 0.005);
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(19);
        for lambda in [0.5, 5.0, 200.0] {
            let n = 50_000;
            let m = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!((m - lambda).abs() < lambda.max(1.0) * 0.05, "lambda {lambda} mean {m}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(xs, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
