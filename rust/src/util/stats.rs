//! Statistics helpers: moments, percentiles, linear regression, and the
//! self-similarity estimators (Hurst exponent, index of dispersion) used to
//! validate the BURSE-substitute workload generator (DESIGN.md S8).

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0 for fewer than 2 samples.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile in [0, 100] by linear interpolation on a sorted copy.
///
/// NaN samples are a caller bug (they carry no rank): debug builds flag
/// them with a `debug_assert`, release builds filter them out and rank
/// the remaining samples — the old `sort_by(partial_cmp().unwrap())`
/// aborted the whole process on the first NaN instead.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p), "percentile {p} out of range");
    let mut s: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    debug_assert_eq!(s.len(), xs.len(), "NaN samples passed to percentile");
    if s.is_empty() {
        return 0.0;
    }
    s.sort_by(f64::total_cmp);
    let rank = p / 100.0 * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Minimum (`inf` for an empty slice).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Maximum (`-inf` for an empty slice).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Least-squares fit `y = a + b x`; returns (a, b).
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2, "need at least 2 points");
    let mx = mean(x);
    let my = mean(y);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for (xi, yi) in x.iter().zip(y) {
        sxy += (xi - mx) * (yi - my);
        sxx += (xi - mx).powi(2);
    }
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    (my - b * mx, b)
}

/// Hurst exponent via rescaled-range (R/S) analysis.
///
/// Splits the series into chunks of growing size, computes E[R/S] per size,
/// and fits log(R/S) ~ H log(n). H in (0.5, 1] indicates long-range
/// dependence; the paper's workload uses H = 0.76.
pub fn hurst_rs(xs: &[f64]) -> f64 {
    assert!(xs.len() >= 64, "R/S needs >= 64 samples, got {}", xs.len());
    let mut log_n = Vec::new();
    let mut log_rs = Vec::new();
    let mut n = 8usize;
    while n <= xs.len() / 4 {
        let mut rs_vals = Vec::new();
        for chunk in xs.chunks_exact(n) {
            let m = mean(chunk);
            let mut cum = 0.0;
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for &x in chunk {
                cum += x - m;
                lo = lo.min(cum);
                hi = hi.max(cum);
            }
            let r = hi - lo;
            let s = std_dev(chunk);
            if s > 1e-12 {
                rs_vals.push(r / s);
            }
        }
        if !rs_vals.is_empty() {
            log_n.push((n as f64).ln());
            log_rs.push(mean(&rs_vals).ln());
        }
        n *= 2;
    }
    let (_, h) = linear_fit(&log_n, &log_rs);
    h
}

/// Hurst exponent via the variance-time plot: Var(X^(m)) ~ m^(2H-2) for the
/// m-aggregated series.
pub fn hurst_variance_time(xs: &[f64]) -> f64 {
    assert!(xs.len() >= 64, "variance-time needs >= 64 samples");
    let mut log_m = Vec::new();
    let mut log_v = Vec::new();
    let mut m = 1usize;
    while m <= xs.len() / 8 {
        let agg: Vec<f64> = xs.chunks_exact(m).map(mean).collect();
        let v = variance(&agg);
        if v > 1e-15 && agg.len() >= 4 {
            log_m.push((m as f64).ln());
            log_v.push(v.ln());
        }
        m *= 2;
    }
    let (_, slope) = linear_fit(&log_m, &log_v);
    1.0 + slope / 2.0
}

/// Index of dispersion for counts at the given aggregation window:
/// IDC(w) = Var(N_w) / E[N_w] where N_w sums `w` consecutive counts.
/// Poisson gives 1; the paper's workload has IDC = 500.
pub fn idc(counts: &[f64], window: usize) -> f64 {
    assert!(window >= 1);
    let sums: Vec<f64> = counts.chunks_exact(window).map(|c| c.iter().sum()).collect();
    assert!(sums.len() >= 2, "IDC window too large for trace");
    let m = mean(&sums);
    if m <= 0.0 {
        return 0.0;
    }
    variance(&sums) / m
}

/// Lag-k autocorrelation.
pub fn autocorr(xs: &[f64], k: usize) -> f64 {
    assert!(k < xs.len());
    let m = mean(xs);
    let v = variance(xs);
    if v <= 1e-15 {
        return 0.0;
    }
    let n = xs.len() - k;
    let mut s = 0.0;
    for i in 0..n {
        s += (xs[i] - m) * (xs[i + k] - m);
    }
    s / (n as f64 * v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn mean_and_variance_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[1.0]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(median(&xs), 2.5);
        assert!((percentile(&xs, 25.0) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn percentile_never_aborts_on_nan_samples() {
        // Regression: sort_by(partial_cmp().unwrap()) panicked on the
        // first NaN. NaNs are a caller bug, so debug builds flag them
        // (debug_assert) while release builds filter and keep ranking.
        let xs = vec![1.0, f64::NAN, 3.0, 2.0];
        if cfg!(debug_assertions) {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                percentile(&xs, 50.0)
            }));
            assert!(r.is_err(), "debug builds must flag NaN samples loudly");
        } else {
            // Filtered ranking: the NaN is dropped, median of {1,2,3} = 2.
            assert_eq!(percentile(&xs, 50.0), 2.0);
            assert_eq!(percentile(&xs, 100.0), 3.0);
            // All-NaN input degrades to the empty-slice default.
            assert_eq!(percentile(&[f64::NAN, f64::NAN], 50.0), 0.0);
        }
        // NaN-free inputs are byte-for-byte unaffected by the fix.
        assert_eq!(percentile(&[2.0, 1.0, 3.0], 50.0), 2.0);
    }

    #[test]
    fn linear_fit_exact_line() {
        let x = [0.0, 1.0, 2.0, 3.0];
        let y = [1.0, 3.0, 5.0, 7.0];
        let (a, b) = linear_fit(&x, &y);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
    }

    #[test]
    fn hurst_of_iid_noise_is_half() {
        let mut r = Rng::new(1);
        let xs: Vec<f64> = (0..8192).map(|_| r.normal()).collect();
        let h = hurst_rs(&xs);
        assert!((h - 0.55).abs() < 0.12, "R/S Hurst of white noise: {h}");
        let hv = hurst_variance_time(&xs);
        assert!((hv - 0.5).abs() < 0.1, "VT Hurst of white noise: {hv}");
    }

    #[test]
    fn hurst_of_trend_is_high() {
        // A strongly persistent series (random walk increments smoothed).
        let mut r = Rng::new(2);
        let mut xs = vec![0.0f64; 8192];
        let mut level = 0.0;
        for x in xs.iter_mut() {
            level = 0.995 * level + r.normal() * 0.1;
            *x = level;
        }
        let h = hurst_variance_time(&xs);
        assert!(h > 0.8, "persistent series Hurst: {h}");
    }

    #[test]
    fn idc_of_poisson_is_one() {
        let mut r = Rng::new(3);
        let counts: Vec<f64> = (0..50_000).map(|_| r.poisson(10.0) as f64).collect();
        let d = idc(&counts, 1);
        assert!((d - 1.0).abs() < 0.05, "Poisson IDC: {d}");
    }

    #[test]
    fn idc_detects_burstiness() {
        // ON/OFF bursts => IDC >> 1 at moderate windows.
        let mut r = Rng::new(4);
        let mut counts = Vec::with_capacity(32_768);
        let mut on = true;
        while counts.len() < 32_768 {
            let dur = r.pareto(1.4, 16.0).min(4000.0) as usize;
            for _ in 0..dur.min(32_768 - counts.len()) {
                counts.push(if on { r.poisson(100.0) as f64 } else { 0.0 });
            }
            on = !on;
        }
        let d = idc(&counts, 64);
        assert!(d > 50.0, "bursty IDC: {d}");
    }

    #[test]
    fn autocorr_bounds() {
        let mut r = Rng::new(5);
        let xs: Vec<f64> = (0..4096).map(|_| r.normal()).collect();
        assert!((autocorr(&xs, 0) - 1.0).abs() < 1e-9);
        assert!(autocorr(&xs, 1).abs() < 0.06);
    }
}
