//! Text tables and CSV emission for experiments and benches.

/// Render an aligned text table. `rows` include the header as row 0.
pub fn table(rows: &[Vec<String>]) -> String {
    if rows.is_empty() {
        return String::new();
    }
    let cols = rows.iter().map(Vec::len).max().unwrap();
    let mut width = vec![0usize; cols];
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            width[i] = width[i].max(cell.len());
        }
    }
    let mut out = String::new();
    for (ri, row) in rows.iter().enumerate() {
        for (i, cell) in row.iter().enumerate() {
            if i > 0 {
                out.push_str("  ");
            }
            out.push_str(&format!("{cell:<w$}", w = width[i]));
        }
        out.push('\n');
        if ri == 0 {
            for (i, w) in width.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(&"-".repeat(*w));
            }
            out.push('\n');
        }
    }
    out
}

/// Render rows as CSV (no quoting needed for our numeric content).
pub fn csv(rows: &[Vec<String>]) -> String {
    rows.iter()
        .map(|r| r.join(","))
        .collect::<Vec<_>>()
        .join("\n")
        + "\n"
}

/// Write results under `results/<name>` (directory created on demand).
pub fn write_results(name: &str, content: &str) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, content)?;
    Ok(path)
}

/// Build a table/CSV row from string literals.
pub fn row<const N: usize>(cells: [&str; N]) -> Vec<String> {
    cells.iter().map(|s| s.to_string()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_aligns_columns() {
        let t = table(&[
            row(["name", "gain"]),
            row(["tabla", "4.1x"]),
            row(["dnnweaver-long", "4.4x"]),
        ]);
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[1].starts_with("----"));
        // Columns align: "gain" starts at the same offset everywhere.
        let off = lines[0].find("gain").unwrap();
        assert_eq!(lines[2].find("4.1x").unwrap(), off);
    }

    #[test]
    fn csv_rows() {
        let c = csv(&[row(["a", "b"]), row(["1", "2"])]);
        assert_eq!(c, "a,b\n1,2\n");
    }
}
