//! Static timing analysis over synthetic netlists — the VTR timing-analyzer
//! substitute (DESIGN.md S4).
//!
//! A single topological pass computes arrival times with per-class,
//! voltage-dependent delays from the characterization library; backtracking
//! yields the critical path and its per-class delay decomposition — the
//! `alpha` parameter of Eq. (1) and the per-class weights the rail-level
//! delay tables are built from.
//!
//! Because scaling `Vcore`/`Vbram` can promote an originally non-critical
//! path (the paper's §II criticism of Zhao et al.), `analyze` also returns
//! the top-K endpoint path compositions; the optimizer checks feasibility
//! against *all* of them, not just the nominal critical path.

use crate::chars::{CharLibrary, ResourceClass};
use crate::netlist::{Netlist, NodeKind};

/// Absolute delay calibration at nominal voltages (ns). Tuned together
/// with `arch::benchmarks::TABLE1::cp_logic_depth` so synthetic STA lands
/// near the paper's Table I Fmax (see `table1_fmax_within_tolerance`).
#[derive(Clone, Copy, Debug)]
pub struct DelayParams {
    /// LUT stage delay (ns).
    pub lut_ns: f64,
    /// Delay per routed wire segment (ns).
    pub route_seg_ns: f64,
    /// BRAM access delay (ns).
    pub bram_ns: f64,
    /// DSP macro delay (ns).
    pub dsp_ns: f64,
}

impl Default for DelayParams {
    fn default() -> Self {
        DelayParams { lut_ns: 0.40, route_seg_ns: 0.20, bram_ns: 2.0, dsp_ns: 2.5 }
    }
}

/// Per-class delay scale multipliers (1.0 = nominal voltage).
#[derive(Clone, Copy, Debug)]
pub struct DelayScales {
    /// Logic delay multiplier.
    pub logic: f64,
    /// Routing delay multiplier.
    pub routing: f64,
    /// BRAM delay multiplier.
    pub bram: f64,
    /// DSP delay multiplier.
    pub dsp: f64,
}

impl DelayScales {
    /// All classes at nominal voltage (1.0 everywhere).
    pub const NOMINAL: DelayScales =
        DelayScales { logic: 1.0, routing: 1.0, bram: 1.0, dsp: 1.0 };

    /// Scales at the given rail voltages.
    pub fn at(chars: &CharLibrary, vcore: f64, vbram: f64) -> Self {
        DelayScales {
            logic: chars.delay_scale(ResourceClass::Logic, vcore),
            routing: chars.delay_scale(ResourceClass::Routing, vcore),
            bram: chars.delay_scale(ResourceClass::Bram, vbram),
            dsp: chars.delay_scale(ResourceClass::Dsp, vcore),
        }
    }
}

/// Per-class delay decomposition of one register-to-register path (ns,
/// at nominal voltage).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PathComposition {
    /// LUT delay on the path (ns).
    pub logic_ns: f64,
    /// Routing delay on the path (ns).
    pub routing_ns: f64,
    /// BRAM delay on the path (ns).
    pub bram_ns: f64,
    /// DSP delay on the path (ns).
    pub dsp_ns: f64,
}

impl PathComposition {
    /// Total path delay at nominal voltage (ns).
    pub fn total_ns(&self) -> f64 {
        self.logic_ns + self.routing_ns + self.bram_ns + self.dsp_ns
    }

    /// Delay on the core rail (logic + routing + DSP).
    pub fn core_ns(&self) -> f64 {
        self.logic_ns + self.routing_ns + self.dsp_ns
    }

    /// Eq. (1)'s `alpha`: BRAM share of the path relative to core delay.
    pub fn alpha(&self) -> f64 {
        if self.core_ns() <= 0.0 {
            0.0
        } else {
            self.bram_ns / self.core_ns()
        }
    }

    /// Path delay under per-class scales.
    pub fn delay_at(&self, s: &DelayScales) -> f64 {
        self.logic_ns * s.logic
            + self.routing_ns * s.routing
            + self.bram_ns * s.bram
            + self.dsp_ns * s.dsp
    }
}

/// STA result at nominal voltage.
#[derive(Clone, Debug)]
pub struct TimingReport {
    /// Critical-path delay decomposition.
    pub cp: PathComposition,
    /// Node ids along the critical path, source to endpoint.
    pub cp_nodes: Vec<u32>,
    /// Maximum frequency (MHz) = 1000 / cp delay.
    pub fmax_mhz: f64,
    /// Distinct near-critical path compositions (cp first), for the
    /// optimizer's multi-path feasibility check.
    pub top_paths: Vec<PathComposition>,
}

fn node_class(kind: NodeKind) -> Option<ResourceClass> {
    match kind {
        NodeKind::Lut => Some(ResourceClass::Logic),
        NodeKind::Bram => Some(ResourceClass::Bram),
        NodeKind::Dsp => Some(ResourceClass::Dsp),
        NodeKind::Input | NodeKind::Output => None,
    }
}

fn node_delay(kind: NodeKind, d: &DelayParams, s: &DelayScales) -> f64 {
    match kind {
        NodeKind::Lut => d.lut_ns * s.logic,
        NodeKind::Bram => d.bram_ns * s.bram,
        NodeKind::Dsp => d.dsp_ns * s.dsp,
        NodeKind::Input | NodeKind::Output => 0.0,
    }
}

/// Arrival-time pass. Returns (arrival, pred_edge) or an error if the
/// netlist has a cycle.
fn arrivals(
    net: &Netlist,
    d: &DelayParams,
    s: &DelayScales,
) -> Result<(Vec<f64>, Vec<i64>), String> {
    let n = net.kinds.len();
    // Fan-out CSR.
    let mut deg = vec![0u32; n + 1];
    for e in &net.edges {
        deg[e.src as usize + 1] += 1;
    }
    for i in 0..n {
        deg[i + 1] += deg[i];
    }
    let mut pos = deg.clone();
    let mut out_edges = vec![0u32; net.edges.len()];
    let mut indeg = vec![0u32; n];
    for (ei, e) in net.edges.iter().enumerate() {
        out_edges[pos[e.src as usize] as usize] = ei as u32;
        pos[e.src as usize] += 1;
        indeg[e.dst as usize] += 1;
    }

    let mut arrival = vec![0.0f64; n];
    let mut pred = vec![-1i64; n];
    let mut queue: Vec<u32> = (0..n as u32).filter(|&i| indeg[i as usize] == 0).collect();
    let mut head = 0;
    let mut seen = queue.len();
    while head < queue.len() {
        let u = queue[head] as usize;
        head += 1;
        let leave = arrival[u] + node_delay(net.kinds[u], d, s);
        for &ei in &out_edges[deg[u] as usize..deg[u + 1] as usize] {
            let e = &net.edges[ei as usize];
            let t = leave + e.segments as f64 * d.route_seg_ns * s.routing;
            let v = e.dst as usize;
            if t > arrival[v] {
                arrival[v] = t;
                pred[v] = ei as i64;
            }
            indeg[v] -= 1;
            if indeg[v] == 0 {
                queue.push(e.dst);
                seen += 1;
            }
        }
    }
    if seen != n {
        return Err(format!("netlist {} contains a combinational cycle", net.name));
    }
    Ok((arrival, pred))
}

fn backtrack(
    net: &Netlist,
    d: &DelayParams,
    pred: &[i64],
    endpoint: u32,
) -> (PathComposition, Vec<u32>) {
    let mut comp = PathComposition::default();
    let mut nodes = vec![endpoint];
    let mut cur = endpoint as usize;
    while pred[cur] >= 0 {
        let e = &net.edges[pred[cur] as usize];
        comp.routing_ns += e.segments as f64 * d.route_seg_ns;
        let src = e.src as usize;
        match node_class(net.kinds[src]) {
            Some(ResourceClass::Logic) => comp.logic_ns += d.lut_ns,
            Some(ResourceClass::Bram) => comp.bram_ns += d.bram_ns,
            Some(ResourceClass::Dsp) => comp.dsp_ns += d.dsp_ns,
            _ => {}
        }
        nodes.push(e.src);
        cur = src;
    }
    nodes.reverse();
    (comp, nodes)
}

/// Full STA at nominal voltage; `top_k` bounds the near-critical path set.
pub fn analyze(net: &Netlist, d: &DelayParams, top_k: usize) -> Result<TimingReport, String> {
    let (arrival, pred) = arrivals(net, d, &DelayScales::NOMINAL)?;

    // Rank endpoints (output nodes) by arrival.
    let mut endpoints: Vec<u32> = (0..net.kinds.len() as u32)
        .filter(|&i| net.kinds[i as usize] == NodeKind::Output)
        .collect();
    if endpoints.is_empty() {
        return Err("netlist has no outputs".into());
    }
    // total_cmp: arrivals are finite here, but a NaN from a degenerate
    // delay model must not panic the ranking or make it order-unstable.
    endpoints.sort_by(|&a, &b| arrival[b as usize].total_cmp(&arrival[a as usize]));

    let (cp, cp_nodes) = backtrack(net, d, &pred, endpoints[0]);
    let mut top_paths = vec![cp];
    for &ep in endpoints.iter().skip(1).take(top_k.saturating_sub(1) * 4) {
        if top_paths.len() >= top_k {
            break;
        }
        let (comp, _) = backtrack(net, d, &pred, ep);
        let dup = top_paths.iter().any(|p| {
            (p.logic_ns - comp.logic_ns).abs() < 1e-9
                && (p.routing_ns - comp.routing_ns).abs() < 1e-9
                && (p.bram_ns - comp.bram_ns).abs() < 1e-9
                && (p.dsp_ns - comp.dsp_ns).abs() < 1e-9
        });
        if !dup {
            top_paths.push(comp);
        }
    }

    let total = cp.total_ns();
    Ok(TimingReport {
        cp,
        cp_nodes,
        fmax_mhz: 1_000.0 / total,
        top_paths,
    })
}

/// Critical-path delay (ns) with the full netlist re-analyzed at the given
/// rail voltages — ground truth for validating the analytic rail model.
pub fn cp_delay_at(
    net: &Netlist,
    d: &DelayParams,
    chars: &CharLibrary,
    vcore: f64,
    vbram: f64,
) -> Result<f64, String> {
    let s = DelayScales::at(chars, vcore, vbram);
    if !(s.logic.is_finite() && s.routing.is_finite() && s.bram.is_finite() && s.dsp.is_finite())
    {
        return Ok(f64::INFINITY);
    }
    let (arrival, _) = arrivals(net, d, &s)?;
    Ok(arrival
        .iter()
        .zip(&net.kinds)
        .filter(|(_, k)| **k == NodeKind::Output)
        .map(|(a, _)| *a)
        .fold(0.0, f64::max))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::TABLE1;
    use crate::netlist::gen::{generate, GenConfig};
    use crate::netlist::{Edge, Netlist, NodeKind};

    fn chain() -> Netlist {
        // in -> lut -> bram -> lut -> out, all 2-segment edges.
        Netlist {
            name: "chain".into(),
            kinds: vec![
                NodeKind::Input,
                NodeKind::Lut,
                NodeKind::Bram,
                NodeKind::Lut,
                NodeKind::Output,
            ],
            edges: vec![
                Edge { src: 0, dst: 1, segments: 2 },
                Edge { src: 1, dst: 2, segments: 2 },
                Edge { src: 2, dst: 3, segments: 2 },
                Edge { src: 3, dst: 4, segments: 2 },
            ],
        }
    }

    #[test]
    fn chain_cp_is_exact() {
        let d = DelayParams::default();
        let r = analyze(&chain(), &d, 4).unwrap();
        // 2 LUTs + 1 BRAM + 8 segments.
        let want = 2.0 * d.lut_ns + d.bram_ns + 8.0 * d.route_seg_ns;
        assert!((r.cp.total_ns() - want).abs() < 1e-9, "{}", r.cp.total_ns());
        assert_eq!(r.cp_nodes, vec![0, 1, 2, 3, 4]);
        assert!((r.cp.alpha() - d.bram_ns / (2.0 * d.lut_ns + 8.0 * d.route_seg_ns)).abs() < 1e-9);
    }

    #[test]
    fn cycle_is_rejected() {
        let mut n = chain();
        n.edges.push(Edge { src: 3, dst: 1, segments: 1 });
        assert!(analyze(&n, &DelayParams::default(), 4).is_err());
    }

    #[test]
    fn table1_fmax_within_tolerance() {
        // The synthetic netlists must land near the paper's Table I Fmax.
        let d = DelayParams::default();
        for spec in TABLE1 {
            let net = generate(spec, &GenConfig { scale: 0.05, seed: 2019, luts_per_lab: 10 });
            let r = analyze(&net, &d, 8).unwrap();
            let err = (r.fmax_mhz - spec.freq_mhz).abs() / spec.freq_mhz;
            assert!(
                err < 0.20,
                "{}: fmax {:.1} MHz vs Table I {:.1} MHz ({:.0}% off)",
                spec.name,
                r.fmax_mhz,
                spec.freq_mhz,
                err * 100.0
            );
        }
    }

    #[test]
    fn table1_alpha_is_plausible_and_similar() {
        // Paper §VI.B: "BRAM delay contributes to a similar portion of
        // critical path delay in all of our accelerators".
        let d = DelayParams::default();
        let mut alphas = Vec::new();
        for spec in TABLE1 {
            let net = generate(spec, &GenConfig { scale: 0.05, seed: 2019, luts_per_lab: 10 });
            let r = analyze(&net, &d, 8).unwrap();
            assert!(spec.cp_has_bram, "{}", spec.name);
            assert!(
                r.cp.bram_ns > 0.0,
                "{}: BRAM must be on the critical path",
                spec.name
            );
            alphas.push(r.cp.alpha());
        }
        for &a in &alphas {
            assert!((0.05..0.6).contains(&a), "alpha out of range: {alphas:?}");
        }
    }

    #[test]
    fn voltage_scaling_increases_cp() {
        let chars = CharLibrary::stratix_iv_22nm();
        let d = DelayParams::default();
        let net = generate(
            TABLE1.iter().find(|s| s.name == "tabla").unwrap(),
            &GenConfig { scale: 0.05, seed: 2019, luts_per_lab: 10 },
        );
        let nom = cp_delay_at(&net, &d, &chars, 0.80, 0.95).unwrap();
        let r = analyze(&net, &d, 4).unwrap();
        assert!((nom - r.cp.total_ns()).abs() < 1e-6);
        let mut prev = nom;
        for (vc, vb) in [(0.75, 0.9), (0.7, 0.85), (0.65, 0.8), (0.6, 0.75)] {
            let dly = cp_delay_at(&net, &d, &chars, vc, vb).unwrap();
            assert!(dly >= prev - 1e-9, "cp not monotone at ({vc},{vb})");
            prev = dly;
        }
        assert!(cp_delay_at(&net, &d, &chars, 0.45, 0.95).unwrap().is_infinite());
    }

    #[test]
    fn analytic_rail_model_tracks_full_sta() {
        // The multi-path analytic model (max over top-K compositions) must
        // stay close to ground-truth STA under moderate scaling.
        let chars = CharLibrary::stratix_iv_22nm();
        let d = DelayParams::default();
        for spec in TABLE1 {
            let net = generate(spec, &GenConfig { scale: 0.05, seed: 2019, luts_per_lab: 10 });
            let r = analyze(&net, &d, 8).unwrap();
            for (vc, vb) in [(0.75, 0.90), (0.70, 0.85), (0.65, 0.80)] {
                let truth = cp_delay_at(&net, &d, &chars, vc, vb).unwrap();
                let s = DelayScales::at(&chars, vc, vb);
                let model = r
                    .top_paths
                    .iter()
                    .map(|p| p.delay_at(&s))
                    .fold(0.0, f64::max);
                let err = (truth - model).abs() / truth;
                assert!(
                    err < 0.10,
                    "{} at ({vc},{vb}): model {model:.2} vs STA {truth:.2} ({:.1}% off)",
                    spec.name,
                    err * 100.0
                );
            }
        }
    }

    #[test]
    fn top_paths_are_deduped_and_bounded() {
        let d = DelayParams::default();
        let net = generate(
            TABLE1.iter().find(|s| s.name == "dnnweaver").unwrap(),
            &GenConfig { scale: 0.05, seed: 2019, luts_per_lab: 10 },
        );
        let r = analyze(&net, &d, 5).unwrap();
        assert!(!r.top_paths.is_empty() && r.top_paths.len() <= 5);
        assert_eq!(r.top_paths[0], r.cp);
    }
}
