//! Adaptive QoS-feedback guardband (DESIGN.md S7.1): replaces the static
//! t% throughput margin with a closed loop on the observed per-tenant
//! violation rate — the paper's "adjustment to the workload" (§IV.A).
//!
//! Control law per epoch:
//! * **decay** — once a *full* rolling window shows the violation rate at
//!   or under the QoS target, clean epochs multiplicatively shrink the
//!   margin toward `margin_min`; while the window is short or the rate
//!   exceeds the target, the floor is the static margin, so the adaptive
//!   path never undercuts the baseline until the workload has earned it;
//! * **boost** — an under-prediction or a capacity violation immediately
//!   raises the margin back up (additive step, clamped at `margin_max`),
//!   and with it — via the margin LUT ladder, within the LUT's own slack
//!   — the frequency published for the next epoch.
//!
//! `margin_max` defaults to the static margin: the controller's default
//! contract is *pareto-no-worse* than the fixed t% baseline — equal
//! margin whenever QoS is at any risk, smaller margin (= less energy)
//! only in provably quiet regimes. Deployments chasing a tighter QoS
//! target than the static margin delivers can raise `margin_max` (the
//! controller pre-builds one LUT per ladder level up to the cap; the
//! default ladder extends to 40%) and buy violations down with energy.

use std::collections::VecDeque;

/// The margin levels the platform pre-computes LUTs for (design-synthesis
/// time, like every other LUT in the paper). Adaptive margins quantize
/// *up* to the next ladder level, so the applied guardband is never
/// smaller than requested. Sorted ascending; contains the default static
/// margin (0.05) so a guardband pinned at its cap reproduces the static
/// baseline exactly.
pub const MARGIN_LADDER: [f64; 10] =
    [0.0, 0.01, 0.02, 0.03, 0.05, 0.08, 0.12, 0.20, 0.30, 0.40];

/// Index of the smallest level in `margins` (sorted ascending) that is
/// `>= margin` — last level when the request exceeds them all. Platforms
/// call this against their *own* level list, which is [`MARGIN_LADDER`]
/// plus the configured static margin when that is not already a ladder
/// level (so a non-ladder `margin_t` stays exactly representable and the
/// pareto-no-worse cap holds for any configuration).
pub fn level_for(margins: &[f64], margin: f64) -> usize {
    margins
        .iter()
        .position(|&m| m >= margin - 1e-12)
        .unwrap_or(margins.len().saturating_sub(1))
}

/// [`level_for`] over the default [`MARGIN_LADDER`].
pub fn ladder_level(margin: f64) -> usize {
    level_for(&MARGIN_LADDER, margin)
}

/// The margin levels a platform should pre-build LUTs for: the default
/// ladder, with `static_margin` spliced in when it is not already a
/// level. Sorted ascending.
pub fn ladder_with(static_margin: f64) -> Vec<f64> {
    let mut margins = MARGIN_LADDER.to_vec();
    if !margins.iter().any(|&m| (m - static_margin).abs() < 1e-12) {
        margins.push(static_margin);
        margins.sort_by(f64::total_cmp);
    }
    margins
}

/// The margin levels to pre-build LUTs for under a specific guardband
/// configuration — THE level list the controller and
/// [`Guardband::applied_margin`] share, so the applied quantization can
/// never disagree with the built tables. [`ladder_with`] splices in the
/// static margin, `margin_max` is spliced the same way (a raised
/// non-ladder cap must be exactly representable, or the quantize-up
/// contract would silently quantize *down* at the cap), and levels
/// above the cap are dropped: the guardband clamps at `margin_max`, so
/// they could never be selected and building them is pure waste.
pub fn levels(cfg: &GuardbandConfig) -> Vec<f64> {
    let mut margins = ladder_with(cfg.static_margin);
    if !margins.iter().any(|&m| (m - cfg.margin_max).abs() < 1e-12) {
        margins.push(cfg.margin_max);
        margins.sort_by(f64::total_cmp);
    }
    margins.retain(|&m| m <= cfg.margin_max + 1e-12);
    margins
}

/// Tuning of the [`Guardband`] control loop.
#[derive(Clone, Copy, Debug)]
pub struct GuardbandConfig {
    /// Target per-tenant violation rate (fraction of epochs).
    pub qos_target: f64,
    /// Lowest margin the controller may reach with a clean full window.
    pub margin_min: f64,
    /// Hard upper bound on the margin. Defaults to the static margin
    /// (pareto-no-worse contract); raise it to trade energy for QoS.
    pub margin_max: f64,
    /// Additive margin boost per under-prediction / violation epoch.
    pub boost: f64,
    /// Multiplicative decay per clean epoch (towards the active floor).
    pub decay: f64,
    /// Rolling window (epochs) the violation rate is measured over; the
    /// margin may not decay below the static margin until the window has
    /// filled once.
    pub window: usize,
    /// Floor used while QoS is unproven (short window) or at risk (rate
    /// above target) — the static margin, so the adaptive path never
    /// does worse than the baseline when it matters.
    pub static_margin: f64,
}

impl GuardbandConfig {
    /// Defaults around a static margin `t` and a violation-rate target.
    pub fn new(static_margin: f64, qos_target: f64) -> Self {
        GuardbandConfig {
            qos_target,
            margin_min: 0.0,
            margin_max: static_margin,
            boost: static_margin.max(0.01),
            decay: 0.97,
            window: 32,
            static_margin,
        }
    }
}

/// Online margin controller fed one `(violated, under_predicted)`
/// observation per epoch.
#[derive(Clone, Debug)]
pub struct Guardband {
    cfg: GuardbandConfig,
    margin: f64,
    window: VecDeque<bool>,
    violations_in_window: usize,
    boosts: usize,
}

impl Guardband {
    /// Start at the static margin: the controller must *earn* a smaller
    /// guardband with a full clean violation window.
    pub fn new(cfg: GuardbandConfig) -> Self {
        let margin = cfg.static_margin.clamp(cfg.margin_min, cfg.margin_max);
        Guardband { cfg, margin, window: VecDeque::new(), violations_in_window: 0, boosts: 0 }
    }

    /// The continuous margin the controller currently requests.
    pub fn margin(&self) -> f64 {
        self.margin
    }

    /// The ladder level actually applied for the current margin —
    /// quantized against [`levels`]`(cfg)`, the exact level list the
    /// controller builds LUTs for, so a non-ladder static margin or
    /// raised `margin_max` reports its own exact cap level instead of
    /// over- (or under-) quantizing to a neighbouring default level.
    pub fn applied_margin(&self) -> f64 {
        let margins = levels(&self.cfg);
        margins[level_for(&margins, self.margin)]
    }

    /// Rolling violation rate over the configured window (0 when empty).
    pub fn violation_rate(&self) -> f64 {
        if self.window.is_empty() {
            return 0.0;
        }
        self.violations_in_window as f64 / self.window.len() as f64
    }

    /// Times the boost path has fired.
    pub fn boost_count(&self) -> usize {
        self.boosts
    }

    /// Feed one epoch's outcome and update the margin.
    pub fn observe(&mut self, violated: bool, under_predicted: bool) {
        self.window.push_back(violated);
        if violated {
            self.violations_in_window += 1;
        }
        while self.window.len() > self.cfg.window {
            if self.window.pop_front() == Some(true) {
                self.violations_in_window -= 1;
            }
        }
        if under_predicted || violated {
            // Immediate correction (paper §IV.A): the next epoch's
            // published frequency rises with the margin, within the LUT's
            // slack (clamped at margin_max / nominal frequency).
            self.margin = (self.margin + self.cfg.boost).min(self.cfg.margin_max);
            self.boosts += 1;
        } else {
            let proven = self.window.len() >= self.cfg.window
                && self.violation_rate() <= self.cfg.qos_target;
            let floor = if proven { self.cfg.margin_min } else { self.cfg.static_margin };
            self.margin = (self.margin * self.cfg.decay)
                .max(floor)
                .min(self.cfg.margin_max);
            // Multiplicative decay never reaches the floor exactly; snap
            // once the gap is immaterial so "fully decayed" is a stable
            // state (and ladder level 0 is actually reachable).
            if self.margin - floor < 1e-3 {
                self.margin = floor;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gb() -> Guardband {
        Guardband::new(GuardbandConfig::new(0.05, 0.01))
    }

    #[test]
    fn ladder_quantizes_up_and_contains_static_margin() {
        assert_eq!(MARGIN_LADDER[ladder_level(0.0)], 0.0);
        assert_eq!(MARGIN_LADDER[ladder_level(0.05)], 0.05, "static margin is a level");
        assert_eq!(MARGIN_LADDER[ladder_level(0.051)], 0.08, "quantize up, never down");
        assert_eq!(MARGIN_LADDER[ladder_level(0.019)], 0.02);
        assert_eq!(MARGIN_LADDER[ladder_level(9.9)], 0.40, "clamped at the top level");
        for w in MARGIN_LADDER.windows(2) {
            assert!(w[0] < w[1], "ladder must be sorted ascending");
        }
    }

    #[test]
    fn holds_static_margin_until_a_full_clean_window_then_decays() {
        let mut g = gb();
        assert!((g.margin() - 0.05).abs() < 1e-12);
        // Short window: even violation-free epochs may not undercut the
        // static baseline yet.
        for i in 0..31 {
            g.observe(false, false);
            assert!(
                (g.margin() - 0.05).abs() < 1e-12,
                "epoch {i}: margin {} moved before the window filled",
                g.margin()
            );
        }
        // Full clean window: decay toward margin_min, snapping to 0.
        for _ in 0..300 {
            g.observe(false, false);
        }
        assert_eq!(g.margin(), 0.0, "clean full window decays to min");
        assert_eq!(g.applied_margin(), 0.0);
        assert_eq!(g.boost_count(), 0);
    }

    #[test]
    fn under_prediction_restores_the_margin_immediately() {
        let mut g = gb();
        for _ in 0..100 {
            g.observe(false, false);
        }
        let before = g.margin();
        assert!(before < 0.02, "decayed first: {before}");
        g.observe(false, true);
        assert!(
            (g.margin() - 0.05).abs() < 1e-12,
            "an under-prediction must boost straight back to the cap: {}",
            g.margin()
        );
        assert_eq!(g.boost_count(), 1);
        // A violation (even without a bin-level under-prediction) boosts
        // too, from a decayed level.
        let mut g = gb();
        for _ in 0..100 {
            g.observe(false, false);
        }
        g.observe(true, false);
        assert!((g.margin() - 0.05).abs() < 1e-12);
    }

    #[test]
    fn margin_never_exceeds_the_static_cap_by_default() {
        // The pareto-no-worse contract: whatever happens, the default
        // guardband never spends more margin (= energy) than the static
        // baseline.
        let mut g = gb();
        for _ in 0..50 {
            g.observe(true, true);
        }
        assert!((g.margin() - 0.05).abs() < 1e-12, "capped at static: {}", g.margin());
    }

    #[test]
    fn raised_margin_max_buys_headroom_above_static() {
        let cfg = GuardbandConfig { margin_max: 0.40, ..GuardbandConfig::new(0.05, 0.01) };
        let mut g = Guardband::new(cfg);
        for _ in 0..50 {
            g.observe(true, true);
        }
        assert!((g.margin() - 0.40).abs() < 1e-12, "climbs to the raised cap");
        assert_eq!(g.applied_margin(), 0.40);
    }

    #[test]
    fn decay_floors_at_static_margin_while_qos_is_at_risk() {
        let mut g = gb();
        // A violation up front: the window holds it for 32 observations,
        // so clean epochs may not undercut the static margin yet.
        g.observe(true, true);
        for _ in 0..30 {
            g.observe(false, false);
        }
        assert!(g.violation_rate() > 0.01);
        assert!((g.margin() - 0.05).abs() < 1e-9, "floored at static: {}", g.margin());
        // Once the violation leaves the window the floor drops to min.
        for _ in 0..60 {
            g.observe(false, false);
        }
        assert!(g.violation_rate() <= 0.01);
        assert!(g.margin() < 0.05, "decays once QoS is proven: {}", g.margin());
    }

    #[test]
    fn non_ladder_static_margins_get_their_own_level() {
        // A configured margin_t of e.g. 6% is not a default ladder level;
        // quantizing it up to 8% would overspend the static baseline and
        // break the pareto contract. ladder_with splices it in.
        let margins = ladder_with(0.06);
        assert_eq!(margins.len(), MARGIN_LADDER.len() + 1);
        assert_eq!(margins[level_for(&margins, 0.06)], 0.06, "exact cap level");
        assert_eq!(margins[level_for(&margins, 0.055)], 0.06, "quantize up to the cap");
        // Ladder-level margins splice nothing.
        assert_eq!(ladder_with(0.05).len(), MARGIN_LADDER.len());
        // level_for on a single-level list always yields that level.
        assert_eq!(level_for(&[0.07], 0.0), 0);
        assert_eq!(level_for(&[0.07], 0.2), 0);
    }

    #[test]
    fn levels_splice_a_raised_non_ladder_cap_and_truncate_above_it() {
        // A raised margin_max that is not a default ladder level (0.07)
        // must become its own exact top level — otherwise a guardband
        // pinned at its cap would be silently quantized DOWN to 0.05 in
        // exactly the QoS-risk regime — and nothing above the cap is
        // built (unreachable by the clamp).
        let cfg = GuardbandConfig { margin_max: 0.07, ..GuardbandConfig::new(0.05, 0.01) };
        let margins = levels(&cfg);
        assert_eq!(margins.last().copied(), Some(0.07), "cap is the top level");
        assert_eq!(margins[level_for(&margins, 0.07)], 0.07, "cap quantizes to itself");
        assert!(margins.iter().all(|&m| m <= 0.07 + 1e-12));
        // applied_margin agrees with the same list at the cap.
        let mut g = Guardband::new(cfg);
        for _ in 0..10 {
            g.observe(true, true);
        }
        assert!((g.margin() - 0.07).abs() < 1e-12);
        assert_eq!(g.applied_margin(), 0.07);
        // Default config: the reachable prefix of the ladder.
        let d = levels(&GuardbandConfig::new(0.05, 0.01));
        assert_eq!(d, MARGIN_LADDER[..=ladder_level(0.05)].to_vec());
    }

    #[test]
    fn rolling_window_is_bounded() {
        let mut g = gb();
        for i in 0..1000 {
            g.observe(i % 3 == 0, false);
        }
        let r = g.violation_rate();
        assert!((0.2..=0.5).contains(&r), "rate over the last 32 only: {r}");
    }
}
