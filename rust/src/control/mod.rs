//! Per-group control plane (DESIGN.md S19): the paper's CC decision loop
//! — predict the workload, consult the pre-characterized
//! delay/power-voltage library, publish the efficient
//! `(V_core, V_bram, f, n_active)` operating point — as ONE reusable
//! engine shared by every plant that needs it.
//!
//! Before this module existed the loop was implemented twice: once in
//! `platform::Platform::step` (the offline simulator) and once in the
//! live Central Controller epoch thread (`coordinator::fleet`). Every
//! policy change paid the "threaded through both paths" tax and the two
//! copies could silently drift. Now both layers are pure *plants*:
//!
//! * `platform::Platform` keeps only physics — PLL lock, capacity,
//!   backlog carry-over, power accounting — and delegates each step's
//!   decision to its [`GroupController`];
//! * the live CC keeps only serving mechanics — arrival counters, shard
//!   gating/drain, gauges, energy integration — and delegates each
//!   epoch's decision to one [`GroupController`] per tenant group. Since
//!   the fleet-of-fleets split (DESIGN.md S21) that CC loop runs in
//!   `coordinator::node`, one thread per serving node, and a group's
//!   controller migrates *whole* between nodes — the decision sequence is
//!   continuous across moves, which is what lets the distributed fleet
//!   keep this module's equivalence contract at any node count.
//!
//! A plant feeds the controller one [`Observation`] per step/epoch (the
//! observed load, whether capacity was violated, the carried backlog)
//! and gets back a [`Decision`] (forecast, applied margin ladder level,
//! and the `(f, V_core, V_bram, n_active)` operating point to publish
//! for the next step). The controller owns the predictor
//! ([`PredictorKind`]-built, possibly the shadow-mode ensemble), the
//! adaptive [`Guardband`], the margin ladder, and one pre-built LUT per
//! ladder level — so per-step decisions stay table lookups (paper §V)
//! and the decision logic exists in exactly one place.
//!
//! Equivalence is enforced by construction *and* by test: the controller
//! is deterministic and pure (no clock, no RNG, no I/O), it logs every
//! [`DecisionRecord`] it produces, and `tests/control_equivalence.rs`
//! replays the live fleet's observed load sequence through the offline
//! platform and asserts the two paths' decision logs are identical.

pub mod guardband;

pub use guardband::{
    ladder_level, ladder_with, level_for, Guardband, GuardbandConfig, MARGIN_LADDER,
};

use crate::markov::{Predictor, PredictorKind};
use crate::vscale::{
    CapacityPolicy, ElasticConfig, ElasticLut, Mode, Optimizer, VoltageLut,
};
use crate::workload::bin_of_load;

/// What the plant observed over the step/epoch that just finished — the
/// controller's only input. Everything in here is plant physics; nothing
/// is predictor or margin state.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Observation {
    /// Normalized load offered over the finished step/epoch, in [0, 1].
    pub load: f64,
    /// True when demand (load + carried backlog) exceeded the capacity
    /// that actually served the step/epoch.
    pub qos_violation: bool,
    /// Unserved work carried into the next step/epoch, normalized to one
    /// step's nominal capacity (the controller sizes the next operating
    /// point for `predicted + backlog` — proportionate backpressure).
    pub backlog: f64,
}

/// One control decision: the forecast behind it, the margin ladder level
/// applied, and the operating point the plant should publish for the
/// next step/epoch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Decision {
    /// Load forecast for the next step/epoch.
    pub predicted: f64,
    /// Throughput margin actually applied (the ladder level's value).
    pub margin: f64,
    /// Index of the applied level in [`GroupController::margins`].
    pub level: usize,
    /// f / f_nom to publish.
    pub freq_ratio: f64,
    /// Core-rail voltage to publish (V).
    pub vcore: f64,
    /// BRAM-rail voltage to publish (V).
    pub vbram: f64,
    /// Instances to keep active (the rest are gated).
    pub n_active: usize,
    /// Requests per dispatched inference batch for the next step/epoch.
    /// Fixed at [`ControlConfig::batch_nominal`] unless
    /// [`ControlConfig::adaptive_batch`] is set, in which case low
    /// frequency ratios get proportionally bigger batches (amortize
    /// `cycles_per_batch` overhead when cycles are slow and latency
    /// headroom is already spent) while full frequency keeps the nominal
    /// latency-bounding batch.
    pub batch: usize,
    /// Name of the prediction source that produced `predicted` (the
    /// ensemble reports its active member, never "ensemble").
    pub predictor: &'static str,
    /// True when the forecast made last step missed the observed bin.
    pub mispredicted: bool,
    /// True when the forecast made last step under-estimated the
    /// observed bin (the QoS-dangerous direction).
    pub under_predicted: bool,
}

impl Decision {
    /// The trace-row projection of this decision (what both the offline
    /// `StepRecord` and the live `EpochRecord` embed).
    pub fn record(&self) -> DecisionRecord {
        DecisionRecord {
            predicted: self.predicted,
            freq_ratio: self.freq_ratio,
            vcore: self.vcore,
            vbram: self.vbram,
            n_active: self.n_active,
            batch: self.batch,
            predictor: self.predictor,
            margin: self.margin,
        }
    }
}

/// Throughput multiplier of serving batches of `batch` requests instead
/// of the nominal `batch_nominal`, with `overhead` per-dispatch overhead
/// cycles expressed as a fraction of `cycles_per_batch` (DESIGN.md S22).
///
/// Model: one dispatch of `b` requests costs
/// `cycles_per_batch * (b/b0 + overhead)` cycles — work scales with
/// fill, the overhead (weight/DMA setup, pipeline refill) is paid once
/// per dispatch. Relative to the nominal batch the delivered
/// requests-per-cycle ratio is `(1 + ov) / (1 + ov * b0 / b)`.
///
/// `batch == batch_nominal` returns **exactly** 1.0 (early return, no
/// float round-trip), so fixed-batch runs multiply capacities by the
/// identity and stay bit-identical to the pre-knob traces.
pub fn batch_amortization(batch: usize, batch_nominal: usize, overhead: f64) -> f64 {
    if batch == batch_nominal {
        return 1.0;
    }
    let (b, b0) = (batch.max(1) as f64, batch_nominal.max(1) as f64);
    (1.0 + overhead) / (1.0 + overhead * b0 / b)
}

/// The decision columns shared by the offline `platform::StepRecord` and
/// the live `coordinator::EpochRecord` — one struct so the two trace
/// formats cannot drift apart. Field alignment (decision-made-this-step
/// vs decision-that-served-this-step) is documented on the embedding
/// record; the controller's own log ([`GroupController::decisions`])
/// always holds the decision *made* at each step, which is what the
/// cross-path equivalence test compares.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DecisionRecord {
    /// Load forecast.
    pub predicted: f64,
    /// f / f_nom.
    pub freq_ratio: f64,
    /// Core-rail voltage (V).
    pub vcore: f64,
    /// BRAM-rail voltage (V).
    pub vbram: f64,
    /// Active (non-gated) instances.
    pub n_active: usize,
    /// Requests per dispatched inference batch.
    pub batch: usize,
    /// Prediction source (the ensemble reports its active member).
    pub predictor: &'static str,
    /// Throughput margin applied.
    pub margin: f64,
}

/// Controller knobs shared by both plants (the offline simulator's τ-step
/// CC and the live per-epoch CC read the same fields from their configs).
#[derive(Clone, Copy, Debug)]
pub struct ControlConfig {
    /// Workload bins M (Markov state space == LUT key space).
    pub m_bins: usize,
    /// Static throughput margin t (the guardband's starting point, floor
    /// while QoS is at risk, and default cap).
    pub margin_t: f64,
    /// Pure-training steps/epochs before predictions are trusted.
    pub warmup: usize,
    /// Which workload predictor drives the decisions (DESIGN.md S7).
    pub predictor: PredictorKind,
    /// Steps per cycle assumed by the periodic predictor member.
    pub predictor_period: usize,
    /// `Some(target)` enables the adaptive QoS-feedback guardband
    /// (DESIGN.md S7.1); `None` keeps the static `margin_t`.
    pub qos_target: Option<f64>,
    /// Nominal requests per dispatched inference batch (the backend's
    /// native geometry; every decision publishes this when
    /// `adaptive_batch` is off).
    pub batch_nominal: usize,
    /// Treat batch size as a control knob: scale the published batch
    /// inversely with the decided frequency ratio (clamped to
    /// `[batch_nominal, 4 * batch_nominal]`) so slow, low-voltage epochs
    /// amortize per-dispatch overhead while full-frequency epochs keep
    /// the nominal latency-bounding batch.
    pub adaptive_batch: bool,
}

impl Default for ControlConfig {
    fn default() -> Self {
        ControlConfig {
            m_bins: 10,
            margin_t: 0.05,
            warmup: 20,
            predictor: PredictorKind::Markov,
            predictor_period: 96,
            qos_target: None,
            batch_nominal: 16,
            adaptive_batch: false,
        }
    }
}

/// Named per-tenant QoS tiers (DESIGN.md S20): a tenant's tier maps to
/// the violation-rate target its group's adaptive guardband aims for.
/// Tiers only *refine* an enabled guardband — when a run's `qos_target`
/// is `None` (the static-margin baselines) tenant tiers are inert, so
/// tiered scenarios replay bit-identically under the static policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QosTier {
    /// Latency-critical tenant: 0.5% violation-rate target.
    Premium,
    /// Default tier: 1% violation-rate target (the ISSUE-4 acceptance
    /// configuration's fleet-wide value).
    Standard,
    /// Throughput/batch tenant: 5% violation-rate target.
    BestEffort,
}

impl QosTier {
    /// Every tier, strictest first.
    pub const ALL: [QosTier; 3] = [QosTier::Premium, QosTier::Standard, QosTier::BestEffort];

    /// CLI/scenario name of the tier.
    pub fn name(&self) -> &'static str {
        match self {
            QosTier::Premium => "premium",
            QosTier::Standard => "standard",
            QosTier::BestEffort => "best-effort",
        }
    }

    /// The violation-rate target the tier's guardband aims for.
    pub fn target(&self) -> f64 {
        match self {
            QosTier::Premium => 0.005,
            QosTier::Standard => 0.01,
            QosTier::BestEffort => 0.05,
        }
    }

    /// Resolve a tier by its [`QosTier::name`].
    pub fn by_name(name: &str) -> Result<QosTier, String> {
        QosTier::ALL
            .into_iter()
            .find(|t| t.name() == name)
            .ok_or_else(|| {
                format!(
                    "unknown QoS tier {name} (known: {})",
                    QosTier::ALL.map(|t| t.name()).join(", ")
                )
            })
    }

    /// The effective per-group guardband target: the fleet default
    /// `run_target` gated on, refined by `tenant_tier` when one is set.
    /// `None` in → `None` out, so static baselines stay bit-identical
    /// whatever tiers a scenario declares.
    pub fn effective(run_target: Option<f64>, tenant_tier: Option<f64>) -> Option<f64> {
        run_target.map(|d| tenant_tier.unwrap_or(d))
    }
}

/// Which pre-built lookup tables the controller consults — the only
/// plant-specific part of the control plane.
#[derive(Clone, Copy, Debug)]
pub enum LutSpec {
    /// Pure DVFS: one [`VoltageLut`] per margin level, every instance
    /// stays active (the paper's baseline framework).
    Dvfs {
        /// Voltage mode of the grid search.
        mode: Mode,
        /// Instances in the group/platform (always all active).
        n_instances: usize,
        /// Clock-stretch cap (`f64::INFINITY` disables it).
        latency_cap_sw: f64,
    },
    /// Joint gating + DVFS: one [`ElasticLut`] per margin level
    /// (DESIGN.md S6.1); `policy` restricts the search to reproduce the
    /// dvfs-only / pg-only baselines with identical machinery.
    Elastic {
        /// Voltage mode of the active instances' grid search.
        mode: Mode,
        /// Instances the elastic search may gate.
        n_instances: usize,
        /// Residual power fraction of a gated instance.
        residual: f64,
        /// Which capacity dimensions the search may move.
        policy: CapacityPolicy,
        /// Clock-stretch cap (`f64::INFINITY` disables it).
        latency_cap_sw: f64,
    },
    /// No scaling: publish the fixed nominal point every step (the
    /// offline `nominal` / `power-gating` plants, whose gating lives in
    /// the plant's power accounting, not in the decision).
    Fixed {
        /// Nominal core-rail voltage (V).
        vcore: f64,
        /// Nominal BRAM-rail voltage (V).
        vbram: f64,
        /// Instance count reported in every decision.
        n_instances: usize,
    },
}

/// Per-margin-level LUT bank (built once at "design synthesis" time).
enum LutBank {
    Voltage { luts: Vec<VoltageLut>, n_instances: usize },
    Elastic(Vec<ElasticLut>),
    Fixed { vcore: f64, vbram: f64, n_instances: usize },
}

/// The unified per-group control plane: owns the predictor, the adaptive
/// guardband, the margin ladder and one LUT per ladder level; consumes
/// one [`Observation`] per step/epoch and returns the [`Decision`] the
/// plant publishes. Deterministic and pure — no clock, no RNG — so the
/// same observation sequence always yields the same decision sequence
/// (property-tested below and cross-path-tested in
/// `tests/control_equivalence.rs`).
pub struct GroupController {
    cfg: ControlConfig,
    /// Margin levels LUTs were built for: `[margin_t]` under the static
    /// policy, the full ladder (plus `margin_t` when it is not already a
    /// level) under the adaptive guardband. Sorted ascending,
    /// index-aligned with the LUT bank.
    margins: Vec<f64>,
    bank: LutBank,
    predictor: Box<dyn Predictor>,
    guardband: Option<Guardband>,
    /// The forecast made last step for this step — misprediction and
    /// under-prediction are judged at bin granularity against it.
    last_predicted: Option<f64>,
    /// Every decision made so far, in order (the cross-path equivalence
    /// witness; the live CC takes it into its final report). Unbounded
    /// by design, like the per-epoch trace the CC has always kept —
    /// ~64 B per step/epoch; a deployment that outgrows that precedent
    /// needs to bound both together, not just this log.
    log: Vec<DecisionRecord>,
}

impl GroupController {
    /// Build the controller: margin ladder, one LUT per level (from
    /// `opt`), predictor and (with `cfg.qos_target`) the guardband.
    /// Static margin → one LUT level, bit-identical to the pre-refactor
    /// plants; adaptive → the whole ladder is pre-built so per-step
    /// decisions stay table lookups (paper §V).
    pub fn new(cfg: ControlConfig, opt: &Optimizer, spec: LutSpec) -> Self {
        let guardband_cfg = cfg
            .qos_target
            .map(|target| GuardbandConfig::new(cfg.margin_t, target));
        // Build LUTs for exactly the levels the guardband can request
        // (guardband::levels: the ladder with static margin and cap
        // spliced in, truncated at the cap — levels above it could
        // never be selected and would be pure construction waste).
        let margins: Vec<f64> = match &guardband_cfg {
            None => vec![cfg.margin_t],
            Some(gb) => guardband::levels(gb),
        };
        let bank = match spec {
            LutSpec::Dvfs { mode, n_instances, latency_cap_sw } => LutBank::Voltage {
                luts: margins
                    .iter()
                    .map(|&t| {
                        VoltageLut::build_with_latency_cap(
                            opt,
                            cfg.m_bins,
                            t,
                            mode,
                            latency_cap_sw,
                        )
                    })
                    .collect(),
                n_instances,
            },
            LutSpec::Elastic { mode, n_instances, residual, policy, latency_cap_sw } => {
                LutBank::Elastic(
                    margins
                        .iter()
                        .map(|&t| {
                            ElasticLut::build(
                                opt,
                                &ElasticConfig {
                                    m_bins: cfg.m_bins,
                                    margin_t: t,
                                    mode,
                                    n_instances,
                                    residual,
                                    policy,
                                    latency_cap_sw,
                                },
                            )
                        })
                        .collect(),
                )
            }
            LutSpec::Fixed { vcore, vbram, n_instances } => {
                LutBank::Fixed { vcore, vbram, n_instances }
            }
        };
        let predictor =
            cfg.predictor
                .build(cfg.m_bins, cfg.warmup, cfg.predictor_period);
        let guardband = guardband_cfg.map(Guardband::new);
        GroupController {
            cfg,
            margins,
            bank,
            predictor,
            guardband,
            last_predicted: None,
            log: Vec::new(),
        }
    }

    /// The controller's configuration.
    pub fn cfg(&self) -> &ControlConfig {
        &self.cfg
    }

    /// The margin levels the LUT bank was built for (sorted ascending).
    pub fn margins(&self) -> &[f64] {
        &self.margins
    }

    /// The continuous margin the guardband currently requests (the
    /// static `margin_t` when the guardband is disabled).
    pub fn margin_now(&self) -> f64 {
        self.guardband
            .as_ref()
            .map(|g| g.margin())
            .unwrap_or(self.cfg.margin_t)
    }

    /// Name of the prediction source currently active (the ensemble
    /// reports its member, never "ensemble").
    pub fn predictor_now(&self) -> &'static str {
        self.predictor.active_name()
    }

    /// Every decision made so far, in order.
    pub fn decisions(&self) -> &[DecisionRecord] {
        &self.log
    }

    /// Take ownership of the decision log (the live CC moves it into the
    /// final fleet report at shutdown).
    pub fn take_decisions(&mut self) -> Vec<DecisionRecord> {
        std::mem::take(&mut self.log)
    }

    /// Feed one step/epoch's observation and return the decision for the
    /// next one (see [`GroupController::decide_with_oracle`]).
    pub fn decide(&mut self, obs: &Observation) -> Decision {
        self.decide_with_oracle(obs, None)
    }

    /// The paper's CC loop, in order:
    ///
    /// 1. judge last step's forecast against the observed bin
    ///    (misprediction / under-prediction, shared
    ///    [`bin_of_load`] mapping);
    /// 2. train the predictor on the observed load;
    /// 3. feed the guardband the `(violated, under_predicted)` outcome —
    ///    boost on either, decay on clean steps (DESIGN.md S7.1);
    /// 4. forecast the next step (`oracle` overrides the predictor for
    ///    the offline oracle policy);
    /// 5. quantize the guardband's margin *up* to its ladder level and
    ///    look up the level's LUT at `predicted + backlog`
    ///    (proportionate backpressure — carried work is capacity-planned,
    ///    not ignored).
    pub fn decide_with_oracle(&mut self, obs: &Observation, oracle: Option<f64>) -> Decision {
        let load_bin = bin_of_load(self.cfg.m_bins, obs.load);
        let (mispredicted, under_predicted) = match self.last_predicted {
            Some(p) => {
                let pb = bin_of_load(self.cfg.m_bins, p);
                (pb != load_bin, pb < load_bin)
            }
            None => (false, false),
        };
        self.predictor.observe(obs.load);
        if let Some(gb) = &mut self.guardband {
            gb.observe(obs.qos_violation, under_predicted);
        }
        let predicted = oracle.unwrap_or_else(|| self.predictor.predict());
        let margin_now = self.margin_now();
        let level = level_for(&self.margins, margin_now);
        let margin = self.margins[level];

        // Backlog pressure: size the next step for predicted + carried
        // work (proportionate backpressure, not a jump to nominal).
        let eff_load = if obs.backlog > 1e-9 {
            (predicted + obs.backlog).min(1.0)
        } else {
            predicted
        };
        let (freq_ratio, vcore, vbram, n_active) = match &self.bank {
            LutBank::Voltage { luts, n_instances } => {
                let e = luts[level].entry_for_load(eff_load);
                (e.freq_ratio, e.point.vcore, e.point.vbram, *n_instances)
            }
            LutBank::Elastic(els) => {
                let e = els[level].entry_for_load(eff_load);
                (e.freq_ratio, e.point.vcore, e.point.vbram, e.n_active)
            }
            LutBank::Fixed { vcore, vbram, n_instances } => {
                (1.0, *vcore, *vbram, *n_instances)
            }
        };
        self.last_predicted = Some(predicted);
        let d = Decision {
            predicted,
            margin,
            level,
            freq_ratio,
            vcore,
            vbram,
            n_active,
            batch: self.batch_for(freq_ratio),
            predictor: self.predictor.active_name(),
            mispredicted,
            under_predicted,
        };
        self.log.push(d.record());
        d
    }

    /// The batch size to publish for an epoch decided at `freq_ratio`:
    /// the nominal backend geometry under the fixed policy; inversely
    /// proportional to the frequency ratio (clamped to `[b0, 4*b0]`)
    /// under `adaptive_batch`. A half-speed epoch doubles the batch —
    /// each dispatch's fixed overhead is amortized over twice the
    /// requests exactly when cycles are slowest and the per-request
    /// latency budget is already being spent on clock stretch; at full
    /// frequency the clamp floor keeps the latency-bounding nominal.
    fn batch_for(&self, freq_ratio: f64) -> usize {
        let b0 = self.cfg.batch_nominal.max(1);
        if !self.cfg.adaptive_batch || freq_ratio <= 0.0 {
            return b0;
        }
        ((b0 as f64 / freq_ratio).round() as usize).clamp(b0, 4 * b0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{BenchmarkSpec, DeviceFamily};
    use crate::chars::CharLibrary;
    use crate::netlist::gen::{generate, GenConfig};
    use crate::power::{DesignPower, PowerParams};
    use crate::sta::{analyze, DelayParams};
    use crate::util::prng::Rng;

    fn optimizer() -> Optimizer {
        let chars = CharLibrary::stratix_iv_22nm();
        let spec = BenchmarkSpec::by_name("tabla").unwrap();
        let dp = DesignPower::from_spec(
            spec,
            &DeviceFamily::stratix_iv(),
            chars.clone(),
            PowerParams::default(),
        )
        .unwrap();
        let net = generate(spec, &GenConfig { scale: 0.05, seed: 2019, luts_per_lab: 10 });
        let rep = analyze(&net, &DelayParams::default(), 8).unwrap();
        Optimizer::new(chars.grid(), dp.rail_tables(&rep.cp))
            .with_paths(&chars, rep.top_paths.clone())
    }

    fn elastic_spec() -> LutSpec {
        LutSpec::Elastic {
            mode: Mode::Proposed,
            n_instances: 4,
            residual: 0.02,
            policy: CapacityPolicy::Hybrid,
            latency_cap_sw: f64::INFINITY,
        }
    }

    fn adaptive_cfg() -> ControlConfig {
        ControlConfig { warmup: 4, qos_target: Some(0.01), ..ControlConfig::default() }
    }

    /// A plant-shaped observation stream: loads drive a tiny capacity
    /// model so violations/backlog are self-consistent, like a real
    /// plant would feed the controller.
    fn drive(ctl: &mut GroupController, loads: &[f64]) -> Vec<DecisionRecord> {
        let mut backlog = 0.0f64;
        let mut capacity = 1.0f64;
        let mut out = Vec::with_capacity(loads.len());
        for &load in loads {
            let demand = load + backlog;
            let delivered = demand.min(capacity);
            backlog = (demand - delivered).min(1.0);
            let d = ctl.decide(&Observation {
                load,
                qos_violation: demand - delivered > 1e-9,
                backlog,
            });
            capacity = d.freq_ratio * (d.n_active as f64 / 4.0);
            out.push(d.record());
        }
        out
    }

    #[test]
    fn qos_tiers_resolve_and_gate_on_the_run_target() {
        for tier in QosTier::ALL {
            assert_eq!(QosTier::by_name(tier.name()).unwrap(), tier);
            assert!((0.0..1.0).contains(&tier.target()));
        }
        assert!(QosTier::by_name("gold").is_err());
        // Tiers are strictly ordered strict -> relaxed.
        assert!(QosTier::Premium.target() < QosTier::Standard.target());
        assert!(QosTier::Standard.target() < QosTier::BestEffort.target());
        // The gating formula: tenant tiers refine an enabled guardband
        // and are inert when the run disables it.
        assert_eq!(QosTier::effective(Some(0.01), Some(0.05)), Some(0.05));
        assert_eq!(QosTier::effective(Some(0.01), None), Some(0.01));
        assert_eq!(QosTier::effective(None, Some(0.05)), None);
        assert_eq!(QosTier::effective(None, None), None);
    }

    #[test]
    fn decisions_are_deterministic_and_pure() {
        // Same observation sequence -> same decision sequence, across
        // independently built controllers (no hidden clock/RNG state),
        // over randomized load traces and both the static and adaptive
        // configurations. The controller's own log must equal the
        // returned sequence (the cross-path witness is trustworthy).
        let opt = optimizer();
        let mut rng = Rng::new(7);
        for case in 0..8 {
            let loads: Vec<f64> = (0..120).map(|_| rng.f64()).collect();
            let cfg = if case % 2 == 0 {
                ControlConfig { warmup: 4, ..ControlConfig::default() }
            } else {
                adaptive_cfg()
            };
            let mut a = GroupController::new(cfg, &opt, elastic_spec());
            let mut b = GroupController::new(cfg, &opt, elastic_spec());
            let da = drive(&mut a, &loads);
            let db = drive(&mut b, &loads);
            assert_eq!(da, db, "case {case}: controllers diverged");
            assert_eq!(a.decisions(), da.as_slice(), "log must equal returned decisions");
            assert_eq!(a.take_decisions(), db, "take_decisions drains the same log");
            assert!(a.decisions().is_empty());
        }
    }

    #[test]
    fn static_config_builds_one_margin_level() {
        let opt = optimizer();
        let ctl = GroupController::new(ControlConfig::default(), &opt, elastic_spec());
        assert_eq!(ctl.margins(), &[0.05]);
        assert!((ctl.margin_now() - 0.05).abs() < 1e-12);
        assert_eq!(ctl.predictor_now(), "markov");
    }

    #[test]
    fn adaptive_config_builds_the_reachable_ladder_prefix() {
        // The default guardband is capped at the static margin, so only
        // ladder levels up to that cap get LUTs — levels above it could
        // never be selected and would be pure construction waste.
        let opt = optimizer();
        let ctl = GroupController::new(adaptive_cfg(), &opt, elastic_spec());
        assert_eq!(ctl.margins(), &MARGIN_LADDER[..=ladder_level(0.05)]);
        assert_eq!(ctl.margins().last().copied(), Some(0.05), "cap is a level");
        // A non-ladder static margin is spliced in as its own exact
        // level (the pareto cap stays representable).
        let cfg = ControlConfig { margin_t: 0.06, ..adaptive_cfg() };
        let ctl = GroupController::new(cfg, &opt, elastic_spec());
        assert_eq!(ctl.margins().last().copied(), Some(0.06));
        assert_eq!(
            ctl.margins().len(),
            ladder_level(0.05) + 2,
            "levels <= 0.05 plus the spliced 0.06 cap"
        );
    }

    #[test]
    fn warmup_pins_to_max_then_tracks_the_load() {
        let opt = optimizer();
        let mut ctl = GroupController::new(
            ControlConfig { warmup: 3, ..ControlConfig::default() },
            &opt,
            elastic_spec(),
        );
        let obs = Observation { load: 0.2, qos_violation: false, backlog: 0.0 };
        // The plant observes before it predicts, so with warmup = 3 the
        // first two decisions still fall inside the training phase.
        for _ in 0..2 {
            let d = ctl.decide(&obs);
            assert_eq!(d.predicted, 1.0, "training phase runs at maximum");
        }
        for _ in 0..10 {
            ctl.decide(&obs);
        }
        let d = ctl.decide(&obs);
        assert!(d.predicted < 0.5, "post-warmup tracks the low load: {d:?}");
        assert!(d.freq_ratio < 1.0 || d.n_active < 4, "operating point follows");
    }

    #[test]
    fn oracle_overrides_the_predictor() {
        let opt = optimizer();
        let mut ctl = GroupController::new(
            ControlConfig { warmup: 0, ..ControlConfig::default() },
            &opt,
            elastic_spec(),
        );
        let obs = Observation { load: 0.1, qos_violation: false, backlog: 0.0 };
        let d = ctl.decide_with_oracle(&obs, Some(0.93));
        assert_eq!(d.predicted, 0.93);
        assert!((d.freq_ratio - 1.0).abs() < 1e-9, "top bin needs full frequency");
        // The oracle forecast is also the baseline the next observation
        // is judged against.
        let d = ctl.decide(&Observation { load: 0.12, qos_violation: false, backlog: 0.0 });
        assert!(d.mispredicted, "0.93 forecast vs 0.12 observed must mispredict");
        assert!(!d.under_predicted, "over-prediction, not under");
    }

    #[test]
    fn backlog_backpressure_raises_the_lookup_bin() {
        let opt = optimizer();
        let mk = || {
            GroupController::new(
                ControlConfig { warmup: 0, ..ControlConfig::default() },
                &opt,
                elastic_spec(),
            )
        };
        // Same trained state, same load; only the carried backlog differs.
        let train = |ctl: &mut GroupController| {
            for _ in 0..30 {
                ctl.decide(&Observation { load: 0.25, qos_violation: false, backlog: 0.0 });
            }
        };
        let (mut clean, mut carrying) = (mk(), mk());
        train(&mut clean);
        train(&mut carrying);
        let d0 = clean.decide(&Observation { load: 0.25, qos_violation: false, backlog: 0.0 });
        let d1 = carrying.decide(&Observation {
            load: 0.25,
            qos_violation: true,
            backlog: 0.5,
        });
        assert!(
            d1.freq_ratio * d1.n_active as f64 > d0.freq_ratio * d0.n_active as f64,
            "carried work must be capacity-planned: {d0:?} vs {d1:?}"
        );
    }

    #[test]
    fn guardband_boost_raises_the_next_operating_point() {
        let opt = optimizer();
        let mut ctl = GroupController::new(
            ControlConfig { warmup: 2, ..adaptive_cfg() },
            &opt,
            elastic_spec(),
        );
        // Long quiet run: the margin decays below the static 5%.
        for _ in 0..120 {
            ctl.decide(&Observation { load: 0.22, qos_violation: false, backlog: 0.0 });
        }
        assert!(ctl.margin_now() < 0.05, "decayed: {}", ctl.margin_now());
        let before = ctl
            .decide(&Observation { load: 0.22, qos_violation: false, backlog: 0.0 });
        // A three-bin surge: the under-prediction boosts the margin and
        // the published capacity covers the observed bin.
        let after = ctl.decide(&Observation { load: 0.62, qos_violation: true, backlog: 0.1 });
        assert!(after.under_predicted);
        assert!(after.margin >= before.margin, "{} -> {}", before.margin, after.margin);
        assert!(
            after.freq_ratio * (after.n_active as f64 / 4.0)
                > before.freq_ratio * (before.n_active as f64 / 4.0),
            "boost must raise published capacity: {before:?} vs {after:?}"
        );
        // Default guardband never exceeds the static cap.
        assert!(after.margin <= 0.05 + 1e-12);
    }

    #[test]
    fn fixed_bank_always_publishes_nominal() {
        let opt = optimizer();
        let mut ctl = GroupController::new(
            ControlConfig { warmup: 0, ..ControlConfig::default() },
            &opt,
            LutSpec::Fixed { vcore: 0.8, vbram: 0.95, n_instances: 4 },
        );
        for load in [0.05, 0.5, 0.95] {
            let d = ctl.decide(&Observation { load, qos_violation: false, backlog: 0.0 });
            assert_eq!((d.freq_ratio, d.vcore, d.vbram, d.n_active), (1.0, 0.8, 0.95, 4));
            assert!(d.predicted <= 1.0, "predictor still runs for the record columns");
        }
    }

    #[test]
    fn dvfs_bank_keeps_every_instance_active() {
        let opt = optimizer();
        let mut ctl = GroupController::new(
            ControlConfig { warmup: 0, ..ControlConfig::default() },
            &opt,
            LutSpec::Dvfs { mode: Mode::Proposed, n_instances: 6, latency_cap_sw: f64::INFINITY },
        );
        for _ in 0..20 {
            let d = ctl.decide(&Observation { load: 0.1, qos_violation: false, backlog: 0.0 });
            assert_eq!(d.n_active, 6, "pure DVFS never gates");
        }
    }

    #[test]
    fn fixed_batch_policy_always_publishes_nominal() {
        let opt = optimizer();
        let mut ctl = GroupController::new(
            ControlConfig { warmup: 0, ..ControlConfig::default() },
            &opt,
            elastic_spec(),
        );
        for load in [0.05, 0.35, 0.65, 0.95] {
            let d = ctl.decide(&Observation { load, qos_violation: false, backlog: 0.0 });
            assert_eq!(d.batch, 16, "fixed policy must publish the nominal batch");
            assert_eq!(d.record().batch, 16, "record carries the batch column");
        }
    }

    #[test]
    fn adaptive_batch_scales_inversely_with_frequency() {
        // Pure DVFS must serve a low bin by downclocking (capacity is
        // freq_ratio alone — no gating escape hatch), so the adaptive
        // batch law is observable without depending on which shape the
        // hybrid optimizer happens to pick.
        let opt = optimizer();
        let mut ctl = GroupController::new(
            ControlConfig {
                warmup: 0,
                adaptive_batch: true,
                ..ControlConfig::default()
            },
            &opt,
            LutSpec::Dvfs {
                mode: Mode::Proposed,
                n_instances: 4,
                latency_cap_sw: f64::INFINITY,
            },
        );
        let obs = |load| Observation { load, qos_violation: false, backlog: 0.0 };
        let low = ctl.decide_with_oracle(&obs(0.12), Some(0.12));
        assert!(
            low.freq_ratio < 1.0 - 1e-9,
            "DVFS at a low bin must downclock: {low:?}"
        );
        assert!(low.batch > 16, "downclocked epochs must batch bigger: {low:?}");
        assert!(low.batch <= 64, "clamped at 4x nominal");
        // The exact law: round(b0 / freq_ratio), clamped to [b0, 4*b0].
        let want = ((16.0 / low.freq_ratio).round() as usize).clamp(16, 64);
        assert_eq!(low.batch, want);
        // A top-bin forecast forces full frequency -> nominal batch.
        let high = ctl.decide_with_oracle(&obs(0.97), Some(0.97));
        assert!((high.freq_ratio - 1.0).abs() < 1e-9, "top bin runs full speed: {high:?}");
        assert_eq!(high.batch, 16, "full frequency keeps the latency-bounding nominal");
    }

    #[test]
    fn batch_amortization_is_exact_at_nominal_and_monotone() {
        // Identity at the nominal batch must be *exact* (fixed-batch
        // traces multiply capacity by it every step).
        assert_eq!(batch_amortization(16, 16, 0.1).to_bits(), 1.0f64.to_bits());
        assert_eq!(batch_amortization(1, 1, 0.25).to_bits(), 1.0f64.to_bits());
        // Bigger batches amortize more; the gain is bounded by 1 + ov.
        let ov = 0.1;
        let mut prev = batch_amortization(16, 16, ov);
        for b in [20, 24, 32, 48, 64, 128] {
            let a = batch_amortization(b, 16, ov);
            assert!(a > prev, "amortization must rise with batch: {b} -> {a}");
            assert!(a < 1.0 + ov + 1e-12, "gain bounded by the overhead itself");
            prev = a;
        }
        // Sub-nominal batches pay the overhead over fewer requests.
        assert!(batch_amortization(8, 16, ov) < 1.0);
        assert!(batch_amortization(1, 16, ov) < batch_amortization(8, 16, ov));
        // Zero overhead means batch size cannot matter.
        assert!((batch_amortization(64, 16, 0.0) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn ensemble_forced_switch_pins_the_gauge_index() {
        // The live `predictor_now` gauge publishes
        // `PredictorKind::index_of_name(active member)`. After a forced
        // switch on a clean sinusoid the controller must report the
        // periodic member — and its gauge index — never "ensemble".
        let opt = optimizer();
        let mut ctl = GroupController::new(
            ControlConfig {
                warmup: 4,
                predictor: PredictorKind::Ensemble,
                predictor_period: 24,
                ..ControlConfig::default()
            },
            &opt,
            elastic_spec(),
        );
        assert_eq!(ctl.predictor_now(), "markov", "startup member, not \"ensemble\"");
        let signal = |t: usize| {
            0.25 + 0.5
                * ((t % 24) as f64 / 24.0 * std::f64::consts::TAU).sin().abs()
        };
        let mut last = None;
        for t in 0..400 {
            last = Some(ctl.decide(&Observation {
                load: signal(t),
                qos_violation: false,
                backlog: 0.0,
            }));
        }
        assert_eq!(ctl.predictor_now(), "periodic", "clean sinusoid forces the switch");
        assert_eq!(PredictorKind::index_of_name(ctl.predictor_now()), 2);
        assert_eq!(last.unwrap().predictor, "periodic", "decisions carry the member name");
    }
}
