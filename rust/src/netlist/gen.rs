//! Deterministic synthetic netlist generation from a Table I row.

use super::{Edge, Netlist, NodeKind};
use crate::arch::BenchmarkSpec;
use crate::util::prng::Rng;

/// Generation knobs. `scale` shrinks resource counts uniformly (tests run
/// at ~0.02; experiments at 1.0 keep Table I counts and the same timing,
/// since the critical-path construction is scale-independent).
#[derive(Clone, Copy, Debug)]
pub struct GenConfig {
    /// Uniform shrink factor on resource counts (1.0 = Table I).
    pub scale: f64,
    /// PRNG seed; identical seeds reproduce the netlist exactly.
    pub seed: u64,
    /// LUTs per LAB (device family convention, 10 for Stratix IV).
    pub luts_per_lab: usize,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig { scale: 1.0, seed: 2019, luts_per_lab: 10 }
    }
}

/// Build a layered DAG reproducing the benchmark's resource mix and
/// intended critical path:
///
/// * `depth` LUT layers; every LUT draws 2–4 fan-ins from the previous
///   layer (or primary inputs) over 1–4 routing segments.
/// * One *spine* path threads all layers with above-average segment counts
///   and splices a BRAM access between the middle layers (plus a DSP for
///   `cp_has_dsp` benchmarks) — this is the intended critical path.
/// * Remaining BRAM/DSP blocks bridge layer `i` to layer `i+3`, so the
///   paths through them stay shorter than the spine.
pub fn generate(spec: &BenchmarkSpec, cfg: &GenConfig) -> Netlist {
    let mut rng = Rng::new(cfg.seed ^ fxhash(spec.name));
    let depth = spec.cp_logic_depth.max(2);

    let scaled = |n: usize| ((n as f64 * cfg.scale).round() as usize).max(1);
    let n_luts = scaled(spec.labs * cfg.luts_per_lab).max(depth * 2);
    let n_brams = if spec.m9ks + spec.m144ks > 0 {
        scaled(spec.m9ks + spec.m144ks)
    } else {
        0
    }
    .max(usize::from(spec.cp_has_bram));
    let n_dsps = if spec.dsps > 0 { scaled(spec.dsps) } else { 0 }
        .max(usize::from(spec.cp_has_dsp));
    let n_in = scaled(spec.io_pins * 2 / 3).max(2);
    let n_out = scaled(spec.io_pins / 3).max(1);

    // ---- node numbering ----------------------------------------------
    let mut kinds = Vec::with_capacity(n_in + n_luts + n_brams + n_dsps + n_out);
    kinds.resize(n_in, NodeKind::Input);

    // LUT layers: distribute evenly, at least one per layer.
    let lut_base = kinds.len() as u32;
    let mut layer_of = Vec::with_capacity(n_luts);
    for i in 0..n_luts {
        layer_of.push(i % depth);
    }
    // Shuffle layer assignment for variety while keeping counts balanced.
    rng.shuffle(&mut layer_of);
    kinds.resize(kinds.len() + n_luts, NodeKind::Lut);

    let bram_base = kinds.len() as u32;
    kinds.resize(kinds.len() + n_brams, NodeKind::Bram);
    let dsp_base = kinds.len() as u32;
    kinds.resize(kinds.len() + n_dsps, NodeKind::Dsp);
    let out_base = kinds.len() as u32;
    kinds.resize(kinds.len() + n_out, NodeKind::Output);

    // Per-layer node id lists.
    let mut layers: Vec<Vec<u32>> = vec![Vec::new(); depth];
    for (i, &l) in layer_of.iter().enumerate() {
        layers[l].push(lut_base + i as u32);
    }

    let mut edges: Vec<Edge> = Vec::with_capacity(n_luts * 3 + n_out + n_brams * 2);
    let push = |edges: &mut Vec<Edge>, src: u32, dst: u32, segments: u8| {
        edges.push(Edge { src, dst, segments });
    };

    // ---- general fabric ----------------------------------------------
    for l in 0..depth {
        for &lut in &layers[l] {
            let fanin = rng.index(2, 5);
            for _ in 0..fanin {
                let src = if l == 0 {
                    rng.below(n_in as u64) as u32
                } else {
                    *rng.choose(&layers[l - 1])
                };
                // Short hops only (1-3 segments): the spine's 3-segment
                // edges plus its BRAM splice then dominate every fabric
                // path by construction (worst fabric hop 1.0 ns vs spine
                // 1.0 ns/hop + 2.8 ns of hard-block slack).
                let segs = if rng.bool(0.25) { 3 } else { rng.index(1, 3) as u8 };
                push(&mut edges, src, lut, segs);
            }
        }
    }

    // Outputs tap the last layer.
    for o in 0..n_out {
        let src = *rng.choose(&layers[depth - 1]);
        push(&mut edges, src, out_base + o as u32, rng.index(1, 4) as u8);
    }

    // ---- the spine (intended critical path) ---------------------------
    // input -> L0 -> L1 -> ... -> L(depth-1) -> output, long segments.
    let spine: Vec<u32> = (0..depth).map(|l| layers[l][0]).collect();
    push(&mut edges, 0, spine[0], 3);
    for w in spine.windows(2) {
        push(&mut edges, w[0], w[1], 3);
    }
    push(&mut edges, spine[depth - 1], out_base, 3);

    // Splice the CP BRAM between the middle spine stages (parallel to the
    // direct hop, so it adds its access time on the longest path).
    if spec.cp_has_bram && n_brams > 0 {
        let m = depth / 2;
        let cp_bram = bram_base;
        push(&mut edges, spine[m - 1], cp_bram, 2);
        push(&mut edges, cp_bram, spine[m], 2);
    }
    if spec.cp_has_dsp && n_dsps > 0 {
        let m = (depth * 3 / 4).max(1);
        let cp_dsp = dsp_base;
        push(&mut edges, spine[m - 1], cp_dsp, 2);
        push(&mut edges, cp_dsp, spine[m], 2);
    }

    // ---- remaining hard blocks: layer i -> i+3 bridges (short paths) ---
    let bridge = |rng: &mut Rng, edges: &mut Vec<Edge>, node: u32, depth: usize, layers: &Vec<Vec<u32>>| {
        if depth < 4 {
            // Shallow designs: hang the block off the fabric sideways
            // (input-fed, output-draining) so it cannot extend the CP.
            let src = rng.below(n_in as u64) as u32;
            edges.push(Edge { src, dst: node, segments: 1 });
            let dst = out_base + rng.below(n_out as u64) as u32;
            edges.push(Edge { src: node, dst, segments: 1 });
        } else {
            let i = rng.index(0, depth - 3);
            let src = *rng.choose(&layers[i]);
            let dst = *rng.choose(&layers[i + 3]);
            edges.push(Edge { src, dst: node, segments: 2 });
            edges.push(Edge { src: node, dst, segments: 2 });
        }
    };
    let cp_bram_used = usize::from(spec.cp_has_bram && n_brams > 0);
    for b in cp_bram_used..n_brams {
        bridge(&mut rng, &mut edges, bram_base + b as u32, depth, &layers);
    }
    let cp_dsp_used = usize::from(spec.cp_has_dsp && n_dsps > 0);
    for d in cp_dsp_used..n_dsps {
        bridge(&mut rng, &mut edges, dsp_base + d as u32, depth, &layers);
    }

    Netlist { name: spec.name.to_string(), kinds, edges }
}

/// Tiny FNV-style string hash for seed mixing.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::TABLE1;

    fn small(spec: &BenchmarkSpec) -> Netlist {
        generate(spec, &GenConfig { scale: 0.02, seed: 7, luts_per_lab: 10 })
    }

    #[test]
    fn all_benchmarks_generate_valid_netlists() {
        for spec in TABLE1 {
            let n = small(spec);
            n.validate().unwrap_or_else(|e| panic!("{}: {e}", spec.name));
            let c = n.counts();
            assert!(c.luts >= spec.cp_logic_depth, "{}", spec.name);
            assert!(c.inputs >= 2 && c.outputs >= 1);
            if spec.cp_has_bram {
                assert!(c.brams >= 1);
            }
            if spec.cp_has_dsp {
                assert!(c.dsps >= 1);
            }
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let spec = &TABLE1[0];
        let a = small(spec);
        let b = small(spec);
        assert_eq!(a.kinds, b.kinds);
        assert_eq!(a.edges, b.edges);
    }

    #[test]
    fn different_seed_differs() {
        let spec = &TABLE1[0];
        let a = small(spec);
        let b = generate(spec, &GenConfig { scale: 0.02, seed: 8, luts_per_lab: 10 });
        assert_ne!(a.edges, b.edges);
    }

    #[test]
    fn scale_controls_size() {
        let spec = BenchmarkSpec::by_name("diannao").unwrap();
        let a = generate(spec, &GenConfig { scale: 0.01, seed: 1, luts_per_lab: 10 });
        let b = generate(spec, &GenConfig { scale: 0.05, seed: 1, luts_per_lab: 10 });
        assert!(b.counts().luts > 3 * a.counts().luts);
    }

    #[test]
    fn full_scale_matches_table1_counts() {
        let spec = BenchmarkSpec::by_name("tabla").unwrap();
        let n = generate(spec, &GenConfig::default());
        let c = n.counts();
        assert_eq!(c.luts, 127 * 10);
        assert_eq!(c.brams, 48); // 47 M9K + 1 M144K
    }
}
