//! BLIF-lite serialization — enough of the Berkeley Logic Interchange
//! Format to round-trip our netlists (the paper's Quartus→VQM→BLIF hop).
//!
//! Standard constructs: `.model`, `.inputs`, `.outputs`, `.names` (LUT).
//! Hard blocks use `.subckt bram|dsp` as VTR does. Routing segment counts
//! ride in a `# segs=` comment per connection — BLIF has no routing info,
//! and we need the netlist to survive the round trip.

use super::{Edge, Netlist, NodeKind};

/// Serialize to BLIF-lite text.
pub fn write_blif(n: &Netlist) -> String {
    let mut out = String::with_capacity(n.edges.len() * 24);
    out.push_str(&format!(".model {}\n", n.name));

    let name_of = |id: u32| format!("n{id}");

    let ins: Vec<String> = n
        .kinds
        .iter()
        .enumerate()
        .filter(|(_, k)| **k == NodeKind::Input)
        .map(|(i, _)| name_of(i as u32))
        .collect();
    out.push_str(&format!(".inputs {}\n", ins.join(" ")));

    let outs: Vec<String> = n
        .kinds
        .iter()
        .enumerate()
        .filter(|(_, k)| **k == NodeKind::Output)
        .map(|(i, _)| name_of(i as u32))
        .collect();
    out.push_str(&format!(".outputs {}\n", outs.join(" ")));

    // Group edges by destination.
    let (off, idx) = n.fanin_index();
    for (dst, kind) in n.kinds.iter().enumerate() {
        let lo = off[dst] as usize;
        let hi = off[dst + 1] as usize;
        if lo == hi {
            continue;
        }
        let fanin: Vec<&Edge> = idx[lo..hi].iter().map(|&e| &n.edges[e as usize]).collect();
        let segs: Vec<String> = fanin.iter().map(|e| e.segments.to_string()).collect();
        let names: Vec<String> = fanin.iter().map(|e| name_of(e.src)).collect();
        match kind {
            NodeKind::Lut | NodeKind::Output => {
                out.push_str(&format!(
                    ".names {} {} # segs={}\n",
                    names.join(" "),
                    name_of(dst as u32),
                    segs.join(",")
                ));
            }
            NodeKind::Bram | NodeKind::Dsp => {
                out.push_str(&format!(
                    ".subckt {} {} out={} # segs={}\n",
                    kind.name(),
                    names
                        .iter()
                        .enumerate()
                        .map(|(i, s)| format!("in{i}={s}"))
                        .collect::<Vec<_>>()
                        .join(" "),
                    name_of(dst as u32),
                    segs.join(",")
                ));
            }
            NodeKind::Input => unreachable!("validated netlists have no input fan-in"),
        }
    }
    out.push_str(".end\n");
    out
}

/// Parse BLIF-lite text back into a netlist.
pub fn parse_blif(text: &str) -> Result<Netlist, String> {
    let mut name = String::new();
    let mut kinds: Vec<NodeKind> = Vec::new();
    let mut ids: std::collections::HashMap<String, u32> = std::collections::HashMap::new();
    let mut edges: Vec<Edge> = Vec::new();

    // Two passes: declare inputs/outputs first, then infer LUT/hard-block
    // node kinds from driver statements.
    let intern = |tok: &str,
                      kind: Option<NodeKind>,
                      ids: &mut std::collections::HashMap<String, u32>,
                      kinds: &mut Vec<NodeKind>|
     -> u32 {
        if let Some(&id) = ids.get(tok) {
            if let Some(k) = kind {
                kinds[id as usize] = k;
            }
            return id;
        }
        let id = kinds.len() as u32;
        kinds.push(kind.unwrap_or(NodeKind::Lut));
        ids.insert(tok.to_string(), id);
        id
    };

    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (stmt, comment) = match line.split_once('#') {
            Some((s, c)) => (s.trim(), c.trim()),
            None => (line, ""),
        };
        let toks: Vec<&str> = stmt.split_whitespace().collect();
        let err = |m: &str| format!("line {}: {m}", lineno + 1);
        let segs_of = |n_fanin: usize| -> Result<Vec<u8>, String> {
            let list = comment
                .strip_prefix("segs=")
                .ok_or_else(|| err("missing segs comment"))?;
            let segs: Result<Vec<u8>, _> = list.split(',').map(|s| s.parse::<u8>()).collect();
            let segs = segs.map_err(|_| err("bad segs list"))?;
            if segs.len() != n_fanin {
                return Err(err("segs count mismatch"));
            }
            Ok(segs)
        };
        match toks.first() {
            Some(&".model") => name = toks.get(1).unwrap_or(&"unnamed").to_string(),
            Some(&".inputs") => {
                for t in &toks[1..] {
                    intern(t, Some(NodeKind::Input), &mut ids, &mut kinds);
                }
            }
            Some(&".outputs") => {
                for t in &toks[1..] {
                    intern(t, Some(NodeKind::Output), &mut ids, &mut kinds);
                }
            }
            Some(&".names") => {
                if toks.len() < 3 {
                    return Err(err(".names needs inputs and an output"));
                }
                let dst_tok = toks[toks.len() - 1];
                // Outputs were declared; everything else driven by .names is a LUT.
                let dst_kind = ids.get(dst_tok).map(|&i| kinds[i as usize]);
                let dst = intern(
                    dst_tok,
                    Some(dst_kind.unwrap_or(NodeKind::Lut)),
                    &mut ids,
                    &mut kinds,
                );
                let fanin = &toks[1..toks.len() - 1];
                let segs = segs_of(fanin.len())?;
                for (t, s) in fanin.iter().zip(segs) {
                    let src = intern(t, None, &mut ids, &mut kinds);
                    edges.push(Edge { src, dst, segments: s });
                }
            }
            Some(&".subckt") => {
                let kind = match toks.get(1) {
                    Some(&"bram") => NodeKind::Bram,
                    Some(&"dsp") => NodeKind::Dsp,
                    _ => return Err(err("unknown subckt")),
                };
                let mut fanin: Vec<&str> = Vec::new();
                let mut out_tok = None;
                for t in &toks[2..] {
                    if let Some(v) = t.strip_prefix("out=") {
                        out_tok = Some(v);
                    } else if let Some((_, v)) = t.split_once('=') {
                        fanin.push(v);
                    }
                }
                let out_tok = out_tok.ok_or_else(|| err("subckt missing out="))?;
                let dst = intern(out_tok, Some(kind), &mut ids, &mut kinds);
                let segs = segs_of(fanin.len())?;
                for (t, s) in fanin.iter().zip(segs) {
                    let src = intern(t, None, &mut ids, &mut kinds);
                    edges.push(Edge { src, dst, segments: s });
                }
            }
            Some(&".end") => break,
            Some(other) => return Err(err(&format!("unknown statement {other}"))),
            None => {}
        }
    }
    let n = Netlist { name, kinds, edges };
    n.validate()?;
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::TABLE1;
    use crate::netlist::gen::{generate, GenConfig};
    use crate::netlist::Counts;

    #[test]
    fn round_trip_preserves_structure() {
        for spec in &TABLE1[..2] {
            let n = generate(spec, &GenConfig { scale: 0.02, seed: 3, luts_per_lab: 10 });
            let text = write_blif(&n);
            let m = parse_blif(&text).unwrap();
            // Node ids may be renumbered; structure must match.
            let (ca, cb): (Counts, Counts) = (n.counts(), m.counts());
            assert_eq!(ca, cb, "{}", spec.name);
            assert_eq!(n.edges.len(), m.edges.len());
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_blif(".model x\n.frobnicate a b\n").is_err());
        assert!(parse_blif(".model x\n.names a b\n").is_err()); // no segs
        assert!(parse_blif(".model x\n.subckt bram in0=a # segs=1\n").is_err());
    }

    #[test]
    fn simple_handwritten_blif() {
        let text = "\
.model demo
.inputs a b
.outputs y
.names a b t # segs=1,2
.subckt bram in0=t out=m # segs=1
.names m y # segs=2
.end
";
        let n = parse_blif(text).unwrap();
        let c = n.counts();
        assert_eq!(c.inputs, 2);
        assert_eq!(c.outputs, 1);
        assert_eq!(c.luts, 1);
        assert_eq!(c.brams, 1);
        assert_eq!(c.routed_segments, 6);
    }
}
