//! Synthetic benchmark netlists — the Quartus→VQM→BLIF→VTR substitute
//! (DESIGN.md S3).
//!
//! The generator produces a layered DAG whose resource counts follow a
//! Table I row and whose intended critical path reproduces the benchmark's
//! post-P&R timing: `cp_logic_depth` LUT stages threaded with routing
//! segments, with a BRAM access (and optionally a DSP macro) spliced in.
//! STA (DESIGN.md S4) then treats these netlists exactly as VTR's timing
//! analyzer treats real ones.
//!
//! A BLIF-lite reader/writer round-trips netlists to disk so experiments
//! can pin a generated design.

pub mod blif;
pub mod gen;

pub use gen::{generate, GenConfig};

/// Node kinds carried by a netlist. FFs are folded into LUT stages (LAB
/// registers), matching the level of detail the paper's framework needs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NodeKind {
    /// Primary input (zero delay).
    Input,
    /// Primary output / timing endpoint (zero delay).
    Output,
    /// LUT stage (LAB register folded in).
    Lut,
    /// Block-RAM access (Vbram rail).
    Bram,
    /// DSP hard macro (Vcore rail).
    Dsp,
}

impl NodeKind {
    /// Stable on-disk code of the kind.
    pub fn code(self) -> u8 {
        match self {
            NodeKind::Input => 0,
            NodeKind::Output => 1,
            NodeKind::Lut => 2,
            NodeKind::Bram => 3,
            NodeKind::Dsp => 4,
        }
    }

    /// Inverse of [`NodeKind::code`].
    pub fn from_code(c: u8) -> Option<NodeKind> {
        Some(match c {
            0 => NodeKind::Input,
            1 => NodeKind::Output,
            2 => NodeKind::Lut,
            3 => NodeKind::Bram,
            4 => NodeKind::Dsp,
            _ => return None,
        })
    }

    /// Lower-case kind name (BLIF subckt names).
    pub fn name(self) -> &'static str {
        match self {
            NodeKind::Input => "input",
            NodeKind::Output => "output",
            NodeKind::Lut => "lut",
            NodeKind::Bram => "bram",
            NodeKind::Dsp => "dsp",
        }
    }
}

/// A directed connection routed through `segments` wire segments.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Edge {
    /// Source node id.
    pub src: u32,
    /// Destination node id.
    pub dst: u32,
    /// Routed wire segments on the connection (each adds delay).
    pub segments: u8,
}

/// Flat netlist representation sized for 10^5..10^6-node designs.
#[derive(Clone, Debug)]
pub struct Netlist {
    /// Design name (benchmark it was generated from).
    pub name: String,
    /// Node kinds, indexed by node id.
    pub kinds: Vec<NodeKind>,
    /// Directed connections.
    pub edges: Vec<Edge>,
}

/// Resource counts of a netlist (compare with `arch::Utilization`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counts {
    /// Primary inputs.
    pub inputs: usize,
    /// Primary outputs.
    pub outputs: usize,
    /// LUT stages.
    pub luts: usize,
    /// BRAM blocks.
    pub brams: usize,
    /// DSP macros.
    pub dsps: usize,
    /// Total routed wire segments across all edges.
    pub routed_segments: usize,
}

impl Netlist {
    /// Total node count.
    pub fn node_count(&self) -> usize {
        self.kinds.len()
    }

    /// Tally resource counts.
    pub fn counts(&self) -> Counts {
        let mut c = Counts::default();
        for &k in &self.kinds {
            match k {
                NodeKind::Input => c.inputs += 1,
                NodeKind::Output => c.outputs += 1,
                NodeKind::Lut => c.luts += 1,
                NodeKind::Bram => c.brams += 1,
                NodeKind::Dsp => c.dsps += 1,
            }
        }
        c.routed_segments = self.edges.iter().map(|e| e.segments as usize).sum();
        c
    }

    /// CSR-style fan-in adjacency: returns (offsets, in_edges) where
    /// `in_edges[offsets[n]..offsets[n+1]]` are indices into `self.edges`
    /// of the edges terminating at node `n`.
    pub fn fanin_index(&self) -> (Vec<u32>, Vec<u32>) {
        let n = self.kinds.len();
        let mut deg = vec![0u32; n + 1];
        for e in &self.edges {
            deg[e.dst as usize + 1] += 1;
        }
        for i in 0..n {
            deg[i + 1] += deg[i];
        }
        let mut pos = deg.clone();
        let mut idx = vec![0u32; self.edges.len()];
        for (ei, e) in self.edges.iter().enumerate() {
            let d = e.dst as usize;
            idx[pos[d] as usize] = ei as u32;
            pos[d] += 1;
        }
        (deg, idx)
    }

    /// Validate structural invariants (DAG-ness is checked by STA's
    /// topological sort; here: edge endpoints, I/O edge directions).
    pub fn validate(&self) -> Result<(), String> {
        let n = self.kinds.len() as u32;
        for (i, e) in self.edges.iter().enumerate() {
            if e.src >= n || e.dst >= n {
                return Err(format!("edge {i} out of range: {e:?}"));
            }
            if e.src == e.dst {
                return Err(format!("edge {i} is a self-loop: {e:?}"));
            }
            if self.kinds[e.dst as usize] == NodeKind::Input {
                return Err(format!("edge {i} drives an input: {e:?}"));
            }
            if self.kinds[e.src as usize] == NodeKind::Output {
                return Err(format!("edge {i} leaves an output: {e:?}"));
            }
            if e.segments == 0 {
                return Err(format!("edge {i} has zero segments"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Netlist {
        Netlist {
            name: "tiny".into(),
            kinds: vec![
                NodeKind::Input,
                NodeKind::Lut,
                NodeKind::Bram,
                NodeKind::Output,
            ],
            edges: vec![
                Edge { src: 0, dst: 1, segments: 2 },
                Edge { src: 1, dst: 2, segments: 1 },
                Edge { src: 2, dst: 3, segments: 3 },
            ],
        }
    }

    #[test]
    fn counts() {
        let c = tiny().counts();
        assert_eq!(
            c,
            Counts {
                inputs: 1,
                outputs: 1,
                luts: 1,
                brams: 1,
                dsps: 0,
                routed_segments: 6
            }
        );
    }

    #[test]
    fn fanin_index_groups_by_dst() {
        let n = tiny();
        let (off, idx) = n.fanin_index();
        assert_eq!(off.len(), 5);
        // node 1 has exactly one in-edge, edge 0
        assert_eq!(&idx[off[1] as usize..off[2] as usize], &[0]);
        assert_eq!(&idx[off[3] as usize..off[4] as usize], &[2]);
        assert_eq!(off[1] - off[0], 0); // inputs have no fan-in
    }

    #[test]
    fn validate_catches_violations() {
        let mut n = tiny();
        assert!(n.validate().is_ok());
        n.edges.push(Edge { src: 3, dst: 1, segments: 1 });
        assert!(n.validate().is_err()); // leaves an output
        n.edges.pop();
        n.edges.push(Edge { src: 1, dst: 0, segments: 1 });
        assert!(n.validate().is_err()); // drives an input
        n.edges.pop();
        n.edges.push(Edge { src: 1, dst: 1, segments: 1 });
        assert!(n.validate().is_err()); // self loop
        n.edges.pop();
        n.edges.push(Edge { src: 0, dst: 9, segments: 1 });
        assert!(n.validate().is_err()); // out of range
    }

    #[test]
    fn node_kind_codes_round_trip() {
        for k in [
            NodeKind::Input,
            NodeKind::Output,
            NodeKind::Lut,
            NodeKind::Bram,
            NodeKind::Dsp,
        ] {
            assert_eq!(NodeKind::from_code(k.code()), Some(k));
        }
        assert_eq!(NodeKind::from_code(9), None);
    }
}
