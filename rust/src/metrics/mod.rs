//! Lightweight metrics: counters, gauges, log-linear latency histograms,
//! and a shared named [`Registry`] (DESIGN.md S14). Lock-free on the hot
//! path; the registry takes a lock only to *resolve* a name — callers hold
//! the returned `Arc` and update it lock-free afterwards.

use std::collections::BTreeMap;

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{Arc, Mutex};

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add one.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// f64 gauge stored as bits.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// Overwrite the value.
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    /// Atomic add (CAS loop; fine for low-rate updates).
    pub fn add(&self, dv: f64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + dv).to_bits();
            match self
                .0
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(c) => cur = c,
            }
        }
    }
}

/// Log-linear histogram: `buckets_per_decade` linear buckets within each
/// power of 10, spanning `min_value`..`min_value * 10^decades`.
#[derive(Debug)]
pub struct Histogram {
    min_value: f64,
    buckets_per_decade: usize,
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_micro: AtomicU64,
    overflow: AtomicU64,
}

impl Histogram {
    /// Default: 1 µs .. 100 s with 20 buckets/decade (for seconds-valued
    /// observations scaled by the caller).
    pub fn new(min_value: f64, decades: usize, buckets_per_decade: usize) -> Self {
        Histogram {
            min_value,
            buckets_per_decade,
            buckets: (0..decades * buckets_per_decade)
                .map(|_| AtomicU64::new(0))
                .collect(),
            count: AtomicU64::new(0),
            sum_micro: AtomicU64::new(0),
            overflow: AtomicU64::new(0),
        }
    }

    /// The serving default: 1 µs .. 10^8 µs (100 s), 20 buckets/decade.
    pub fn latency_us() -> Self {
        Histogram::new(1.0, 8, 20)
    }

    /// Bucket index for a value plus whether the value overran the range
    /// and was clamped. Under-range values land in bucket 0; over-range
    /// values clamp into the *last* bucket instead of falling out of the
    /// distribution — dropping them made every quantile at or above the
    /// overflow fraction report `inf` while the mean stayed finite.
    fn bucket_of(&self, v: f64) -> (usize, bool) {
        if v < self.min_value {
            return (0, false);
        }
        let decades = (v / self.min_value).log10();
        let idx = (decades * self.buckets_per_decade as f64) as usize;
        if idx >= self.buckets.len() {
            (self.buckets.len() - 1, true)
        } else {
            (idx, false)
        }
    }

    /// Record one observation.
    pub fn observe(&self, v: f64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_micro
            .fetch_add((v * 1e6).max(0.0) as u64, Ordering::Relaxed);
        let (i, clamped) = self.bucket_of(v);
        self.buckets[i].fetch_add(1, Ordering::Relaxed);
        if clamped {
            // Still counted in the last bucket; this is observability for
            // "the range is too small", not a separate population.
            self.overflow.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Observations that overran the bucket range and were clamped into
    /// the last bucket.
    pub fn overflow_count(&self) -> u64 {
        self.overflow.load(Ordering::Relaxed)
    }

    /// Number of observations recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Arithmetic mean of all observations (0 when empty).
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_micro.load(Ordering::Relaxed) as f64 / 1e6 / c as f64
        }
    }

    /// Approximate quantile from bucket boundaries (upper edge).
    ///
    /// The target rank is clamped to ≥ 1 observation: `q = 0` means "the
    /// smallest observation's bucket", not "the first bucket of the
    /// histogram" — with `ceil(0·n) = 0` the old code matched before any
    /// count was seen and reported bucket 0's upper bound even when the
    /// first populated bucket was far higher.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((q * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return self.min_value
                    * 10f64.powf((i + 1) as f64 / self.buckets_per_decade as f64);
            }
        }
        // Unreachable now that every observation lands in some bucket
        // (over-range values clamp into the last one); kept as a defined
        // fallback rather than a panic.
        self.min_value * 10f64.powf(self.buckets.len() as f64 / self.buckets_per_decade as f64)
    }
}

/// A named metrics surface shared across the fleet: groups and workers
/// resolve counters/gauges once by name and update them lock-free.
/// Snapshots flatten everything into `(name, value)` rows for reports.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
}

impl Registry {
    /// Create an empty registry.
    pub fn new() -> Self {
        Registry::default()
    }

    /// Resolve (creating on first use) the counter named `name`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.counters.lock().unwrap();
        m.entry(name.to_string())
            .or_insert_with(|| Arc::new(Counter::default()))
            .clone()
    }

    /// Resolve (creating on first use) the gauge named `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.gauges.lock().unwrap();
        m.entry(name.to_string())
            .or_insert_with(|| Arc::new(Gauge::default()))
            .clone()
    }

    /// Resolve the gauge `{scope}.{name}` — the namespacing convention
    /// for per-owner metrics (`{node}.{group}.margin_now`, ...). Exactly
    /// equivalent to [`Registry::gauge`] on the joined name, so a scoped
    /// resolve and a flat resolve of the same full name share one
    /// instance.
    pub fn scoped_gauge(&self, scope: &str, name: &str) -> Arc<Gauge> {
        self.gauge(&format!("{scope}.{name}"))
    }

    /// Flatten all metrics into sorted `(name, value)` rows (counters as
    /// f64; gauges as stored).
    pub fn snapshot(&self) -> Vec<(String, f64)> {
        let mut out: Vec<(String, f64)> = Vec::new();
        for (k, c) in self.counters.lock().unwrap().iter() {
            out.push((k.clone(), c.get() as f64));
        }
        for (k, g) in self.gauges.lock().unwrap().iter() {
            out.push((k.clone(), g.get()));
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::default();
        g.set(2.5);
        g.add(0.5);
        assert!((g.get() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantiles_are_ordered() {
        let h = Histogram::latency_us();
        for i in 1..=1000u64 {
            h.observe(i as f64);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        // Log-linear resolution: within a bucket (~12% at 20/decade).
        assert!((400.0..700.0).contains(&p50), "p50 {p50}");
        assert!((850.0..1300.0).contains(&p95), "p95 {p95}");
        assert!((h.mean() - 500.5).abs() < 1.0);
    }

    #[test]
    fn histogram_overflow_and_underflow() {
        let h = Histogram::new(1.0, 2, 10); // 1..100
        h.observe(0.01); // underflow -> bucket 0
        h.observe(1e9); // over-range -> clamped into the last bucket
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.25) <= 2.0);
        // The clamped sample stays in the distribution: p100 is the last
        // bucket's upper bound (100 here), not the old `inf` which made
        // every p99 report useless once a single sample overran 100 s.
        assert_eq!(h.quantile(1.0), 100.0);
        assert_eq!(h.overflow_count(), 1, "clamping is still observable");
    }

    #[test]
    fn quantile_q0_is_the_first_populated_bucket() {
        // Regression: target = ceil(0·n) = 0 matched before any count was
        // seen, so q=0 reported bucket 0's upper bound even when every
        // observation sat far higher.
        let h = Histogram::latency_us();
        h.observe(5_000.0);
        h.observe(9_000.0);
        let q0 = h.quantile(0.0);
        assert!(q0 >= 5_000.0, "q0 {q0} must be the smallest observation's bucket");
        assert!(q0 <= 9_000.0);
        assert!(h.quantile(1.0) >= 9_000.0);
    }

    #[test]
    fn quantiles_of_a_single_sample_histogram_agree() {
        let h = Histogram::latency_us();
        h.observe(123.0);
        let (q0, q50, q100) = (h.quantile(0.0), h.quantile(0.5), h.quantile(1.0));
        assert_eq!(q0, q50, "all quantiles of one sample share its bucket");
        assert_eq!(q50, q100);
        assert!((100.0..200.0).contains(&q50), "bucket upper bound near 123: {q50}");
    }

    #[test]
    fn registry_resolves_and_snapshots() {
        let r = Registry::new();
        let a = r.counter("fleet.completed");
        let b = r.counter("fleet.completed");
        a.inc();
        b.add(2);
        assert_eq!(r.counter("fleet.completed").get(), 3, "same instance by name");
        r.gauge("fleet.energy_j").set(1.5);
        let snap = r.snapshot();
        assert_eq!(
            snap,
            vec![
                ("fleet.completed".to_string(), 3.0),
                ("fleet.energy_j".to_string(), 1.5),
            ]
        );
    }

    #[test]
    fn scoped_gauge_is_the_flat_gauge_under_the_joined_name() {
        let r = Registry::new();
        let scoped = r.scoped_gauge("node0.tabla", "margin_now");
        scoped.set(0.07);
        let flat = r.gauge("node0.tabla.margin_now");
        assert!(Arc::ptr_eq(&scoped, &flat), "one instance per full name");
        assert!((flat.get() - 0.07).abs() < 1e-12);
    }

    #[test]
    fn concurrent_updates() {
        let c = Arc::new(Counter::default());
        let h = Arc::new(Histogram::latency_us());
        let mut threads = Vec::new();
        for _ in 0..4 {
            let c = c.clone();
            let h = h.clone();
            // detlint: allow(thread-spawn) -- counter stress test; no
            // simulated time
            threads.push(std::thread::spawn(move || {
                for i in 0..10_000 {
                    c.inc();
                    h.observe(i as f64);
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(c.get(), 40_000);
        assert_eq!(h.count(), 40_000);
    }
}
