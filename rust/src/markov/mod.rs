//! Workload prediction (paper §IV.A, §V) — DESIGN.md S7.
//!
//! The paper uses a light-weight online predictor in the PRESS [37] style:
//! * workloads with known periodic signatures use the per-phase average of
//!   previous periods as a bias (`PeriodicPredictor`);
//! * otherwise a discrete-time Markov chain over M workload bins learns
//!   transition probabilities online (`MarkovPredictor`), predicts the next
//!   bin, and adds a t% throughput margin to absorb one-bin
//!   under-estimates. Mispredictions snap the chain to the observed state
//!   and (past a threshold) re-learn the offending edge.
//!
//! `EwmaPredictor` and `LastValuePredictor` are baselines for the
//! prediction-accuracy bench (Fig. 8).
//!
//! On top of the single predictors sits the adaptive [`Ensemble`]
//! (DESIGN.md S7): every predictor runs shadow-mode and the active one
//! switches per workload with hysteresis. The margin side of the loop —
//! the adaptive [`Guardband`](crate::control::Guardband) and its LUT
//! ladder — lives in the shared control plane
//! ([`crate::control::guardband`], DESIGN.md S19/S7.1).

pub mod ensemble;

pub use ensemble::{Ensemble, EnsembleConfig};

use crate::workload::bin_of_load;

/// Common interface: observe the load of the finished time step, then
/// predict the next step's load (both normalized to peak, in [0, 1]).
/// `Send` so boxed predictors can live inside CC threads.
pub trait Predictor: Send {
    /// Record the actual load of the just-finished step.
    fn observe(&mut self, load: f64);
    /// Predict the next step's load.
    fn predict(&self) -> f64;
    /// Short predictor name for reports/benches.
    fn name(&self) -> &'static str;
    /// Name of the prediction source actually in use — for single
    /// predictors this is [`Predictor::name`]; the [`Ensemble`] reports
    /// its currently-active member.
    fn active_name(&self) -> &'static str {
        self.name()
    }
}

/// Selectable predictor implementations (`--predictor` on the CLI).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PredictorKind {
    /// The adaptive shadow-mode ensemble (DESIGN.md S7).
    Ensemble,
    /// The paper's M-bin discrete-time Markov chain.
    Markov,
    /// Per-phase running average over a known period.
    Periodic,
    /// Exponentially-weighted moving average baseline.
    Ewma,
    /// Naive last-value baseline.
    LastValue,
}

/// Report names of every [`PredictorKind`] plus the ensemble's member
/// names, in [`PredictorKind::ALL`] order — the index table behind the
/// live `predictor_now` gauge.
pub const PREDICTOR_NAMES: [&str; 5] = ["ensemble", "markov", "periodic", "ewma", "last-value"];

impl PredictorKind {
    /// Every kind, ensemble first.
    pub const ALL: [PredictorKind; 5] = [
        PredictorKind::Ensemble,
        PredictorKind::Markov,
        PredictorKind::Periodic,
        PredictorKind::Ewma,
        PredictorKind::LastValue,
    ];

    /// CLI/report name of the kind.
    pub fn name(self) -> &'static str {
        PREDICTOR_NAMES[self as usize]
    }

    /// Resolve a CLI name (`ensemble`, `markov`, `ewma`, ...).
    pub fn by_name(name: &str) -> Result<PredictorKind, String> {
        Ok(match name {
            "ensemble" => PredictorKind::Ensemble,
            "markov" => PredictorKind::Markov,
            "periodic" => PredictorKind::Periodic,
            "ewma" => PredictorKind::Ewma,
            "last-value" | "last" => PredictorKind::LastValue,
            other => {
                return Err(format!(
                    "unknown predictor {other} (known: {})",
                    PREDICTOR_NAMES.join(", ")
                ))
            }
        })
    }

    /// Index of a predictor *name* in [`PREDICTOR_NAMES`] (0 when the
    /// name is unknown — names come from [`Predictor::active_name`], so
    /// an unknown one would be a new member not yet registered here).
    pub fn index_of_name(name: &str) -> usize {
        PREDICTOR_NAMES.iter().position(|&n| n == name).unwrap_or(0)
    }

    /// Name of the prediction source that is active at startup — the
    /// kind itself for single predictors, the [`Ensemble`]'s startup
    /// member (Markov, the paper's default) for the ensemble. The live
    /// `predictor_now` gauge is seeded from this so it reports a real
    /// member from epoch 0 instead of the literal "ensemble"
    /// (`active_name_consistency` pins it against the built predictor).
    pub fn initial_active_name(self) -> &'static str {
        match self {
            PredictorKind::Ensemble => "markov",
            k => k.name(),
        }
    }

    /// Build the predictor: `m_bins` workload bins, `warmup` pure-training
    /// steps, `period` steps/cycle for the periodic member.
    pub fn build(self, m_bins: usize, warmup: usize, period: usize) -> Box<dyn Predictor> {
        match self {
            PredictorKind::Ensemble => {
                Box::new(Ensemble::new(m_bins, warmup, period, EnsembleConfig::default()))
            }
            PredictorKind::Markov => Box::new(MarkovPredictor::new(m_bins, warmup)),
            PredictorKind::Periodic => Box::new(PeriodicPredictor::new(period.max(1))),
            PredictorKind::Ewma => Box::new(EwmaPredictor::new(0.3)),
            PredictorKind::LastValue => Box::new(LastValuePredictor::default()),
        }
    }
}

/// Discrete-time Markov chain over M bins with online count learning.
#[derive(Clone, Debug)]
pub struct MarkovPredictor {
    m: usize,
    /// Transition counts; row = current bin.
    counts: Vec<Vec<f64>>,
    state: usize,
    steps_seen: usize,
    /// Steps of pure training before predictions are trusted (paper: the
    /// platform runs at nominal frequency for the first I steps).
    warmup: usize,
    /// Consecutive-misprediction counter per edge (predicted -> actual).
    mispredictions: usize,
    /// After this many mispredictions the offending row is re-weighted.
    mispredict_threshold: usize,
    last_prediction: Option<usize>,
}

impl MarkovPredictor {
    /// Create an untrained chain over `m` bins with `warmup` pure-training
    /// steps (during which predictions pin to the top bin).
    pub fn new(m: usize, warmup: usize) -> Self {
        assert!(m >= 2, "need at least 2 bins");
        MarkovPredictor {
            m,
            // Laplace prior keeps rows stochastic before data arrives.
            counts: vec![vec![1.0 / m as f64; m]; m],
            state: 0,
            steps_seen: 0,
            warmup,
            mispredictions: 0,
            mispredict_threshold: 8,
            last_prediction: None,
        }
    }

    /// Load a pre-trained transition matrix (the paper's "if a pre-trained
    /// model of the workload is available, it can be loaded on FPGA").
    pub fn with_matrix(m: usize, rows: Vec<Vec<f64>>) -> Result<Self, String> {
        if rows.len() != m || rows.iter().any(|r| r.len() != m) {
            return Err(format!("matrix must be {m}x{m}"));
        }
        for (i, row) in rows.iter().enumerate() {
            let s: f64 = row.iter().sum();
            if (s - 1.0).abs() > 1e-6 {
                return Err(format!("row {i} sums to {s}, not 1"));
            }
            if row.iter().any(|&p| p < 0.0) {
                return Err(format!("row {i} has negative probability"));
            }
        }
        let mut p = MarkovPredictor::new(m, 0);
        // Scale to pseudo-counts so online learning keeps adapting.
        p.counts = rows
            .into_iter()
            .map(|r| r.into_iter().map(|x| x * 16.0).collect())
            .collect();
        Ok(p)
    }

    /// Number of workload bins M.
    pub fn m_bins(&self) -> usize {
        self.m
    }

    /// Bin index of a normalized load in [0, 1] — delegates to the shared
    /// [`workload::bin_of_load`](crate::workload::bin_of_load) so the
    /// Markov state space, the voltage/elastic LUT keys and the workload
    /// bins can never drift apart.
    pub fn bin_of(&self, load: f64) -> usize {
        bin_of_load(self.m, load)
    }

    /// Upper edge of a bin — the load the platform must be able to serve
    /// when it predicts this bin.
    pub fn bin_upper(&self, bin: usize) -> f64 {
        crate::workload::bin_upper(self.m, bin)
    }

    /// Row-normalized transition probabilities.
    pub fn transition_matrix(&self) -> Vec<Vec<f64>> {
        self.counts
            .iter()
            .map(|row| {
                let s: f64 = row.iter().sum();
                row.iter().map(|&c| c / s).collect()
            })
            .collect()
    }

    /// Whether the last prediction missed the observed bin, and by how
    /// many bins (signed: positive = under-estimate).
    pub fn last_misprediction(&self, observed: f64) -> Option<i64> {
        self.last_prediction.map(|p| self.bin_of(observed) as i64 - p as i64)
    }

    /// True while the chain is still in its pure-training phase.
    pub fn in_warmup(&self) -> bool {
        self.steps_seen < self.warmup
    }

    /// Most likely next bin from the current state (top bin in warmup).
    /// Ties break toward the *current* state, so a cold row — e.g. right
    /// after a surge snapped the chain into a state it has never left —
    /// predicts persistence instead of collapsing to bin 0 (which would
    /// publish minimum frequency at the worst possible moment).
    pub fn predicted_bin(&self) -> usize {
        if self.in_warmup() {
            // Training phase: platform runs at maximum frequency.
            return self.m - 1;
        }
        let row = &self.counts[self.state];
        let mut best = self.state;
        let mut best_c = row[self.state];
        for (j, &c) in row.iter().enumerate() {
            if c > best_c {
                best_c = c;
                best = j;
            }
        }
        best
    }
}

impl Predictor for MarkovPredictor {
    fn observe(&mut self, load: f64) {
        let actual = self.bin_of(load);
        // Misprediction handling (paper §V): snap to the observed state;
        // past the threshold, boost the corrected edge so the chain
        // re-learns quickly.
        if let Some(pred) = self.last_prediction {
            if pred != actual {
                self.mispredictions += 1;
                if self.mispredictions >= self.mispredict_threshold {
                    self.counts[self.state][actual] += 4.0;
                    self.mispredictions = 0;
                }
            } else {
                self.mispredictions = 0;
            }
        }
        self.counts[self.state][actual] += 1.0;
        self.state = actual;
        self.steps_seen += 1;
        self.last_prediction = Some(self.predicted_bin());
    }

    fn predict(&self) -> f64 {
        self.bin_upper(self.predicted_bin())
    }

    fn name(&self) -> &'static str {
        "markov"
    }
}

/// Periodic-signature predictor: per-phase running average over a known
/// period (paper: "workloads with repeating patterns are divided into time
/// intervals which are repeated with the period").
#[derive(Clone, Debug)]
pub struct PeriodicPredictor {
    period: usize,
    phase: usize,
    sums: Vec<f64>,
    counts: Vec<usize>,
}

impl PeriodicPredictor {
    /// Create a predictor for a known `period` (steps per cycle).
    pub fn new(period: usize) -> Self {
        assert!(period >= 1);
        PeriodicPredictor { period, phase: 0, sums: vec![0.0; period], counts: vec![0; period] }
    }
}

impl Predictor for PeriodicPredictor {
    fn observe(&mut self, load: f64) {
        self.sums[self.phase] += load.clamp(0.0, 1.0);
        self.counts[self.phase] += 1;
        self.phase = (self.phase + 1) % self.period;
    }

    fn predict(&self) -> f64 {
        if self.counts[self.phase] == 0 {
            return 1.0; // untrained phase: be safe, run at maximum
        }
        self.sums[self.phase] / self.counts[self.phase] as f64
    }

    fn name(&self) -> &'static str {
        "periodic"
    }
}

/// Exponentially-weighted moving average baseline.
#[derive(Clone, Debug)]
pub struct EwmaPredictor {
    alpha: f64,
    value: Option<f64>,
}

impl EwmaPredictor {
    /// Create an EWMA with smoothing factor `alpha` in [0, 1].
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        EwmaPredictor { alpha, value: None }
    }
}

impl Predictor for EwmaPredictor {
    fn observe(&mut self, load: f64) {
        let load = load.clamp(0.0, 1.0);
        self.value = Some(match self.value {
            None => load,
            Some(v) => self.alpha * load + (1.0 - self.alpha) * v,
        });
    }

    fn predict(&self) -> f64 {
        self.value.unwrap_or(1.0)
    }

    fn name(&self) -> &'static str {
        "ewma"
    }
}

/// Naive last-value baseline.
#[derive(Clone, Debug, Default)]
pub struct LastValuePredictor {
    value: Option<f64>,
}

impl Predictor for LastValuePredictor {
    fn observe(&mut self, load: f64) {
        self.value = Some(load.clamp(0.0, 1.0));
    }

    fn predict(&self) -> f64 {
        self.value.unwrap_or(1.0)
    }

    fn name(&self) -> &'static str {
        "last-value"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn bins_partition_unit_interval() {
        let p = MarkovPredictor::new(4, 0);
        assert_eq!(p.bin_of(0.0), 0);
        assert_eq!(p.bin_of(0.25), 0);
        assert_eq!(p.bin_of(0.2501), 1);
        assert_eq!(p.bin_of(0.75), 2);
        assert_eq!(p.bin_of(1.0), 3);
        assert_eq!(p.bin_upper(3), 1.0);
    }

    #[test]
    fn rows_stay_stochastic() {
        let mut p = MarkovPredictor::new(5, 0);
        let mut rng = Rng::new(1);
        for _ in 0..500 {
            p.observe(rng.f64());
        }
        for row in p.transition_matrix() {
            let s: f64 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(row.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn warmup_predicts_maximum() {
        let mut p = MarkovPredictor::new(4, 10);
        for _ in 0..5 {
            p.observe(0.1);
            assert_eq!(p.predict(), 1.0, "training phase must run at max");
        }
    }

    #[test]
    fn learns_a_deterministic_cycle() {
        // 0.1 -> 0.5 -> 0.9 -> 0.1 ... must become perfectly predictable.
        let mut p = MarkovPredictor::new(10, 5);
        let cycle = [0.1, 0.5, 0.9];
        for i in 0..60 {
            p.observe(cycle[i % 3]);
        }
        let mut correct = 0;
        for i in 60..90 {
            let predicted = p.predict();
            let actual = cycle[i % 3];
            if p.bin_of(predicted) == p.bin_of(actual) {
                correct += 1;
            }
            p.observe(actual);
        }
        assert!(correct >= 28, "cycle accuracy {correct}/30");
    }

    #[test]
    fn prediction_covers_sticky_workloads() {
        // Slowly varying (high-Hurst-ish) loads: next bin ~ current bin.
        let mut p = MarkovPredictor::new(10, 10);
        let mut rng = Rng::new(3);
        let mut load = 0.4;
        let mut hits = 0;
        let mut total = 0;
        for step in 0..2000 {
            load = (load + rng.normal() * 0.02).clamp(0.05, 0.95);
            if step > 100 {
                total += 1;
                // Covered if predicted bin >= actual bin (enough capacity).
                if p.predict() >= load - 0.1 {
                    hits += 1;
                }
            }
            p.observe(load);
        }
        assert!(hits as f64 / total as f64 > 0.9, "coverage {hits}/{total}");
    }

    #[test]
    fn misprediction_is_reported_signed() {
        let mut p = MarkovPredictor::new(4, 0);
        for _ in 0..10 {
            p.observe(0.1); // learns to predict bin 0
        }
        assert_eq!(p.predicted_bin(), 0);
        // A burst to bin 3 is an under-estimate of +3.
        assert_eq!(p.last_misprediction(0.9), Some(3));
        assert_eq!(p.last_misprediction(0.1), Some(0));
    }

    #[test]
    fn cold_state_predicts_persistence_not_bin_zero() {
        // Regression: a surge snaps the chain into a state whose row is
        // still the uniform Laplace prior; the argmax used to tie-break
        // to bin 0 and publish minimum frequency right after the surge.
        let mut p = MarkovPredictor::new(10, 0);
        for _ in 0..50 {
            p.observe(0.15); // lock onto bin 1
        }
        p.observe(0.55); // jump into the never-visited bin 5
        assert_eq!(
            p.predicted_bin(),
            5,
            "a cold row must predict persistence: {:?}",
            p.transition_matrix()[5]
        );
        assert!(p.predict() >= 0.55, "the published capacity covers the surge");
    }

    #[test]
    fn pretrained_matrix_round_trip() {
        let rows = vec![
            vec![0.9, 0.1, 0.0],
            vec![0.2, 0.6, 0.2],
            vec![0.0, 0.5, 0.5],
        ];
        let p = MarkovPredictor::with_matrix(3, rows.clone()).unwrap();
        let got = p.transition_matrix();
        for (a, b) in rows.iter().flatten().zip(got.iter().flatten()) {
            assert!((a - b).abs() < 1e-9);
        }
        assert!(MarkovPredictor::with_matrix(3, vec![vec![1.0; 3]; 3]).is_err());
        assert!(MarkovPredictor::with_matrix(2, vec![vec![1.0, 0.0]]).is_err());
    }

    #[test]
    fn periodic_predictor_learns_signature() {
        let mut p = PeriodicPredictor::new(24);
        let signal = |h: usize| 0.2 + 0.6 * ((h as f64 / 24.0) * std::f64::consts::TAU).sin().abs();
        for day in 0..5 {
            for h in 0..24 {
                let _ = day;
                p.observe(signal(h));
            }
        }
        for h in 0..24 {
            let err = (p.predict() - signal(h)).abs();
            assert!(err < 0.05, "phase {h}: err {err}");
            p.observe(signal(h));
        }
    }

    #[test]
    fn active_name_consistency() {
        // initial_active_name must agree with what the freshly-built
        // predictor actually reports, for every kind — the live
        // predictor_now gauge is seeded from it before the first epoch.
        for kind in PredictorKind::ALL {
            let p = kind.build(10, 5, 24);
            assert_eq!(
                p.active_name(),
                kind.initial_active_name(),
                "{}: gauge seed drifted from the built predictor",
                kind.name()
            );
            assert_ne!(p.active_name(), "", "active name must be a real member");
        }
        assert_eq!(PredictorKind::Ensemble.initial_active_name(), "markov");
        assert_eq!(PredictorKind::Ewma.initial_active_name(), "ewma");
    }

    #[test]
    fn ewma_and_last_value() {
        let mut e = EwmaPredictor::new(0.5);
        assert_eq!(e.predict(), 1.0); // safe default
        e.observe(0.4);
        e.observe(0.8);
        assert!((e.predict() - 0.6).abs() < 1e-12);

        let mut l = LastValuePredictor::default();
        assert_eq!(l.predict(), 1.0);
        l.observe(0.3);
        assert_eq!(l.predict(), 0.3);
    }
}
