//! Adaptive predictor ensemble (DESIGN.md S7): every registered predictor
//! runs shadow-mode on the same load stream, an online score (rolling MAE
//! plus an under-prediction penalty) ranks them, and the active predictor
//! switches with hysteresis — at most once per dwell period, and only for
//! a clear relative advantage. PRESS-style adaptive prediction: the
//! workload picks its own predictor instead of a fixed startup choice.

use std::collections::VecDeque;

use super::{
    EwmaPredictor, LastValuePredictor, MarkovPredictor, PeriodicPredictor, Predictor,
};
use crate::workload::bin_of_load;

/// Tuning of the ensemble's scoring and switching behavior.
#[derive(Clone, Copy, Debug)]
pub struct EnsembleConfig {
    /// Rolling scoring window in steps (per member).
    pub window: usize,
    /// Weight of the under-prediction rate in the score. Under-estimates
    /// cost QoS, over-estimates only energy, so they are penalized on top
    /// of the symmetric MAE term.
    pub under_penalty: f64,
    /// Minimum steps between predictor switches (dwell hysteresis).
    pub min_dwell: usize,
    /// Relative score advantage a challenger needs to take over
    /// (score hysteresis): `best < active · (1 - advantage)`.
    pub advantage: f64,
}

impl Default for EnsembleConfig {
    fn default() -> Self {
        // Conservative on purpose: a challenger must beat the active
        // predictor by a wide margin *including* a heavy under-prediction
        // penalty before it takes over, so the ensemble only ever leaves
        // the paper's Markov default for a clearly superior fit (and the
        // QoS acceptance bound vs the Markov baseline stays safe).
        EnsembleConfig { window: 32, under_penalty: 2.0, min_dwell: 16, advantage: 0.25 }
    }
}

/// Per-member rolling score state.
struct MemberScore {
    /// `(abs_error, under_predicted)` of the member's last `window`
    /// shadow predictions.
    window: VecDeque<(f64, bool)>,
}

impl MemberScore {
    fn new() -> Self {
        MemberScore { window: VecDeque::new() }
    }

    fn push(&mut self, err: f64, under: bool, cap: usize) {
        self.window.push_back((err, under));
        while self.window.len() > cap {
            self.window.pop_front();
        }
    }

    fn mae(&self) -> f64 {
        if self.window.is_empty() {
            return 0.0;
        }
        self.window.iter().map(|(e, _)| e).sum::<f64>() / self.window.len() as f64
    }

    fn under_rate(&self) -> f64 {
        if self.window.is_empty() {
            return 0.0;
        }
        self.window.iter().filter(|(_, u)| *u).count() as f64 / self.window.len() as f64
    }
}

/// Shadow-mode predictor ensemble: all members observe every load, the
/// best-scoring member predicts (with switch hysteresis), and the first
/// `warmup` steps pin the prediction to 1.0 (train at maximum frequency,
/// like the Markov warmup).
pub struct Ensemble {
    m_bins: usize,
    warmup: usize,
    steps_seen: usize,
    cfg: EnsembleConfig,
    members: Vec<Box<dyn Predictor>>,
    scores: Vec<MemberScore>,
    active: usize,
    since_switch: usize,
    switches: usize,
}

impl Ensemble {
    /// Build the standard member set — Markov (`m_bins` bins), Periodic
    /// (`period` steps/cycle), EWMA and last-value — with `warmup` pure
    /// training steps.
    pub fn new(m_bins: usize, warmup: usize, period: usize, cfg: EnsembleConfig) -> Self {
        let members: Vec<Box<dyn Predictor>> = vec![
            Box::new(MarkovPredictor::new(m_bins, warmup)),
            Box::new(PeriodicPredictor::new(period.max(1))),
            Box::new(EwmaPredictor::new(0.3)),
            Box::new(LastValuePredictor::default()),
        ];
        let scores = members.iter().map(|_| MemberScore::new()).collect();
        Ensemble {
            m_bins,
            warmup,
            steps_seen: 0,
            cfg,
            members,
            scores,
            active: 0, // Markov: the paper's default until scores say otherwise
            since_switch: 0,
            switches: 0,
        }
    }

    /// True while every prediction pins to 1.0 (training phase).
    pub fn in_warmup(&self) -> bool {
        self.steps_seen < self.warmup
    }

    /// Combined score of member `i`: rolling MAE + penalized under rate.
    /// Lower is better.
    pub fn score(&self, i: usize) -> f64 {
        self.scores[i].mae() + self.cfg.under_penalty * self.scores[i].under_rate()
    }

    /// `(name, score, under_rate)` rows for every member, member order.
    pub fn score_rows(&self) -> Vec<(&'static str, f64, f64)> {
        (0..self.members.len())
            .map(|i| (self.members[i].name(), self.score(i), self.scores[i].under_rate()))
            .collect()
    }

    /// Index of the currently active member.
    pub fn active_index(&self) -> usize {
        self.active
    }

    /// How many times the active predictor has switched so far.
    pub fn switch_count(&self) -> usize {
        self.switches
    }

    fn maybe_switch(&mut self) {
        if self.in_warmup() || self.since_switch < self.cfg.min_dwell {
            return;
        }
        let mut best = self.active;
        let mut best_score = self.score(self.active);
        for i in 0..self.members.len() {
            let s = self.score(i);
            if s < best_score {
                best_score = s;
                best = i;
            }
        }
        if best != self.active
            && best_score < self.score(self.active) * (1.0 - self.cfg.advantage)
        {
            self.active = best;
            self.since_switch = 0;
            self.switches += 1;
        }
    }
}

impl Predictor for Ensemble {
    fn observe(&mut self, load: f64) {
        let load = load.clamp(0.0, 1.0);
        let load_bin = bin_of_load(self.m_bins, load);
        // Warmup steps train the members but are not scored: the Markov
        // member deliberately pins to 1.0 during training (run at max),
        // and counting those forecasts as errors would poison its score
        // for a whole window and hand the lead to whichever baseline
        // happened to track the warmup loads.
        let scored = !self.in_warmup();
        for i in 0..self.members.len() {
            if scored {
                // Score the member's forecast *for this step* before it
                // sees the outcome, then train it.
                let pred = self.members[i].predict();
                let under = bin_of_load(self.m_bins, pred) < load_bin;
                self.scores[i].push((pred - load).abs(), under, self.cfg.window);
            }
            self.members[i].observe(load);
        }
        self.steps_seen += 1;
        if scored {
            // The dwell clock also starts post-warmup, so the earliest
            // possible switch is min_dwell *scored* steps in.
            self.since_switch += 1;
            self.maybe_switch();
        }
    }

    fn predict(&self) -> f64 {
        if self.in_warmup() {
            return 1.0; // training phase: run at maximum, like Markov warmup
        }
        self.members[self.active].predict()
    }

    fn name(&self) -> &'static str {
        "ensemble"
    }

    fn active_name(&self) -> &'static str {
        self.members[self.active].name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_predicts_maximum_then_releases() {
        let mut e = Ensemble::new(10, 5, 24, EnsembleConfig::default());
        for _ in 0..5 {
            assert_eq!(e.predict(), 1.0, "warmup pins to max");
            e.observe(0.2);
        }
        assert!(!e.in_warmup());
        assert!(e.predict() < 1.0, "post-warmup tracks the low load");
    }

    #[test]
    fn ensemble_switches_to_periodic_on_a_clean_sinusoid() {
        // A noiseless diurnal signal: the periodic member's per-phase
        // average becomes near-exact while Markov stays bin-granular, so
        // the ensemble must eventually hand over.
        let period = 24;
        let signal =
            |t: usize| 0.25 + 0.5 * ((t % period) as f64 / period as f64 * std::f64::consts::TAU)
                .sin()
                .abs();
        let mut e = Ensemble::new(10, 4, period, EnsembleConfig::default());
        for t in 0..400 {
            e.observe(signal(t));
        }
        assert_eq!(e.active_name(), "periodic", "scores: {:?}", e.score_rows());
        // And having switched, its predictions track the signal closely.
        let mut worst: f64 = 0.0;
        for t in 400..424 {
            worst = worst.max((e.predict() - signal(t)).abs());
            e.observe(signal(t));
        }
        assert!(worst < 0.12, "periodic forecast error {worst}");
    }

    #[test]
    fn switching_respects_dwell_hysteresis() {
        let cfg = EnsembleConfig { min_dwell: 50, ..Default::default() };
        let mut e = Ensemble::new(10, 0, 8, cfg);
        // An 8-periodic square wave — periodic/last-value/markov all see
        // very different scores immediately, but no switch may land before
        // the dwell expires.
        for t in 0..49 {
            e.observe(if (t / 4) % 2 == 0 { 0.2 } else { 0.8 });
            assert_eq!(e.switch_count(), 0, "switched inside the dwell window");
        }
    }

    #[test]
    fn under_predictions_are_penalized() {
        let mut e = Ensemble::new(10, 0, 4, EnsembleConfig::default());
        // Rising staircase: last-value and EWMA chronically under-predict.
        for t in 0..200 {
            e.observe(((t % 10) as f64) / 10.0);
        }
        let rows = e.score_rows();
        let last = rows.iter().find(|(n, _, _)| *n == "last-value").unwrap();
        assert!(last.2 > 0.5, "last-value must under-predict a rising ramp: {rows:?}");
    }

    #[test]
    fn warmup_predictions_are_not_scored_against_markov() {
        // Regression: the Markov member pins to 1.0 during warmup by
        // design; scoring those steps gave it a poisoned MAE and the
        // ensemble abandoned it right after warmup on any steady load.
        let warmup = 20;
        let mut e = Ensemble::new(10, warmup, 24, EnsembleConfig::default());
        for _ in 0..warmup {
            e.observe(0.2);
        }
        let rows = e.score_rows();
        assert!(
            rows.iter().all(|(_, s, _)| *s == 0.0),
            "warmup must leave score windows empty: {rows:?}"
        );
        // A few post-warmup steps on the same steady load: every member
        // tracks it, so no one clears the switch hysteresis and Markov
        // keeps the lead.
        for _ in 0..EnsembleConfig::default().min_dwell + 4 {
            e.observe(0.2);
        }
        assert_eq!(e.active_name(), "markov", "{:?}", e.score_rows());
    }

    #[test]
    fn score_rows_cover_all_members() {
        let e = Ensemble::new(10, 0, 24, EnsembleConfig::default());
        let names: Vec<&str> = e.score_rows().iter().map(|(n, _, _)| *n).collect();
        assert_eq!(names, vec!["markov", "periodic", "ewma", "last-value"]);
        assert_eq!(e.name(), "ensemble");
        assert_eq!(e.active_name(), "markov", "markov is the startup default");
    }
}
