//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime, parsed with the in-repo JSON module.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Shape + dtype of one argument or result.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    /// Row-major dimensions.
    pub shape: Vec<usize>,
    /// Element dtype tag (`f32` or `i32`).
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count (product of dims).
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<Self> {
        let shape = v
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = v
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("missing dtype"))?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// Golden-data pointers for DNN artifacts.
#[derive(Clone, Debug)]
pub struct GoldenMeta {
    /// Relative path of the flat f32 parameter blob.
    pub params_bin: String,
    /// Relative path of the golden x/y blob.
    pub golden_bin: String,
    /// First 8 golden outputs (quick sanity values).
    pub y_first8: Vec<f64>,
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// Artifact name (manifest key).
    pub name: String,
    /// Relative path of the HLO text file.
    pub path: String,
    /// Argument specs, in call order.
    pub args: Vec<TensorSpec>,
    /// Result specs, in tuple order.
    pub results: Vec<TensorSpec>,
    /// Artifact kind (`voltage_opt`, `dnn`, ...).
    pub kind: String,
    /// Golden-data pointers (DNN artifacts only).
    pub golden: Option<GoldenMeta>,
    /// Raw numeric metadata (nv, nm, batch, v_step, ...).
    meta_nums: BTreeMap<String, f64>,
}

impl ArtifactMeta {
    /// Numeric metadata value by key.
    pub fn meta_f64(&self, key: &str) -> Result<f64> {
        self.meta_nums
            .get(key)
            .copied()
            .ok_or_else(|| anyhow!("{}: missing meta {key}", self.name))
    }

    /// Numeric metadata value that must be a non-negative integer.
    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        let v = self.meta_f64(key)?;
        if v < 0.0 || v.fract() != 0.0 {
            bail!("{}: meta {key} = {v} is not a usize", self.name);
        }
        Ok(v as usize)
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Manifest schema version (1).
    pub version: usize,
    /// jax version that produced the artifacts.
    pub jax_version: String,
    /// Artifacts by name.
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    /// Read and parse `manifest.json` from disk.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Self> {
        let root = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let version = root
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest: missing version"))?;
        if version != 1 {
            bail!("manifest: unsupported version {version}");
        }
        let jax_version = root
            .get("jax")
            .and_then(Json::as_str)
            .unwrap_or("unknown")
            .to_string();
        let mut artifacts = BTreeMap::new();
        let arts = root
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest: missing artifacts"))?;
        for (name, v) in arts {
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                v.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("{name}: missing {key}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            let meta = v.get("meta").ok_or_else(|| anyhow!("{name}: missing meta"))?;
            let mut meta_nums = BTreeMap::new();
            if let Some(obj) = meta.as_obj() {
                for (k, mv) in obj {
                    if let Some(x) = mv.as_f64() {
                        meta_nums.insert(k.clone(), x);
                    }
                }
            }
            let golden = meta.get("golden").map(|g| -> Result<GoldenMeta> {
                Ok(GoldenMeta {
                    params_bin: g
                        .get("params_bin")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("{name}: golden.params_bin"))?
                        .to_string(),
                    golden_bin: g
                        .get("golden_bin")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("{name}: golden.golden_bin"))?
                        .to_string(),
                    y_first8: g
                        .get("y_first8")
                        .and_then(Json::as_arr)
                        .map(|a| a.iter().filter_map(Json::as_f64).collect())
                        .unwrap_or_default(),
                })
            });
            let golden = match golden {
                Some(Ok(g)) => Some(g),
                Some(Err(e)) => return Err(e),
                None => None,
            };
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    path: v
                        .get("path")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("{name}: missing path"))?
                        .to_string(),
                    args: parse_specs("args")?,
                    results: parse_specs("results")?,
                    kind: meta
                        .get("kind")
                        .and_then(Json::as_str)
                        .unwrap_or("unknown")
                        .to_string(),
                    golden,
                    meta_nums,
                },
            );
        }
        Ok(Manifest { version, jax_version, artifacts })
    }

    /// Names of DNN variants present (sorted).
    pub fn dnn_variants(&self) -> Vec<String> {
        self.artifacts
            .keys()
            .filter_map(|k| k.strip_prefix("dnn_").map(str::to_string))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "jax": "0.8.2",
      "artifacts": {
        "voltage_opt_prop": {
          "path": "voltage_opt_prop.hlo.txt",
          "args": [
            {"shape": [13], "dtype": "f32"},
            {"shape": [64], "dtype": "f32"}
          ],
          "results": [
            {"shape": [64], "dtype": "i32"},
            {"shape": [64], "dtype": "f32"}
          ],
          "meta": {"kind": "voltage_opt", "nv": 13, "nm": 19, "batch": 64,
                   "v_step": 0.025, "vcore_nom": 0.8, "vbram_nom": 0.95}
        },
        "dnn_tabla": {
          "path": "dnn_tabla.hlo.txt",
          "args": [{"shape": [16, 128], "dtype": "f32"}],
          "results": [{"shape": [16, 64], "dtype": "f32"}],
          "meta": {"kind": "dnn", "batch": 16,
                   "golden": {"params_bin": "p.bin", "golden_bin": "g.bin",
                              "y_first8": [0.1, -0.2]}}
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.version, 1);
        let v = &m.artifacts["voltage_opt_prop"];
        assert_eq!(v.args.len(), 2);
        assert_eq!(v.args[0].shape, vec![13]);
        assert_eq!(v.args[0].elements(), 13);
        assert_eq!(v.meta_usize("batch").unwrap(), 64);
        assert!((v.meta_f64("v_step").unwrap() - 0.025).abs() < 1e-12);
        assert!(v.golden.is_none());
        let d = &m.artifacts["dnn_tabla"];
        assert_eq!(d.kind, "dnn");
        let g = d.golden.as_ref().unwrap();
        assert_eq!(g.params_bin, "p.bin");
        assert_eq!(g.y_first8.len(), 2);
        assert_eq!(m.dnn_variants(), vec!["tabla".to_string()]);
    }

    #[test]
    fn rejects_bad_version_and_missing_fields() {
        assert!(Manifest::parse(r#"{"version": 2, "artifacts": {}}"#).is_err());
        assert!(Manifest::parse(r#"{"artifacts": {}}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
        assert!(Manifest::parse(
            r#"{"version":1,"artifacts":{"x":{"args":[],"results":[],"meta":{}}}}"#
        )
        .is_err());
    }

    #[test]
    fn meta_usize_validation() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let v = &m.artifacts["voltage_opt_prop"];
        assert!(v.meta_usize("v_step").is_err()); // fractional
        assert!(v.meta_usize("missing").is_err());
    }
}
