//! PJRT runtime: load the AOT-compiled HLO-text artifacts and execute them
//! on the request path (DESIGN.md S10). Python never runs here.
//!
//! Flow (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO *text* is the interchange format —
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids.

pub mod manifest;

pub use manifest::{ArtifactMeta, Manifest, TensorSpec};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::power::RailTables;
use crate::vscale::Mode;

/// A typed host tensor (f32 or i32), row-major.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    /// 32-bit float elements.
    F32(Vec<f32>),
    /// 32-bit signed integer elements.
    I32(Vec<i32>),
}

impl Tensor {
    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(v) => v.len(),
            Tensor::I32(v) => v.len(),
        }
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The elements as f32s, if that is the dtype.
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Tensor::F32(v) => Some(v),
            _ => None,
        }
    }

    /// The elements as i32s, if that is the dtype.
    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            Tensor::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// One compiled artifact bound to the PJRT client.
pub struct Executable {
    /// Manifest entry of the artifact (shapes, dtypes, metadata).
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// A device-resident tensor (pre-uploaded argument).
pub struct DeviceTensor {
    buf: xla::PjRtBuffer,
}

impl Executable {
    /// Execute with host tensors; validates shapes/dtypes against the
    /// manifest and unpacks the result tuple.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.meta.args.len() {
            bail!(
                "{}: expected {} args, got {}",
                self.meta.name,
                self.meta.args.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (t, spec)) in inputs.iter().zip(&self.meta.args).enumerate() {
            if t.len() != spec.elements() {
                bail!(
                    "{} arg {i}: expected {} elements ({:?}), got {}",
                    self.meta.name,
                    spec.elements(),
                    spec.shape,
                    t.len()
                );
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = match (t, spec.dtype.as_str()) {
                (Tensor::F32(v), "f32") => xla::Literal::vec1(v).reshape(&dims)?,
                (Tensor::I32(v), "i32") => xla::Literal::vec1(v).reshape(&dims)?,
                (t, d) => bail!("{} arg {i}: dtype mismatch {t:?} vs {d}", self.meta.name),
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        self.unpack(result)
    }

    /// Execute with pre-uploaded device buffers (zero host->device copies
    /// on the hot path; see EXPERIMENTS.md §Perf-L3).
    pub fn run_device(&self, inputs: &[&DeviceTensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.meta.args.len() {
            bail!(
                "{}: expected {} args, got {}",
                self.meta.name,
                self.meta.args.len(),
                inputs.len()
            );
        }
        let bufs: Vec<&xla::PjRtBuffer> = inputs.iter().map(|t| &t.buf).collect();
        let result = self.exe.execute_b::<&xla::PjRtBuffer>(&bufs)?[0][0]
            .to_literal_sync()?;
        self.unpack(result)
    }

    fn unpack(&self, result: xla::Literal) -> Result<Vec<Tensor>> {
        // Artifacts are lowered with return_tuple=True.
        let parts = result.to_tuple()?;
        if parts.len() != self.meta.results.len() {
            bail!(
                "{}: expected {} results, got {}",
                self.meta.name,
                self.meta.results.len(),
                parts.len()
            );
        }
        parts
            .into_iter()
            .zip(&self.meta.results)
            .map(|(lit, spec)| {
                Ok(match spec.dtype.as_str() {
                    "f32" => Tensor::F32(lit.to_vec::<f32>()?),
                    "i32" => Tensor::I32(lit.to_vec::<i32>()?),
                    other => bail!("unsupported result dtype {other}"),
                })
            })
            .collect()
    }
}

/// The engine: one PJRT CPU client + a compile cache over the manifest.
pub struct Engine {
    /// Artifacts directory the engine was opened on.
    pub dir: PathBuf,
    /// Parsed `manifest.json`.
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Engine {
    /// Open an artifacts directory produced by `make artifacts`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { dir, manifest, client, cache: Mutex::new(HashMap::new()) })
    }

    /// PJRT platform the client runs on (e.g. `cpu`).
    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))?
            .clone();
        let path = self.dir.join(&meta.path);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let executable = std::sync::Arc::new(Executable { meta, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), executable.clone());
        Ok(executable)
    }

    /// Upload an f32 tensor to the device once (for loop-invariant args).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<DeviceTensor> {
        Ok(DeviceTensor { buf: self.client.buffer_from_host_buffer(data, dims, None)? })
    }

    /// Read a side binary (params/golden) as f32 little-endian.
    pub fn read_f32_bin(&self, rel: &str) -> Result<Vec<f32>> {
        let bytes = std::fs::read(self.dir.join(rel))
            .with_context(|| format!("reading {rel}"))?;
        if bytes.len() % 4 != 0 {
            bail!("{rel}: length {} not a multiple of 4", bytes.len());
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// High-level client for the AOT'd Voltage Selector artifacts: pads
/// operating-point queries to the artifact batch and converts grid
/// indices back to voltages.
pub struct VoltageSelectorClient<'a> {
    engine: &'a Engine,
}

/// One query row: Eq. (1)-(3) parameters for an operating point.
#[derive(Clone, Copy, Debug)]
pub struct OpQuery {
    /// Eq. (1): BRAM share of the path relative to core delay.
    pub alpha: f32,
    /// Eq. (3): BRAM-rail share of total power.
    pub beta: f32,
    /// Dynamic fraction of the core rail.
    pub gamma_l: f32,
    /// Dynamic fraction of the BRAM rail.
    pub gamma_m: f32,
    /// Allowed clock-period stretch factor (≥ 1).
    pub sw: f32,
}

/// The artifact's answer for one [`OpQuery`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OpChoice {
    /// Core-rail grid index (0 = nominal).
    pub icore: usize,
    /// BRAM-rail grid index (0 = nominal).
    pub ibram: usize,
    /// Core-rail voltage (V).
    pub vcore: f64,
    /// BRAM-rail voltage (V).
    pub vbram: f64,
    /// Total power, normalized to nominal = 1.
    pub power_norm: f64,
}

impl<'a> VoltageSelectorClient<'a> {
    /// Bind the client to an engine.
    pub fn new(engine: &'a Engine) -> Self {
        VoltageSelectorClient { engine }
    }

    /// Run the `mode` variant over the given rail tables and queries.
    pub fn select(
        &self,
        mode: Mode,
        tables: &RailTables,
        queries: &[OpQuery],
    ) -> Result<Vec<OpChoice>> {
        let art = mode
            .artifact()
            .ok_or_else(|| anyhow!("mode {mode:?} has no artifact"))?;
        let exe = self.engine.load(art)?;
        let meta = &exe.meta;
        let (nv, nm, batch) = (meta.meta_usize("nv")?, meta.meta_usize("nm")?, meta.meta_usize("batch")?);
        if tables.dl.len() != nv || tables.dm.len() != nm {
            bail!(
                "rail tables ({}, {}) do not match artifact grid ({nv}, {nm})",
                tables.dl.len(),
                tables.dm.len()
            );
        }
        if queries.is_empty() {
            return Ok(vec![]);
        }
        let f32v = |xs: &[f64]| Tensor::F32(xs.iter().map(|&x| x as f32).collect());
        let v_step = meta.meta_f64("v_step")?;
        let vcore_nom = meta.meta_f64("vcore_nom")?;
        let vbram_nom = meta.meta_f64("vbram_nom")?;

        let mut out = Vec::with_capacity(queries.len());
        for chunk in queries.chunks(batch) {
            // Pad the batch with the last query (results discarded).
            let pad = |f: fn(&OpQuery) -> f32| {
                let mut v: Vec<f32> = chunk.iter().map(f).collect();
                v.resize(batch, f(chunk.last().unwrap()));
                Tensor::F32(v)
            };
            let results = exe.run(&[
                f32v(&tables.dl),
                f32v(&tables.dm),
                f32v(&tables.pl_dyn),
                f32v(&tables.pl_st),
                f32v(&tables.pm_dyn),
                f32v(&tables.pm_st),
                pad(|q| q.alpha),
                pad(|q| q.beta),
                pad(|q| q.gamma_l),
                pad(|q| q.gamma_m),
                pad(|q| q.sw),
            ])?;
            let icore = results[0].as_i32().ok_or_else(|| anyhow!("icore dtype"))?;
            let ibram = results[1].as_i32().ok_or_else(|| anyhow!("ibram dtype"))?;
            let power = results[2].as_f32().ok_or_else(|| anyhow!("power dtype"))?;
            for k in 0..chunk.len() {
                out.push(OpChoice {
                    icore: icore[k] as usize,
                    ibram: ibram[k] as usize,
                    vcore: vcore_nom - v_step * icore[k] as f64,
                    vbram: vbram_nom - v_step * ibram[k] as f64,
                    power_norm: power[k] as f64,
                });
            }
        }
        Ok(out)
    }
}

/// High-level client for a served DNN variant: loads its parameters from
/// the side binary once and runs inference batches.
pub struct DnnClient {
    /// Benchmark variant the client serves.
    pub variant: String,
    exe: std::sync::Arc<Executable>,
    client: xla::PjRtClient,
    /// Parameters uploaded once, device-resident for every request batch.
    param_bufs: Vec<DeviceTensor>,
    x_dims: Vec<usize>,
    /// Requests per inference dispatch (artifact batch).
    pub batch: usize,
    /// Input feature width.
    pub in_dim: usize,
    /// Output width (logits).
    pub out_dim: usize,
}

impl DnnClient {
    /// Load the `dnn_<variant>` artifact and upload its parameters.
    pub fn new(engine: &Engine, variant: &str) -> Result<Self> {
        let name = format!("dnn_{variant}");
        let exe = engine.load(&name)?;
        let meta = exe.meta.clone();
        let batch = meta.meta_usize("batch")?;
        let in_dim = meta.args[0].shape[1];
        let out_dim = meta.results[0].shape[1];

        // Slice the flat params blob into per-arg tensors (args[1..]).
        let params_bin = meta
            .golden
            .as_ref()
            .ok_or_else(|| anyhow!("{name}: no params metadata"))?
            .params_bin
            .clone();
        let flat = engine.read_f32_bin(&params_bin)?;
        let mut param_bufs = Vec::new();
        let mut off = 0usize;
        for spec in &meta.args[1..] {
            let n = spec.elements();
            if off + n > flat.len() {
                bail!("{name}: params blob too short");
            }
            // Upload once; stays device-resident for the client's lifetime.
            param_bufs.push(engine.upload_f32(&flat[off..off + n], &spec.shape)?);
            off += n;
        }
        if off != flat.len() {
            bail!("{name}: params blob has {} trailing floats", flat.len() - off);
        }
        Ok(DnnClient {
            variant: variant.to_string(),
            exe,
            client: engine.client.clone(),
            param_bufs,
            x_dims: meta.args[0].shape.clone(),
            batch,
            in_dim,
            out_dim,
        })
    }

    /// Run one inference batch (x is batch×in_dim, row-major). Only `x`
    /// crosses the host boundary; parameters are device-resident.
    pub fn infer(&self, x: &[f32]) -> Result<Vec<f32>> {
        if x.len() != self.batch * self.in_dim {
            bail!(
                "dnn_{}: expected {}x{} input, got {} floats",
                self.variant,
                self.batch,
                self.in_dim,
                x.len()
            );
        }
        let xbuf = DeviceTensor {
            buf: self.client.buffer_from_host_buffer(x, &self.x_dims, None)?,
        };
        let mut inputs: Vec<&DeviceTensor> = Vec::with_capacity(1 + self.param_bufs.len());
        inputs.push(&xbuf);
        inputs.extend(self.param_bufs.iter());
        let out = self.exe.run_device(&inputs)?;
        Ok(out[0].as_f32().ok_or_else(|| anyhow!("output dtype"))?.to_vec())
    }

    /// Verify numerics against the python-side golden x/y.
    pub fn verify_golden(&self, engine: &Engine) -> Result<f32> {
        let g = self
            .exe
            .meta
            .golden
            .as_ref()
            .ok_or_else(|| anyhow!("no golden metadata"))?;
        let blob = engine.read_f32_bin(&g.golden_bin)?;
        let nx = self.batch * self.in_dim;
        let ny = self.batch * self.out_dim;
        if blob.len() != nx + ny {
            bail!("golden blob length {} != {}", blob.len(), nx + ny);
        }
        let y = self.infer(&blob[..nx])?;
        let mut max_err = 0.0f32;
        for (a, b) in y.iter().zip(&blob[nx..]) {
            let err = (a - b).abs() / (1.0 + b.abs());
            max_err = max_err.max(err);
        }
        Ok(max_err)
    }
}
