//! Tiny benchmark harness (criterion is unavailable offline; DESIGN.md
//! S15). Used by the `harness = false` bench binaries.
//!
//! `bench_fn` warms up, then runs timed iterations until both a minimum
//! iteration count and a minimum wall time are reached, and reports
//! median / mean / p95 per-iteration times.

// detlint: allow(wallclock) -- a benchmark harness measures wall time by
// definition; bench binaries never write replayable traces
use std::time::{Duration, Instant};

/// Timing summary of one benchmarked closure.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Timed iterations performed.
    pub iters: usize,
    /// Median per-iteration time.
    pub median: Duration,
    /// Mean per-iteration time.
    pub mean: Duration,
    /// 95th-percentile per-iteration time.
    pub p95: Duration,
    /// Iterations per second over the whole run.
    pub throughput_hz: f64,
}

impl BenchResult {
    /// One-line aligned report of the result.
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>10} iters  median {:>12?}  mean {:>12?}  p95 {:>12?}  ({:.1}/s)",
            self.name, self.iters, self.median, self.mean, self.p95, self.throughput_hz
        )
    }
}

/// Benchmark a closure. The closure's return value is black-boxed.
pub fn bench_fn<T>(name: &str, mut f: impl FnMut() -> T) -> BenchResult {
    // Warmup: at least 3 iterations / 50 ms.
    let warm_start = Instant::now(); // detlint: allow(wallclock) -- bench timing
    let mut warm_iters = 0;
    while warm_iters < 3 || warm_start.elapsed() < Duration::from_millis(50) {
        black_box(f());
        warm_iters += 1;
        if warm_iters > 10_000 {
            break;
        }
    }

    let mut samples: Vec<Duration> = Vec::new();
    let run_start = Instant::now(); // detlint: allow(wallclock) -- bench timing
    while samples.len() < 10 || run_start.elapsed() < Duration::from_millis(300) {
        let t0 = Instant::now(); // detlint: allow(wallclock) -- bench timing
        black_box(f());
        samples.push(t0.elapsed());
        if samples.len() >= 100_000 {
            break;
        }
    }
    samples.sort();
    let iters = samples.len();
    let total: Duration = samples.iter().sum();
    let mean = total / iters as u32;
    let median = samples[iters / 2];
    let p95 = samples[((iters as f64 * 0.95) as usize).min(iters - 1)];
    BenchResult {
        name: name.to_string(),
        iters,
        median,
        mean,
        p95,
        throughput_hz: iters as f64 / total.as_secs_f64().max(1e-12),
    }
}

/// Prevent the optimizer from discarding a value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a bench section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_fn_reports_sane_stats() {
        let mut calls = 0u64;
        let r = bench_fn("noop", || {
            calls += 1;
            calls
        });
        assert!(r.iters >= 10);
        assert!(calls as usize >= r.iters);
        assert!(r.median <= r.p95);
        assert!(r.throughput_hz > 0.0);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn bench_fn_measures_real_work() {
        let fast = bench_fn("fast", || 1 + 1);
        let slow = bench_fn("slow", || {
            std::thread::sleep(std::time::Duration::from_micros(300));
        });
        assert!(slow.median > fast.median * 5, "{:?} vs {:?}", slow.median, fast.median);
    }
}
