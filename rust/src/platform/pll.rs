//! PLL models (paper §IV.B, §V "PLL Overhead").
//!
//! Reprogramming a PLL through its Reconfiguration Port de-asserts the
//! Lock signal for up to 100 µs. With a single PLL the fabric must stall
//! until lock; with two PLLs the shadow is programmed during the previous
//! step and a glitchless mux swaps clocks at the step edge (Fig. 9c), so
//! retunes cost no stall time — at the price of a second PLL's power
//! (Eq. 4/5 decide when that trade is worth it; for τ ≳ 2 ms it always is).

/// Dual-PLL bank: `program` targets the shadow; the swap happens at the
/// next `tick_us` (step edge) if the shadow has locked.
#[derive(Clone, Debug)]
pub struct DualPll {
    active_mhz: f64,
    shadow_mhz: f64,
    shadow_lock_remaining_us: f64,
    lock_us: f64,
    retunes: usize,
}

impl DualPll {
    /// Both PLLs locked at `f_mhz`; relock takes `lock_us`.
    pub fn new(f_mhz: f64, lock_us: f64) -> Self {
        DualPll {
            active_mhz: f_mhz,
            shadow_mhz: f_mhz,
            shadow_lock_remaining_us: 0.0,
            lock_us,
            retunes: 0,
        }
    }

    /// Frequency of the active (fabric-driving) PLL.
    pub fn freq_mhz(&self) -> f64 {
        self.active_mhz
    }

    /// Number of frequency changes so far.
    pub fn retunes(&self) -> usize {
        self.retunes
    }

    /// Program the shadow PLL for the next step.
    pub fn program(&mut self, f_mhz: f64) {
        if (f_mhz - self.shadow_mhz).abs() > 1e-9 {
            self.shadow_mhz = f_mhz;
            self.shadow_lock_remaining_us = self.lock_us;
            self.retunes += 1;
        }
    }

    /// Advance one step of `dt_us`. Returns stall time (always 0 for the
    /// dual scheme as long as τ ≫ lock time, asserted here).
    pub fn tick_us(&mut self, dt_us: f64) -> f64 {
        debug_assert!(dt_us >= self.lock_us, "step shorter than PLL lock time");
        // Shadow locks during the step, swap at the edge.
        self.shadow_lock_remaining_us = (self.shadow_lock_remaining_us - dt_us).max(0.0);
        if self.shadow_lock_remaining_us <= 0.0 {
            self.active_mhz = self.shadow_mhz;
        }
        0.0
    }
}

/// Single-PLL: reprogramming stalls the fabric for the lock time at the
/// start of the next step (Eq. 4's overhead).
#[derive(Clone, Debug)]
pub struct SinglePll {
    freq_mhz: f64,
    pending_mhz: Option<f64>,
    lock_us: f64,
    total_stall_us: f64,
    retunes: usize,
}

impl SinglePll {
    /// PLL locked at `f_mhz`; relock stalls the fabric for `lock_us`.
    pub fn new(f_mhz: f64, lock_us: f64) -> Self {
        SinglePll {
            freq_mhz: f_mhz,
            pending_mhz: None,
            lock_us,
            total_stall_us: 0.0,
            retunes: 0,
        }
    }

    /// Current output frequency.
    pub fn freq_mhz(&self) -> f64 {
        self.freq_mhz
    }

    /// Accumulated fabric stall from relocking (µs).
    pub fn total_stall_us(&self) -> f64 {
        self.total_stall_us
    }

    /// Number of frequency changes so far.
    pub fn retunes(&self) -> usize {
        self.retunes
    }

    /// Request a frequency change at the next step edge.
    pub fn program(&mut self, f_mhz: f64) {
        if (f_mhz - self.freq_mhz).abs() > 1e-9 {
            self.pending_mhz = Some(f_mhz);
        }
    }

    /// Advance one step; returns the stall time consumed by locking.
    pub fn tick_us(&mut self, dt_us: f64) -> f64 {
        if let Some(f) = self.pending_mhz.take() {
            self.freq_mhz = f;
            self.retunes += 1;
            let stall = self.lock_us.min(dt_us);
            self.total_stall_us += stall;
            stall
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dual_pll_swaps_without_stall() {
        let mut p = DualPll::new(100.0, 100.0);
        p.program(50.0);
        assert_eq!(p.freq_mhz(), 100.0, "swap waits for the step edge");
        let stall = p.tick_us(1_000_000.0);
        assert_eq!(stall, 0.0);
        assert_eq!(p.freq_mhz(), 50.0);
        assert_eq!(p.retunes(), 1);
    }

    #[test]
    fn dual_pll_no_retune_for_same_freq() {
        let mut p = DualPll::new(100.0, 100.0);
        p.program(100.0);
        p.tick_us(1_000_000.0);
        assert_eq!(p.retunes(), 0);
    }

    #[test]
    fn single_pll_accumulates_stall() {
        let mut p = SinglePll::new(100.0, 100.0);
        p.program(80.0);
        let s1 = p.tick_us(1_000_000.0);
        assert_eq!(s1, 100.0);
        assert_eq!(p.freq_mhz(), 80.0);
        p.program(60.0);
        p.tick_us(1_000_000.0);
        assert_eq!(p.total_stall_us(), 200.0);
        assert_eq!(p.retunes(), 2);
        // No pending change, no stall.
        assert_eq!(p.tick_us(1_000_000.0), 0.0);
    }
}
