//! Multi-FPGA platform simulator (paper Figs. 7 & 9; DESIGN.md S9).
//!
//! Time is divided into steps of length τ. Each step the Central
//! Controller (CC) on the lead FPGA:
//!   1. reads the workload counter (actual load of the finished step),
//!   2. updates the predictor and predicts the next step's bin,
//!   3. selects the platform frequency for that bin (+t% margin),
//!   4. looks up the pre-computed optimal (Vcore, Vbram) for the policy,
//!   5. programs the *shadow* PLL and the DVS rails so the swap at the
//!      step edge costs nothing (dual-PLL scheme, Eq. 4/5).
//!
//! All n FPGA instances process a share of the input stream at the common
//! frequency; delivered throughput is capacity-limited and shortfalls
//! carry over as bounded backlog (QoS accounting).
//!
//! Since the control-plane extraction (DESIGN.md S19) this module is a
//! pure *plant*: it keeps the physics — PLL lock, capacity, backlog,
//! power accounting — and delegates every per-step decision (predict,
//! guardband, margin ladder, LUT lookup) to the shared
//! [`GroupController`](crate::control::GroupController), the same engine
//! the live `coordinator::fleet` CC runs.

pub mod fleet;
pub mod pll;

use crate::control::{
    batch_amortization, ControlConfig, DecisionRecord, GroupController, LutSpec,
    Observation,
};
use crate::markov::PredictorKind;
use crate::power::DesignPower;
use crate::vscale::{CapacityPolicy, Mode, Optimizer};
use pll::{DualPll, SinglePll};

/// Platform-level power management policy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Policy {
    /// The paper's DVFS framework under the given voltage mode, driven by
    /// the Markov predictor.
    Dvfs(Mode),
    /// DVFS with a perfect (oracle) predictor — the upper bound.
    DvfsOracle(Mode),
    /// Conventional power gating: `ceil(n·load)` boards at nominal V/f.
    PowerGating,
    /// No management: all boards at nominal V/f (the gain baseline).
    NominalStatic,
    /// Elastic capacity: the Markov-predicted bin picks the joint
    /// minimum-power (active count, Vcore, Vbram, f) from the
    /// [`ElasticLut`]; gated boards draw `pg_residual` of nominal
    /// (DESIGN.md S6.1).
    Hybrid(Mode),
}

impl Policy {
    /// CLI/report name of the policy.
    pub fn name(&self) -> String {
        match self {
            Policy::Dvfs(m) => m.name().to_string(),
            Policy::DvfsOracle(m) => format!("oracle-{}", m.name()),
            Policy::PowerGating => "power-gating".to_string(),
            Policy::NominalStatic => "nominal".to_string(),
            Policy::Hybrid(m) => format!("hybrid-{}", m.name()),
        }
    }
}

/// Simulator configuration (defaults follow the paper's evaluation).
#[derive(Clone, Debug)]
pub struct PlatformConfig {
    /// FPGA instances in the platform.
    pub n_fpgas: usize,
    /// Step length τ in seconds (paper: "at least in order of seconds").
    pub tau_s: f64,
    /// Markov bins M.
    pub m_bins: usize,
    /// Throughput margin t, a fraction in [0, 1): capacity is sized for
    /// the predicted bin's *upper edge* × (1 + t), so the margin absorbs
    /// boundary effects on top of the edge sizing (paper §IV.A, default
    /// 5%).
    pub margin_t: f64,
    /// Pure-training steps I before predictions are trusted.
    pub warmup_steps: usize,
    /// Dual-PLL shadow reprogramming (paper's recommendation) vs single.
    pub dual_pll: bool,
    /// PLL lock time (µs, ≤ 100).
    pub pll_lock_us: f64,
    /// Residual power fraction of a gated board.
    pub pg_residual: f64,
    /// Bounded backlog, in units of one step's nominal capacity.
    pub max_backlog_steps: f64,
    /// Optional latency restriction (paper §IV: "if an application has
    /// specific latency restrictions, it should be considered in the
    /// voltage and frequency scaling"): the clock may never be stretched
    /// beyond this factor, i.e. freq_ratio >= 1 / latency_cap_sw.
    pub latency_cap_sw: Option<f64>,
    /// Which workload predictor drives the CC (DESIGN.md S7);
    /// `PredictorKind::Ensemble` runs all of them shadow-mode and
    /// switches with hysteresis.
    pub predictor: PredictorKind,
    /// Steps per cycle assumed by the periodic predictor (ensemble
    /// member / `PredictorKind::Periodic`).
    pub predictor_period: usize,
    /// `Some(target)` enables the adaptive guardband (DESIGN.md S7.1):
    /// the static `margin_t` becomes the controller's starting point and
    /// QoS-at-risk floor, and the margin tracks the observed violation
    /// rate against `target`. `None` keeps the paper's fixed t% margin.
    pub qos_target: Option<f64>,
    /// Which capacity dimensions [`Policy::Hybrid`]'s elastic search may
    /// move (DESIGN.md S6.1). `Hybrid` (default) is the joint manager;
    /// `DvfsOnly` / `GatingOnly` turn `Policy::Hybrid` into exactly the
    /// live coordinator's baseline capacity policies, which is what the
    /// cross-path equivalence suite replays. Ignored by the other
    /// policies.
    pub capacity_policy: CapacityPolicy,
    /// Nominal requests per dispatched inference batch (the backend's
    /// native geometry; mirrors the live `FleetServingConfig`).
    pub batch_nominal: usize,
    /// Treat batch size as a per-step control decision (DESIGN.md S22):
    /// the controller publishes bigger batches at low frequency ratios to
    /// amortize per-dispatch overhead. Off by default — fixed-batch runs
    /// multiply capacity by an exact 1.0 and stay bit-identical to the
    /// pre-knob traces.
    pub adaptive_batch: bool,
    /// Per-dispatch overhead as a fraction of `cycles_per_batch` (weight
    /// swap/DMA setup/pipeline refill), the lever
    /// [`batch_amortization`] trades against batch size.
    pub batch_overhead: f64,
}

impl Default for PlatformConfig {
    fn default() -> Self {
        PlatformConfig {
            n_fpgas: 4,
            tau_s: 1.0,
            m_bins: 10,
            margin_t: 0.05,
            warmup_steps: 20,
            dual_pll: true,
            pll_lock_us: 100.0,
            pg_residual: 0.02,
            max_backlog_steps: 1.0,
            latency_cap_sw: None,
            predictor: PredictorKind::Markov,
            predictor_period: 96,
            qos_target: None,
            capacity_policy: CapacityPolicy::Hybrid,
            batch_nominal: 16,
            adaptive_batch: false,
            batch_overhead: 0.1,
        }
    }
}

/// Per-step record (the rows behind Figs. 10–12).
///
/// The decision columns live in the embedded [`DecisionRecord`] —
/// shared with the live `coordinator::EpochRecord` so the two trace
/// formats cannot drift — and are reachable directly through `Deref`
/// (`rec.freq_ratio`, `rec.margin`, ...). Alignment within the record:
/// `freq_ratio`/`vcore`/`vbram`/`n_active`/`batch` are the operating
/// point that *served* this step (chosen at the end of the previous
/// step), while
/// `predicted`/`predictor`/`margin` come from the decision *made* this
/// step — the historical column semantics of this trace.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    /// Step index.
    pub step: usize,
    /// Normalized load offered this step.
    pub load: f64,
    /// Shared decision columns (see the struct-level note on alignment).
    pub decision: DecisionRecord,
    /// Total platform power this step (W), PLLs included.
    pub power_w: f64,
    /// Work actually served (capacity-limited), normalized.
    pub delivered: f64,
    /// Unserved work carried to the next step, normalized.
    pub backlog: f64,
    /// True when demand exceeded capacity this step.
    pub qos_violation: bool,
    /// True when the predictor missed the observed bin.
    pub mispredicted: bool,
    /// Boards the *power accounting* charged as active this step: the
    /// decision's count for [`Policy::Hybrid`], `n_fpgas` for pure-DVFS
    /// and nominal, and the load-tracking `ceil(n·load)` for
    /// [`Policy::PowerGating`] (whose gating is plant physics, not a
    /// control decision).
    pub active_boards: f64,
}

impl std::ops::Deref for StepRecord {
    type Target = DecisionRecord;

    fn deref(&self) -> &DecisionRecord {
        &self.decision
    }
}

/// Aggregate simulation outcome.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Name of the simulated policy.
    pub policy: String,
    /// Per-step trace rows.
    pub records: Vec<StepRecord>,
    /// Average platform power over the run (W).
    pub avg_power_w: f64,
    /// All-nominal platform power (W), the gain baseline.
    pub nominal_power_w: f64,
    /// Paper's headline metric: nominal power / policy power.
    pub power_gain: f64,
    /// Total energy over the run (J).
    pub energy_j: f64,
    /// Energy spent by the PLLs alone (J).
    pub pll_energy_j: f64,
    /// Steps whose demand exceeded capacity.
    pub qos_violations: usize,
    /// `qos_violations / steps`.
    pub violation_rate: f64,
    /// Steps whose predicted bin missed the observed bin.
    pub mispredictions: usize,
    /// Fabric stall time from PLL relocking (µs; single-PLL only).
    pub stalled_us: f64,
}

/// The platform: n instances of one benchmark design (the plant) + the
/// shared per-group control plane making its CC decisions.
pub struct Platform {
    /// Simulator configuration.
    pub cfg: PlatformConfig,
    /// Power model of the design on its device.
    pub design: DesignPower,
    optimizer: Optimizer,
    policy: Policy,
    /// The shared control plane (DESIGN.md S19): predictor, guardband,
    /// margin ladder and per-level LUTs — the same engine the live
    /// coordinator's CC runs.
    controller: GroupController,
    plls: PllBank,
    /// Normalized backlog carried between steps.
    backlog: f64,
    /// Current step's frequency ratio (set at the end of the previous
    /// step; the platform starts at nominal frequency).
    freq_ratio: f64,
    vcore: f64,
    vbram: f64,
    /// Boards active this step (only [`Policy::Hybrid`] gates below n).
    active: usize,
    /// Requests per dispatched batch this step (set at the end of the
    /// previous step, like the frequency; starts at the nominal).
    batch: usize,
    step_idx: usize,
}

enum PllBank {
    Dual(Vec<DualPll>),
    Single(Vec<SinglePll>),
}

impl Platform {
    /// Assemble a platform from its parts (see [`build_platform`] for the
    /// by-name convenience).
    pub fn new(
        cfg: PlatformConfig,
        design: DesignPower,
        optimizer: Optimizer,
        policy: Policy,
    ) -> Self {
        assert!(cfg.n_fpgas >= 1);
        // Real invariants (the old margin/bins assert was vacuously true
        // for every m_bins >= 1): the Markov state space needs >= 2 bins
        // and the margin is a fraction — same rules SimConfig::validate
        // enforces on the CLI/JSON path.
        assert!(cfg.m_bins >= 2, "m_bins must be >= 2");
        assert!(
            (0.0..1.0).contains(&cfg.margin_t),
            "margin_t must be a fraction in [0, 1), got {}",
            cfg.margin_t
        );
        let cap = cfg.latency_cap_sw.unwrap_or(f64::INFINITY);
        let (vcore, vbram) = (design.chars.logic.v_nom, design.chars.bram.v_nom);
        // The plant only chooses which LUT family the shared controller
        // consults; ladder construction, guardband and LUT builds all
        // live in `control` (DESIGN.md S19).
        let spec = match policy {
            Policy::Dvfs(m) | Policy::DvfsOracle(m) => LutSpec::Dvfs {
                mode: m,
                n_instances: cfg.n_fpgas,
                latency_cap_sw: cap,
            },
            Policy::Hybrid(m) => LutSpec::Elastic {
                mode: m,
                n_instances: cfg.n_fpgas,
                residual: cfg.pg_residual,
                policy: cfg.capacity_policy,
                latency_cap_sw: cap,
            },
            Policy::PowerGating | Policy::NominalStatic => LutSpec::Fixed {
                vcore,
                vbram,
                n_instances: cfg.n_fpgas,
            },
        };
        let controller = GroupController::new(
            ControlConfig {
                m_bins: cfg.m_bins,
                margin_t: cfg.margin_t,
                warmup: cfg.warmup_steps,
                predictor: cfg.predictor,
                predictor_period: cfg.predictor_period,
                qos_target: cfg.qos_target,
                batch_nominal: cfg.batch_nominal,
                adaptive_batch: cfg.adaptive_batch,
            },
            &optimizer,
            spec,
        );
        let f_nom = design.spec.freq_mhz;
        let plls = if cfg.dual_pll {
            PllBank::Dual(
                (0..cfg.n_fpgas)
                    .map(|_| DualPll::new(f_nom, cfg.pll_lock_us))
                    .collect(),
            )
        } else {
            PllBank::Single(
                (0..cfg.n_fpgas)
                    .map(|_| SinglePll::new(f_nom, cfg.pll_lock_us))
                    .collect(),
            )
        };
        let active = cfg.n_fpgas;
        let batch = cfg.batch_nominal.max(1);
        Platform {
            cfg,
            design,
            optimizer,
            policy,
            controller,
            plls,
            backlog: 0.0,
            freq_ratio: 1.0,
            vcore,
            vbram,
            active,
            batch,
            step_idx: 0,
        }
    }

    /// The optimizer backing this platform's LUT.
    pub fn optimizer_ref(&self) -> &Optimizer {
        &self.optimizer
    }

    /// Nominal platform power (all boards, nominal V/f, PLLs on).
    pub fn nominal_power_w(&self) -> f64 {
        self.cfg.n_fpgas as f64
            * (self.design.nominal().total_w() + self.design.params.pll_w)
    }

    /// Advance one step. `load` is the platform-normalized incoming
    /// workload of this step; `next_load_oracle` feeds the oracle policy.
    pub fn step(&mut self, load: f64, next_load_oracle: Option<f64>) -> StepRecord {
        let cfg = &self.cfg;
        let n = cfg.n_fpgas as f64;
        let p_pll_each = self.design.params.pll_w;

        // ---- serve this step at the frequency chosen last step ----------
        let mut stalled_frac = 0.0;
        let locking: f64 = match &mut self.plls {
            PllBank::Dual(b) => b.iter_mut().map(|p| p.tick_us(cfg.tau_s * 1e6)).sum(),
            PllBank::Single(b) => {
                let stall: f64 = b.iter_mut().map(|p| p.tick_us(cfg.tau_s * 1e6)).sum();
                stalled_frac = stall / (n * cfg.tau_s * 1e6);
                stall
            }
        };
        // Hybrid serves with only its active boards; everyone else's
        // capacity is the whole platform at the current frequency.
        let active_frac = match self.policy {
            Policy::Hybrid(_) => self.active as f64 / n,
            _ => 1.0,
        };
        // Batch amortization (DESIGN.md S22): serving batches above the
        // nominal geometry spreads the per-dispatch overhead over more
        // requests. Exactly 1.0 at the nominal batch, so fixed-batch runs
        // stay bit-identical.
        let amort =
            batch_amortization(self.batch, cfg.batch_nominal, cfg.batch_overhead);
        let capacity = self.freq_ratio * active_frac * (1.0 - stalled_frac) * amort;
        let demand = load + self.backlog;
        let delivered = demand.min(capacity);
        self.backlog = (demand - delivered).min(cfg.max_backlog_steps);
        let qos_violation = demand - delivered > 1e-9;

        // ---- power accounting -------------------------------------------
        let f_mhz = self.design.spec.freq_mhz * self.freq_ratio;
        let (board_w, active_boards) = match self.policy {
            Policy::PowerGating => {
                let active = (load.clamp(0.0, 1.0) * n).ceil().min(n).max(1.0);
                (self.design.nominal().total_w(), active)
            }
            Policy::NominalStatic => (self.design.nominal().total_w(), n),
            Policy::Hybrid(_) => (
                self.design.breakdown(self.vcore, self.vbram, f_mhz).total_w(),
                self.active as f64,
            ),
            _ => (
                self.design.breakdown(self.vcore, self.vbram, f_mhz).total_w(),
                n,
            ),
        };
        let gated = n - active_boards;
        // Static policies never retune: one PLL suffices. DVFS policies pay
        // for the shadow PLL when configured (Eq. 4/5 trade-off).
        let pll_count = match self.policy {
            Policy::NominalStatic | Policy::PowerGating => 1.0,
            _ if cfg.dual_pll => 2.0,
            _ => 1.0,
        };
        let pll_w = pll_count * p_pll_each * n;
        let power_w = board_w * active_boards
            + self.design.nominal().total_w() * cfg.pg_residual * gated
            + pll_w;

        // ---- CC: one decision through the shared control plane -----------
        // Misprediction judgement, predictor training, guardband feedback,
        // margin-ladder quantization, backlog backpressure and the LUT
        // lookup all live in `control::GroupController` (DESIGN.md S19) —
        // the exact engine the live coordinator's CC runs. The oracle
        // policy overrides the forecast with the true next-step load.
        let oracle = match self.policy {
            Policy::DvfsOracle(_) => Some(next_load_oracle.unwrap_or(load)),
            _ => None,
        };
        let d = self.controller.decide_with_oracle(
            &Observation { load, qos_violation, backlog: self.backlog },
            oracle,
        );

        let f_next = self.design.spec.freq_mhz * d.freq_ratio;
        match &mut self.plls {
            PllBank::Dual(b) => b.iter_mut().for_each(|p| p.program(f_next)),
            PllBank::Single(b) => b.iter_mut().for_each(|p| p.program(f_next)),
        }

        let rec = StepRecord {
            step: self.step_idx,
            load,
            decision: DecisionRecord {
                predicted: d.predicted,
                freq_ratio: self.freq_ratio,
                vcore: self.vcore,
                vbram: self.vbram,
                n_active: self.active,
                batch: self.batch,
                predictor: d.predictor,
                margin: d.margin,
            },
            power_w,
            delivered,
            backlog: self.backlog,
            qos_violation,
            mispredicted: d.mispredicted,
            active_boards,
        };
        self.freq_ratio = d.freq_ratio;
        self.vcore = d.vcore;
        self.vbram = d.vbram;
        self.active = d.n_active;
        self.batch = d.batch;
        self.step_idx += 1;
        let _ = locking;
        rec
    }

    /// The margin the guardband currently requests (`margin_t` under the
    /// static policy).
    pub fn margin_now(&self) -> f64 {
        self.controller.margin_now()
    }

    /// Name of the prediction source currently active (the ensemble
    /// reports its member).
    pub fn predictor_now(&self) -> &'static str {
        self.controller.predictor_now()
    }

    /// The control plane's full decision log, in step order — what
    /// `tests/control_equivalence.rs` compares against the live
    /// coordinator's log for the same observed loads.
    pub fn decisions(&self) -> &[DecisionRecord] {
        self.controller.decisions()
    }

    /// Run a whole trace and aggregate.
    pub fn run(&mut self, loads: &[f64]) -> SimReport {
        let mut records = Vec::with_capacity(loads.len());
        let mut stalled_us = 0.0;
        for (i, &load) in loads.iter().enumerate() {
            let oracle = loads.get(i + 1).copied();
            let before = match &self.plls {
                PllBank::Single(b) => b.iter().map(|p| p.total_stall_us()).sum::<f64>(),
                _ => 0.0,
            };
            records.push(self.step(load, oracle));
            let after = match &self.plls {
                PllBank::Single(b) => b.iter().map(|p| p.total_stall_us()).sum::<f64>(),
                _ => 0.0,
            };
            stalled_us += after - before;
        }
        let nominal = self.nominal_power_w();
        let avg_power_w = if records.is_empty() {
            0.0
        } else {
            records.iter().map(|r| r.power_w).sum::<f64>() / records.len() as f64
        };
        // Skip the warmup steps (training at max frequency) for the gain,
        // matching the paper's steady-state comparison.
        let steady: Vec<&StepRecord> =
            records.iter().skip(self.cfg.warmup_steps.min(records.len())).collect();
        let steady_avg = if steady.is_empty() {
            avg_power_w
        } else {
            steady.iter().map(|r| r.power_w).sum::<f64>() / steady.len() as f64
        };
        let qos_violations = records.iter().filter(|r| r.qos_violation).count();
        let pll_count = match self.policy {
            Policy::NominalStatic | Policy::PowerGating => 1.0,
            _ if self.cfg.dual_pll => 2.0,
            _ => 1.0,
        };
        SimReport {
            policy: self.policy.name(),
            avg_power_w,
            nominal_power_w: nominal,
            power_gain: nominal / steady_avg.max(1e-12),
            energy_j: avg_power_w * self.cfg.tau_s * records.len() as f64,
            pll_energy_j: pll_count
                * self.design.params.pll_w
                * self.cfg.n_fpgas as f64
                * self.cfg.tau_s
                * records.len() as f64,
            qos_violations,
            violation_rate: qos_violations as f64 / records.len().max(1) as f64,
            mispredictions: records.iter().filter(|r| r.mispredicted).count(),
            stalled_us,
            records,
        }
    }
}

/// Convenience: build design + optimizer + platform for a benchmark.
pub fn build_platform(
    benchmark: &str,
    cfg: PlatformConfig,
    policy: Policy,
) -> Result<Platform, String> {
    use crate::arch::{BenchmarkSpec, DeviceFamily};
    use crate::chars::CharLibrary;
    use crate::netlist::gen::{generate, GenConfig};
    use crate::power::PowerParams;
    use crate::sta::{analyze, DelayParams};

    // Synthetic scale-sweep tenants are named `{base}@{suffix}` (group
    // names must be unique; only the Table-1 designs physically exist) —
    // the platform is built for the base design.
    let benchmark = benchmark.split('@').next().unwrap_or(benchmark);
    let spec = BenchmarkSpec::by_name(benchmark)
        .ok_or_else(|| format!("unknown benchmark {benchmark}"))?;
    let chars = CharLibrary::stratix_iv_22nm();
    let design = DesignPower::from_spec(
        spec,
        &DeviceFamily::stratix_iv(),
        chars.clone(),
        PowerParams::default(),
    )?;
    let net = generate(spec, &GenConfig { scale: 0.05, seed: 2019, luts_per_lab: 10 });
    let rep = analyze(&net, &DelayParams::default(), 8)?;
    let optimizer = Optimizer::new(chars.grid(), design.rail_tables(&rep.cp))
        .with_paths(&chars, rep.top_paths);
    Ok(Platform::new(cfg, design, optimizer, policy))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{bursty, BurstyConfig};

    fn sim(policy: Policy, loads: &[f64]) -> SimReport {
        let mut p = build_platform("tabla", PlatformConfig::default(), policy).unwrap();
        p.run(loads)
    }

    fn test_trace() -> Vec<f64> {
        bursty(&BurstyConfig { steps: 400, ..Default::default() }).loads
    }

    #[test]
    fn nominal_policy_gain_is_one() {
        let r = sim(Policy::NominalStatic, &test_trace());
        assert!((r.power_gain - 1.0).abs() < 1e-6, "gain {}", r.power_gain);
        assert_eq!(r.qos_violations, 0);
    }

    #[test]
    fn proposed_beats_singles_beats_nominal() {
        let t = test_trace();
        let prop = sim(Policy::Dvfs(Mode::Proposed), &t);
        let core = sim(Policy::Dvfs(Mode::CoreOnly), &t);
        let bram = sim(Policy::Dvfs(Mode::BramOnly), &t);
        assert!(prop.power_gain > core.power_gain, "{} vs {}", prop.power_gain, core.power_gain);
        assert!(prop.power_gain > bram.power_gain);
        assert!(core.power_gain > 1.2 && bram.power_gain > 1.2);
    }

    #[test]
    fn qos_holds_under_margin() {
        // With the 5% margin and 10 bins, violations should be rare.
        let t = test_trace();
        let r = sim(Policy::Dvfs(Mode::Proposed), &t);
        assert!(
            r.violation_rate < 0.10,
            "violation rate {:.3} too high",
            r.violation_rate
        );
        // And the backlog never exceeds the bound.
        assert!(r.records.iter().all(|x| x.backlog <= 1.0 + 1e-9));
    }

    #[test]
    fn oracle_is_at_least_as_good_as_predicted() {
        let t = test_trace();
        let o = sim(Policy::DvfsOracle(Mode::Proposed), &t);
        let p = sim(Policy::Dvfs(Mode::Proposed), &t);
        // Oracle avoids margin + misprediction overhead.
        assert!(o.power_gain > 0.95 * p.power_gain);
        assert!(o.violation_rate <= p.violation_rate + 0.05);
    }

    #[test]
    fn power_gating_tracks_load_linearly() {
        let loads = vec![0.5; 200];
        let r = sim(Policy::PowerGating, &loads);
        // 2 of 4 boards active (+ residual + PLLs): gain just under 2x.
        assert!((1.5..2.1).contains(&r.power_gain), "gain {}", r.power_gain);
    }

    #[test]
    fn single_pll_stalls_dual_does_not() {
        let t = bursty(&BurstyConfig { steps: 300, ..Default::default() }).loads;
        let mk = |dual| {
            let cfg = PlatformConfig { dual_pll: dual, ..Default::default() };
            let mut p = build_platform("tabla", cfg, Policy::Dvfs(Mode::Proposed)).unwrap();
            p.run(&t)
        };
        let dual = mk(true);
        let single = mk(false);
        assert_eq!(dual.stalled_us, 0.0);
        assert!(single.stalled_us > 0.0, "single PLL must stall on retune");
        // The shadow PLL buys zero stall at a small continuous power cost
        // (Eq. 4/5); it must not cost more than ~10% of the gain here.
        assert!(dual.power_gain > 0.90 * single.power_gain);
    }

    #[test]
    fn frequency_follows_workload() {
        let loads: Vec<f64> = (0..100).map(|i| if i < 50 { 0.2 } else { 0.9 }).collect();
        let mut p = build_platform(
            "tabla",
            PlatformConfig { warmup_steps: 5, ..Default::default() },
            Policy::Dvfs(Mode::Proposed),
        )
        .unwrap();
        let r = p.run(&loads);
        let early: f64 = r.records[20..45].iter().map(|x| x.freq_ratio).sum::<f64>() / 25.0;
        let late: f64 = r.records[70..95].iter().map(|x| x.freq_ratio).sum::<f64>() / 25.0;
        assert!(early < 0.5, "low-load frequency ratio {early}");
        assert!(late > 0.8, "high-load frequency ratio {late}");
    }

    #[test]
    fn voltages_follow_frequency() {
        let t = test_trace();
        let r = sim(Policy::Dvfs(Mode::Proposed), &t);
        // Steps at low frequency must not use higher voltage than steps at
        // high frequency (spot-check the extremes).
        let lo = r
            .records
            .iter()
            .filter(|x| x.freq_ratio < 0.3)
            .map(|x| x.vcore)
            .fold(0.0, f64::max);
        let hi = r
            .records
            .iter()
            .filter(|x| x.freq_ratio > 0.9)
            .map(|x| x.vcore)
            .fold(0.0, f64::max);
        if lo > 0.0 && hi > 0.0 {
            assert!(lo <= hi + 1e-9, "vcore lo {lo} vs hi {hi}");
        }
    }

    #[test]
    fn build_platform_rejects_unknown() {
        assert!(build_platform("nope", PlatformConfig::default(), Policy::NominalStatic).is_err());
    }

    #[test]
    fn hybrid_beats_both_baselines_in_a_deep_trough() {
        // Constant 8% load: below the crash-voltage floor's reach, where
        // the paper's §III says gating must take over.
        let loads = vec![0.08; 260];
        let h = sim(Policy::Hybrid(Mode::Proposed), &loads);
        let d = sim(Policy::Dvfs(Mode::Proposed), &loads);
        let p = sim(Policy::PowerGating, &loads);
        assert!(
            h.energy_j <= d.energy_j * 1.01,
            "hybrid {} vs dvfs {}",
            h.energy_j,
            d.energy_j
        );
        assert!(
            h.energy_j <= p.energy_j * 1.01,
            "hybrid {} vs pg {}",
            h.energy_j,
            p.energy_j
        );
        assert!(
            h.energy_j < d.energy_j * 0.995,
            "hybrid must strictly beat DVFS-only in the trough: {} vs {}",
            h.energy_j,
            d.energy_j
        );
        // Gating is actually happening once warmup training ends.
        assert!(h.records.iter().skip(25).any(|r| r.active_boards < 4.0));
        // Elastic capacity still meets QoS (margin absorbs the bin edge).
        assert!(h.violation_rate < 0.10, "violation rate {}", h.violation_rate);
    }

    #[test]
    fn forced_under_prediction_boosts_next_epoch_frequency_within_lut_slack() {
        // Mispredict-recovery (paper §IV.A "adjustment to the workload"):
        // a workload the chain has locked onto jumps three bins; the step
        // after the under-prediction must publish a higher frequency —
        // both from the Markov snap *and* the guardband boost — bounded
        // by the LUT's own slack (freq_ratio <= 1).
        let mut loads = vec![0.15; 80];
        loads.extend(vec![0.55; 40]);
        let cfg = PlatformConfig {
            warmup_steps: 5,
            qos_target: Some(0.01),
            ..Default::default()
        };
        // DVFS policy: freq_ratio alone is the capacity, so the boost is
        // directly observable (under Hybrid the same capacity boost can
        // appear as an active-count change instead).
        let mut p = build_platform("tabla", cfg, Policy::Dvfs(Mode::Proposed)).unwrap();
        let r = p.run(&loads);
        let jump = 80; // first 0.55 step
        let rec = &r.records[jump];
        assert!(rec.mispredicted, "the jump must register as a misprediction");
        // Before the jump the guardband had decayed below the static 5%.
        assert!(
            r.records[jump - 1].margin < 0.05,
            "clean steps must shrink the margin: {}",
            r.records[jump - 1].margin
        );
        // The under-prediction boosts the margin used for the next
        // decision and the published frequency recovers immediately.
        assert!(
            rec.margin > r.records[jump - 1].margin,
            "margin must boost on the under-prediction: {} -> {}",
            r.records[jump - 1].margin,
            rec.margin
        );
        let next = &r.records[jump + 1];
        assert!(
            next.freq_ratio > rec.freq_ratio,
            "next epoch must run faster: {} -> {}",
            rec.freq_ratio,
            next.freq_ratio
        );
        assert!(
            next.freq_ratio >= 0.55 && next.freq_ratio <= 1.0 + 1e-12,
            "boost covers the observed bin within LUT slack: {}",
            next.freq_ratio
        );
        // Every step's record carries its prediction source and margin.
        assert!(r.records.iter().all(|x| !x.predictor.is_empty()));
        assert!(r.records.iter().all(|x| (0.0..=0.40 + 1e-12).contains(&x.margin)));
    }

    #[test]
    fn adaptive_guardband_saves_energy_on_a_quiet_trace_without_qos_loss() {
        // On a steady low trace the guardband decays to ~0 margin, so the
        // adaptive platform must spend no more energy than the static 5%
        // margin while violating no more often.
        let loads = vec![0.25; 300];
        let run = |qos: Option<f64>| {
            let cfg = PlatformConfig {
                warmup_steps: 10,
                qos_target: qos,
                ..Default::default()
            };
            let mut p = build_platform("tabla", cfg, Policy::Hybrid(Mode::Proposed)).unwrap();
            p.run(&loads)
        };
        let adaptive = run(Some(0.01));
        let fixed = run(None);
        assert!(
            adaptive.energy_j <= fixed.energy_j * 1.001,
            "adaptive {} J vs static {} J",
            adaptive.energy_j,
            fixed.energy_j
        );
        assert!(
            adaptive.violation_rate <= fixed.violation_rate + 0.005,
            "adaptive {} vs static {}",
            adaptive.violation_rate,
            fixed.violation_rate
        );
        // The static path reports its fixed margin on every record.
        assert!(fixed.records.iter().all(|r| (r.margin - 0.05).abs() < 1e-12));
    }

    #[test]
    fn ensemble_predictor_runs_the_platform_end_to_end() {
        let loads = crate::workload::periodic(400, 96, 0.15, 0.85, 0.0, 3).loads;
        let cfg = PlatformConfig {
            warmup_steps: 10,
            predictor: PredictorKind::Ensemble,
            qos_target: Some(0.01),
            ..Default::default()
        };
        let mut p = build_platform("tabla", cfg, Policy::Hybrid(Mode::Proposed)).unwrap();
        let r = p.run(&loads);
        assert!(r.power_gain > 1.0, "gain {}", r.power_gain);
        assert!(r.violation_rate < 0.15, "violations {}", r.violation_rate);
        // The records name whichever member is active; on a clean
        // sinusoid the ensemble should eventually hand over to periodic.
        let tail_names: Vec<&str> =
            r.records.iter().rev().take(50).map(|x| x.predictor).collect();
        assert!(
            tail_names.iter().any(|n| *n == "periodic"),
            "late steps should be served by the periodic member: {tail_names:?}"
        );
    }

    #[test]
    fn fixed_batch_is_the_exact_identity_and_adaptive_batches_bigger_when_slow() {
        // Fixed policy: every record carries the nominal batch and the
        // capacity multiplier is the exact 1.0 identity — the trace is
        // bit-identical to the pre-knob platform by construction.
        let t = test_trace();
        let fixed = sim(Policy::Dvfs(Mode::Proposed), &t);
        assert!(fixed.records.iter().all(|r| r.batch == 16));
        // Adaptive: downclocked steps publish bigger batches following
        // the inverse-frequency law, clamped to [b0, 4*b0]; QoS must not
        // degrade (amortization only adds capacity at batch > nominal).
        let cfg = PlatformConfig {
            warmup_steps: 10,
            adaptive_batch: true,
            ..Default::default()
        };
        let mut p = build_platform("tabla", cfg, Policy::Dvfs(Mode::Proposed)).unwrap();
        let r = p.run(&t);
        assert!(
            r.records.iter().skip(11).any(|x| x.batch > 16),
            "a bursty trace has slow steps that must batch bigger"
        );
        for x in r.records.iter() {
            assert!((16..=64).contains(&x.batch), "clamp violated: {}", x.batch);
        }
        assert!(
            r.violation_rate <= fixed.violation_rate + 0.02,
            "adaptive {} vs fixed {}",
            r.violation_rate,
            fixed.violation_rate
        );
    }

    #[test]
    fn hybrid_keeps_every_board_active_at_high_load() {
        let loads = vec![0.9; 120];
        let mut pl = build_platform(
            "tabla",
            PlatformConfig { warmup_steps: 5, ..Default::default() },
            Policy::Hybrid(Mode::Proposed),
        )
        .unwrap();
        let r = pl.run(&loads);
        for rec in r.records.iter().skip(10) {
            assert!(rec.active_boards >= 4.0 - 1e-9, "{rec:?}");
        }
        assert_eq!(r.policy, "hybrid-prop");
    }
}
