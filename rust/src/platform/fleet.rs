//! Heterogeneous fleet: several benchmark groups sharing one datacenter
//! workload (paper Fig. 7: "all of them are processing the input data
//! gathered from one or different users").
//!
//! Each group is an independent [`Platform`] (own design, own CC, own
//! voltage LUT) fed a share of the common trace — or its own per-tenant
//! trace via [`Fleet::run_scenario`] / [`Fleet::run_per_group`]; the fleet
//! report aggregates power and QoS across groups. This models the
//! realistic deployment where Tabla and DianNao instances coexist under
//! one operator and one DVFS policy choice. The *live* counterpart of
//! this offline model is `coordinator::FleetServing`.

use super::{build_platform, Platform, PlatformConfig, Policy, SimReport};
use crate::control::QosTier;
use crate::markov::PredictorKind;
use crate::vscale::Mode;
use crate::workload::Scenario;

/// One group of identical FPGA instances serving one benchmark.
pub struct FleetGroup {
    /// Benchmark (Table I name) the group serves.
    pub benchmark: String,
    /// Fraction of the fleet-level workload routed to this group.
    pub share: f64,
    /// The group's independent platform (design, CC, LUT).
    pub platform: Platform,
}

/// Aggregate outcome across groups.
#[derive(Clone, Debug)]
pub struct FleetReport {
    /// Per-group `(benchmark, report)` rows, in group order.
    pub per_group: Vec<(String, SimReport)>,
    /// Sum of per-group average powers (W).
    pub avg_power_w: f64,
    /// Sum of per-group nominal powers (W).
    pub nominal_power_w: f64,
    /// Fleet-level steady-state power gain (nominal / steady power).
    pub power_gain: f64,
    /// Worst per-group QoS violation rate (QoS is per-tenant).
    pub violation_rate: f64,
}

impl FleetReport {
    /// Total fleet energy over the run (J): sum of per-group energies.
    pub fn energy_j(&self) -> f64 {
        self.per_group.iter().map(|(_, r)| r.energy_j).sum()
    }
}

/// A multi-tenant fleet under a single policy.
pub struct Fleet {
    /// The fleet's groups, in construction order.
    pub groups: Vec<FleetGroup>,
}

impl Fleet {
    /// Build one group per (benchmark, workload share). Shares must sum
    /// to ~1; each group gets the same platform config and policy.
    pub fn new(
        groups: &[(&str, f64)],
        cfg: PlatformConfig,
        policy: Policy,
    ) -> Result<Self, String> {
        if groups.is_empty() {
            return Err("fleet needs at least one group".into());
        }
        let total: f64 = groups.iter().map(|(_, s)| s).sum();
        if (total - 1.0).abs() > 1e-6 {
            return Err(format!("group shares sum to {total}, expected 1"));
        }
        let mut out = Vec::with_capacity(groups.len());
        for (name, share) in groups {
            if *share <= 0.0 {
                return Err(format!("{name}: share must be positive"));
            }
            out.push(FleetGroup {
                benchmark: name.to_string(),
                share: *share,
                platform: build_platform(name, cfg.clone(), policy)?,
            });
        }
        Ok(Fleet { groups: out })
    }

    /// Build a fleet matching a scenario's group layout. Tenant QoS tiers
    /// ([`crate::workload::TenantTrace::qos_target`]) refine the
    /// run-level guardband target per group via [`QosTier::effective`]:
    /// they apply only when `cfg.qos_target` is `Some`, so static-margin
    /// baselines stay bit-identical whatever tiers the scenario declares.
    pub fn from_scenario(
        scenario: &Scenario,
        cfg: PlatformConfig,
        policy: Policy,
    ) -> Result<Self, String> {
        scenario.validate()?;
        let mut out = Vec::with_capacity(scenario.tenants.len());
        for t in &scenario.tenants {
            let group_cfg = PlatformConfig {
                qos_target: QosTier::effective(cfg.qos_target, t.qos_target),
                ..cfg.clone()
            };
            out.push(FleetGroup {
                benchmark: t.benchmark.clone(),
                share: t.share,
                platform: build_platform(&t.benchmark, group_cfg, policy)?,
            });
        }
        if out.is_empty() {
            return Err("fleet needs at least one group".into());
        }
        Ok(Fleet { groups: out })
    }

    /// Run the common trace. Each group sees the *same normalized load*
    /// (its capacity is provisioned for its share), so DVFS decisions are
    /// per-group while the workload pattern is shared.
    pub fn run(&mut self, loads: &[f64]) -> FleetReport {
        let mut per_group = Vec::with_capacity(self.groups.len());
        for g in &mut self.groups {
            per_group.push((g.benchmark.clone(), g.platform.run(loads)));
        }
        Self::aggregate(per_group)
    }

    /// Run one trace per group (index-aligned) — heterogeneous tenant
    /// loads, the general case behind [`Fleet::run_scenario`].
    pub fn run_per_group(&mut self, traces: &[&[f64]]) -> Result<FleetReport, String> {
        if traces.len() != self.groups.len() {
            return Err(format!(
                "{} traces for {} groups",
                traces.len(),
                self.groups.len()
            ));
        }
        let mut per_group = Vec::with_capacity(self.groups.len());
        for (g, t) in self.groups.iter_mut().zip(traces) {
            if t.is_empty() {
                return Err(format!("{}: empty trace", g.benchmark));
            }
            per_group.push((g.benchmark.clone(), g.platform.run(t)));
        }
        Ok(Self::aggregate(per_group))
    }

    /// Run a scenario's per-tenant traces through the matching groups.
    /// The fleet must have been built with the scenario's group layout
    /// (see [`Fleet::from_scenario`]).
    pub fn run_scenario(&mut self, scenario: &Scenario) -> Result<FleetReport, String> {
        if scenario.tenants.len() != self.groups.len()
            || scenario
                .tenants
                .iter()
                .zip(&self.groups)
                .any(|(t, g)| t.benchmark != g.benchmark)
        {
            return Err(format!(
                "scenario {} groups do not match this fleet",
                scenario.name
            ));
        }
        let traces: Vec<&[f64]> = scenario
            .tenants
            .iter()
            .map(|t| t.trace.loads.as_slice())
            .collect();
        self.run_per_group(&traces)
    }

    /// Run `scenario` under the three capacity policies — DVFS-only
    /// (`Policy::Dvfs(mode)`), PG-only (`Policy::PowerGating`) and the
    /// elastic hybrid (`Policy::Hybrid(mode)`) — on identical fleets and
    /// return `(policy name, report)` rows in that order. This is the
    /// offline side-by-side the `scenario` / `serve-fleet` CLI
    /// subcommands and the `hybrid_capacity` bench report.
    pub fn compare_capacity_policies(
        scenario: &Scenario,
        cfg: PlatformConfig,
        mode: Mode,
    ) -> Result<Vec<(String, FleetReport)>, String> {
        let mut out = Vec::with_capacity(3);
        for policy in [Policy::Dvfs(mode), Policy::PowerGating, Policy::Hybrid(mode)] {
            let mut fleet = Fleet::from_scenario(scenario, cfg.clone(), policy)?;
            out.push((policy.name(), fleet.run_scenario(scenario)?));
        }
        Ok(out)
    }

    /// Run `scenario` under hybrid capacity with the batch knob off and
    /// on — otherwise identical fleets — returning `(label, report)` rows
    /// `["fixed-batch", "adaptive-batch"]` (DESIGN.md S22). This is the
    /// offline side-by-side behind the ISSUE-8 acceptance gate and the
    /// `perf_fleet_serving` batch comparison: the adaptive controller
    /// grows dispatch batches while downclocked, amortizing the
    /// per-dispatch overhead exactly when cycles are scarce.
    pub fn compare_batch_policies(
        scenario: &Scenario,
        cfg: PlatformConfig,
        mode: Mode,
    ) -> Result<Vec<(String, FleetReport)>, String> {
        let mut out = Vec::with_capacity(2);
        for adaptive in [false, true] {
            let knob = PlatformConfig { adaptive_batch: adaptive, ..cfg.clone() };
            let mut fleet = Fleet::from_scenario(scenario, knob, Policy::Hybrid(mode))?;
            let label = if adaptive { "adaptive-batch" } else { "fixed-batch" };
            out.push((label.to_string(), fleet.run_scenario(scenario)?));
        }
        Ok(out)
    }

    /// Run `scenario` under hybrid capacity once per predictor
    /// configuration — the static-margin Markov baseline first, then
    /// every [`PredictorKind`] with the adaptive guardband at
    /// `qos_target` — on identical fleets, returning `(label, report)`
    /// rows. This is the offline side of the Fig. 8 predictor comparison
    /// (`perf_predictor` bench, `predict` CLI) and the acceptance gate
    /// for the adaptive ensemble.
    pub fn compare_predictors(
        scenario: &Scenario,
        cfg: PlatformConfig,
        mode: Mode,
        qos_target: f64,
    ) -> Result<Vec<(String, FleetReport)>, String> {
        let mut out = Vec::with_capacity(1 + PredictorKind::ALL.len());
        let baseline = PlatformConfig {
            predictor: PredictorKind::Markov,
            qos_target: None,
            ..cfg.clone()
        };
        let mut fleet =
            Fleet::from_scenario(scenario, baseline, Policy::Hybrid(mode))?;
        out.push(("markov-static".to_string(), fleet.run_scenario(scenario)?));
        for kind in PredictorKind::ALL {
            let adaptive = PlatformConfig {
                predictor: kind,
                qos_target: Some(qos_target),
                ..cfg.clone()
            };
            let mut fleet =
                Fleet::from_scenario(scenario, adaptive, Policy::Hybrid(mode))?;
            out.push((
                format!("{}+guardband", kind.name()),
                fleet.run_scenario(scenario)?,
            ));
        }
        Ok(out)
    }

    fn aggregate(per_group: Vec<(String, SimReport)>) -> FleetReport {
        let avg_power_w: f64 = per_group.iter().map(|(_, r)| r.avg_power_w).sum();
        let nominal_power_w: f64 = per_group.iter().map(|(_, r)| r.nominal_power_w).sum();
        // Steady-state gain: nominal over steady power, aggregated.
        let steady: f64 = per_group
            .iter()
            .map(|(_, r)| r.nominal_power_w / r.power_gain.max(1e-12))
            .sum();
        let violation_rate = per_group
            .iter()
            .map(|(_, r)| r.violation_rate)
            .fold(0.0, f64::max);
        FleetReport {
            avg_power_w,
            nominal_power_w,
            power_gain: nominal_power_w / steady.max(1e-12),
            violation_rate,
            per_group,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vscale::Mode;
    use crate::workload::{bursty, BurstyConfig};

    fn trace() -> Vec<f64> {
        bursty(&BurstyConfig { steps: 300, ..Default::default() }).loads
    }

    #[test]
    fn heterogeneous_fleet_aggregates_gains() {
        let mut fleet = Fleet::new(
            &[("tabla", 0.4), ("diannao", 0.35), ("stripes", 0.25)],
            PlatformConfig::default(),
            Policy::Dvfs(Mode::Proposed),
        )
        .unwrap();
        let r = fleet.run(&trace());
        assert_eq!(r.per_group.len(), 3);
        assert!(r.power_gain > 2.5, "fleet gain {}", r.power_gain);
        // Aggregate gain sits between the best and worst group gains.
        let gains: Vec<f64> = r.per_group.iter().map(|(_, x)| x.power_gain).collect();
        let lo = gains.iter().copied().fold(f64::INFINITY, f64::min);
        let hi = gains.iter().copied().fold(0.0, f64::max);
        assert!(r.power_gain >= lo - 1e-9 && r.power_gain <= hi + 1e-9);
        // The fleet is dominated by its largest board (stripes).
        assert!(r.nominal_power_w > 50.0, "{}", r.nominal_power_w);
    }

    #[test]
    fn fleet_validates_shares() {
        let cfg = PlatformConfig::default();
        assert!(Fleet::new(&[], cfg.clone(), Policy::NominalStatic).is_err());
        assert!(Fleet::new(&[("tabla", 0.5)], cfg.clone(), Policy::NominalStatic).is_err());
        assert!(
            Fleet::new(&[("tabla", 1.5), ("diannao", -0.5)], cfg.clone(), Policy::NominalStatic)
                .is_err()
        );
        assert!(Fleet::new(&[("nope", 1.0)], cfg, Policy::NominalStatic).is_err());
    }

    #[test]
    fn scenario_runs_per_group_traces_and_aggregates_qos() {
        let s = Scenario::mixed_tenant(300, 2019);
        let mut fleet =
            Fleet::from_scenario(&s, PlatformConfig::default(), Policy::Dvfs(Mode::Proposed))
                .unwrap();
        let r = fleet.run_scenario(&s).unwrap();
        assert_eq!(r.per_group.len(), s.tenants.len());
        for ((name, rep), t) in r.per_group.iter().zip(&s.tenants) {
            assert_eq!(name, &t.benchmark);
            assert_eq!(rep.records.len(), t.trace.len());
            assert!(rep.power_gain > 1.0, "{name}: gain {}", rep.power_gain);
        }
        // Fleet violation rate is the worst per-group rate.
        let worst = r
            .per_group
            .iter()
            .map(|(_, rep)| rep.violation_rate)
            .fold(0.0, f64::max);
        assert!((r.violation_rate - worst).abs() < 1e-12);

        // Mismatched layouts are rejected.
        let other = Scenario::diurnal(300, 1);
        assert!(fleet.run_scenario(&other).is_err());
        assert!(fleet.run_per_group(&[&[0.5][..]]).is_err());
    }

    #[test]
    fn scenario_qos_tiers_refine_only_an_enabled_guardband() {
        let s = Scenario::by_name("tiered-tenants", 120, 2019).unwrap();
        // Static baseline (guardband off): tiers are inert, every group
        // keeps qos_target None and the run is bit-identical to a
        // tierless scenario of the same traces.
        let fleet = Fleet::from_scenario(
            &s,
            PlatformConfig::default(),
            Policy::Hybrid(Mode::Proposed),
        )
        .unwrap();
        assert!(fleet.groups.iter().all(|g| g.platform.cfg.qos_target.is_none()));
        // Guardband on: each group resolves to its tenant's tier; the
        // run-level target is the default for untiered tenants.
        let cfg = PlatformConfig { qos_target: Some(0.01), ..PlatformConfig::default() };
        let fleet =
            Fleet::from_scenario(&s, cfg.clone(), Policy::Hybrid(Mode::Proposed)).unwrap();
        let targets: Vec<Option<f64>> =
            fleet.groups.iter().map(|g| g.platform.cfg.qos_target).collect();
        assert_eq!(targets, vec![Some(0.005), Some(0.01), Some(0.05)]);
        let legacy = Scenario::by_name("diurnal", 120, 2019).unwrap();
        let fleet =
            Fleet::from_scenario(&legacy, cfg, Policy::Hybrid(Mode::Proposed)).unwrap();
        assert!(fleet.groups.iter().all(|g| g.platform.cfg.qos_target == Some(0.01)));
    }

    #[test]
    fn hybrid_energy_never_worse_on_any_named_scenario() {
        // Acceptance gate for the elastic capacity manager: on every
        // named scenario the hybrid's epoch energy is within 1% of the
        // better baseline, and in the overnight trough (crash-voltage
        // floor territory) it strictly beats DVFS-only.
        for s in Scenario::all(240, 2019) {
            let rows = Fleet::compare_capacity_policies(
                &s,
                PlatformConfig::default(),
                Mode::Proposed,
            )
            .unwrap();
            assert_eq!(rows.len(), 3);
            let (dvfs, pg, hybrid) =
                (rows[0].1.energy_j(), rows[1].1.energy_j(), rows[2].1.energy_j());
            assert!(
                hybrid <= dvfs * 1.01,
                "{}: hybrid {hybrid} J vs dvfs {dvfs} J",
                s.name
            );
            assert!(
                hybrid <= pg * 1.01,
                "{}: hybrid {hybrid} J vs pg {pg} J",
                s.name
            );
            if s.name == "overnight" {
                assert!(
                    hybrid < dvfs * 0.995,
                    "overnight: hybrid {hybrid} J must strictly beat dvfs {dvfs} J"
                );
            }
        }
    }

    #[test]
    fn adaptive_batch_never_worse_than_fixed_on_any_named_scenario() {
        // Acceptance gate for the batch knob (ISSUE 8): on every named
        // scenario the adaptive-batch hybrid's energy is within 1% of
        // the fixed-batch hybrid's, and it strictly wins somewhere — the
        // amortization factor only exceeds 1 while downclocked, so the
        // win comes from absorbing load that arrives against a
        // still-low served frequency (trough exits, surge onsets).
        let mut strictly_better = 0usize;
        for s in Scenario::all(240, 2019) {
            let rows = Fleet::compare_batch_policies(
                &s,
                PlatformConfig::default(),
                Mode::Proposed,
            )
            .unwrap();
            assert_eq!(rows.len(), 2);
            assert_eq!(rows[0].0, "fixed-batch");
            assert_eq!(rows[1].0, "adaptive-batch");
            let (fixed, adaptive) = (rows[0].1.energy_j(), rows[1].1.energy_j());
            assert!(
                adaptive <= fixed * 1.01,
                "{}: adaptive batch {adaptive} J vs fixed {fixed} J",
                s.name
            );
            // The knob must never buy energy with QoS: violations stay
            // within half a point of the fixed-batch baseline.
            assert!(
                rows[1].1.violation_rate <= rows[0].1.violation_rate + 0.005,
                "{}: adaptive violations {} vs fixed {}",
                s.name,
                rows[1].1.violation_rate,
                rows[0].1.violation_rate
            );
            if adaptive < fixed - 1e-9 {
                strictly_better += 1;
            }
        }
        assert!(
            strictly_better >= 1,
            "adaptive batch never strictly beat fixed on any named scenario"
        );
    }

    #[test]
    fn adaptive_ensemble_never_worse_than_static_markov_on_named_scenarios() {
        // Acceptance gate for the predictor ensemble + guardband
        // (ISSUE 4): on every named scenario under hybrid capacity the
        // adaptive ensemble's energy is within 1% of the static-margin
        // Markov baseline while its violation rate stays within 0.5pp.
        for s in Scenario::all(240, 2019) {
            let rows = Fleet::compare_predictors(
                &s,
                PlatformConfig::default(),
                Mode::Proposed,
                0.01,
            )
            .unwrap();
            assert_eq!(rows[0].0, "markov-static");
            let (base_e, base_v) = (rows[0].1.energy_j(), rows[0].1.violation_rate);
            let ens = rows
                .iter()
                .find(|(name, _)| name == "ensemble+guardband")
                .expect("ensemble row");
            assert!(
                ens.1.energy_j() <= base_e * 1.01,
                "{}: ensemble {} J vs static markov {} J",
                s.name,
                ens.1.energy_j(),
                base_e
            );
            assert!(
                ens.1.violation_rate <= base_v + 0.005,
                "{}: ensemble violations {} vs static markov {}",
                s.name,
                ens.1.violation_rate,
                base_v
            );
        }
    }

    #[test]
    fn single_group_fleet_matches_platform() {
        let t = trace();
        let mut fleet = Fleet::new(
            &[("tabla", 1.0)],
            PlatformConfig::default(),
            Policy::Dvfs(Mode::Proposed),
        )
        .unwrap();
        let fr = fleet.run(&t);
        let mut p = build_platform("tabla", PlatformConfig::default(), Policy::Dvfs(Mode::Proposed))
            .unwrap();
        let pr = p.run(&t);
        assert!((fr.power_gain - pr.power_gain).abs() < 1e-9);
    }
}
