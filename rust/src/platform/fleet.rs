//! Heterogeneous fleet: several benchmark groups sharing one datacenter
//! workload (paper Fig. 7: "all of them are processing the input data
//! gathered from one or different users").
//!
//! Each group is an independent [`Platform`] (own design, own CC, own
//! voltage LUT) fed a share of the common trace; the fleet report
//! aggregates power and QoS across groups. This models the realistic
//! deployment where Tabla and DianNao instances coexist under one
//! operator and one DVFS policy choice.

use super::{build_platform, Platform, PlatformConfig, Policy, SimReport};

/// One group of identical FPGA instances serving one benchmark.
pub struct FleetGroup {
    pub benchmark: String,
    /// Fraction of the fleet-level workload routed to this group.
    pub share: f64,
    pub platform: Platform,
}

/// Aggregate outcome across groups.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub per_group: Vec<(String, SimReport)>,
    pub avg_power_w: f64,
    pub nominal_power_w: f64,
    pub power_gain: f64,
    pub violation_rate: f64,
}

/// A multi-tenant fleet under a single policy.
pub struct Fleet {
    pub groups: Vec<FleetGroup>,
}

impl Fleet {
    /// Build one group per (benchmark, workload share). Shares must sum
    /// to ~1; each group gets the same platform config and policy.
    pub fn new(
        groups: &[(&str, f64)],
        cfg: PlatformConfig,
        policy: Policy,
    ) -> Result<Self, String> {
        if groups.is_empty() {
            return Err("fleet needs at least one group".into());
        }
        let total: f64 = groups.iter().map(|(_, s)| s).sum();
        if (total - 1.0).abs() > 1e-6 {
            return Err(format!("group shares sum to {total}, expected 1"));
        }
        let mut out = Vec::with_capacity(groups.len());
        for (name, share) in groups {
            if *share <= 0.0 {
                return Err(format!("{name}: share must be positive"));
            }
            out.push(FleetGroup {
                benchmark: name.to_string(),
                share: *share,
                platform: build_platform(name, cfg.clone(), policy)?,
            });
        }
        Ok(Fleet { groups: out })
    }

    /// Run the common trace. Each group sees the *same normalized load*
    /// (its capacity is provisioned for its share), so DVFS decisions are
    /// per-group while the workload pattern is shared.
    pub fn run(&mut self, loads: &[f64]) -> FleetReport {
        let mut per_group = Vec::with_capacity(self.groups.len());
        for g in &mut self.groups {
            per_group.push((g.benchmark.clone(), g.platform.run(loads)));
        }
        let avg_power_w: f64 = per_group.iter().map(|(_, r)| r.avg_power_w).sum();
        let nominal_power_w: f64 = per_group.iter().map(|(_, r)| r.nominal_power_w).sum();
        // Steady-state gain: nominal over steady power, aggregated.
        let steady: f64 = per_group
            .iter()
            .map(|(_, r)| r.nominal_power_w / r.power_gain.max(1e-12))
            .sum();
        let violation_rate = per_group
            .iter()
            .map(|(_, r)| r.violation_rate)
            .fold(0.0, f64::max);
        FleetReport {
            avg_power_w,
            nominal_power_w,
            power_gain: nominal_power_w / steady.max(1e-12),
            violation_rate,
            per_group,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vscale::Mode;
    use crate::workload::{bursty, BurstyConfig};

    fn trace() -> Vec<f64> {
        bursty(&BurstyConfig { steps: 300, ..Default::default() }).loads
    }

    #[test]
    fn heterogeneous_fleet_aggregates_gains() {
        let mut fleet = Fleet::new(
            &[("tabla", 0.4), ("diannao", 0.35), ("stripes", 0.25)],
            PlatformConfig::default(),
            Policy::Dvfs(Mode::Proposed),
        )
        .unwrap();
        let r = fleet.run(&trace());
        assert_eq!(r.per_group.len(), 3);
        assert!(r.power_gain > 2.5, "fleet gain {}", r.power_gain);
        // Aggregate gain sits between the best and worst group gains.
        let gains: Vec<f64> = r.per_group.iter().map(|(_, x)| x.power_gain).collect();
        let lo = gains.iter().copied().fold(f64::INFINITY, f64::min);
        let hi = gains.iter().copied().fold(0.0, f64::max);
        assert!(r.power_gain >= lo - 1e-9 && r.power_gain <= hi + 1e-9);
        // The fleet is dominated by its largest board (stripes).
        assert!(r.nominal_power_w > 50.0, "{}", r.nominal_power_w);
    }

    #[test]
    fn fleet_validates_shares() {
        let cfg = PlatformConfig::default();
        assert!(Fleet::new(&[], cfg.clone(), Policy::NominalStatic).is_err());
        assert!(Fleet::new(&[("tabla", 0.5)], cfg.clone(), Policy::NominalStatic).is_err());
        assert!(
            Fleet::new(&[("tabla", 1.5), ("diannao", -0.5)], cfg.clone(), Policy::NominalStatic)
                .is_err()
        );
        assert!(Fleet::new(&[("nope", 1.0)], cfg, Policy::NominalStatic).is_err());
    }

    #[test]
    fn single_group_fleet_matches_platform() {
        let t = trace();
        let mut fleet = Fleet::new(
            &[("tabla", 1.0)],
            PlatformConfig::default(),
            Policy::Dvfs(Mode::Proposed),
        )
        .unwrap();
        let fr = fleet.run(&t);
        let mut p = build_platform("tabla", PlatformConfig::default(), Policy::Dvfs(Mode::Proposed))
            .unwrap();
        let pr = p.run(&t);
        assert!((fr.power_gain - pr.power_gain).abs() < 1e-9);
    }
}
