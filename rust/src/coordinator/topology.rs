//! Fleet topology — the single source of truth for group placement
//! (DESIGN.md S21).
//!
//! A [`FleetTopology`] is a *versioned, pure-data* map of the fleet:
//! which node hosts which tenant group, each node's capacity and health,
//! and each group's shard count and QoS tier. Nothing in here owns a
//! thread, a queue or a backend — the topology is data that the router
//! reads on every submit and the node agents cache by version, exactly
//! the coordinator-as-source-of-truth pattern: mutations (migrations,
//! health changes) go through the [`TopologyStore`], bump the version,
//! and every consumer refreshes from the store when its cached version
//! goes stale.
//!
//! Placement changes are *migrations*: [`FleetTopology::migrate`] moves a
//! group's hosting bit from one node to another. The serving-side
//! mechanics (gate + drain + re-dispatch of the in-flight backlog, then
//! controller hand-off) live in `coordinator::node`; this module only
//! records the authoritative outcome. A [`MigrationPlan`] is the
//! deterministic scripted twin of `workload::FaultPlan`: epoch-indexed
//! moves that the hosting node executes at CC epoch boundaries, so a
//! seeded virtual-time run replays its migrations bitwise
//! (`tests/sim_properties.rs::prop_migration_conserves_work`).
//!
//! [`TopologySnapshot`] is the observability surface — the `topology` CLI
//! subcommand prints its [`TopologySnapshot::to_json`] document (schema
//! in DESIGN.md S21.4), the live analog of a `GET /topology` endpoint.

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{Mutex, MutexGuard};

use crate::util::json::Json;
use crate::util::prng::Rng;

use super::fleet::GroupConfig;

/// Most nodes a topology may carry: hosting sets are stored as `u64`
/// bitmasks so the router's hot path reads placement lock-free.
pub const MAX_NODES: usize = 64;

/// Health of one node, as recorded in the topology.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeHealth {
    /// Serving normally.
    Healthy,
    /// The node's rebalancer reported sustained backlog pressure; the
    /// router still routes here but the rebalancer is looking for a
    /// migration target.
    Saturated,
}

impl NodeHealth {
    /// Stable lowercase name (snapshot JSON uses it).
    pub fn name(self) -> &'static str {
        match self {
            NodeHealth::Healthy => "healthy",
            NodeHealth::Saturated => "saturated",
        }
    }
}

/// Static description + mutable health of one node.
#[derive(Clone, Debug)]
pub struct NodeInfo {
    /// Display name (`node0`, `node1`, ...), used to namespace per-node
    /// metrics as `{node}.{group}.*`.
    pub name: String,
    /// Worker instances this node can host across all groups.
    pub capacity: usize,
    /// Current health state.
    pub health: NodeHealth,
}

/// Why a topology (or a mutation of it) was rejected.
#[derive(Clone, Debug, PartialEq)]
pub enum TopologyError {
    /// Node count outside `[1, MAX_NODES]`.
    BadNodeCount(usize),
    /// A group index outside the topology's group list.
    UnknownGroup(usize),
    /// A node index outside the topology's node list.
    UnknownNode(usize),
    /// `migrate` named a source node that does not host the group.
    NotHostedOn {
        /// Group index of the rejected move.
        group: usize,
        /// Node the caller claimed was hosting it.
        node: usize,
    },
    /// `migrate` named an identical source and destination.
    SelfMigration(usize),
}

impl std::fmt::Display for TopologyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TopologyError::BadNodeCount(n) => {
                write!(f, "node count {n} outside [1, {MAX_NODES}]")
            }
            TopologyError::UnknownGroup(g) => write!(f, "group index {g} not in topology"),
            TopologyError::UnknownNode(n) => write!(f, "node index {n} not in topology"),
            TopologyError::NotHostedOn { group, node } => {
                write!(f, "group {group} is not hosted on node {node}")
            }
            TopologyError::SelfMigration(n) => {
                write!(f, "migration source and destination are both node {n}")
            }
        }
    }
}

impl std::error::Error for TopologyError {}

/// The versioned, pure-data fleet map: groups → nodes → shards.
///
/// `hosting[gi]` is a bitmask over node ids; bit `n` set means node `n`
/// hosts a slice (shard set + workers) of group `gi`. The canonical
/// layouts built by [`FleetTopology::spread`] host every group on exactly
/// one node, and [`FleetTopology::migrate`] preserves that invariant —
/// one controller per group, wherever it lives, which is what keeps the
/// distributed decision logs identical to the offline replay
/// (`tests/control_equivalence.rs`).
#[derive(Clone, Debug)]
pub struct FleetTopology {
    version: u64,
    nodes: Vec<NodeInfo>,
    groups: Vec<GroupConfig>,
    hosting: Vec<u64>,
}

impl FleetTopology {
    /// The legacy single-process layout: one node hosting every group.
    pub fn single_node(groups: Vec<GroupConfig>) -> FleetTopology {
        // 1 is always a valid node count, so spread cannot fail here.
        match Self::spread(groups, 1) {
            Ok(t) => t,
            Err(_) => unreachable!("single-node spread is always valid"),
        }
    }

    /// Spread `groups` round-robin over `n_nodes` nodes (group `i` →
    /// node `i % n_nodes`), each node named `node{i}` with capacity for
    /// the whole fleet so any later migration has a feasible target.
    pub fn spread(groups: Vec<GroupConfig>, n_nodes: usize) -> Result<FleetTopology, TopologyError> {
        if n_nodes == 0 || n_nodes > MAX_NODES {
            return Err(TopologyError::BadNodeCount(n_nodes));
        }
        let capacity: usize = groups.iter().map(|g| g.n_instances).sum();
        let nodes = (0..n_nodes)
            .map(|i| NodeInfo {
                name: format!("node{i}"),
                capacity,
                health: NodeHealth::Healthy,
            })
            .collect();
        let hosting = (0..groups.len()).map(|gi| 1u64 << (gi % n_nodes)).collect();
        Ok(FleetTopology { version: 0, nodes, groups, hosting })
    }

    /// Monotonic version; every mutation bumps it.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// The topology's nodes, id-ordered.
    pub fn nodes(&self) -> &[NodeInfo] {
        &self.nodes
    }

    /// The topology's groups, index-aligned with the fleet's.
    pub fn groups(&self) -> &[GroupConfig] {
        &self.groups
    }

    /// Hosting bitmask of a group (bit `n` ⇒ node `n` hosts it).
    pub fn hosting_mask(&self, group: usize) -> u64 {
        self.hosting.get(group).copied().unwrap_or(0)
    }

    /// Node ids hosting a group, ascending.
    pub fn nodes_hosting(&self, group: usize) -> Vec<usize> {
        let mask = self.hosting_mask(group);
        (0..self.nodes.len()).filter(|n| mask & (1 << n) != 0).collect()
    }

    /// Whether node `node` hosts group `group`.
    pub fn is_hosted_on(&self, group: usize, node: usize) -> bool {
        self.hosting_mask(group) & (1u64 << node) != 0
    }

    /// Worker instances node `node` currently hosts (its placement load).
    pub fn hosted_instances(&self, node: usize) -> usize {
        self.groups
            .iter()
            .enumerate()
            .filter(|(gi, _)| self.is_hosted_on(*gi, node))
            .map(|(_, g)| g.n_instances)
            .sum()
    }

    /// Move a group's hosting bit from `from` to `to`, bumping the
    /// version. The data plane (drain + re-dispatch + controller
    /// hand-off) must run *before* this call so consumers that refresh on
    /// the new version observe a consistent fleet.
    pub fn migrate(&mut self, group: usize, from: usize, to: usize) -> Result<(), TopologyError> {
        if group >= self.groups.len() {
            return Err(TopologyError::UnknownGroup(group));
        }
        if from >= self.nodes.len() {
            return Err(TopologyError::UnknownNode(from));
        }
        if to >= self.nodes.len() {
            return Err(TopologyError::UnknownNode(to));
        }
        if from == to {
            return Err(TopologyError::SelfMigration(from));
        }
        if !self.is_hosted_on(group, from) {
            return Err(TopologyError::NotHostedOn { group, node: from });
        }
        self.hosting[group] = (self.hosting[group] & !(1u64 << from)) | (1u64 << to);
        self.version += 1;
        Ok(())
    }

    /// Record a node's health, bumping the version on change only (so
    /// steady-state health reports do not invalidate consumer caches).
    pub fn set_health(&mut self, node: usize, health: NodeHealth) -> Result<(), TopologyError> {
        let info = self.nodes.get_mut(node).ok_or(TopologyError::UnknownNode(node))?;
        if info.health != health {
            info.health = health;
            self.version += 1;
        }
        Ok(())
    }

    /// An immutable observability copy of the whole map.
    pub fn snapshot(&self) -> TopologySnapshot {
        TopologySnapshot {
            version: self.version,
            nodes: self
                .nodes
                .iter()
                .enumerate()
                .map(|(id, n)| NodeSnapshot {
                    id,
                    name: n.name.clone(),
                    capacity: n.capacity,
                    health: n.health,
                    hosted_instances: self.hosted_instances(id),
                    hosted_groups: self
                        .groups
                        .iter()
                        .enumerate()
                        .filter(|(gi, _)| self.is_hosted_on(*gi, id))
                        .map(|(_, g)| g.benchmark.clone())
                        .collect(),
                })
                .collect(),
            groups: self
                .groups
                .iter()
                .enumerate()
                .map(|(gi, g)| GroupSnapshot {
                    name: g.benchmark.clone(),
                    share: g.share,
                    n_shards: g.n_instances,
                    qos_target: g.qos_target,
                    hosted_on: self
                        .nodes_hosting(gi)
                        .into_iter()
                        .map(|n| self.nodes[n].name.clone())
                        .collect(),
                })
                .collect(),
        }
    }
}

/// One node's row in a [`TopologySnapshot`].
#[derive(Clone, Debug)]
pub struct NodeSnapshot {
    /// Node id (bit position in hosting masks).
    pub id: usize,
    /// Display name.
    pub name: String,
    /// Worker instances the node can host.
    pub capacity: usize,
    /// Health at snapshot time.
    pub health: NodeHealth,
    /// Worker instances currently placed here.
    pub hosted_instances: usize,
    /// Benchmark names of the groups hosted here.
    pub hosted_groups: Vec<String>,
}

/// One group's row in a [`TopologySnapshot`].
#[derive(Clone, Debug)]
pub struct GroupSnapshot {
    /// Benchmark / tenant name.
    pub name: String,
    /// Provisioned traffic share.
    pub share: f64,
    /// Shards (worker instances) per hosting node.
    pub n_shards: usize,
    /// Per-tenant QoS tier target, when set.
    pub qos_target: Option<f64>,
    /// Names of the hosting nodes, id-ascending.
    pub hosted_on: Vec<String>,
}

/// Point-in-time copy of the fleet map for observability — what the
/// `topology` CLI subcommand prints (DESIGN.md S21.4 documents the JSON
/// schema).
#[derive(Clone, Debug)]
pub struct TopologySnapshot {
    /// Topology version the snapshot was taken at.
    pub version: u64,
    /// Per-node placement + health.
    pub nodes: Vec<NodeSnapshot>,
    /// Per-group placement, index-aligned with the fleet's groups.
    pub groups: Vec<GroupSnapshot>,
}

impl TopologySnapshot {
    /// Deterministic JSON rendering (key order fixed, so two snapshots of
    /// the same topology serialize byte-identically).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::Num(self.version as f64)),
            (
                "nodes",
                Json::Arr(
                    self.nodes
                        .iter()
                        .map(|n| {
                            Json::obj(vec![
                                ("id", Json::Num(n.id as f64)),
                                ("name", Json::Str(n.name.clone())),
                                ("capacity", Json::Num(n.capacity as f64)),
                                ("health", Json::Str(n.health.name().into())),
                                ("hosted_instances", Json::Num(n.hosted_instances as f64)),
                                (
                                    "hosted_groups",
                                    Json::Arr(
                                        n.hosted_groups
                                            .iter()
                                            .map(|g| Json::Str(g.clone()))
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "groups",
                Json::Arr(
                    self.groups
                        .iter()
                        .map(|g| {
                            Json::obj(vec![
                                ("name", Json::Str(g.name.clone())),
                                ("share", Json::Num(g.share)),
                                ("shards", Json::Num(g.n_shards as f64)),
                                (
                                    "qos_target",
                                    g.qos_target.map(Json::Num).unwrap_or(Json::Null),
                                ),
                                (
                                    "hosted_on",
                                    Json::Arr(
                                        g.hosted_on
                                            .iter()
                                            .map(|n| Json::Str(n.clone()))
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// One scripted group move: at the CC pass for `epoch`, the node hosting
/// `group` (which the plan claims is `from`) gates + drains its slice,
/// re-dispatches the backlog into `to`'s slice, and hands the group's
/// controller over.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScriptedMigration {
    /// CC epoch index at which the move executes.
    pub epoch: usize,
    /// Group index to move.
    pub group: usize,
    /// Node expected to host the group when the epoch arrives. A stale
    /// `from` (the group moved elsewhere first) makes the move a no-op —
    /// the topology, not the plan, is the source of truth.
    pub from: usize,
    /// Destination node.
    pub to: usize,
}

/// A deterministic, epoch-indexed migration schedule — the placement
/// twin of `workload::FaultPlan`. The default empty plan is neutral:
/// no CC pass ever matches a move, so plans-off runs replay untouched.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MigrationPlan {
    /// Scripted moves, in no particular order; the hosting node executes
    /// the ones matching its id at each epoch boundary.
    pub moves: Vec<ScriptedMigration>,
}

impl MigrationPlan {
    /// Whether the plan schedules anything at all.
    pub fn is_empty(&self) -> bool {
        self.moves.is_empty()
    }

    /// Moves scheduled for `epoch` whose claimed source is `node`.
    pub fn moves_at(&self, epoch: usize, node: usize) -> impl Iterator<Item = &ScriptedMigration> {
        self.moves.iter().filter(move |m| m.epoch == epoch && m.from == node)
    }

    /// Structural validation against a fleet layout: indices in range,
    /// no self-moves, at most one move per (group, epoch) so execution
    /// order within a pass can never be ambiguous.
    pub fn validate(&self, n_groups: usize, n_nodes: usize) -> Result<(), String> {
        for m in &self.moves {
            if m.group >= n_groups {
                return Err(format!("migration names group {} of {n_groups}", m.group));
            }
            if m.from >= n_nodes || m.to >= n_nodes {
                return Err(format!(
                    "migration ({} -> {}) outside the {n_nodes}-node fleet",
                    m.from, m.to
                ));
            }
            if m.from == m.to {
                return Err(format!("migration of group {} moves to its own node", m.group));
            }
        }
        for (i, a) in self.moves.iter().enumerate() {
            for b in &self.moves[i + 1..] {
                if a.group == b.group && a.epoch == b.epoch {
                    return Err(format!(
                        "two moves of group {} at epoch {}",
                        a.group, a.epoch
                    ));
                }
            }
        }
        Ok(())
    }

    /// A randomized-but-deterministic plan for property tests: the same
    /// seed over the same layout reproduces the plan exactly. Moves are
    /// *coherent* — each group's moves chain from its round-robin start
    /// node through random destinations at strictly increasing epochs —
    /// so with the rebalancer off every scripted move finds its group
    /// where the plan expects it and executes.
    pub fn scripted(seed: u64, n_groups: usize, n_nodes: usize, epochs: usize) -> MigrationPlan {
        let mut plan = MigrationPlan::default();
        if n_nodes < 2 || n_groups == 0 || epochs < 3 {
            return plan;
        }
        let mut rng = Rng::new(seed ^ 0x70u64.rotate_left(48));
        for g in 0..n_groups {
            let mut r = rng.fork(g as u64 + 1);
            let mut host = g % n_nodes;
            let mut epoch = 0usize;
            let n_moves = r.index(0, 3);
            for _ in 0..n_moves {
                // Leave the last epoch for the post-move drain.
                if epoch + 1 >= epochs.saturating_sub(1) {
                    break;
                }
                epoch = r.index(epoch + 1, epochs.saturating_sub(1));
                let mut to = r.index(0, n_nodes - 1);
                if to >= host {
                    to += 1; // uniform over nodes != host
                }
                plan.moves.push(ScriptedMigration { epoch, group: g, from: host, to });
                host = to;
            }
        }
        plan
    }

    /// Deterministic JSON rendering for trace headers.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.moves
                .iter()
                .map(|m| {
                    Json::obj(vec![
                        ("epoch", Json::Num(m.epoch as f64)),
                        ("group", Json::Num(m.group as f64)),
                        ("from", Json::Num(m.from as f64)),
                        ("to", Json::Num(m.to as f64)),
                    ])
                })
                .collect(),
        )
    }
}

/// Shared, mutation-serialized home of the fleet's [`FleetTopology`] —
/// the object node agents and the router actually hold.
///
/// Reads on the submit hot path never take the lock: the store mirrors
/// the version and every group's hosting mask into atomics, refreshed
/// under the same mutex that serializes mutations. Consumers cache
/// whatever they derive from a read and re-derive when
/// [`TopologyStore::version`] moves past their cached value.
#[derive(Debug)]
pub struct TopologyStore {
    inner: Mutex<FleetTopology>,
    version: AtomicU64,
    hosting: Vec<AtomicU64>,
}

impl TopologyStore {
    /// Wrap a topology for shared use.
    pub fn new(topology: FleetTopology) -> TopologyStore {
        let hosting = (0..topology.groups().len())
            .map(|gi| AtomicU64::new(topology.hosting_mask(gi)))
            .collect();
        TopologyStore {
            version: AtomicU64::new(topology.version()),
            hosting,
            inner: Mutex::new(topology),
        }
    }

    fn locked(&self) -> MutexGuard<'_, FleetTopology> {
        match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Lock-free current version (cache invalidation signal).
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Lock-free hosting mask of a group.
    pub fn hosting_mask(&self, group: usize) -> u64 {
        self.hosting.get(group).map(|m| m.load(Ordering::Acquire)).unwrap_or(0)
    }

    /// Node ids hosting a group right now, ascending (lock-free).
    pub fn nodes_hosting(&self, group: usize) -> Vec<usize> {
        let mask = self.hosting_mask(group);
        (0..MAX_NODES).filter(|n| mask & (1 << n) != 0).collect()
    }

    /// Run a closure over the locked topology (observability reads that
    /// need more than a mask).
    pub fn with<T>(&self, f: impl FnOnce(&FleetTopology) -> T) -> T {
        f(&self.locked())
    }

    /// Apply a migration and publish the new mask + version. The Release
    /// stores pair with consumers' Acquire loads: a consumer that sees
    /// the new version also sees the new mask.
    pub fn migrate(&self, group: usize, from: usize, to: usize) -> Result<(), TopologyError> {
        let mut t = self.locked();
        t.migrate(group, from, to)?;
        if let Some(slot) = self.hosting.get(group) {
            slot.store(t.hosting_mask(group), Ordering::Release);
        }
        self.version.store(t.version(), Ordering::Release);
        Ok(())
    }

    /// Record a node's health (version bumps only on change).
    pub fn set_health(&self, node: usize, health: NodeHealth) -> Result<(), TopologyError> {
        let mut t = self.locked();
        t.set_health(node, health)?;
        self.version.store(t.version(), Ordering::Release);
        Ok(())
    }

    /// Observability snapshot of the current map.
    pub fn snapshot(&self) -> TopologySnapshot {
        self.locked().snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn groups(n: usize) -> Vec<GroupConfig> {
        (0..n)
            .map(|i| GroupConfig {
                benchmark: format!("g{i}"),
                share: 1.0 / n as f64,
                n_instances: 2,
                qos_target: None,
            })
            .collect()
    }

    #[test]
    fn spread_places_groups_round_robin() {
        let t = FleetTopology::spread(groups(3), 2).unwrap();
        assert_eq!(t.nodes_hosting(0), vec![0]);
        assert_eq!(t.nodes_hosting(1), vec![1]);
        assert_eq!(t.nodes_hosting(2), vec![0]);
        assert_eq!(t.hosted_instances(0), 4);
        assert_eq!(t.hosted_instances(1), 2);
        assert_eq!(t.version(), 0);
        assert!(FleetTopology::spread(groups(1), 0).is_err());
        assert!(FleetTopology::spread(groups(1), MAX_NODES + 1).is_err());
    }

    #[test]
    fn migrate_moves_the_hosting_bit_and_bumps_the_version() {
        let mut t = FleetTopology::spread(groups(2), 2).unwrap();
        t.migrate(0, 0, 1).unwrap();
        assert_eq!(t.nodes_hosting(0), vec![1]);
        assert_eq!(t.version(), 1);
        // Typed rejections, version untouched.
        assert_eq!(t.migrate(0, 0, 1), Err(TopologyError::NotHostedOn { group: 0, node: 0 }));
        assert_eq!(t.migrate(9, 0, 1), Err(TopologyError::UnknownGroup(9)));
        assert_eq!(t.migrate(0, 1, 1), Err(TopologyError::SelfMigration(1)));
        assert_eq!(t.migrate(0, 1, 7), Err(TopologyError::UnknownNode(7)));
        assert_eq!(t.version(), 1);
    }

    #[test]
    fn health_bumps_version_only_on_change() {
        let mut t = FleetTopology::spread(groups(1), 2).unwrap();
        t.set_health(1, NodeHealth::Healthy).unwrap();
        assert_eq!(t.version(), 0, "no-op health writes must not churn caches");
        t.set_health(1, NodeHealth::Saturated).unwrap();
        assert_eq!(t.version(), 1);
        assert_eq!(t.nodes()[1].health, NodeHealth::Saturated);
    }

    #[test]
    fn store_mirrors_masks_and_version_lock_free() {
        let store = TopologyStore::new(FleetTopology::spread(groups(2), 2).unwrap());
        assert_eq!(store.version(), 0);
        assert_eq!(store.hosting_mask(0), 0b01);
        assert_eq!(store.hosting_mask(1), 0b10);
        store.migrate(1, 1, 0).unwrap();
        assert_eq!(store.version(), 1);
        assert_eq!(store.hosting_mask(1), 0b01);
        assert_eq!(store.nodes_hosting(1), vec![0]);
        assert_eq!(store.with(|t| t.hosted_instances(0)), 4);
    }

    #[test]
    fn snapshot_json_is_deterministic_and_complete() {
        let store = TopologyStore::new(FleetTopology::spread(groups(2), 2).unwrap());
        let a = store.snapshot().to_json().to_string_pretty();
        let b = store.snapshot().to_json().to_string_pretty();
        assert_eq!(a, b, "snapshots of an unchanged topology are byte-stable");
        let json = store.snapshot().to_json();
        assert_eq!(json.path("version").and_then(Json::as_f64), Some(0.0));
        assert_eq!(
            json.path("nodes").and_then(Json::as_arr).map(|n| n.len()),
            Some(2)
        );
        assert_eq!(
            json.path("groups").and_then(Json::as_arr).map(|g| g.len()),
            Some(2)
        );
        let g0 = &json.path("groups").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(g0.get("name").and_then(Json::as_str), Some("g0"));
        assert_eq!(
            g0.get("hosted_on").and_then(Json::as_arr).map(|h| h.len()),
            Some(1)
        );
    }

    #[test]
    fn scripted_migration_plans_are_deterministic_and_coherent() {
        let a = MigrationPlan::scripted(7, 3, 4, 24);
        let b = MigrationPlan::scripted(7, 3, 4, 24);
        assert_eq!(a, b, "same seed, same plan");
        a.validate(3, 4).unwrap();
        // Chained coherence: each group's moves start at its round-robin
        // home and each move departs where the previous one landed.
        for g in 0..3 {
            let mut host = g % 4;
            for m in a.moves.iter().filter(|m| m.group == g) {
                assert_eq!(m.from, host, "group {g} move departs its current host");
                host = m.to;
            }
        }
        assert!(MigrationPlan::scripted(7, 3, 1, 24).is_empty(), "1 node: nowhere to go");
        assert_ne!(
            MigrationPlan::scripted(8, 3, 4, 24),
            a,
            "different seeds should (generically) differ"
        );
    }

    #[test]
    fn migration_plan_validation_rejects_malformed_moves() {
        let bad = |m| MigrationPlan { moves: vec![m] };
        assert!(bad(ScriptedMigration { epoch: 1, group: 5, from: 0, to: 1 })
            .validate(2, 2)
            .is_err());
        assert!(bad(ScriptedMigration { epoch: 1, group: 0, from: 0, to: 2 })
            .validate(2, 2)
            .is_err());
        assert!(bad(ScriptedMigration { epoch: 1, group: 0, from: 1, to: 1 })
            .validate(2, 2)
            .is_err());
        let dup = MigrationPlan {
            moves: vec![
                ScriptedMigration { epoch: 2, group: 0, from: 0, to: 1 },
                ScriptedMigration { epoch: 2, group: 0, from: 1, to: 0 },
            ],
        };
        assert!(dup.validate(2, 2).is_err(), "ambiguous same-epoch double move");
        MigrationPlan::default().validate(0, 1).unwrap();
    }
}
